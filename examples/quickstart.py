"""Quickstart: the paper's pipeline end-to-end in ~a minute on CPU.

1. Generate approximate multipliers (gate-level pruning + precision scaling,
   NSGA-II Pareto front).
2. Run the GA-CDP co-design for VGG16 @ 7nm under 30 FPS / <=2% drop.
3. Evaluate a small DNN under the chosen approximate multiplier (the
   ApproxTrain-style accuracy check, on the TPU-native low-rank GEMM path).

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import gemm as G
from repro.core import codesign, ga, multipliers as mm, pareto
from repro.data import synthetic
from repro.models import cnn


def main() -> int:
    print("=== step 1: area-aware approximate multipliers (NSGA-II) ===")
    front = pareto.default_front()
    print(f"Pareto front: {len(front)} multipliers, area "
          f"{front[0].area_nand2eq:.0f}..{front[-1].area_nand2eq:.0f} "
          f"NAND2-eq, NMED {front[0].stats.nmed:.4f}.."
          f"{front[-1].stats.nmed:.6f}")

    print("\n=== step 2: GA-CDP accelerator co-design (VGG16 @ 7nm) ===")
    rep = codesign.run_codesign(
        "vgg16", 7, fps_min=30.0, max_accuracy_drop=2.0,
        mults=front + list(mm.static_library().values()),
        ga_cfg=ga.GAConfig(pop_size=20, generations=10, seed=0))
    print(rep.summary())

    print("\n=== step 3: DNN accuracy under the chosen multiplier ===")
    chosen = mm.get_multiplier(rep.ga_cdp.config.multiplier)
    spec = G.from_multiplier(chosen)
    x, y = synthetic.shapes_classification(128, image=32, seed=7)
    params = cnn.init_vgg("vgg_mini", jax.random.key(0), n_classes=4,
                          image=32)
    exact_logits = cnn.vgg_forward(params, jnp.asarray(x), "vgg_mini")
    approx_logits = cnn.vgg_forward(params, jnp.asarray(x), "vgg_mini",
                                    spec=spec)
    agree = float((jnp.argmax(exact_logits, -1) ==
                   jnp.argmax(approx_logits, -1)).mean())
    drift = float(jnp.abs(approx_logits - exact_logits).mean())
    print(f"multiplier={chosen.name} (mode={spec.mode}, rank={spec.rank}, "
          f"NMED={chosen.stats.nmed:.5f})")
    print(f"prediction agreement exact-vs-approx: {agree:.3f}, "
          f"mean logit drift: {drift:.4f}")
    print("\nDone.  Carbon saving vs exact baseline: "
          f"{100 * rep.ga_reduction:.1f}% at {rep.ga_cdp.fps:.0f} FPS.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
