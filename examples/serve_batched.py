"""Serve a small model with batched requests: prefill + greedy decode,
exact vs approximate multiplier side by side (the inference half of the
paper's 'meets performance and accuracy requirements' claim).

  PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch import serve


def main() -> int:
    print("=== exact serving ===")
    serve.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "4",
                "--prompt-len", "64", "--gen", "24"])
    print("\n=== approximate serving (trunc2x2 multiplier) ===")
    serve.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "4",
                "--prompt-len", "64", "--gen", "24", "--mult", "trunc2x2"])
    print("\n=== SSM long-context decode (mamba2, O(1) state) ===")
    serve.main(["--arch", "mamba2-370m", "--reduced", "--batch", "2",
                "--prompt-len", "64", "--gen", "24"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
