"""Serve mixed traffic through the continuous-batching Engine: exact vs
approximate multiplier side by side (the inference half of the paper's
'meets performance and accuracy requirements' claim), plus a
mixed-length / mixed-arrival demo where late requests join mid-decode.

  PYTHONPATH=src python examples/serve_batched.py

The example asserts on output shapes and token counts, so it doubles as
an executable check.
"""

import numpy as np

from repro import configs
from repro.serving import Engine, Request, SamplingParams


def serve_uniform(arch: str, mult: str = "", batch: int = 4,
                  prompt_len: int = 64, gen: int = 24):
    """Old-driver-shaped workload: equal prompts, simultaneous arrival."""
    cfg = configs.apply_overrides(configs.get_config(arch), reduced=True,
                                  mult=mult)
    rng = np.random.default_rng(0)
    eng = Engine(cfg, capacity=batch, max_len=prompt_len + gen,
                 prefill_buckets=(prompt_len,), seed=0)
    for i in range(batch):
        eng.submit(Request(f"r{i}",
                           rng.integers(0, cfg.vocab, (prompt_len,)).tolist(),
                           SamplingParams(max_new_tokens=gen)))
    done = eng.run_until_complete()
    assert len(done) == batch, (len(done), batch)
    for c in done:
        assert len(c.tokens) == gen, (c.request_id, len(c.tokens))
        assert c.finish_reason == "length"
        assert all(0 <= t < cfg.vocab for t in c.tokens)
    s = eng.stats()
    toks = sum(len(c.tokens) - 1 for c in done)
    print(f"[{arch} mult={mult or 'exact'}] {batch} reqs x {gen} toks: "
          f"prefill {s['prefill_s']:.2f}s, "
          f"decode {toks / max(s['decode_s'], 1e-9):.1f} tok/s")
    return done


def serve_mixed(arch: str = "tinyllama-1.1b"):
    """Continuous batching: heterogeneous prompt lengths AND arrival
    times on a capacity-2 arena, so late requests must join mid-decode
    and finished requests free slots for the queue."""
    cfg = configs.apply_overrides(configs.get_config(arch), reduced=True)
    rng = np.random.default_rng(1)
    eng = Engine(cfg, capacity=2, max_len=96, seed=0)
    lens = [9, 31, 17, 24]
    arrivals = [0.0, 0.0, 2.0, 5.0]
    gens = [6, 10, 4, 8]
    for i, (n, arr, g) in enumerate(zip(lens, arrivals, gens)):
        eng.submit(Request(f"m{i}", rng.integers(0, cfg.vocab, (n,)).tolist(),
                           SamplingParams(max_new_tokens=g), arrival=arr))
    done = eng.run_until_complete()
    assert len(done) == 4
    by_id = {c.request_id: c for c in done}
    for i, g in enumerate(gens):
        c = by_id[f"m{i}"]
        assert len(c.tokens) == g, (c.request_id, len(c.tokens), g)
        assert c.admitted_tick >= arrivals[i]
    # capacity 2 with 4 requests: the later ones waited for a free slot
    assert by_id["m3"].admitted_tick > 0
    stats = eng.stats()
    assert stats.get("decode_compiles", 1) == 1, stats
    print(f"[mixed] 4 reqs (lens {lens}, arrivals {arrivals}) on 2 slots: "
          f"{stats['decode_steps']} decode steps, "
          f"admit ticks {[by_id[f'm{i}'].admitted_tick for i in range(4)]}")
    return done


def main() -> int:
    print("=== exact serving ===")
    exact = serve_uniform("tinyllama-1.1b")
    print("=== approximate serving (trunc2x2 multiplier) ===")
    approx = serve_uniform("tinyllama-1.1b", mult="trunc2x2")
    # same request set, different arithmetic: streams must eventually differ
    assert any(e.tokens != a.tokens for e, a in zip(exact, approx)), \
        "approximate multiplier produced identical streams"
    print("=== SSM long-context decode (mamba2, O(1) state) ===")
    serve_uniform("mamba2-370m", batch=2)
    print("=== mixed lengths + late arrivals, capacity 2 ===")
    serve_mixed()
    print("serve_batched: all assertions passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
