"""Full paper reproduction for one workload: VGG16 across 7/14/28 nm with
measured (not proxy) accuracy drops.

Trains a small CNN on the synthetic classification task, measures real
top-1 drop per Pareto multiplier, feeds the measured accuracy function into
the GA, and prints the Fig.2/Fig.3-style comparison.

  PYTHONPATH=src python examples/codesign_vgg16.py
"""

import functools
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))  # for the benchmarks package

import jax
import jax.numpy as jnp

from benchmarks.bench_accuracy import accuracy, train_small_cnn
from repro.approx import gemm as G
from repro.core import codesign, ga, multipliers as mm, pareto


def main() -> int:
    print("training calibration CNN (synthetic shapes task)...")
    params = train_small_cnn(steps=260)
    base = accuracy(params, None)
    print(f"exact top-1: {base:.3f}")

    mults = pareto.default_front() + list(mm.static_library().values())

    @functools.lru_cache(maxsize=None)
    def measured_drop_by_name(name: str) -> float:
        m = next(x for x in mults if x.name == name)
        spec = G.from_multiplier(m)
        return max(0.0, 100.0 * (base - accuracy(params, spec)))

    def measured_drop(m) -> float:
        return measured_drop_by_name(m.name)

    for node in (7, 14, 28):
        rep = codesign.run_codesign(
            "vgg16", node, fps_min=30.0, max_accuracy_drop=2.0,
            mults=mults, accuracy_fn=measured_drop,
            ga_cfg=ga.GAConfig(pop_size=16, generations=8, seed=0))
        drop = measured_drop(
            mm.get_multiplier(rep.ga_cdp.config.multiplier)) \
            if rep.ga_cdp.config.multiplier != "exact" else 0.0
        print(f"\n--- {node} nm ---")
        print(rep.summary())
        print(f"  measured top-1 drop of chosen multiplier: {drop:.2f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
