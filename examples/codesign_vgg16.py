"""Full paper reproduction for one workload: VGG16 across 7/14/28 nm with
measured (not proxy) accuracy drops, searched by the population-parallel
GA engine.

Trains a small CNN on the synthetic classification task, measures real
top-1 drop per Pareto multiplier, feeds the measured accuracy function
into the batched GA (`core/ga_batched.py`), and prints the
Fig.2/Fig.3-style comparison.  It also refits the proxy accuracy-drop
coefficients (`ga.ACC_DROP_NMED_COEF` / `ga.ACC_DROP_MRED_COEF`) from the
measured drops — the calibration procedure documented in EXPERIMENTS.md.

  PYTHONPATH=src python examples/codesign_vgg16.py
"""

import functools
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))  # for the benchmarks package

import numpy as np

from benchmarks.bench_accuracy import accuracy, train_small_cnn
from repro.approx import gemm as G
from repro.core import codesign, ga, ga_batched, multipliers as mm, pareto


def fit_proxy_coefficients(mults, drop_fn) -> tuple[float, float]:
    """Least-squares refit of `drop ~ a*NMED + b*MRED` on the measured
    drops — how ACC_DROP_NMED_COEF / ACC_DROP_MRED_COEF were calibrated
    (see EXPERIMENTS.md)."""
    feats, targets = [], []
    for m in mults:
        if m.is_exact:
            continue
        feats.append([m.stats.nmed, m.stats.mred])
        targets.append(drop_fn(m))
    coef, *_ = np.linalg.lstsq(np.asarray(feats), np.asarray(targets),
                               rcond=None)
    return float(max(coef[0], 0.0)), float(max(coef[1], 0.0))


def main() -> int:
    print("training calibration CNN (synthetic shapes task)...")
    params = train_small_cnn(steps=260)
    base = accuracy(params, None)
    print(f"exact top-1: {base:.3f}")

    mults = pareto.default_front() + list(mm.static_library().values())

    @functools.lru_cache(maxsize=None)
    def measured_drop_by_name(name: str) -> float:
        m = next(x for x in mults if x.name == name)
        spec = G.from_multiplier(m)
        return max(0.0, 100.0 * (base - accuracy(params, spec)))

    def measured_drop(m) -> float:
        return measured_drop_by_name(m.name)

    for node in (7, 14, 28):
        rep = codesign.run_codesign(
            "vgg16", node, fps_min=30.0, max_accuracy_drop=2.0,
            mults=mults, accuracy_fn=measured_drop,
            engine="batched",
            batched_cfg=ga_batched.BatchedGAConfig(
                pop_size=2048, generations=8, seed=0))
        drop = measured_drop(
            mm.get_multiplier(rep.ga_cdp.config.multiplier)) \
            if rep.ga_cdp.config.multiplier != "exact" else 0.0
        print(f"\n--- {node} nm ---")
        print(rep.summary())
        print(f"  measured top-1 drop of chosen multiplier: {drop:.2f}%")

    a, b = fit_proxy_coefficients(mults, measured_drop)
    print(f"\nproxy refit from measured drops: "
          f"ACC_DROP_NMED_COEF≈{a:.1f} (current {ga.ACC_DROP_NMED_COEF}), "
          f"ACC_DROP_MRED_COEF≈{b:.1f} (current {ga.ACC_DROP_MRED_COEF})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
