"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps,
optionally under an approximate multiplier (approximate-aware training with
straight-through gradients — the ApproxTrain regime at LM scale).

  PYTHONPATH=src python examples/train_lm.py                # exact
  PYTHONPATH=src python examples/train_lm.py trunc2x2       # approximate

~100M params: tinyllama family at d_model=768, 12 layers, vocab 32000.
Uses the full production stack: sharded train step (over whatever devices
exist), AdamW, checkpointing, straggler watchdog, synthetic Markov data.
"""

import sys

from repro.launch import train


def main() -> int:
    mult = sys.argv[1] if len(sys.argv) > 1 else ""
    args = [
        "--arch", "tinyllama-1.1b",
        "--d-model", "768", "--n-layers", "12",
        "--steps", "300", "--batch", "16", "--seq", "256",
        "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_train_lm",
        "--ckpt-every", "100", "--log-every", "20",
    ]
    if mult:
        args += ["--mult", mult]
    return train.main(args)


if __name__ == "__main__":
    raise SystemExit(main())
