"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features: auto-mesh over available devices, sharded train state, synthetic
deterministic data, async checkpointing + auto-resume (crash/preemption
safe), straggler watchdog, optional approximate-multiplier mode (--mult),
optional int8-compressed gradient all-reduce (--compress-grads, shard_map
path), elastic restore (checkpoints reshard onto whatever mesh exists).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import synthetic
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import train_step as ts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mult", default="",
                    help="approximate multiplier (paper mode)")
    ap.add_argument("--kernel-policy", default="",
                    choices=["", "auto", "pallas", "xla"],
                    help="Pallas/XLA GEMM dispatch (kernels/dispatch.py); "
                         "'pallas' on CPU runs kernels in interpret mode")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--moment-dtype", default="f32",
                    choices=["f32", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M quickstart)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    over = {}
    if args.d_model:
        over["d_model"] = args.d_model
        over["n_heads"] = max(4, args.d_model // 64)
        over["n_kv_heads"] = max(2, args.d_model // 128)
        over["d_ff"] = args.d_model * 3
        over["head_dim"] = 64
    if args.n_layers:
        over["n_layers"] = args.n_layers
    cfg = configs.apply_overrides(configs.get_config(args.arch),
                                  reduced=args.reduced, mult=args.mult,
                                  kernel_policy=args.kernel_policy, **over)

    mesh = make_host_mesh()
    options = ts.StepOptions(
        accum_steps=args.accum, optimizer=args.optimizer,
        moment_dtype=args.moment_dtype, lr=args.lr,
        total_steps=args.steps, warmup_steps=max(10, args.steps // 20))
    init_fn, step_fn, st_sh = ts.make_train_step(cfg, options, mesh,
                                                 donate=False)

    guard = fault.PreemptionGuard()
    guard.install()
    watchdog = fault.StragglerWatchdog(
        on_straggler=lambda s, d, m: print(
            f"[fault] straggler at step {s}: {d:.3f}s vs median {m:.3f}s"))

    mgr = ckpt.CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    state = None
    if mgr is not None and mgr.latest_step() is not None:
        target = jax.eval_shape(init_fn, jax.random.key(args.seed))
        state, start_step = mgr.restore(target, shardings=st_sh)
        print(f"[train] resumed from step {start_step}")
    if state is None:
        state = jax.device_put(init_fn(jax.random.key(args.seed)), st_sh)

    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        watchdog.step_start()
        batch_np = synthetic.batch_for(cfg, "train", args.batch, args.seq,
                                       step, args.seed)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        watchdog.step_end(step)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t_start
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['gnorm']):8.3f} "
                  f"({dt / max(step - start_step + 1, 1):.2f}s/step)")
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(state, step + 1, blocking=False)
        if guard.preempted:
            print("[train] preemption requested: checkpointing + exit")
            if mgr is not None:
                mgr.save(state, step + 1, blocking=True)
            return 0
    if mgr is not None:
        mgr.save(state, args.steps, blocking=True)
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({len(watchdog.flagged)} straggler steps flagged)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
