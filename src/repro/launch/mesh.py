"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod-slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis composes
    with data for batch/gradient parallelism with hierarchical collectives
    (DCN between pods, ICI within)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return compat.make_mesh((data, model), ("data", "model"))
