"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set XLA_FLAGS before any
jax initialization.

The serving/train CLIs accept a ``--mesh model=4,data=2`` override (or the
``REPRO_MESH`` environment variable) instead of hardcoding the host mesh;
`make_mesh_from_spec` resolves it (flag > env > host default) and
validates the axis product against the visible devices.
"""

from __future__ import annotations

import os

import jax
from jax.sharding import Mesh

from repro import compat
from repro.core.target import parse_mesh_spec

MESH_ENV_VAR = "REPRO_MESH"


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod-slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis composes
    with data for batch/gradient parallelism with hierarchical collectives
    (DCN between pods, ICI within)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return compat.make_mesh((data, model), ("data", "model"))


def mesh_from_axes(axes: tuple[tuple[str, int], ...]) -> Mesh:
    """Concrete mesh from parsed (name, size) pairs; always carries a
    "data" and a "model" axis (size-1 filled in) so sharding/rules.py
    applies uniformly.  Unknown axis names raise — silently dropping one
    would serve on a different mesh than the caller modeled."""
    from repro.core.target import MESH_AXIS_NAMES
    for name, _ in axes:
        if name not in MESH_AXIS_NAMES:
            raise ValueError(f"unknown mesh axis {name!r}; expected axes "
                             f"from {MESH_AXIS_NAMES}")
    d = dict(axes)
    d.setdefault("data", 1)
    d.setdefault("model", 1)
    names = tuple(n for n in ("pod", "data", "model") if n in d)
    sizes = tuple(d[n] for n in names)
    need = 1
    for s in sizes:
        need *= s
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(names, sizes))} needs {need} devices but only "
            f"{have} visible (hint: XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} on CPU)")
    return compat.make_mesh(sizes, names)


def make_mesh_from_spec(spec: str | None = None) -> Mesh:
    """Mesh from a ``"model=4,data=2"`` spec string; precedence is the
    explicit argument, then $REPRO_MESH, then the host-mesh default."""
    spec = spec if spec not in (None, "") else os.environ.get(
        MESH_ENV_VAR, "")
    axes = parse_mesh_spec(spec)
    if not axes:
        return make_host_mesh()
    return mesh_from_axes(axes)
