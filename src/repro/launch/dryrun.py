import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  This module is the ONLY place the 512-placeholder-device world is
# created; tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, prove the sharding config is coherent, and
capture memory/cost/collective analyses for EXPERIMENTS.md §Dry-run and
§Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.roofline import analysis as roofline
from repro.sharding import rules
from repro.train import train_step as ts


def _sds_with_sharding(tree, shardings):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def _auto_accum(cfg: ModelConfig, shape: ShapeSpec, dp: int) -> int:
    """Gradient-accumulation steps: keep per-device saved layer carries
    (n_layers * mb_local * seq * d_model * 2B) under ~6 GiB."""
    forced = os.environ.get("REPRO_FORCE_ACCUM")
    if forced:
        return int(forced)
    budget = 6 * 1024 ** 3
    accum = 1
    while accum < shape.global_batch:
        mb_local = shape.global_batch // dp // accum
        if mb_local == 0:
            break
        carries = cfg.n_layers * mb_local * shape.seq_len * \
            cfg.d_model * 2
        if carries <= budget or mb_local == 1:
            break
        accum *= 2
    return accum


def _step_options(cfg: ModelConfig, shape: ShapeSpec, mesh) -> ts.StepOptions:
    big = cfg.param_count() >= 100e9
    dp = mesh.devices.size // mesh.shape.get("model", 1)
    return ts.StepOptions(
        accum_steps=_auto_accum(cfg, shape, dp),
        moment_dtype="int8" if big else "f32",
        optimizer="adamw",
    )


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               int8_weights: bool = False) -> tuple:
    """Build + lower the right step function for a cell.  Returns
    (lowered, chips)."""
    chips = mesh.devices.size
    in_specs = configs.input_specs(cfg, shape)
    batch_sh = rules.batch_shardings(in_specs, mesh)
    batch_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                         sharding=batch_sh[k])
                 for k, v in in_specs.items()}

    if shape.kind == "train":
        options = _step_options(cfg, shape, mesh)
        init_fn, _ = ts.make_train_fns(cfg, options)
        st_sh = ts.state_shardings(cfg, options, mesh, init_fn)
        state_sds = _sds_with_sharding(
            jax.eval_shape(init_fn, jax.random.key(0)), st_sh)
        _, step, _ = ts.make_train_step(cfg, options, mesh)
        lowered = step.lower(state_sds, batch_sds)
        return lowered, chips

    # serving cells
    fsdp = rules.should_fsdp(cfg)
    if int8_weights:
        from repro.approx import quant as quant_mod

        def mk_params():
            return quant_mod.quantize_param_tree(
                api.init_params(cfg, jax.random.key(0)))
    else:
        def mk_params():
            return api.init_params(cfg, jax.random.key(0))
    params_shape = jax.eval_shape(mk_params)
    params_sh = rules.param_shardings(params_shape, mesh, fsdp)
    params_sds = _sds_with_sharding(params_shape, params_sh)

    if shape.kind == "prefill":
        extras_sds = {}
        if cfg.family == "encdec":
            extras_sds["frames"] = batch_sds.pop("frames")
        if cfg.cross_every:
            extras_sds["img_embeds"] = batch_sds.pop("img")
        step = ts.make_prefill_step(cfg, mesh)
        lowered = step.lower(params_sds, batch_sds["tokens"], extras_sds)
        return lowered, chips

    # decode: cache as sharded SDS, donated
    cache_shape = configs.cache_specs(cfg, shape)
    cache_sh = rules.cache_shardings(cache_shape, mesh)
    cache_sds = _sds_with_sharding(cache_shape, cache_sh)
    step = ts.make_decode_step(cfg, mesh)
    lowered = step.lower(params_sds, cache_sds, batch_sds["tokens"], {})
    return lowered, chips


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skip_reason: str = ""
    error: str = ""
    compile_s: float = 0.0
    memory: dict = dataclasses.field(default_factory=dict)
    roofline: dict = dataclasses.field(default_factory=dict)


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             overrides: dict | None = None, verbose: bool = True,
             int8_weights: bool = False) -> CellResult:
    cfg = configs.get_config(arch, **(overrides or {}))
    shape = configs.SHAPES[shape_name]
    ok, why = configs.cell_supported(cfg, shape)
    if not ok:
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          skip_reason=why)
    t0 = time.time()
    try:
        lowered, chips = lower_cell(cfg, shape, mesh,
                                    int8_weights=int8_weights)
        compiled = lowered.compile()
        dt = time.time() - t0
        mem = roofline.memory_summary(compiled)
        mesh_shape = dict(mesh.shape)
        accum = (_auto_accum(cfg, shape,
                             chips // mesh_shape.get("model", 1))
                 if shape.kind == "train" else 1)
        big = cfg.param_count() >= 100e9
        mem["tpu_estimate"] = roofline.analytic_memory_per_device(
            cfg, shape, mesh_shape, accum=accum,
            moment_bytes=2.2 if big else 8.0)
        mem["accum_steps"] = accum
        terms = roofline.terms_from_compiled(compiled, cfg, shape, chips)
        res = CellResult(arch, shape_name, mesh_name, ok=True,
                         compile_s=dt, memory=mem,
                         roofline=terms.as_dict())
        if verbose:
            r = res.roofline
            print(f"[dryrun] {arch:28s} {shape_name:12s} {mesh_name:6s} "
                  f"OK {dt:6.1f}s  flops={r['flops']:.3e} "
                  f"bytes={r['hbm_bytes']:.3e} "
                  f"coll={r['collective_bytes']:.3e} "
                  f"bottleneck={r['bottleneck']}")
        return res
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        if verbose:
            print(f"[dryrun] {arch:28s} {shape_name:12s} {mesh_name:6s} "
                  f"FAIL: {type(e).__name__}: {e}")
            traceback.print_exc()
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          error=f"{type(e).__name__}: {e}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="all archs x shapes x both meshes")
    ap.add_argument("--out", default="")
    ap.add_argument("--mult", default="",
                    help="approximate multiplier (paper mode)")
    ap.add_argument("--mesh-override", default="",
                    help="single-pod mesh reshape 'data,model' (256 chips; "
                         "perf-iteration lever, e.g. '32,8' for archs "
                         "whose heads/experts don't divide 16)")
    ap.add_argument("--int8-weights", action="store_true",
                    help="serve decode/prefill with int8-stored weights "
                         "(the paper's accelerators are int8; halves the "
                         "weight HBM traffic of decode cells)")
    args = ap.parse_args()

    if args.all:
        args.arch = args.shape = "all"
        args.mesh = "both"

    archs = configs.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh_override:
        d, m = (int(x) for x in args.mesh_override.split(","))
        assert d * m == 256, "single-pod override must use 256 chips"
        meshes.append((f"single{d}x{m}",
                       compat.make_mesh((d, m), ("data", "model"))))
    if args.mesh in ("single", "both") and not args.mesh_override:
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both") and not args.mesh_override:
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    overrides = {"mult": args.mult} if args.mult else {}
    results = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_name, mesh in meshes:
                with mesh:
                    results.append(run_cell(
                        arch, shape_name, mesh, mesh_name, overrides,
                        int8_weights=args.int8_weights))

    n_ok = sum(r.ok for r in results)
    n_skip = sum(bool(r.skip_reason) for r in results)
    n_fail = len(results) - n_ok - n_skip
    print(f"\n[dryrun] {n_ok} ok / {n_skip} skipped / {n_fail} FAILED "
          f"of {len(results)} cells")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([dataclasses.asdict(r) for r in results], f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
