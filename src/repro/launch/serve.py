"""Batched serving CLI: a thin shell over the continuous-batching Engine
(`repro.serving`).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 64 --gen 32

Submits a batch of synthetic prompts as requests, serves them through the
engine's prefill-then-join decode loop, and reports per-phase latency and
tokens/s.  `--mult` serves under an approximate multiplier (the paper's
accelerator in simulation) on the exact same code path.  All four model
families go through the engine's single jitted prefill — no family
special cases.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro import configs
from repro.data import synthetic
from repro.serving import Engine, Request, SamplingParams


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mult", default="")
    ap.add_argument("--kernel-policy", default="",
                    choices=["", "auto", "pallas", "xla"],
                    help="Pallas/XLA GEMM dispatch (kernels/dispatch.py); "
                         "'pallas' on CPU runs kernels in interpret mode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="device mesh spec, e.g. 'model=4,data=2' "
                         "(default: $REPRO_MESH, then the host mesh); a "
                         "multi-device 'model' axis serves tensor-parallel")
    ap.add_argument("--capacity", type=int, default=0,
                    help="decode-arena slots (default: --batch)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter (0 = off)")
    args = ap.parse_args(argv)

    cfg = configs.apply_overrides(configs.get_config(args.arch),
                                  reduced=args.reduced, mult=args.mult,
                                  kernel_policy=args.kernel_policy)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    frames = img = None
    if cfg.family == "encdec":
        frames = synthetic.frames_batch(args.batch, cfg.enc_seq,
                                        cfg.d_model, 0, args.seed)
    if cfg.cross_every:
        img = synthetic.img_batch(args.batch, cfg.n_img_tokens,
                                  cfg.d_model, 0, args.seed)

    from repro.launch.mesh import make_mesh_from_spec
    max_len = args.prompt_len + args.gen
    eng = Engine(cfg, capacity=args.capacity or args.batch, max_len=max_len,
                 prefill_buckets=(args.prompt_len,), seed=args.seed,
                 mesh=make_mesh_from_spec(args.mesh))
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        max_new_tokens=args.gen)
    for i in range(args.batch):
        extras = {}
        if frames is not None:
            extras["frames"] = frames[i]
        if img is not None:
            extras["img_embeds"] = img[i]
        eng.submit(Request(f"r{i}", prompts[i].tolist(), sp,
                           extras=extras or None))
    done = eng.run_until_complete()

    stats = eng.stats()
    decode_toks = sum(len(c.tokens) - 1 for c in done)
    toks_per_s = decode_toks / max(stats["decode_s"], 1e-9)
    first = next(c for c in done if c.request_id == "r0")
    print(f"[serve] arch={cfg.name} mult={cfg.mult or 'exact'} "
          f"batch={args.batch}")
    print(f"[serve] prefill {args.prompt_len} toks: "
          f"{stats['prefill_s']:.3f}s; decode: {toks_per_s:.1f} tok/s")
    print(f"[serve] sample continuation ids: "
          f"{np.asarray(first.tokens[:16])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
