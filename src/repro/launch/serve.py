"""Batched serving driver: prefill + decode with a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 64 --gen 32

Runs a batch of synthetic prompts through prefill, then greedy-decodes;
reports per-phase latency and tokens/s.  `--mult` serves under an
approximate multiplier (the paper's accelerator in simulation).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import reduced as reduce_cfg
from repro.data import synthetic
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.train import train_step as ts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mult", default="")
    ap.add_argument("--kernel-policy", default="",
                    choices=["", "auto", "pallas", "xla"],
                    help="Pallas/XLA GEMM dispatch (kernels/dispatch.py); "
                         "'pallas' on CPU runs kernels in interpret mode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    over = {}
    if args.mult:
        over["mult"] = args.mult
    if args.kernel_policy:
        over["kernel_policy"] = args.kernel_policy
    if over:
        import dataclasses
        cfg = dataclasses.replace(cfg, **over)

    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)
    params = api.init_params(cfg, jax.random.key(args.seed))

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jnp.asarray(synthetic.frames_batch(
            args.batch, cfg.enc_seq, cfg.d_model, 0, args.seed))
    if cfg.cross_every:
        extras["img_embeds"] = jnp.asarray(synthetic.img_batch(
            args.batch, cfg.n_img_tokens, cfg.d_model, 0, args.seed))

    max_len = args.prompt_len + args.gen
    prefill = ts.make_prefill_step(cfg, mesh)
    decode = ts.make_decode_step(cfg, mesh, donate=False)

    t0 = time.time()
    if cfg.family == "hybrid":
        # hybrid prefill keeps O(window) state; use api.prefill via jit
        logits, cache = prefill(params, prompts, extras)
    else:
        spec = api.make_spec(cfg)
        logits, cache = api.prefill(params, prompts, cfg, spec=spec,
                                    max_len=max_len, extras=extras)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # greedy decode
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        lg, cache = decode(params, cache, tok, extras)
        tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    toks_per_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} mult={cfg.mult or 'exact'} "
          f"batch={args.batch}")
    print(f"[serve] prefill {args.prompt_len} toks: {t_prefill:.3f}s; "
          f"decode: {toks_per_s:.1f} tok/s")
    print(f"[serve] sample continuation ids: {np.asarray(out[0, :16])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
