"""Carbon-aware fleet serving demo: N replicas, live grid routing,
mid-trace failover.

  PYTHONPATH=src python -m repro.launch.fleet --arch tinyllama-1.1b \
      --reduced --requests 12 --gen 8 --trace diurnal

Builds a small fleet (default two replicas in different-intensity
regions, each its own Engine + EnergyMeter), replays a Poisson arrival
trace through the carbon-aware router, and reports where traffic went,
what it cost in gCO2e, and whether the TTFT SLO held.  With `--trace
diurnal` the regions' intensities cross over the (virtual) day, so the
routed share visibly follows the cleaner grid.  `--kill T` injects a
replica-0 fault after T of its steps mid-trace: its in-flight requests
re-queue onto the survivors and the run still completes every request —
the zero-lost check prints at the end.

`build_fleet` / `poisson_requests` are importable; `benchmarks/
bench_fleet.py` drives the same path headlessly for CI.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro import configs
from repro.fleet.grid import (REGION_INTENSITY_G_PER_KWH, StaticGrid,
                              diurnal_trace)
from repro.fleet.replica import Replica
from repro.fleet.router import Fleet, FleetConfig
from repro.serving import Request, SamplingParams
from repro.train.fault import PreemptionGuard

DEFAULT_REGIONS = ("us-west", "eu-west")   # close means -> diurnal crossover


def build_fleet(cfg, *, regions: tuple[str, ...] = DEFAULT_REGIONS,
                trace: str = "static", capacity: int = 2,
                max_len: int = 64, seed: int = 0,
                ttft_slo_ticks: float = 32.0,
                seconds_per_tick: float = 1800.0,
                params=None, mesh=None, targets=None,
                tiers: tuple[str, ...] | None = None,
                fleet_cfg: FleetConfig | None = None) -> Fleet:
    """One replica per region.  `trace="diurnal"` gives each region a
    phase-shifted sinusoidal day curve (half a period apart for two
    replicas), so the lowest-carbon region changes over the run;
    `"static"` pins each to its annual-average intensity.  `targets`
    (optional, one per region) lets replicas run different accelerator
    designs.  `tiers` gives every engine a multiplier-tier degradation
    ladder; `fleet_cfg` overrides the whole router config (retry
    budget, probation, `DegradationConfig`, ...) — `ttft_slo_ticks` is
    ignored when it is passed."""
    replicas = []
    for i, region in enumerate(regions):
        if trace == "diurnal":
            grid = diurnal_trace(region, phase=i / len(regions))
        elif trace == "static":
            grid = StaticGrid(region)
        else:
            raise ValueError(f"unknown trace {trace!r}")
        replicas.append(Replica(
            f"{region}", cfg, grid=grid,
            target=targets[i] if targets else None,
            seconds_per_tick=seconds_per_tick, params=params, mesh=mesh,
            capacity=capacity, max_len=max_len, seed=seed, tiers=tiers))
    return Fleet(replicas,
                 fleet_cfg or FleetConfig(ttft_slo_ticks=ttft_slo_ticks))


def poisson_requests(n: int, prompt_len: int, gen: int, vocab: int,
                     seed: int = 0, mean_gap_ticks: float = 2.0
                     ) -> list[Request]:
    """Synthetic arrival trace: exponential inter-arrival gaps (Poisson
    process) on the fleet's virtual tick clock, deterministic by seed."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.exponential(mean_gap_ticks)
        out.append(Request(
            request_id=f"t{i}",
            tokens=rng.integers(1, vocab, (prompt_len,)).tolist(),
            sampling=SamplingParams(max_new_tokens=gen),
            arrival=float(round(t))))
    return out


def ttft_ticks(completion) -> int:
    """Admission-to-first-token in engine ticks (arrival is restamped to
    the routing tick, so this includes replica queueing)."""
    return int(completion.admitted_tick - completion.arrival) + 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--regions", default=",".join(DEFAULT_REGIONS),
                    help="comma-separated regions, one replica each "
                         f"(known: {', '.join(REGION_INTENSITY_G_PER_KWH)})")
    ap.add_argument("--trace", default="diurnal",
                    choices=["static", "diurnal"],
                    help="grid-intensity model per region")
    ap.add_argument("--capacity", type=int, default=2)
    ap.add_argument("--slo-ticks", type=float, default=32.0)
    ap.add_argument("--seconds-per-tick", type=float, default=1800.0,
                    help="virtual seconds per fleet tick (ticks sweep the "
                         "diurnal curve)")
    ap.add_argument("--kill", type=int, default=-1,
                    help="inject a replica-0 fault after this many of its "
                         "steps (-1 = no fault)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    guard = PreemptionGuard()
    guard.install()

    cfg = configs.apply_overrides(configs.get_config(args.arch),
                                  reduced=args.reduced)
    regions = tuple(args.regions.split(","))
    max_len = args.prompt_len + args.gen + 8
    fleet = build_fleet(cfg, regions=regions, trace=args.trace,
                        capacity=args.capacity, max_len=max_len,
                        seed=args.seed, ttft_slo_ticks=args.slo_ticks,
                        seconds_per_tick=args.seconds_per_tick)
    reqs = poisson_requests(args.requests, args.prompt_len, args.gen,
                            cfg.vocab, seed=args.seed)
    for r in reqs:
        fleet.submit(r)
    if args.kill >= 0:
        fleet.replicas[0].inject_fault(at_step=args.kill)

    comps = []
    while fleet.busy() and not guard.preempted:
        fleet.step()
    if not guard.preempted:
        comps = fleet.run_until_complete()

    s = fleet.stats()
    print(f"[fleet] {len(regions)} replicas, trace={args.trace}, "
          f"slo={args.slo_ticks:.0f} ticks, kill="
          f"{args.kill if args.kill >= 0 else 'off'}")
    for rs in s["replicas"]:
        c = rs["carbon"]
        print(f"[fleet]   {rs['name']:<12} alive={rs['alive']} "
              f"routed={rs['routed']:3d} done={rs['completed']:3d} "
              f"ci_now={rs['g_per_kwh_now']:6.1f} g/kWh  "
              f"energy={c['energy_j']:8.2f} J  co2e={c['co2e_g']:.3e} g")
    # routed share per half of the route log: under a diurnal trace the
    # cleaner region flips, and so should the majority share
    recs = fleet.routes
    half = len(recs) // 2
    for label, part in (("first half", recs[:half]),
                        ("second half", recs[half:])):
        if part:
            share = {n: sum(1 for r in part if r.replica == n) / len(part)
                     for n in sorted({r.replica for r in recs})}
            print(f"[fleet] routed share ({label}): "
                  + "  ".join(f"{k}={v:.2f}" for k, v in share.items()))
    print(f"[fleet] low-carbon share: {s['low_carbon_share']:.2f} "
          f"(fraction routed to the cleanest live region)")
    if comps:
        tt = sorted(ttft_ticks(c) for c in comps)
        p95 = tt[min(int(0.95 * len(tt)), len(tt) - 1)]
        print(f"[fleet] ttft ticks p50={tt[len(tt) // 2]} p95={p95} "
              f"(slo {args.slo_ticks:.0f}: "
              f"{'OK' if p95 <= args.slo_ticks else 'VIOLATED'})")
    t = s["totals"]
    print(f"[fleet] totals: {t['energy_j']:.2f} J, {t['co2e_g']:.3e} gCO2e, "
          f"{t['co2e_g_per_token']:.3e} g/token over {t['tokens']} tokens")
    lost = s["lost"]
    print(f"[fleet] submitted={s['submitted']} completed={s['completed']} "
          f"requeued={s['requeued']} lost={len(lost)} "
          f"{'(ZERO-LOST OK)' if not lost else f'LOST: {lost}'}")
    return 0 if not lost else 1


if __name__ == "__main__":
    raise SystemExit(main())
