"""Embodied-carbon model (paper Eq. 1-2), ACT [Gupta+ ISCA'22] /
ECO-CHIP [Sudarshan+ HPCA'24] style.

    C_embodied = CFPA * A_die + CFPA_Si * A_wasted                      (1)
    CFPA       = (CI_fab * EPA + C_gas + C_material) / Y                (2)

with Murphy yield Y(A) = ((1 - e^{-A*D0}) / (A*D0))^2, 300 mm wafers and the
standard dies-per-wafer edge-loss formula.  Constants are public-ballpark
values (ACT's fab model); the paper's claims are *relative* (percent carbon
reduction), which depend on area ratios, not on the absolute CFPA scale.

CDP (Carbon-Delay-Product) = C_embodied * delay, delay = 1/FPS.
"""

from __future__ import annotations

import dataclasses
import math

# --- per-technology-node fab parameters -------------------------------------
# EPA:   manufacturing energy per unit area [kWh / cm^2]
# C_gas: direct greenhouse-gas emissions from processing [g CO2 / cm^2]
# D0:    defect density [defects / cm^2]
# freq:  nominal accelerator clock at that node [Hz]
NODE_PARAMS: dict[int, dict[str, float]] = {
    7:  {"EPA": 2.15, "C_gas": 280.0, "D0": 0.20, "freq": 1.4e9},
    14: {"EPA": 1.20, "C_gas": 200.0, "D0": 0.10, "freq": 1.0e9},
    28: {"EPA": 0.85, "C_gas": 150.0, "D0": 0.05, "freq": 0.7e9},
}

CI_FAB_G_PER_KWH = 620.0      # fab electricity carbon intensity [g CO2/kWh]
C_MATERIAL_G_PER_CM2 = 500.0  # raw material procurement [g CO2 / cm^2]
CFPA_SI_G_PER_CM2 = 130.0     # raw silicon wafer processing [g CO2 / cm^2]
WAFER_DIAMETER_MM = 300.0


def murphy_yield(area_mm2: float, node_nm: int) -> float:
    """Murphy's yield model; area in mm^2, D0 in defects/cm^2."""
    d0 = NODE_PARAMS[node_nm]["D0"]
    ad = (area_mm2 / 100.0) * d0
    if ad < 1e-9:
        return 1.0
    return ((1.0 - math.exp(-ad)) / ad) ** 2


def dies_per_wafer(area_mm2: float) -> float:
    """Gross dies per 300 mm wafer (standard edge-loss approximation)."""
    d = WAFER_DIAMETER_MM
    side = math.sqrt(max(area_mm2, 1e-9))
    return max(1.0, math.pi * (d / 2.0) ** 2 / area_mm2
               - math.pi * d / (math.sqrt(2.0) * side))


@dataclasses.dataclass(frozen=True)
class CarbonBreakdown:
    die_g: float          # CFPA * A_die
    wasted_g: float       # CFPA_Si * A_wasted
    total_g: float
    cfpa_g_per_cm2: float
    yield_: float
    area_mm2: float
    node_nm: int

    @property
    def total_kg(self) -> float:
        return self.total_g / 1000.0


def cfpa(node_nm: int, area_mm2: float) -> tuple[float, float]:
    """Eq. 2: carbon footprint per cm^2 of *die* area; returns (CFPA, Y)."""
    p = NODE_PARAMS[node_nm]
    y = murphy_yield(area_mm2, node_nm)
    val = (CI_FAB_G_PER_KWH * p["EPA"] + p["C_gas"] + C_MATERIAL_G_PER_CM2) / y
    return val, y


def embodied_carbon(area_mm2: float, node_nm: int) -> CarbonBreakdown:
    """Eq. 1 for a monolithic accelerator die."""
    cfpa_val, y = cfpa(node_nm, area_mm2)
    area_cm2 = area_mm2 / 100.0
    dpw = dies_per_wafer(area_mm2)
    wafer_area_cm2 = math.pi * (WAFER_DIAMETER_MM / 20.0) ** 2
    wasted_cm2_per_die = max(0.0, wafer_area_cm2 / dpw - area_cm2)
    die_g = cfpa_val * area_cm2
    wasted_g = CFPA_SI_G_PER_CM2 * wasted_cm2_per_die
    return CarbonBreakdown(
        die_g=die_g, wasted_g=wasted_g, total_g=die_g + wasted_g,
        cfpa_g_per_cm2=cfpa_val, yield_=y, area_mm2=area_mm2, node_nm=node_nm)


def cdp(carbon_g: float, fps: float) -> float:
    """Carbon-Delay-Product [g CO2 * s]; lower is better."""
    return carbon_g / max(fps, 1e-9)


def node_frequency(node_nm: int) -> float:
    return NODE_PARAMS[node_nm]["freq"]
