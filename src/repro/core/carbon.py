"""Embodied-carbon model (paper Eq. 1-2), ACT [Gupta+ ISCA'22] /
ECO-CHIP [Sudarshan+ HPCA'24] style.

    C_embodied = CFPA * A_die + CFPA_Si * A_wasted                      (1)
    CFPA       = (CI_fab * EPA + C_gas + C_material) / Y                (2)

with Murphy yield Y(A) = ((1 - e^{-A*D0}) / (A*D0))^2, 300 mm wafers and the
standard dies-per-wafer edge-loss formula.  Constants are public-ballpark
values (ACT's fab model); the paper's claims are *relative* (percent carbon
reduction), which depend on area ratios, not on the absolute CFPA scale.
See README "Carbon model & co-design" for the per-constant sources.

CDP (Carbon-Delay-Product) = C_embodied * delay, delay = 1/FPS.

Two call surfaces share the same constants:

  * scalar Python functions (`murphy_yield`, `cfpa`, `embodied_carbon`,
    `cdp`) — the numpy GA reference twin and the report printers;
  * batched jnp array functions (`murphy_yield_arr`, `cfpa_arr`,
    `embodied_carbon_g_arr`, `cdp_arr`) — pure elementwise maps over whole
    GA populations, traced inside the jitted GA step (`core/ga_batched.py`).

Every function takes an optional `ci_fab` override (fab grid carbon
intensity, g CO2/kWh) so scenario sweeps can model hydro-backed vs
coal-backed fabs without mutating module state.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

# --- per-technology-node fab parameters -------------------------------------
# EPA:   manufacturing energy per unit area [kWh / cm^2].  ACT [Gupta+
#        ISCA'22] Fig. 4 fab-energy trend (older nodes: imec/TSMC
#        sustainability-report ballpark); rises toward advanced nodes with
#        the EUV layer count.
# C_gas: direct greenhouse-gas emissions from processing [g CO2 / cm^2]
#        (PFC/NF3 etch+clean chemistry; ACT's "gas" term, scaled per cm^2).
# D0:    defect density [defects / cm^2]; public foundry-ballpark maturity
#        figures, feeding the Murphy yield model (ECO-CHIP uses the same
#        yield treatment for chiplet vs monolithic carbon).
# freq:  nominal accelerator clock at that node [Hz] (DVFS-free edge-SoC
#        operating point; sets the dataflow model's cycle time).
NODE_PARAMS: dict[int, dict[str, float]] = {
    7:  {"EPA": 2.15, "C_gas": 280.0, "D0": 0.20, "freq": 1.4e9},
    14: {"EPA": 1.20, "C_gas": 200.0, "D0": 0.10, "freq": 1.0e9},
    28: {"EPA": 0.85, "C_gas": 150.0, "D0": 0.05, "freq": 0.7e9},
}

# Fab electricity carbon intensity [g CO2/kWh].  ACT's default fab mix
# (Taiwan/Korea grid-dominated, ~0.6 kg/kWh); scenario sweeps override this
# via the `ci_fab` argument (e.g. ~50 hydro/nuclear-backed, ~820 coal grid).
CI_FAB_G_PER_KWH = 620.0
# Raw material procurement [g CO2 / cm^2]: ACT's per-area materials term
# (wafer + chemicals + gases procurement upstream of the fab).
C_MATERIAL_G_PER_CM2 = 500.0
# Raw silicon wafer processing [g CO2 / cm^2], charged to *wasted* wafer
# area in Eq. 1 (edge dies + sawing loss carry silicon cost but no
# patterning cost) — the ECO-CHIP A_wasted treatment.
CFPA_SI_G_PER_CM2 = 130.0
WAFER_DIAMETER_MM = 300.0

# --- multi-die packaging (ECO-CHIP-style chiplet integration) ----------------
# Splitting one accelerator across N dies buys per-die Murphy yield (small
# dies) and an extra DRAM channel per die, but pays a packaging term:
# an interposer/RDL substrate sized to the summed die area plus spacing,
# charged at the raw-silicon rate (it is patterned coarsely, not at the
# logic node), and a per-die bonding/assembly energy share.
PACKAGING_AREA_OVERHEAD = 0.10      # interposer area beyond summed die area
C_BONDING_G_PER_DIE = 8.0           # die-attach / D2D bonding per die [g]


def murphy_yield(area_mm2: float, node_nm: int) -> float:
    """Murphy's yield model; area in mm^2, D0 in defects/cm^2."""
    d0 = NODE_PARAMS[node_nm]["D0"]
    ad = (area_mm2 / 100.0) * d0
    if ad < 1e-9:
        return 1.0
    return ((1.0 - math.exp(-ad)) / ad) ** 2


def dies_per_wafer(area_mm2: float) -> float:
    """Gross dies per 300 mm wafer (standard edge-loss approximation)."""
    d = WAFER_DIAMETER_MM
    side = math.sqrt(max(area_mm2, 1e-9))
    return max(1.0, math.pi * (d / 2.0) ** 2 / area_mm2
               - math.pi * d / (math.sqrt(2.0) * side))


@dataclasses.dataclass(frozen=True)
class CarbonBreakdown:
    die_g: float          # CFPA * A_die
    wasted_g: float       # CFPA_Si * A_wasted
    total_g: float
    cfpa_g_per_cm2: float
    yield_: float
    area_mm2: float
    node_nm: int

    @property
    def total_kg(self) -> float:
        return self.total_g / 1000.0


def cfpa(node_nm: int, area_mm2: float,
         ci_fab: float | None = None) -> tuple[float, float]:
    """Eq. 2: carbon footprint per cm^2 of *die* area; returns (CFPA, Y)."""
    p = NODE_PARAMS[node_nm]
    ci = CI_FAB_G_PER_KWH if ci_fab is None else ci_fab
    y = murphy_yield(area_mm2, node_nm)
    val = (ci * p["EPA"] + p["C_gas"] + C_MATERIAL_G_PER_CM2) / y
    return val, y


def embodied_carbon(area_mm2: float, node_nm: int,
                    ci_fab: float | None = None) -> CarbonBreakdown:
    """Eq. 1 for a monolithic accelerator die."""
    cfpa_val, y = cfpa(node_nm, area_mm2, ci_fab)
    area_cm2 = area_mm2 / 100.0
    dpw = dies_per_wafer(area_mm2)
    wafer_area_cm2 = math.pi * (WAFER_DIAMETER_MM / 20.0) ** 2
    wasted_cm2_per_die = max(0.0, wafer_area_cm2 / dpw - area_cm2)
    die_g = cfpa_val * area_cm2
    wasted_g = CFPA_SI_G_PER_CM2 * wasted_cm2_per_die
    return CarbonBreakdown(
        die_g=die_g, wasted_g=wasted_g, total_g=die_g + wasted_g,
        cfpa_g_per_cm2=cfpa_val, yield_=y, area_mm2=area_mm2, node_nm=node_nm)


def cdp(carbon_g: float, fps: float) -> float:
    """Carbon-Delay-Product [g CO2 * s]; lower is better."""
    return carbon_g / max(fps, 1e-9)


# ---------------------------------------------------------------------------
# Multi-die packages: per-die Murphy yield + packaging overhead.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultiDieBreakdown:
    """Embodied carbon of an `n_dies`-die package (Eq. 1 per die + the
    ECO-CHIP packaging term).  `n_dies == 1` collapses exactly to the
    monolithic `embodied_carbon` (zero packaging)."""
    per_die: CarbonBreakdown   # one die at `die_area_mm2`
    n_dies: int
    packaging_g: float
    total_g: float

    @property
    def die_area_mm2(self) -> float:
        return self.per_die.area_mm2

    @property
    def die_yield(self) -> float:
        return self.per_die.yield_

    @property
    def total_area_mm2(self) -> float:
        """Total patterned silicon (excl. interposer)."""
        return self.n_dies * self.per_die.area_mm2


def packaging_carbon(die_area_mm2: float, n_dies: int) -> float:
    """Packaging/bonding carbon [g] for an `n_dies` package; 0 for a
    monolithic die (no interposer, no D2D bonding)."""
    if n_dies <= 1:
        return 0.0
    interposer_cm2 = n_dies * (die_area_mm2 / 100.0) * \
        (1.0 + PACKAGING_AREA_OVERHEAD)
    return CFPA_SI_G_PER_CM2 * interposer_cm2 + C_BONDING_G_PER_DIE * n_dies


def multi_die_carbon(die_area_mm2: float, n_dies: int, node_nm: int,
                     ci_fab: float | None = None) -> MultiDieBreakdown:
    """Embodied carbon of `n_dies` identical dies of `die_area_mm2` each,
    plus packaging.  The per-die Murphy yield is evaluated at the DIE area,
    which is the whole point: N small dies out-yield one N-times-larger
    die superlinearly (the chiplet lever of ECO-CHIP / the paper's Eq. 2
    denominator)."""
    per_die = embodied_carbon(die_area_mm2, node_nm, ci_fab)
    pkg = packaging_carbon(die_area_mm2, n_dies)
    return MultiDieBreakdown(
        per_die=per_die, n_dies=n_dies, packaging_g=pkg,
        total_g=n_dies * per_die.total_g + pkg)


def node_frequency(node_nm: int) -> float:
    return NODE_PARAMS[node_nm]["freq"]


# ---------------------------------------------------------------------------
# Batched array forms — same equations over whole populations.
# ---------------------------------------------------------------------------

def murphy_yield_arr(area_mm2: jnp.ndarray, d0: float) -> jnp.ndarray:
    ad = (area_mm2 / 100.0) * d0
    safe = jnp.maximum(ad, 1e-9)
    # -expm1(-x) == 1 - e^{-x} without the f32 cancellation at small x
    y = (-jnp.expm1(-safe) / safe) ** 2
    return jnp.where(ad < 1e-9, 1.0, y)


def cfpa_arr(area_mm2: jnp.ndarray, node_nm: int,
             ci_fab: float | jnp.ndarray | None = None) -> jnp.ndarray:
    p = NODE_PARAMS[node_nm]
    ci = CI_FAB_G_PER_KWH if ci_fab is None else ci_fab
    y = murphy_yield_arr(area_mm2, p["D0"])
    return (ci * p["EPA"] + p["C_gas"] + C_MATERIAL_G_PER_CM2) / y


def embodied_carbon_g_arr(area_mm2: jnp.ndarray, node_nm: int,
                          ci_fab: float | jnp.ndarray | None = None
                          ) -> jnp.ndarray:
    """Eq. 1 total grams for an array of die areas (population-parallel).

    The wasted-area term is algebraically restructured: with
    dpw = wafer/area - edge (unclamped), `wafer/dpw - area` equals
    `area * edge / dpw` exactly — the product form avoids the f32
    catastrophic cancellation of subtracting two nearly equal quotients
    for small dies."""
    cfpa_val = cfpa_arr(area_mm2, node_nm, ci_fab)
    area_cm2 = area_mm2 / 100.0
    d = WAFER_DIAMETER_MM
    wafer_area_cm2 = math.pi * (d / 20.0) ** 2
    side = jnp.sqrt(jnp.maximum(area_mm2, 1e-9))
    edge = math.pi * d / (math.sqrt(2.0) * side)
    dpw_raw = math.pi * (d / 2.0) ** 2 / area_mm2 - edge
    wasted = jnp.where(dpw_raw >= 1.0,
                       area_cm2 * edge / jnp.maximum(dpw_raw, 1.0),
                       wafer_area_cm2 - area_cm2)
    wasted = jnp.maximum(0.0, wasted)
    return cfpa_val * area_cm2 + CFPA_SI_G_PER_CM2 * wasted


def cdp_arr(carbon_g: jnp.ndarray, fps: jnp.ndarray) -> jnp.ndarray:
    return carbon_g / jnp.maximum(fps, 1e-9)


def packaging_carbon_arr(die_area_mm2: jnp.ndarray, n_dies: jnp.ndarray
                         ) -> jnp.ndarray:
    """`packaging_carbon` over arrays (n_dies may be float-valued)."""
    interposer_cm2 = n_dies * (die_area_mm2 / 100.0) * \
        (1.0 + PACKAGING_AREA_OVERHEAD)
    pkg = CFPA_SI_G_PER_CM2 * interposer_cm2 + C_BONDING_G_PER_DIE * n_dies
    return jnp.where(n_dies > 1, pkg, 0.0)


def multi_die_carbon_g_arr(die_area_mm2: jnp.ndarray, n_dies: jnp.ndarray,
                           node_nm: int,
                           ci_fab: float | jnp.ndarray | None = None
                           ) -> jnp.ndarray:
    """`multi_die_carbon(...).total_g` as a pure array function (the
    population-parallel form used inside the jitted GA step)."""
    per_die = embodied_carbon_g_arr(die_area_mm2, node_nm, ci_fab)
    return n_dies * per_die + packaging_carbon_arr(die_area_mm2, n_dies)
