"""NSGA-II multi-objective search for area-aware approximate multipliers.

Genome = (pruning bitmask over the prunable gates of the BW8 netlist,
          trunc_a in 0..4, trunc_b in 0..4).
Objectives = minimize (area_nand2eq, NMED).

This is the paper's step 1 ("approximations guided by a multi-objective
optimization algorithm ... near-Pareto-optimal solutions with minimal
functional error") in the spirit of [5] (genetic circuit approximation).
Deterministic under a fixed seed; the default front is cached in-process and
on disk (benchmarks re-use it).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib

import numpy as np

from . import lut as lutmod
from . import multipliers as multmod
from . import netlist as nlmod


@dataclasses.dataclass
class NSGAConfig:
    pop_size: int = 32
    generations: int = 16
    p_mut_gate: float = 0.01     # per-gene bitflip probability
    p_mut_trunc: float = 0.15
    p_crossover: float = 0.9
    max_trunc: int = 4
    nmed_cap: float = 0.08       # discard individuals worse than this
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Individual:
    mask: np.ndarray     # (n_prunable,) bool
    trunc_a: int
    trunc_b: int
    area: float
    nmed: float

    def key(self) -> tuple:
        return (self.mask.tobytes(), self.trunc_a, self.trunc_b)


def _evaluate(mask: np.ndarray, ta: int, tb: int) -> tuple[float, float]:
    nl = nlmod.bw8()
    prunable = nl.prunable_gates()
    probs = multmod._signal_probs()
    pr: dict[int, int] = {}
    for k in np.flatnonzero(mask):
        gid = prunable[k]
        pr[gid] = int(probs[gid] >= 0.5)
    pr.update(nlmod.truncation_pruning(nl, ta, tb))
    full = nlmod.constant_propagate(nl, pr)
    lut = nlmod.netlist_lut(nl, full)
    area = nl.area_nand2eq(full)
    e = np.abs(nlmod.exact_lut().astype(np.int64) - lut.astype(np.int64))
    nmed = float(e.mean() / lutmod.MAX_ABS_PRODUCT)
    return area, nmed


def _nondominated_sort(objs: np.ndarray) -> list[np.ndarray]:
    """objs (n, m) minimize-all -> list of index arrays per front.

    i dominates j iff i is <= j on every objective and < on at least
    one (works for any m >= 1; the NSGA-II loop uses m = 2)."""
    n = len(objs)
    dominates = (np.all(objs[:, None, :] <= objs[None, :, :], axis=-1)
                 & np.any(objs[:, None, :] < objs[None, :, :], axis=-1))
    dom_count = dominates.sum(axis=0)  # how many dominate i
    fronts: list[np.ndarray] = []
    remaining = np.arange(n)
    counts = dom_count.copy()
    while len(remaining):
        cur = remaining[counts[remaining] == 0]
        if len(cur) == 0:  # numerical ties; break arbitrarily
            cur = remaining[np.argsort(counts[remaining])[:1]]
        fronts.append(cur)
        mask = np.ones(n, dtype=bool)
        mask[cur] = False
        for i in cur:
            counts[dominates[i]] -= 1
        remaining = np.array([r for r in remaining if mask[r]], dtype=int)
    return fronts


def nondominated_front(points: np.ndarray) -> np.ndarray:
    """Indices of the nondominated rows of `points` (n, m objectives,
    all minimized), sorted ascending by the first objective.

    Public surface for frontier reporting outside the NSGA loop — e.g.
    `core.codesign` extracts the (carbon, delay) frontier of a final GA
    population with it."""
    pts = np.asarray(points, dtype=float)
    if pts.ndim != 2:
        raise ValueError(f"points must be (n, m), got shape {pts.shape}")
    if len(pts) == 0:
        return np.empty((0,), dtype=int)
    front = _nondominated_sort(pts)[0]
    return front[np.argsort(pts[front, 0], kind="stable")]


def _crowding(objs: np.ndarray, front: np.ndarray) -> np.ndarray:
    d = np.zeros(len(front))
    for m in range(objs.shape[1]):
        order = front[np.argsort(objs[front, m])]
        lo, hi = objs[order[0], m], objs[order[-1], m]
        span = max(hi - lo, 1e-12)
        pos = {int(idx): k for k, idx in enumerate(order)}
        for k, idx in enumerate(front):
            p = pos[int(idx)]
            if p == 0 or p == len(order) - 1:
                d[k] = np.inf
            else:
                d[k] += (objs[order[p + 1], m] - objs[order[p - 1], m]) / span
    return d


def nsga2(cfg: NSGAConfig | None = None) -> list[Individual]:
    """Run NSGA-II; returns the final nondominated front sorted by area."""
    cfg = cfg or NSGAConfig()
    rng = np.random.default_rng(cfg.seed)
    nl = nlmod.bw8()
    n_genes = len(nl.prunable_gates())

    def random_ind() -> tuple[np.ndarray, int, int]:
        density = rng.uniform(0.0, 0.08)
        mask = rng.random(n_genes) < density
        return mask, int(rng.integers(0, cfg.max_trunc + 1)), \
            int(rng.integers(0, cfg.max_trunc + 1))

    def make(mask: np.ndarray, ta: int, tb: int) -> Individual:
        area, nmed = _evaluate(mask, ta, tb)
        return Individual(mask, ta, tb, area, nmed)

    pop = [make(*random_ind()) for _ in range(cfg.pop_size)]
    pop.append(make(np.zeros(n_genes, dtype=bool), 0, 0))  # seed exact

    for _gen in range(cfg.generations):
        objs = np.array([[p.area, p.nmed] for p in pop])
        fronts = _nondominated_sort(objs)
        rank = np.zeros(len(pop), dtype=int)
        for fi, fr in enumerate(fronts):
            rank[fr] = fi
        crowd = np.zeros(len(pop))
        for fr in fronts:
            crowd[fr] = _crowding(objs, fr)

        def tournament() -> Individual:
            i, j = rng.integers(0, len(pop), size=2)
            if rank[i] != rank[j]:
                return pop[i] if rank[i] < rank[j] else pop[j]
            return pop[i] if crowd[i] >= crowd[j] else pop[j]

        children: list[Individual] = []
        seen = {p.key() for p in pop}
        while len(children) < cfg.pop_size:
            p1, p2 = tournament(), tournament()
            if rng.random() < cfg.p_crossover:
                cx = rng.random(n_genes) < 0.5
                mask = np.where(cx, p1.mask, p2.mask)
                ta = p1.trunc_a if rng.random() < 0.5 else p2.trunc_a
                tb = p1.trunc_b if rng.random() < 0.5 else p2.trunc_b
            else:
                mask, ta, tb = p1.mask.copy(), p1.trunc_a, p1.trunc_b
            flip = rng.random(n_genes) < cfg.p_mut_gate
            mask = mask ^ flip
            if rng.random() < cfg.p_mut_trunc:
                ta = int(np.clip(ta + rng.integers(-1, 2), 0, cfg.max_trunc))
            if rng.random() < cfg.p_mut_trunc:
                tb = int(np.clip(tb + rng.integers(-1, 2), 0, cfg.max_trunc))
            child = make(mask, ta, tb)
            if child.nmed <= cfg.nmed_cap and child.key() not in seen:
                seen.add(child.key())
                children.append(child)
            elif child.nmed > cfg.nmed_cap:
                # still allow occasionally to keep diversity pressure low
                pass
            if len(seen) > 10 * cfg.pop_size and len(children) == 0:
                children.append(child)  # safety: avoid infinite loop

        merged = pop + children
        objs = np.array([[p.area, p.nmed] for p in merged])
        fronts = _nondominated_sort(objs)
        next_pop: list[Individual] = []
        for fr in fronts:
            if len(next_pop) + len(fr) <= cfg.pop_size:
                next_pop.extend(merged[i] for i in fr)
            else:
                cd = _crowding(objs, fr)
                order = fr[np.argsort(-cd)]
                for i in order[: cfg.pop_size - len(next_pop)]:
                    next_pop.append(merged[i])
                break
        pop = next_pop

    objs = np.array([[p.area, p.nmed] for p in pop])
    front = _nondominated_sort(objs)[0]
    result = sorted((pop[i] for i in front), key=lambda p: p.area)
    return result


def front_to_multipliers(front: list[Individual]) -> list[multmod.ApproxMultiplier]:
    out = []
    seen: set[tuple] = set()
    for k, ind in enumerate(front):
        okey = (round(ind.area, 3), round(ind.nmed, 7))
        if okey in seen:  # duplicate objective point -> keep one
            continue
        seen.add(okey)
        m = multmod.pruned(ind.mask, name=f"nsga{k}_a{ind.area:.0f}",
                           trunc_a=ind.trunc_a, trunc_b=ind.trunc_b)
        out.append(m)
    return out


# ---------------------------------------------------------------------------
# Cached default front (used by the GA and benchmarks)
# ---------------------------------------------------------------------------

_CACHE_DIR = pathlib.Path(os.environ.get(
    "REPRO_CACHE_DIR", pathlib.Path(__file__).resolve().parents[3] / ".cache"))


@functools.lru_cache(maxsize=1)
def default_front(pop_size: int = 56, generations: int = 44, seed: int = 0
                  ) -> list[multmod.ApproxMultiplier]:
    """NSGA-II front with disk cache (genome-level, re-evaluated on load)."""
    _CACHE_DIR.mkdir(parents=True, exist_ok=True)
    cache = _CACHE_DIR / f"nsga_front_p{pop_size}_g{generations}_s{seed}.json"
    nl = nlmod.bw8()
    n_genes = len(nl.prunable_gates())
    if cache.exists():
        try:
            data = json.loads(cache.read_text())
            if data.get("n_genes") == n_genes:
                front = [
                    Individual(
                        np.array(e["mask"], dtype=bool), e["ta"], e["tb"],
                        e["area"], e["nmed"])
                    for e in data["front"]
                ]
                return front_to_multipliers(front)
        except (json.JSONDecodeError, KeyError):
            pass
    front = nsga2(NSGAConfig(pop_size=pop_size, generations=generations,
                             seed=seed))
    cache.write_text(json.dumps({
        "n_genes": n_genes,
        "front": [
            {"mask": ind.mask.astype(int).tolist(), "ta": ind.trunc_a,
             "tb": ind.trunc_b, "area": ind.area, "nmed": ind.nmed}
            for ind in front
        ],
    }))
    return front_to_multipliers(front)


def pick_by_nmed(mults: list[multmod.ApproxMultiplier], max_nmed: float
                 ) -> multmod.ApproxMultiplier:
    """Smallest-area multiplier with NMED <= max_nmed."""
    ok = [m for m in mults if m.stats.nmed <= max_nmed]
    if not ok:
        return multmod.exact_multiplier()
    return min(ok, key=lambda m: m.area_nand2eq)
