"""DNN workload descriptions for the analytical dataflow model.

The paper evaluates VGG16, VGG19, ResNet50, ResNet152 (ImageNet, 224x224).
Each workload is a list of layers with enough loop-nest structure for the
nn-dataflow-style performance model: Conv (C,K,H,W,R,S,stride) and GEMM
(M,N,K).  FC layers are GEMMs; transformer blocks (our beyond-paper
extension: sizing edge accelerators for LM workloads) decompose into GEMMs.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    c_in: int
    c_out: int
    h_out: int
    w_out: int
    r: int = 3
    s: int = 3
    stride: int = 1

    @property
    def macs(self) -> int:
        return self.c_in * self.c_out * self.h_out * self.w_out * self.r * self.s

    @property
    def weight_bytes(self) -> int:  # int8 weights
        return self.c_in * self.c_out * self.r * self.s

    @property
    def ifmap_bytes(self) -> int:
        return self.c_in * (self.h_out * self.stride + self.r - 1) * \
            (self.w_out * self.stride + self.s - 1)

    @property
    def ofmap_bytes(self) -> int:
        return self.c_out * self.h_out * self.w_out


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    """C[M,N] = A[M,K] @ B[K,N]; B is the stationary (weight) operand."""
    name: str
    m: int
    n: int
    k: int

    @property
    def macs(self) -> int:
        return self.m * self.n * self.k

    @property
    def weight_bytes(self) -> int:
        return self.k * self.n

    @property
    def ifmap_bytes(self) -> int:
        return self.m * self.k

    @property
    def ofmap_bytes(self) -> int:
        return self.m * self.n


Layer = ConvLayer | GemmLayer


def _vgg(cfg: list[int | str], name: str) -> list[Layer]:
    layers: list[Layer] = []
    c_in, hw, idx = 3, 224, 1
    for v in cfg:
        if v == "M":
            hw //= 2
            continue
        layers.append(ConvLayer(f"{name}.conv{idx}", c_in, int(v), hw, hw))
        c_in = int(v)
        idx += 1
    layers.append(GemmLayer(f"{name}.fc1", 1, 4096, 512 * 7 * 7))
    layers.append(GemmLayer(f"{name}.fc2", 1, 4096, 4096))
    layers.append(GemmLayer(f"{name}.fc3", 1, 1000, 4096))
    return layers


def vgg16() -> list[Layer]:
    return _vgg([64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                 512, 512, 512, "M", 512, 512, 512, "M"], "vgg16")


def vgg19() -> list[Layer]:
    return _vgg([64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
                 512, 512, 512, 512, "M", 512, 512, 512, 512, "M"], "vgg19")


def _resnet(blocks: list[int], name: str) -> list[Layer]:
    layers: list[Layer] = [ConvLayer(f"{name}.conv1", 3, 64, 112, 112, 7, 7, 2)]
    c_in = 64
    hw = 56
    widths = [64, 128, 256, 512]
    for stage, (nblk, w) in enumerate(zip(blocks, widths)):
        for b in range(nblk):
            stride = 2 if (stage > 0 and b == 0) else 1
            if stride == 2:
                hw //= 2
            tag = f"{name}.s{stage + 2}b{b}"
            layers.append(ConvLayer(f"{tag}.c1", c_in, w, hw, hw, 1, 1, stride))
            layers.append(ConvLayer(f"{tag}.c2", w, w, hw, hw, 3, 3, 1))
            layers.append(ConvLayer(f"{tag}.c3", w, 4 * w, hw, hw, 1, 1, 1))
            if b == 0:
                layers.append(ConvLayer(f"{tag}.proj", c_in, 4 * w, hw, hw,
                                        1, 1, stride))
            c_in = 4 * w
    layers.append(GemmLayer(f"{name}.fc", 1, 1000, 2048))
    return layers


def resnet50() -> list[Layer]:
    return _resnet([3, 4, 6, 3], "resnet50")


def resnet152() -> list[Layer]:
    return _resnet([3, 8, 36, 3], "resnet152")


def attn_block_gemms(name: str, d_model: int, d_ff: int, n_heads: int,
                     n_kv_heads: int, q_len: int, kv_len: int) -> list[Layer]:
    """One decoder block as GEMMs: `q_len` query tokens attending over
    `kv_len` cached positions.  `q_len == kv_len == seq` is a prefill /
    per-token-batch block; `q_len == 1` is a serving decode step."""
    head_dim = d_model // n_heads
    return [
        GemmLayer(f"{name}.q", q_len, n_heads * head_dim, d_model),
        GemmLayer(f"{name}.kv", q_len, 2 * n_kv_heads * head_dim, d_model),
        GemmLayer(f"{name}.scores", q_len * n_heads, kv_len, head_dim),
        GemmLayer(f"{name}.ctx", q_len * n_heads, head_dim, kv_len),
        GemmLayer(f"{name}.o", q_len, d_model, n_heads * head_dim),
        GemmLayer(f"{name}.up", q_len, 2 * d_ff, d_model),
        GemmLayer(f"{name}.down", q_len, d_model, d_ff),
    ]


def transformer_block_gemms(name: str, d_model: int, d_ff: int, n_heads: int,
                            n_kv_heads: int, seq: int) -> list[Layer]:
    """One decoder block as GEMMs (per-token batch = seq), for sizing edge
    accelerators on LM workloads (beyond-paper extension)."""
    return attn_block_gemms(name, d_model, d_ff, n_heads, n_kv_heads,
                            seq, seq)


def tiny_lm(seq: int = 128, layers: int = 4, d_model: int = 256) -> list[Layer]:
    out: list[Layer] = []
    for i in range(layers):
        out += transformer_block_gemms(f"lm.l{i}", d_model, 4 * d_model,
                                       8, 8, seq)
    return out


def decode_block_gemms(name: str, d_model: int, d_ff: int, n_heads: int,
                       n_kv_heads: int, kv_len: int) -> list[Layer]:
    """One decoder block for a SINGLE new token against a KV cache of
    `kv_len` entries — the serving engine's decode-step shape."""
    return attn_block_gemms(name, d_model, d_ff, n_heads, n_kv_heads,
                            1, kv_len)


def lm_decode(kv_len: int = 128, layers: int = 2, d_model: int = 256
              ) -> list[Layer]:
    """One decode step of the tiny LM (all blocks, fixed cache length):
    1/fps of this workload = per-token decode latency, the quantity the
    serving calibration bridge (`core/calibrate.py`) measures for real."""
    out: list[Layer] = []
    for i in range(layers):
        out += decode_block_gemms(f"lmdec.l{i}", d_model, 4 * d_model,
                                  8, 8, kv_len)
    return out


def lm_serving(prompt: int = 48, gen: int = 8, layers: int = 2,
               d_model: int = 256) -> list[Layer]:
    """One serving request end to end: a `prompt`-token prefill followed by
    `gen` decode steps against the growing KV cache — the layer-level
    mirror of one `repro.serving` request, so scenario sweeps can size
    accelerators for LM serving traces, not just CNN frames.  1/fps =
    request latency."""
    out: list[Layer] = []
    for i in range(layers):
        out += transformer_block_gemms(f"lmsrv.pre.l{i}", d_model,
                                       4 * d_model, 8, 8, prompt)
    for t in range(gen):
        for i in range(layers):
            out += decode_block_gemms(f"lmsrv.d{t}.l{i}", d_model,
                                      4 * d_model, 8, 8, prompt + t + 1)
    return out


WORKLOADS = {
    "vgg16": vgg16,
    "vgg19": vgg19,
    "resnet50": resnet50,
    "resnet152": resnet152,
    "tiny_lm": tiny_lm,
    "lm_decode": lm_decode,
    "lm_serving": lm_serving,
}


def total_macs(layers: list[Layer]) -> int:
    return sum(l.macs for l in layers)
