"""Population-parallel co-design search: the paper's step-2 GA with the
whole population evaluated as one batched array program.

`core/ga.py` (the numpy reference twin) evaluates genomes one Python call
at a time; this module keeps its design space, fitness definition, and
constraint semantics but turns them into struct-of-arrays compute:

  * genomes are an int32 (P, 6) array over
    (pe_idx, aspect_idx, rf_idx, glb_idx, mult_idx, die_idx);
  * FPS comes from a (n_pe, n_aspect, n_glb, n_die) lattice precomputed
    ONCE per (workload, node) by the batched dataflow model
    (`dataflow.batched_fps`) — the performance model itself runs as a
    jnp array program, then the GA gathers from the lattice;
  * area / embodied carbon / CDP fitness are the pure array functions in
    `accelerator.area_total_mm2_arr` and `carbon.*_arr`;
  * tournament selection, uniform crossover, per-gene mutation, and
    constraint masking (accuracy-drop ceiling on the multiplier gene,
    FPS-floor penalty identical to the reference) all run inside ONE
    jitted GA step (`_ga_step`), so a generation is a single device
    program regardless of population size.

Populations two orders of magnitude beyond the sequential loop (4096+ vs
24) run in comparable wall time; `benchmarks/bench_codesign.py` records
the measured speedup and the design-parity check against the numpy twin
in `BENCH_codesign.json`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import accelerator as accmod
from . import carbon as carbonmod
from . import dataflow as dfmod
from . import ga as gamod
from . import multipliers as mm

GENE_NAMES = ("pe_idx", "aspect_idx", "rf_idx", "glb_idx", "mult_idx",
              "die_idx")
N_GENES = len(GENE_NAMES)
MULT_GENE = GENE_NAMES.index("mult_idx")
DIE_GENE = GENE_NAMES.index("die_idx")


@dataclasses.dataclass
class BatchedGAConfig:
    pop_size: int = 4096
    generations: int = 12
    tournament: int = 3
    p_crossover: float = 0.7
    p_mutate_gene: float = 0.25
    seed: int = 0
    fps_penalty: float = 50.0
    elitism: int = 2
    #: "cdp" (the paper's embodied-carbon-x-delay fitness) or
    #: "total_carbon" (amortized embodied + operational gCO2e per
    #: inference; requires `DesignSpace.op`, see `repro.fleet.total`).
    objective: str = "cdp"


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Host-side index->physical-quantity tables for one (workload, node,
    constraint) instance.  `tables()` repackages them as a jnp pytree for
    the jitted step."""
    workload: str
    node_nm: int
    fps_min: float
    max_accuracy_drop: float
    ci_fab: float | None
    mults: tuple[mm.ApproxMultiplier, ...]
    rows: np.ndarray          # (n_pe, n_aspect) physical PE rows
    cols: np.ndarray          # (n_pe, n_aspect)
    num_pes: np.ndarray       # (n_pe,)
    rf_bytes: np.ndarray      # (n_rf,)
    glb_kib: np.ndarray       # (n_glb,)
    mult_area: np.ndarray     # (n_mults,) NAND2-equivalents
    mult_allowed: np.ndarray  # (n_mults,) bool — accuracy-drop ceiling
    fps_table: np.ndarray     # (n_pe, n_aspect, n_glb, n_die)
    exact_idx: int            # fallback gene for constraint masking
    dies: np.ndarray          # (n_die,) die counts (gamod.DIE_CHOICES)
    die_ok: np.ndarray        # (n_pe, n_aspect, n_die) bool — even splits
    #: operational-carbon model for the "total_carbon" objective.
    #: Duck-typed (`repro.fleet.total.OperationalModel` in practice:
    #: scalar fields ci_use_g_per_kwh / lifetime_s / util / idle_frac
    #: plus `pe_active_w(node_nm)`) so core never imports fleet.
    op: Any = None

    @property
    def gene_sizes(self) -> tuple[int, ...]:
        return (len(self.num_pes), self.rows.shape[1], len(self.rf_bytes),
                len(self.glb_kib), len(self.mults), len(self.dies))

    @property
    def size(self) -> int:
        n = 1
        for s in self.gene_sizes:
            n *= s
        return n

    def tables(self) -> dict:
        f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
        t = {
            "rows": f32(self.rows), "cols": f32(self.cols),
            "num_pes": f32(self.num_pes), "rf": f32(self.rf_bytes),
            "glb": f32(self.glb_kib), "mult_area": f32(self.mult_area),
            # multiplier-array energy scale: area ratio vs the exact
            # design (approx multipliers are smaller AND lower power)
            "mult_escale": f32(self.mult_area
                               / self.mult_area[self.exact_idx]),
            "allowed": jnp.asarray(self.mult_allowed),
            "fps": f32(self.fps_table),
            "dies": f32(self.dies),
            "die_ok": jnp.asarray(self.die_ok),
            "exact_idx": jnp.int32(self.exact_idx),
            "ci_fab": jnp.float32(
                carbonmod.CI_FAB_G_PER_KWH if self.ci_fab is None
                else self.ci_fab),
            "fps_min": jnp.float32(self.fps_min),
        }
        if self.op is not None:
            t["op_ci_use"] = jnp.float32(self.op.ci_use_g_per_kwh)
            t["op_life_s"] = jnp.float32(self.op.lifetime_s)
            t["op_util"] = jnp.float32(self.op.util)
            t["op_idle_frac"] = jnp.float32(self.op.idle_frac)
            t["op_die_w"] = jnp.float32(self.op.die_w)
            t["op_pe_w"] = jnp.float32(self.op.pe_active_w(self.node_nm))
        return t

    def decode(self, genome_row: np.ndarray) -> gamod.Genome:
        return gamod.Genome(*(int(g) for g in genome_row))


def build_space(workload: str, node_nm: int, fps_min: float,
                max_accuracy_drop: float,
                mults: Sequence[mm.ApproxMultiplier] | None = None,
                accuracy_fn: gamod.AccuracyFn = gamod.proxy_accuracy_drop,
                ci_fab: float | None = None,
                dram_gbps: float = 19.2,
                op: Any = None) -> DesignSpace:
    """Resolve the genome design space into gatherable arrays, including
    the FPS lattice from the batched dataflow model."""
    if mults is None:
        from . import pareto
        mults = pareto.default_front()
    mults = list(mults)
    drops = np.array([accuracy_fn(m) for m in mults])
    allowed = drops <= max_accuracy_drop
    # mirror run_ga: the feasible set always contains an exact multiplier
    if not any(m.is_exact and ok for m, ok in zip(mults, allowed)):
        mults.append(mm.exact_multiplier())
        allowed = np.append(allowed, True)
    gamod._register(mults)
    exact_idx = next(i for i, m in enumerate(mults)
                     if m.is_exact and allowed[i])

    n_pe, n_aspect = len(accmod.VALID_PE_COUNTS), len(gamod.ASPECTS)
    rows = np.zeros((n_pe, n_aspect), np.int64)
    cols = np.zeros((n_pe, n_aspect), np.int64)
    for i, pes in enumerate(accmod.VALID_PE_COUNTS):
        for j, aspect in enumerate(gamod.ASPECTS):
            rows[i, j], cols[i, j] = gamod._pe_split(pes, aspect)

    glb = np.asarray(gamod.GLB_KIB_CHOICES, np.int64)
    dies = np.asarray(gamod.DIE_CHOICES, np.int64)
    n_die = len(dies)
    die_ok = np.zeros((n_pe, n_aspect, n_die), bool)
    for i, pes in enumerate(accmod.VALID_PE_COUNTS):
        for j in range(n_aspect):
            for di, d in enumerate(gamod.DIE_CHOICES):
                die_ok[i, j, di] = gamod.die_feasible(
                    int(cols[i, j]), pes, d)
    # FPS lattice: every (pe, aspect, glb, die) combo in one batched call
    ri, rj, rk, rd = np.meshgrid(np.arange(n_pe), np.arange(n_aspect),
                                 np.arange(len(glb)), np.arange(n_die),
                                 indexing="ij")
    fps_flat = dfmod.batched_fps(
        workload, rows[ri.ravel(), rj.ravel()], cols[ri.ravel(), rj.ravel()],
        glb[rk.ravel()], node_nm, dram_gbps, dies=dies[rd.ravel()])
    fps_table = np.asarray(fps_flat).reshape(n_pe, n_aspect, len(glb),
                                             n_die)

    return DesignSpace(
        workload=workload, node_nm=node_nm, fps_min=fps_min,
        max_accuracy_drop=max_accuracy_drop, ci_fab=ci_fab,
        mults=tuple(mults), rows=rows, cols=cols,
        num_pes=np.asarray(accmod.VALID_PE_COUNTS, np.int64),
        rf_bytes=np.asarray(gamod.RF_CHOICES, np.int64),
        glb_kib=glb,
        mult_area=np.array([m.area_nand2eq for m in mults]),
        mult_allowed=allowed,
        fps_table=fps_table, exact_idx=exact_idx,
        dies=dies, die_ok=die_ok, op=op)


# ---------------------------------------------------------------------------
# Jitted population evaluation + GA step
# ---------------------------------------------------------------------------

def _metrics(pop: jnp.ndarray, t: dict, node_nm: int,
             fps_penalty: float, objective: str = "cdp") -> dict:
    """Fitness of a (P, 6) genome array — pure gathers + elementwise
    array math, no Python per-genome work.  `objective` picks what the
    GA minimizes: "cdp" (embodied carbon x delay) or "total_carbon"
    (amortized embodied + operational gCO2e per inference — the batched
    twin of `repro.fleet.total.total_carbon_g_per_inf`; requires the op_*
    table scalars from `DesignSpace.op`)."""
    pe, aspect, rf, glb, mult, die = (pop[:, i] for i in range(N_GENES))
    fps = t["fps"][pe, aspect, glb, die]
    n_dies = t["dies"][die]
    die_area = accmod.area_total_mm2_arr(
        t["num_pes"][pe] / n_dies, t["rf"][rf], t["glb"][glb],
        t["mult_area"][mult], node_nm)
    area = n_dies * die_area
    carbon = carbonmod.multi_die_carbon_g_arr(die_area, n_dies, node_nm,
                                              t["ci_fab"])
    cdp = carbonmod.cdp_arr(carbon, fps)
    fps_min = t["fps_min"]
    # identical semantics to ga.evaluate: fps capped at the threshold
    # (speed beyond the requirement must not buy carbon headroom), with
    # a superlinear penalty under the floor.
    eff = jnp.where(fps_min > 0, jnp.minimum(fps, fps_min), fps)
    out = {"fps": fps, "area_mm2": area, "carbon_g": carbon, "cdp": cdp,
           "n_dies": n_dies, "die_area_mm2": die_area}
    if "op_pe_w" in t:
        # operational term (see fleet/total.py for the derivation):
        # race-to-idle active energy + duty-cycle idle tail, amortized
        # embodied over lifetime inferences at the duty-cycled rate.
        escale = t["mult_escale"][mult]
        p_active = (t["op_pe_w"] * t["num_pes"][pe]
                    * (0.5 + 0.5 * escale)
                    + t["op_die_w"] * jnp.maximum(n_dies - 1.0, 0.0))
        p_idle = t["op_idle_frac"] * p_active
        e_inf = (p_active / fps
                 + p_idle * jnp.maximum(0.0, 1.0 / eff - 1.0 / fps))
        op_g = e_inf / 3.6e6 * t["op_ci_use"]
        emb_g = carbon / (t["op_life_s"] * t["op_util"] * eff)
        out["energy_j_per_inf"] = e_inf
        out["operational_g_per_inf"] = op_g
        out["embodied_g_per_inf"] = emb_g
        out["total_g_per_inf"] = emb_g + op_g
    if objective == "total_carbon":
        if "op_pe_w" not in t:
            raise ValueError(
                "objective='total_carbon' needs DesignSpace.op (an "
                "OperationalModel) to supply the op_* tables")
        fitness = out["total_g_per_inf"]
    elif objective == "cdp":
        fitness = carbonmod.cdp_arr(carbon, eff)
    else:
        raise ValueError(f"unknown objective {objective!r}")
    deficit = (fps_min - fps) / jnp.maximum(fps_min, 1e-9)
    penalized = fitness * (1.0 + fps_penalty * deficit * (1.0 + deficit))
    fitness = jnp.where((fps_min > 0) & (fps < fps_min), penalized, fitness)
    # constraint mask: accuracy-infeasible multiplier genes and uneven die
    # splits never score
    feasible = t["allowed"][mult] & t["die_ok"][pe, aspect, die]
    out["fitness"] = jnp.where(feasible, fitness, jnp.inf)
    out["feasible"] = feasible
    return out


@functools.partial(jax.jit,
                   static_argnames=("node_nm", "fps_penalty", "objective"))
def evaluate_population(pop: jnp.ndarray, tables: dict, node_nm: int,
                        fps_penalty: float = 50.0,
                        objective: str = "cdp") -> dict:
    return _metrics(pop, tables, node_nm, fps_penalty, objective)


def _random_genes(key: jnp.ndarray, n: int, gene_sizes: tuple[int, ...],
                  allowed: jnp.ndarray) -> jnp.ndarray:
    """(n, 6) random genomes; the multiplier gene is drawn ONLY from the
    accuracy-feasible set (constraint satisfaction by construction).  The
    die gene is uniform — its feasibility depends on the (pe, aspect)
    genes, so uneven splits are repaired by `_snap_die_gene` instead."""
    keys = jax.random.split(key, N_GENES)
    logits = jnp.where(allowed, 0.0, -jnp.inf)
    cols = []
    for i in range(N_GENES):
        if i == MULT_GENE:
            cols.append(jax.random.categorical(
                keys[i], logits, shape=(n,)).astype(jnp.int32))
        else:
            cols.append(jax.random.randint(keys[i], (n,), 0, gene_sizes[i],
                                           jnp.int32))
    return jnp.stack(cols, axis=1)


def _snap_die_gene(pop: jnp.ndarray, die_ok: jnp.ndarray) -> jnp.ndarray:
    """Repair uneven die splits to the always-feasible monolithic gene 0
    (DIE_CHOICES[0] == 1)."""
    ok = die_ok[pop[:, 0], pop[:, 1], pop[:, DIE_GENE]]
    return pop.at[:, DIE_GENE].set(
        jnp.where(ok, pop[:, DIE_GENE], 0).astype(pop.dtype))


@functools.partial(jax.jit, static_argnames=(
    "node_nm", "gene_sizes", "tournament", "elitism", "fps_penalty",
    "objective"))
def _ga_step(key: jnp.ndarray, pop: jnp.ndarray, tables: dict,
             node_nm: int, gene_sizes: tuple[int, ...], tournament: int,
             elitism: int, p_crossover: float, p_mutate: float,
             fps_penalty: float, objective: str = "cdp"):
    """One generation — selection, crossover, mutation, constraint
    masking — as a single device program over the whole population."""
    t = tables
    P = pop.shape[0]
    fit = _metrics(pop, t, node_nm, fps_penalty, objective)["fitness"]
    order = jnp.argsort(fit)
    k_sel, k_cross, k_genes, k_mut, k_rand = jax.random.split(key, 5)

    # tournament selection: two parents per child slot
    idx = jax.random.randint(k_sel, (2, P, tournament), 0, P)
    win = jnp.take_along_axis(
        idx, jnp.argmin(fit[idx], axis=-1, keepdims=True), axis=-1)[..., 0]
    p1, p2 = pop[win[0]], pop[win[1]]

    # uniform crossover (per pair with prob p_crossover, per gene 50/50)
    pair_cross = jax.random.uniform(k_cross, (P, 1)) < p_crossover
    from_p2 = (jax.random.uniform(k_genes, (P, N_GENES)) < 0.5) & pair_cross
    child = jnp.where(from_p2, p2, p1)

    # per-gene mutation; the mult gene resamples within the feasible set
    mut = jax.random.uniform(k_mut, (P, N_GENES)) < p_mutate
    child = jnp.where(mut, _random_genes(k_rand, P, gene_sizes,
                                         t["allowed"]), child)

    # elitism: best `elitism` genomes survive
    child = child.at[:elitism].set(pop[order[:elitism]])

    # constraint masking, applied last so even seeded-infeasible elites
    # cannot carry an accuracy-infeasible multiplier gene (snap to the
    # exact multiplier) or an uneven die split (snap to 1 die) forward.
    mult = child[:, MULT_GENE]
    child = child.at[:, MULT_GENE].set(
        jnp.where(t["allowed"][mult], mult, t["exact_idx"]))
    child = _snap_die_gene(child, t["die_ok"])
    return child, fit[order[0]], pop[order[0]]


@dataclasses.dataclass
class BatchedGAResult:
    best: gamod.Evaluated           # decoded + re-scored by the reference
    best_genome: gamod.Genome
    history: list[float]            # best fitness per generation
    population: np.ndarray          # (P, 5) final genomes
    metrics: dict                   # final-population arrays (np)
    space: DesignSpace


def run_ga_batched(workload: str, node_nm: int, fps_min: float,
                   max_accuracy_drop: float,
                   mults: Sequence[mm.ApproxMultiplier] | None = None,
                   accuracy_fn: gamod.AccuracyFn = gamod.proxy_accuracy_drop,
                   cfg: BatchedGAConfig | None = None,
                   ci_fab: float | None = None,
                   space: DesignSpace | None = None,
                   op: Any = None) -> BatchedGAResult:
    """Carbon-minimizing GA over a whole population per device step
    (objective per `cfg.objective`: CDP, or total carbon when an
    operational model is supplied).  The returned `best` is re-evaluated
    through the numpy reference (`ga.evaluate`), so reported CDP numbers
    are the reference model's."""
    cfg = cfg or BatchedGAConfig()
    if space is None:
        space = build_space(workload, node_nm, fps_min, max_accuracy_drop,
                            mults=mults, accuracy_fn=accuracy_fn,
                            ci_fab=ci_fab, op=op)
    elif op is not None and space.op is None:
        space = dataclasses.replace(space, op=op)
    if cfg.objective == "total_carbon" and space.op is None:
        raise ValueError("objective='total_carbon' requires an "
                         "OperationalModel (op=... or space.op)")
    # a prebuilt space must describe THIS problem: the GA searches on
    # the space's tables but reports through the args
    got = (space.workload, space.node_nm, space.fps_min,
           space.max_accuracy_drop)
    want = (workload, node_nm, fps_min, max_accuracy_drop)
    if got != want:
        raise ValueError(f"space {got} != requested problem {want}")
    tables = space.tables()
    gene_sizes = space.gene_sizes
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    pop = _random_genes(k_init, cfg.pop_size, gene_sizes, tables["allowed"])
    pop = _snap_die_gene(pop, tables["die_ok"])

    history: list[float] = []
    for _ in range(cfg.generations):
        key, k_step = jax.random.split(key)
        pop, best_fit, _ = _ga_step(
            k_step, pop, tables, space.node_nm, gene_sizes, cfg.tournament,
            cfg.elitism, cfg.p_crossover, cfg.p_mutate_gene, cfg.fps_penalty,
            cfg.objective)
        history.append(float(best_fit))

    final = evaluate_population(pop, tables, space.node_nm, cfg.fps_penalty,
                                cfg.objective)
    final = {k: np.asarray(v) for k, v in final.items()}
    best_row = np.asarray(pop)[int(np.argmin(final["fitness"]))]
    history.append(float(final["fitness"].min()))

    genome = space.decode(best_row)
    best = gamod.evaluate(genome, workload, node_nm, space.mults, fps_min,
                          gamod.GAConfig(fps_penalty=cfg.fps_penalty,
                                         seed=cfg.seed),
                          ci_fab=space.ci_fab)
    return BatchedGAResult(best=best, best_genome=genome, history=history,
                           population=np.asarray(pop), metrics=final,
                           space=space)


def exhaustive_best(space: DesignSpace, fps_penalty: float = 50.0,
                    max_dies: int | None = None,
                    objective: str = "cdp") -> tuple[gamod.Genome, dict]:
    """Ground truth by brute force: evaluate EVERY genome in the space in
    one batched call (the space is small enough that the batched model
    makes exhaustive search cheaper than the sequential GA's first
    generation).  Returns (argmin genome, its metrics).  `max_dies=1`
    restricts to monolithic designs — the baseline the multi-die
    scenarios are compared against."""
    grids = np.meshgrid(*(np.arange(s) for s in space.gene_sizes),
                        indexing="ij")
    pop = np.stack([g.ravel() for g in grids], axis=1).astype(np.int32)
    if max_dies is not None:
        pop = pop[space.dies[pop[:, DIE_GENE]] <= max_dies]
    met = evaluate_population(jnp.asarray(pop), space.tables(),
                              space.node_nm, fps_penalty, objective)
    met = {k: np.asarray(v) for k, v in met.items()}
    i = int(np.argmin(met["fitness"]))
    return space.decode(pop[i]), {k: v[i] for k, v in met.items()}
