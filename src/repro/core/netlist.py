"""Gate-level netlist model of an 8x8 signed (two's-complement) multiplier.

This is the substrate for the paper's *gate-level pruning* and *precision
scaling* approximation techniques [Balaskas et al., TCAS-I'22 — ref 5 of the
paper]: we build a modified Baugh-Wooley multiplier as an explicit boolean DAG
(AND/NAND partial products + Wallace-tree full/half adders + final ripple
carry), evaluate it exhaustively over all 65,536 input pairs with vectorized
numpy, and approximate it by

  * pruning: replacing any gate's output with its most-probable constant
    (signal-probability-directed pruning, as in [5]) and removing the gate --
    plus transitive dead-gate elimination of its now-unused fanin cone;
  * precision scaling: forcing the k LSBs of either operand to zero, which
    constant-propagates through the array and kills entire partial-product
    rows/columns (a special case of pruning).

Area is accounted in NAND2-equivalent units per gate type and converted to
um^2 with per-technology-node standard-cell constants (7/14/28 nm).

Everything here is plain numpy (no JAX): the netlist engine is a design-time
tool; the JAX/Pallas side consumes its outputs (LUTs + low-rank error factors).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ----------------------------------------------------------------------------
# Gate model
# ----------------------------------------------------------------------------

# op codes
INPUT, CONST0, CONST1, NOT, AND, NAND, OR, NOR, XOR, XNOR = range(10)

OP_NAMES = {
    INPUT: "input", CONST0: "const0", CONST1: "const1", NOT: "not",
    AND: "and", NAND: "nand", OR: "or", NOR: "nor", XOR: "xor", XNOR: "xnor",
}

# Relative cell area in NAND2-equivalents (typical standard-cell library
# ratios; the absolute scale is set per technology node below).
GATE_AREA_NAND2EQ = {
    INPUT: 0.0, CONST0: 0.0, CONST1: 0.0,
    NOT: 0.67, NAND: 1.0, NOR: 1.0, AND: 1.33, OR: 1.33,
    XOR: 2.33, XNOR: 2.33,
}

# Approximate NAND2 cell area (um^2) per technology node.  Public-ballpark
# values (high-density std-cell libraries); only *ratios across nodes* matter
# for the paper's trends, absolute values set the die-area scale.
NAND2_UM2 = {7: 0.063, 14: 0.196, 28: 0.49}


@dataclasses.dataclass(frozen=True)
class Gate:
    op: int
    a: int = -1  # fanin node ids (-1 = unused)
    b: int = -1
    tag: str = ""  # debugging / structure tag, e.g. "pp_3_5", "fa_sum"


class Netlist:
    """A topologically-ordered boolean DAG with 16 primary product outputs."""

    def __init__(self) -> None:
        self.gates: list[Gate] = []
        self.outputs: list[int] = []  # 16 node ids, LSB first
        self.a_inputs: list[int] = []  # 8 node ids for operand a bits
        self.b_inputs: list[int] = []

    # -- construction -------------------------------------------------------
    def add(self, op: int, a: int = -1, b: int = -1, tag: str = "") -> int:
        self.gates.append(Gate(op, a, b, tag))
        return len(self.gates) - 1

    def num_gates(self) -> int:
        return len(self.gates)

    # -- evaluation ---------------------------------------------------------
    def evaluate(
        self,
        a_bits: np.ndarray,  # (8, N) uint8/bool — bit i of operand a
        b_bits: np.ndarray,
        pruned: dict[int, int] | None = None,  # node id -> forced const (0/1)
    ) -> np.ndarray:
        """Vectorized evaluation; returns (16, N) bool output bits."""
        pruned = pruned or {}
        n = a_bits.shape[1]
        vals: list[np.ndarray | None] = [None] * len(self.gates)
        false = np.zeros(n, dtype=bool)
        true = np.ones(n, dtype=bool)
        a_map = {nid: i for i, nid in enumerate(self.a_inputs)}
        b_map = {nid: i for i, nid in enumerate(self.b_inputs)}
        for nid, g in enumerate(self.gates):
            if nid in pruned:
                vals[nid] = true if pruned[nid] else false
                continue
            if g.op == INPUT:
                if nid in a_map:
                    vals[nid] = a_bits[a_map[nid]].astype(bool)
                else:
                    vals[nid] = b_bits[b_map[nid]].astype(bool)
            elif g.op == CONST0:
                vals[nid] = false
            elif g.op == CONST1:
                vals[nid] = true
            elif g.op == NOT:
                vals[nid] = ~vals[g.a]
            elif g.op == AND:
                vals[nid] = vals[g.a] & vals[g.b]
            elif g.op == NAND:
                vals[nid] = ~(vals[g.a] & vals[g.b])
            elif g.op == OR:
                vals[nid] = vals[g.a] | vals[g.b]
            elif g.op == NOR:
                vals[nid] = ~(vals[g.a] | vals[g.b])
            elif g.op == XOR:
                vals[nid] = vals[g.a] ^ vals[g.b]
            elif g.op == XNOR:
                vals[nid] = ~(vals[g.a] ^ vals[g.b])
            else:  # pragma: no cover
                raise ValueError(f"bad op {g.op}")
        return np.stack([vals[o] for o in self.outputs])

    # -- liveness / area ----------------------------------------------------
    def live_gates(self, pruned: dict[int, int] | None = None) -> set[int]:
        """Gates transitively reachable from outputs, not crossing pruned
        nodes (a pruned node is a constant: its fanin cone is dead unless
        reachable some other way)."""
        pruned = pruned or {}
        live: set[int] = set()
        stack = list(self.outputs)
        while stack:
            nid = stack.pop()
            if nid in live:
                continue
            live.add(nid)
            if nid in pruned:
                continue  # constant — do not traverse fanin
            g = self.gates[nid]
            if g.a >= 0:
                stack.append(g.a)
            if g.b >= 0:
                stack.append(g.b)
        return live

    def area_nand2eq(self, pruned: dict[int, int] | None = None) -> float:
        pruned = pruned or {}
        live = self.live_gates(pruned)
        total = 0.0
        for nid in live:
            if nid in pruned:
                continue  # replaced by a wire to vdd/gnd
            total += GATE_AREA_NAND2EQ[self.gates[nid].op]
        return total

    def area_um2(self, node_nm: int, pruned: dict[int, int] | None = None) -> float:
        return self.area_nand2eq(pruned) * NAND2_UM2[node_nm]

    def prunable_gates(self) -> list[int]:
        """Gate ids eligible for pruning: every logic gate (not inputs or
        constants)."""
        return [
            nid for nid, g in enumerate(self.gates)
            if g.op not in (INPUT, CONST0, CONST1)
        ]


# ----------------------------------------------------------------------------
# Adder cells (decomposed to gates, as synthesized netlists would be)
# ----------------------------------------------------------------------------

def _half_adder(nl: Netlist, x: int, y: int, tag: str) -> tuple[int, int]:
    s = nl.add(XOR, x, y, tag + ".s")
    c = nl.add(AND, x, y, tag + ".c")
    return s, c


def _full_adder(nl: Netlist, x: int, y: int, z: int, tag: str) -> tuple[int, int]:
    t = nl.add(XOR, x, y, tag + ".t")
    s = nl.add(XOR, t, z, tag + ".s")
    c1 = nl.add(AND, x, y, tag + ".c1")
    c2 = nl.add(AND, t, z, tag + ".c2")
    c = nl.add(OR, c1, c2, tag + ".c")
    return s, c


# ----------------------------------------------------------------------------
# Modified Baugh-Wooley 8x8 signed multiplier with Wallace reduction
# ----------------------------------------------------------------------------

def build_bw8_multiplier() -> Netlist:
    """8x8 two's-complement multiplier, 16-bit product.

    Modified Baugh-Wooley partial-product matrix for n=8:
      pp(i,j) = a_i AND b_j            for i<7, j<7 and (i,j)=(7,7)
      pp(7,j) = NOT(a_7 AND b_j)       for j<7   (NAND)
      pp(i,7) = NOT(a_i AND b_7)       for i<7   (NAND)
      plus constant 1 at bit 8 and constant 1 at bit 15.
    Reduced with a Wallace tree of the full/half adders above, finished by a
    ripple-carry stage.  Product taken mod 2^16 (exact for int8 x int8).
    """
    nl = Netlist()
    nl.a_inputs = [nl.add(INPUT, tag=f"a{i}") for i in range(8)]
    nl.b_inputs = [nl.add(INPUT, tag=f"b{j}") for j in range(8)]

    cols: list[list[int]] = [[] for _ in range(17)]
    for i in range(8):
        for j in range(8):
            inv = (i == 7) != (j == 7)  # exactly one sign bit -> NAND
            op = NAND if inv else AND
            nid = nl.add(op, nl.a_inputs[i], nl.b_inputs[j], f"pp_{i}_{j}")
            cols[i + j].append(nid)
    cols[8].append(nl.add(CONST1, tag="bw_k8"))
    cols[15].append(nl.add(CONST1, tag="bw_k15"))

    # Wallace reduction to <=2 bits per column.
    rnd = 0
    while any(len(c) > 2 for c in cols[:16]):
        new_cols: list[list[int]] = [[] for _ in range(17)]
        for w in range(16):
            bits = cols[w]
            k = 0
            while len(bits) - k >= 3:
                s, c = _full_adder(nl, bits[k], bits[k + 1], bits[k + 2],
                                   f"w{rnd}.fa{w}.{k}")
                new_cols[w].append(s)
                new_cols[w + 1].append(c)
                k += 3
            if len(bits) - k == 2 and len(bits) > 2:
                s, c = _half_adder(nl, bits[k], bits[k + 1], f"w{rnd}.ha{w}")
                new_cols[w].append(s)
                new_cols[w + 1].append(c)
                k += 2
            new_cols[w].extend(bits[k:])
        cols = new_cols
        rnd += 1

    # Final ripple-carry across the (<=2)-bit columns.
    outputs: list[int] = []
    carry: int | None = None
    for w in range(16):
        bits = list(cols[w])
        if carry is not None:
            bits.append(carry)
        if len(bits) == 0:
            outputs.append(nl.add(CONST0, tag=f"out{w}.z"))
            carry = None
        elif len(bits) == 1:
            outputs.append(bits[0])
            carry = None
        elif len(bits) == 2:
            s, c = _half_adder(nl, bits[0], bits[1], f"rc.ha{w}")
            outputs.append(s)
            carry = c
        else:  # 3
            s, c = _full_adder(nl, bits[0], bits[1], bits[2], f"rc.fa{w}")
            outputs.append(s)
            carry = c
    nl.outputs = outputs
    return nl


# ----------------------------------------------------------------------------
# Exhaustive evaluation -> LUT
# ----------------------------------------------------------------------------

def _all_input_bits() -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """All 65,536 (a, b) int8 pairs as bit arrays.

    Returns (a_bits (8, 65536), b_bits, a_vals (65536,), b_vals)."""
    ua = np.arange(256, dtype=np.uint16)
    aa, bb = np.meshgrid(ua, ua, indexing="ij")
    aa = aa.ravel()
    bb = bb.ravel()
    a_bits = np.stack([(aa >> i) & 1 for i in range(8)]).astype(bool)
    b_bits = np.stack([(bb >> i) & 1 for i in range(8)]).astype(bool)
    a_vals = aa.astype(np.uint8).view(np.int8).astype(np.int32)
    b_vals = bb.astype(np.uint8).view(np.int8).astype(np.int32)
    return a_bits, b_bits, a_vals, b_vals


_INPUT_CACHE: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray] | None = None


def all_input_bits() -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    global _INPUT_CACHE
    if _INPUT_CACHE is None:
        _INPUT_CACHE = _all_input_bits()
    return _INPUT_CACHE


def bits_to_int16(out_bits: np.ndarray) -> np.ndarray:
    """(16, N) bool -> (N,) int32 interpreting two's-complement int16."""
    acc = np.zeros(out_bits.shape[1], dtype=np.uint32)
    for w in range(16):
        acc |= out_bits[w].astype(np.uint32) << w
    return acc.astype(np.uint16).view(np.int16).astype(np.int32)


_PACKED_CACHE: tuple[np.ndarray, np.ndarray] | None = None


def _packed_inputs() -> tuple[np.ndarray, np.ndarray]:
    """Bit-packed (8, 1024)-uint64 input planes for 64x faster evaluation."""
    global _PACKED_CACHE
    if _PACKED_CACHE is None:
        a_bits, b_bits, _, _ = all_input_bits()
        def pack(x: np.ndarray) -> np.ndarray:
            u8 = np.packbits(x, axis=1, bitorder="little")
            return u8.view(np.uint64)
        _PACKED_CACHE = (pack(a_bits), pack(b_bits))
    return _PACKED_CACHE


def evaluate_packed(nl: Netlist, pruned: dict[int, int] | None = None
                    ) -> np.ndarray:
    """Exhaustive evaluation over all 65,536 pairs using uint64 bit-packing.

    Returns (16, 65536) bool output bits; ~20-60x faster than bool arrays.
    """
    pruned = pruned or {}
    a_pk, b_pk = _packed_inputs()
    nwords = a_pk.shape[1]
    zeros = np.zeros(nwords, dtype=np.uint64)
    ones = np.full(nwords, np.uint64(0xFFFFFFFFFFFFFFFF))
    vals: list[np.ndarray | None] = [None] * len(nl.gates)
    a_map = {nid: i for i, nid in enumerate(nl.a_inputs)}
    b_map = {nid: i for i, nid in enumerate(nl.b_inputs)}
    for nid, g in enumerate(nl.gates):
        if nid in pruned:
            vals[nid] = ones if pruned[nid] else zeros
            continue
        op = g.op
        if op == INPUT:
            vals[nid] = a_pk[a_map[nid]] if nid in a_map else b_pk[b_map[nid]]
        elif op == CONST0:
            vals[nid] = zeros
        elif op == CONST1:
            vals[nid] = ones
        elif op == NOT:
            vals[nid] = ~vals[g.a]
        elif op == AND:
            vals[nid] = vals[g.a] & vals[g.b]
        elif op == NAND:
            vals[nid] = ~(vals[g.a] & vals[g.b])
        elif op == OR:
            vals[nid] = vals[g.a] | vals[g.b]
        elif op == NOR:
            vals[nid] = ~(vals[g.a] | vals[g.b])
        elif op == XOR:
            vals[nid] = vals[g.a] ^ vals[g.b]
        else:  # XNOR
            vals[nid] = ~(vals[g.a] ^ vals[g.b])
    out = np.stack([vals[o] for o in nl.outputs])
    u8 = out.view(np.uint8)
    return np.unpackbits(u8, axis=1, bitorder="little").astype(bool)


def netlist_lut(nl: Netlist, pruned: dict[int, int] | None = None) -> np.ndarray:
    """(256, 256) int32 LUT indexed by [a & 0xFF, b & 0xFF]."""
    out = evaluate_packed(nl, pruned)
    return bits_to_int16(out).reshape(256, 256)


def exact_lut() -> np.ndarray:
    """(256, 256) int32 exact signed product LUT, same indexing."""
    _, _, a_vals, b_vals = all_input_bits()
    return (a_vals * b_vals).reshape(256, 256)


def signal_probabilities(nl: Netlist) -> np.ndarray:
    """P(gate output == 1) under uniform inputs, for prune-constant choice."""
    a_bits, b_bits, _, _ = all_input_bits()
    n = a_bits.shape[1]
    vals: list[np.ndarray | None] = [None] * len(nl.gates)
    probs = np.zeros(len(nl.gates))
    false = np.zeros(n, dtype=bool)
    true = np.ones(n, dtype=bool)
    a_map = {nid: i for i, nid in enumerate(nl.a_inputs)}
    b_map = {nid: i for i, nid in enumerate(nl.b_inputs)}
    for nid, g in enumerate(nl.gates):
        if g.op == INPUT:
            vals[nid] = a_bits[a_map[nid]] if nid in a_map else b_bits[b_map[nid]]
            vals[nid] = vals[nid].astype(bool)
        elif g.op == CONST0:
            vals[nid] = false
        elif g.op == CONST1:
            vals[nid] = true
        elif g.op == NOT:
            vals[nid] = ~vals[g.a]
        elif g.op == AND:
            vals[nid] = vals[g.a] & vals[g.b]
        elif g.op == NAND:
            vals[nid] = ~(vals[g.a] & vals[g.b])
        elif g.op == OR:
            vals[nid] = vals[g.a] | vals[g.b]
        elif g.op == NOR:
            vals[nid] = ~(vals[g.a] | vals[g.b])
        elif g.op == XOR:
            vals[nid] = vals[g.a] ^ vals[g.b]
        elif g.op == XNOR:
            vals[nid] = ~(vals[g.a] ^ vals[g.b])
        probs[nid] = float(np.mean(vals[nid]))
    return probs


def truncation_pruning(nl: Netlist, trunc_a: int, trunc_b: int) -> dict[int, int]:
    """Precision scaling as input forcing: k LSBs of each operand -> 0."""
    pruned: dict[int, int] = {}
    for i in range(min(trunc_a, 8)):
        pruned[nl.a_inputs[i]] = 0
    for j in range(min(trunc_b, 8)):
        pruned[nl.b_inputs[j]] = 0
    return pruned


def constant_propagate(nl: Netlist, pruned: dict[int, int]) -> dict[int, int]:
    """Extend a pruning assignment with every gate whose output becomes
    constant under it (so dead-gate elimination credits the full savings of
    e.g. truncated partial-product rows)."""
    const: dict[int, int] = dict(pruned)
    for nid, g in enumerate(nl.gates):
        if nid in const:
            continue
        if g.op == CONST0:
            const[nid] = 0
        elif g.op == CONST1:
            const[nid] = 1
        elif g.op == NOT and g.a in const:
            const[nid] = 1 - const[g.a]
        elif g.op in (AND, NAND):
            ca, cb = const.get(g.a), const.get(g.b)
            if ca == 0 or cb == 0:
                const[nid] = 1 if g.op == NAND else 0
            elif ca == 1 and cb == 1:
                const[nid] = 0 if g.op == NAND else 1
        elif g.op in (OR, NOR):
            ca, cb = const.get(g.a), const.get(g.b)
            if ca == 1 or cb == 1:
                const[nid] = 0 if g.op == NOR else 1
            elif ca == 0 and cb == 0:
                const[nid] = 1 if g.op == NOR else 0
        elif g.op in (XOR, XNOR):
            ca, cb = const.get(g.a), const.get(g.b)
            if ca is not None and cb is not None:
                v = ca ^ cb
                const[nid] = (1 - v) if g.op == XNOR else v
    # Only keep entries that are *constants*; inputs forced by caller stay.
    return const


def self_check() -> None:
    """Assert the exact netlist reproduces int8 x int8 for all pairs."""
    nl = build_bw8_multiplier()
    lut = netlist_lut(nl)
    if not np.array_equal(lut, exact_lut()):
        bad = np.argwhere(lut != exact_lut())
        raise AssertionError(
            f"BW8 netlist mismatch at {len(bad)} entries, first {bad[:4]}")


_BW8_CACHE: Netlist | None = None


def bw8() -> Netlist:
    """Cached exact 8x8 Baugh-Wooley netlist (verified on first build)."""
    global _BW8_CACHE
    if _BW8_CACHE is None:
        nl = build_bw8_multiplier()
        _BW8_CACHE = nl
    return _BW8_CACHE
