"""Measured (not analytical) delay for the CDP objective.

The analytical dataflow model (`core/dataflow.py`) predicts *relative*
performance across accelerator configs well — that is what the paper's
claims rest on — but its absolute time scale is a stack of optimistic
assumptions (perfect double buffering, no host overhead).  This module
anchors that scale to a real measurement: it runs the repo's own fast
path — the `repro.serving` continuous-batching engine, or the fused
approximate-GEMM kernel that `benchmarks/bench_gemm.py` times — in smoke
mode, and returns a `DelayCalibration` whose `scale` maps analytical
throughput onto measured throughput.

Scenario sweeps (`core/codesign.py`) then report CDP twice: the paper's
analytical figure, and the serving-calibrated figure
`carbon / (fps * scale)` in which a design's delay is what the measured
software stack would actually deliver.  Everything downstream stays a
pure array program: a calibration is one scalar multiplier on the FPS
lattice, so the population-parallel GA consumes it for free.

All imports of the serving/kernel stack are lazy: `core` stays light for
consumers that only want the carbon/GA models.
"""

from __future__ import annotations

import dataclasses
import time

from . import accelerator as accmod
from . import carbon as carbonmod
from . import dataflow as dfmod
from . import workloads as wl


@dataclasses.dataclass(frozen=True)
class DelayCalibration:
    """`measured / analytical` throughput for the same work.

    `analytical` is the dataflow model's prediction for the anchor
    accelerator running a layer-level mirror of the measured workload, so
    `scale` carries exactly one piece of information: how the modeled
    absolute time scale relates to a real end-to-end measurement."""
    measured: float           # measured throughput [unit]
    analytical: float         # model-predicted throughput [unit]
    unit: str                 # "tokens/s" | "macs/s"
    source: str               # "serving" | "gemm" | "identity"
    anchor: str               # anchor accelerator description
    meta: dict

    @property
    def scale(self) -> float:
        return self.measured / max(self.analytical, 1e-12)

    def calibrated_fps(self, fps: float) -> float:
        return fps * self.scale

    def calibrated_cdp(self, carbon_g: float, fps: float) -> float:
        return carbonmod.cdp(carbon_g, self.calibrated_fps(fps))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["scale"] = self.scale
        return d


def identity() -> DelayCalibration:
    """No-op calibration (scale 1): calibrated CDP == analytical CDP."""
    return DelayCalibration(1.0, 1.0, "", "identity", "", {})


def _anchor_config(node_nm: int) -> accmod.AcceleratorConfig:
    """The calibration anchor: the full-size exact NVDLA default."""
    return accmod.nvdla_default(2048, node_nm)


def calibrate_serving(arch: str = "tinyllama-1.1b", *, requests: int = 3,
                      capacity: int = 2, max_len: int = 48, prompt: int = 8,
                      gen: int = 4, node_nm: int = 7, mult: str = "",
                      kernel_policy: str = "", seed: int = 0,
                      mesh_spec: str = "", n_dies: int | None = None,
                      target=None) -> DelayCalibration:
    """Measure the decode-step rate by serving a tiny deterministic trace
    through `repro.serving.Engine` (reduced config), and anchor it against
    the dataflow model's decode-step prediction built from the SAME model
    dimensions (`workloads.decode_block_gemms`).

    Measured throughput is steps/s, i.e. SINGLE-STREAM tokens/s: one
    engine step advances every occupied slot, so dividing emitted tokens
    by wall time would fold the arena's batch concurrency into the scale
    (capacity would silently 'improve' calibrated CDP).  The per-step
    rate is the quantity the analytical single decode step predicts; the
    batched-throughput figure is recorded in `meta` for reference.

    `mesh_spec` (e.g. ``"model=4"``) serves the trace tensor-parallel:
    the measured side runs the engine on that device mesh, and the
    analytical mirror runs the SAME partitioning — `n_dies` = the mesh's
    model-axis size — through the multi-die dataflow model (per-die
    K-split + D2D all-gather), so a multi-die target's calibrated delay
    is anchored by a measurement that actually communicates.  Passing a
    `core.target.HardwareTarget` instead derives both (one die == one TP
    shard, by construction)."""
    from repro import configs
    from repro.serving import Engine, Request, SamplingParams

    cfg = configs.apply_overrides(configs.get_config(arch), reduced=True,
                                  mult=mult, kernel_policy=kernel_policy)
    mesh = None
    if target is not None:
        if mesh_spec or n_dies is not None:
            raise ValueError("pass either target= or mesh_spec/n_dies, "
                             "not both")
        mesh = target.make_mesh()
        mesh_spec = target.mesh_spec()
        n_dies = target.n_dies
    elif mesh_spec:
        from repro.launch import mesh as meshmod
        mesh = meshmod.make_mesh_from_spec(mesh_spec)
        if n_dies is None:
            n_dies = int(mesh.shape.get("model", 1))
    n_dies = n_dies or 1
    eng = Engine(cfg, capacity=capacity, max_len=max_len, seed=seed,
                 mesh=mesh)
    # warm the jitted phases so the measurement is steady-state decode
    eng.submit(Request("_warmup", [1] * prompt,
                       SamplingParams(max_new_tokens=2)))
    eng.run_until_complete()
    base = eng.stats()
    for i in range(requests):
        eng.submit(Request(f"cal{i}", [(7 * i + j) % (cfg.vocab - 1) + 1
                                       for j in range(prompt)],
                           SamplingParams(max_new_tokens=gen)))
    done = [c for c in eng.run_until_complete() if c.request_id != "_warmup"]
    stats = eng.stats()
    decode_s = stats["decode_s"] - base["decode_s"]
    decode_steps = stats["decode_steps"] - base["decode_steps"]
    decode_toks = sum(max(len(c.tokens) - 1, 0) for c in done)
    measured = decode_steps / max(decode_s, 1e-9)

    # analytical mirror: one decode step of this model at mid-trace cache
    # length, on the anchor accelerator under the SAME die partitioning
    head_dim = cfg.head_dim or cfg.d_model // cfg.n_heads
    kv_len = prompt + max(gen // 2, 1)
    layers: list[wl.Layer] = []
    for i in range(cfg.n_layers):
        layers += wl.decode_block_gemms(
            f"cal.l{i}", cfg.n_heads * head_dim, cfg.d_ff, cfg.n_heads,
            max(cfg.n_kv_heads, 1), kv_len)
    anchor = _anchor_config(node_nm)
    analytical = dfmod.layers_perf(layers, anchor, n_dies).fps

    return DelayCalibration(
        measured=measured, analytical=analytical, unit="tokens/s",
        source="serving",
        anchor=f"nvdla_default(2048, {node_nm}nm) x {n_dies} dies",
        meta={"arch": cfg.name, "family": cfg.family, "requests": requests,
              "prompt": prompt, "gen": gen, "kv_len": kv_len,
              "mesh_spec": mesh_spec, "n_dies": n_dies,
              "decode_s": decode_s, "decode_steps": decode_steps,
              "decode_tokens": decode_toks,
              "batched_tokens_per_s": decode_toks / max(decode_s, 1e-9),
              "engine": {k: v for k, v in stats.items()
                         if isinstance(v, (int, float))}})


def calibrate_gemm(m: int = 128, k: int = 160, n: int = 128, *,
                   mult_name: str = "trunc2x2", reps: int = 3,
                   node_nm: int = 7, seed: int = 0,
                   policy: str | None = None) -> DelayCalibration:
    """Measure effective MAC/s of the approximate-GEMM data path (the
    kernels `benchmarks/bench_gemm.py` times, same smoke shape) and anchor
    it against the dataflow model's prediction for a single GEMM layer of
    the same shape.

    The measured side runs whatever `kernels/dispatch.choose_gemm_path`
    would actually pick for this GEMM — tuned tiles from the autotune
    cache when one exists, the roofline prediction otherwise — so the GA's
    delay anchor reflects the dispatched reality, not one hard-coded
    kernel.  The chosen plan is recorded in `meta["dispatch"]`."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.approx import gemm as G
    from repro.core import multipliers as mm
    from repro.kernels import dispatch, ops

    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    spec = G.from_multiplier(mm.get_multiplier(mult_name))
    rank = spec.rank if spec.mode == "lowrank" else 0
    plan = dispatch.choose_gemm_path(policy or spec.policy, m=m, k=k, n=n,
                                     mode=spec.mode, rank=rank,
                                     n_planes=spec.n_planes)
    if plan.use_pallas:
        fn = jax.jit(lambda x, y: ops.approx_qgemm_planned(x, y, spec, plan))
    else:
        fn = jax.jit(lambda x, y: G.approx_qgemm(x, y, spec))
    jax.block_until_ready(fn(a, b))  # compile
    samples = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(a, b))
        samples.append(time.perf_counter() - t0)
    samples.sort()
    h = len(samples) // 2
    sec = samples[h] if len(samples) % 2 else \
        0.5 * (samples[h - 1] + samples[h])
    measured = m * k * n / max(sec, 1e-12)

    anchor = _anchor_config(node_nm)
    layer = wl.GemmLayer("cal.gemm", m, n, k)
    analytical = dfmod.layers_perf([layer], anchor).fps * layer.macs

    return DelayCalibration(
        measured=measured, analytical=analytical, unit="macs/s",
        source="gemm", anchor=f"nvdla_default(2048, {node_nm}nm)",
        meta={"shape": {"m": m, "k": k, "n": n}, "mult": mult_name,
              "reps": reps, "us_per_call": sec * 1e6,
              "dispatch": plan.as_dict(),
              "backend": jax.default_backend()})


def get_calibration(source: str, node_nm: int = 7,
                    **kwargs) -> DelayCalibration:
    """Dispatch by name — the CLI surface used by bench_codesign."""
    if source in ("", "none", "identity"):
        return identity()
    if source == "serving":
        return calibrate_serving(node_nm=node_nm, **kwargs)
    if source == "gemm":
        return calibrate_gemm(node_nm=node_nm, **kwargs)
    raise ValueError(f"unknown calibration source {source!r}")
