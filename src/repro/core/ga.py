"""Genetic algorithm over (accelerator config x approximate multiplier) with
Carbon-Delay-Product fitness under FPS and accuracy-drop constraints.

This is the paper's step 2: "a genetic algorithm, with CDP metric as fitness
function, to select the Pareto-optimal approximate multipliers from step one
and identify the most efficient topology ... constrained by thresholds for
accuracy drop and performance".
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from . import accelerator as accmod
from . import carbon as carbonmod
from . import dataflow as dfmod
from . import multipliers as mm

# --- accuracy-drop model -----------------------------------------------------
# Default proxy mapping multiplier error statistics -> top-1 accuracy drop
# (percent) for int8-quantized CNNs.  Coefficients calibrated against the
# framework's own ApproxTrain-style evaluation (examples/codesign_vgg16.py
# trains a small CNN and measures real drops; see EXPERIMENTS.md).  The GA
# accepts any callable so the calibrated evaluator can be plugged in.

ACC_DROP_NMED_COEF = 55.0   # %drop per unit NMED
ACC_DROP_MRED_COEF = 4.0    # %drop per unit MRED


def proxy_accuracy_drop(mult: mm.ApproxMultiplier) -> float:
    return (ACC_DROP_NMED_COEF * mult.stats.nmed
            + ACC_DROP_MRED_COEF * mult.stats.mred) * 1.0


AccuracyFn = Callable[[mm.ApproxMultiplier], float]

# --- design space ------------------------------------------------------------

RF_CHOICES = (32, 64, 128)
GLB_KIB_CHOICES = (64, 128, 256, 512, 1024)
ASPECTS = ("square", "wide", "tall")
#: Dies per package: the genome's partitioning gene.  >1 splits the PE
#: array's output-channel columns across identical dies (per-die Murphy
#: yield + one DRAM channel per die, at a packaging-carbon and D2D-delay
#: cost — core/carbon.py, core/dataflow.py).
DIE_CHOICES = (1, 2, 4)


def _pe_split(num_pes: int, aspect: str) -> tuple[int, int]:
    rows = 1
    while rows * rows < num_pes:
        rows *= 2
    cols = num_pes // rows
    if aspect == "wide":
        rows, cols = max(rows // 2, 1), cols * 2
    elif aspect == "tall":
        rows, cols = rows * 2, max(cols // 2, 1)
    return rows, cols


def die_feasible(pe_cols: int, num_pes: int, n_dies: int) -> bool:
    """An n-die split must cut the output-channel columns evenly and leave
    each die a full design-space array (>= smallest VALID_PE_COUNTS)."""
    return (n_dies == 1 or
            (pe_cols % n_dies == 0 and
             num_pes // n_dies >= accmod.VALID_PE_COUNTS[0]))


@dataclasses.dataclass(frozen=True)
class Genome:
    pe_idx: int
    aspect_idx: int
    rf_idx: int
    glb_idx: int
    mult_idx: int
    die_idx: int = 0

    @property
    def n_dies(self) -> int:
        return DIE_CHOICES[self.die_idx]

    def to_config(self, mults: Sequence[mm.ApproxMultiplier], node_nm: int
                  ) -> accmod.AcceleratorConfig:
        """FULL-array config (all dies cooperating); `glb_kib` is per-die."""
        pes = accmod.VALID_PE_COUNTS[self.pe_idx]
        rows, cols = _pe_split(pes, ASPECTS[self.aspect_idx])
        return accmod.AcceleratorConfig(
            pe_rows=rows, pe_cols=cols,
            rf_bytes_per_pe=RF_CHOICES[self.rf_idx],
            glb_kib=GLB_KIB_CHOICES[self.glb_idx],
            multiplier=mults[self.mult_idx].name,
            node_nm=node_nm)

    def to_target(self, mults: Sequence[mm.ApproxMultiplier], node_nm: int):
        """Decode into a `HardwareTarget` (per-die config + serving mesh
        with the model axis = die count)."""
        from . import target as targetmod
        full = self.to_config(mults, node_nm)
        n = self.n_dies
        if not die_feasible(full.pe_cols, full.num_pes, n):
            raise ValueError(f"genome {self} is not an even die split")
        die = dataclasses.replace(full, pe_cols=full.pe_cols // n)
        return targetmod.HardwareTarget(
            die=die, n_dies=n, mesh_axes=(("data", 1), ("model", n)))


@dataclasses.dataclass
class GAConfig:
    pop_size: int = 24
    generations: int = 14
    tournament: int = 3
    p_crossover: float = 0.7
    p_mutate_gene: float = 0.25
    seed: int = 0
    fps_penalty: float = 50.0


@dataclasses.dataclass(frozen=True)
class Evaluated:
    genome: Genome
    config: accmod.AcceleratorConfig   # full array; glb_kib is per-die
    fps: float
    carbon_g: float                    # package total (dies + packaging)
    cdp: float
    fitness: float
    area_mm2: float                    # total patterned silicon, all dies
    n_dies: int = 1
    die_area_mm2: float = 0.0
    die_yield: float = 1.0
    packaging_g: float = 0.0


@dataclasses.dataclass
class GAResult:
    best: Evaluated
    history: list[float]            # best fitness per generation
    population: list[Evaluated]
    mults: list[mm.ApproxMultiplier]


def _register(mults: Sequence[mm.ApproxMultiplier]) -> None:
    """Make GA multipliers resolvable by name for the area model."""
    lib = mm.static_library()
    for m in mults:
        lib.setdefault(m.name, m)


def evaluate(genome: Genome, workload: str, node_nm: int,
             mults: Sequence[mm.ApproxMultiplier], fps_min: float,
             cfg: GAConfig, ci_fab: float | None = None) -> Evaluated:
    acfg = genome.to_config(mults, node_nm)
    n_dies = genome.n_dies
    perf = dfmod.workload_perf(workload, acfg, n_dies)
    die_area = accmod.die_area_mm2(acfg, n_dies)
    cb = carbonmod.multi_die_carbon(die_area, n_dies, node_nm, ci_fab)
    cdp = carbonmod.cdp(cb.total_g, perf.fps)
    # Fitness uses fps CAPPED at the threshold: the paper's premise is that
    # edge applications need fps_min and nothing more ("accelerators are
    # often overdesigned, providing more performance than necessary") — so
    # speed beyond the requirement must not buy carbon headroom.
    eff_fps = min(perf.fps, fps_min) if fps_min > 0 else perf.fps
    fitness = carbonmod.cdp(cb.total_g, eff_fps)
    if perf.fps < fps_min:
        deficit = (fps_min - perf.fps) / fps_min
        fitness = fitness * (1.0 + cfg.fps_penalty * deficit *
                             (1.0 + deficit))
    # uneven die splits never score (mirrors the batched engine's
    # die-feasibility mask); metrics stay reportable for parity checks
    if not die_feasible(acfg.pe_cols, acfg.num_pes, n_dies):
        fitness = float("inf")
    return Evaluated(genome, acfg, perf.fps, cb.total_g, cdp, fitness,
                     n_dies * die_area, n_dies=n_dies,
                     die_area_mm2=die_area, die_yield=cb.die_yield,
                     packaging_g=cb.packaging_g)


def run_ga(workload: str, node_nm: int, fps_min: float,
           max_accuracy_drop: float,
           mults: Sequence[mm.ApproxMultiplier] | None = None,
           accuracy_fn: AccuracyFn = proxy_accuracy_drop,
           cfg: GAConfig | None = None,
           ci_fab: float | None = None) -> GAResult:
    """CDP-minimizing GA.  Multipliers violating the accuracy constraint are
    excluded up front (constraint satisfaction by construction).

    This sequential numpy loop is the PARITY REFERENCE TWIN of the
    population-parallel engine in `core/ga_batched.py`: both must select
    the same best-CDP design at a fixed seed (tests/test_ga_batched.py;
    `benchmarks/bench_codesign.py` records the check in
    BENCH_codesign.json)."""
    cfg = cfg or GAConfig()
    rng = np.random.default_rng(cfg.seed)
    if mults is None:
        from . import pareto
        mults = pareto.default_front()
    allowed = [m for m in mults if accuracy_fn(m) <= max_accuracy_drop]
    if not any(m.is_exact for m in allowed):
        allowed = [mm.exact_multiplier()] + list(allowed)
    _register(allowed)

    n_pe = len(accmod.VALID_PE_COUNTS)

    def random_genome() -> Genome:
        return Genome(
            int(rng.integers(0, n_pe)), int(rng.integers(0, len(ASPECTS))),
            int(rng.integers(0, len(RF_CHOICES))),
            int(rng.integers(0, len(GLB_KIB_CHOICES))),
            int(rng.integers(0, len(allowed))),
            int(rng.integers(0, len(DIE_CHOICES))))

    def ev(g: Genome) -> Evaluated:
        return evaluate(g, workload, node_nm, allowed, fps_min, cfg, ci_fab)

    pop = [ev(random_genome()) for _ in range(cfg.pop_size)]
    history: list[float] = []
    genes = ("pe_idx", "aspect_idx", "rf_idx", "glb_idx", "mult_idx",
             "die_idx")
    ranges = (n_pe, len(ASPECTS), len(RF_CHOICES), len(GLB_KIB_CHOICES),
              len(allowed), len(DIE_CHOICES))

    for _gen in range(cfg.generations):
        pop.sort(key=lambda e: e.fitness)
        history.append(pop[0].fitness)
        next_pop = pop[:2]  # elitism
        while len(next_pop) < cfg.pop_size:
            def pick() -> Evaluated:
                idx = rng.integers(0, len(pop), size=cfg.tournament)
                return min((pop[i] for i in idx), key=lambda e: e.fitness)
            p1, p2 = pick(), pick()
            vals = {}
            for gname in genes:
                src = p1 if (rng.random() < 0.5 or
                             rng.random() >= cfg.p_crossover) else p2
                vals[gname] = getattr(src.genome, gname)
            for gname, rng_n in zip(genes, ranges):
                if rng.random() < cfg.p_mutate_gene:
                    vals[gname] = int(rng.integers(0, rng_n))
            next_pop.append(ev(Genome(**vals)))
        pop = next_pop

    pop.sort(key=lambda e: e.fitness)
    history.append(pop[0].fitness)
    return GAResult(best=pop[0], history=history, population=pop,
                    mults=list(allowed))


def exact_baseline(workload: str, node_nm: int, fps_min: float,
                   ci_fab: float | None = None) -> Evaluated:
    """Smallest-carbon *exact* NVDLA-default config meeting the FPS bound
    (the paper's 'exact baseline meeting a 30 FPS threshold')."""
    best: Evaluated | None = None
    for pe_idx in range(len(accmod.VALID_PE_COUNTS)):
        # NVDLA default buffers for this PE count (the genome record is
        # descriptive only — the config does not come from genome decode,
        # so no GA evaluate() call belongs here):
        acfg = accmod.nvdla_default(accmod.VALID_PE_COUNTS[pe_idx], node_nm)
        perf = dfmod.workload_perf(workload, acfg)
        area = accmod.area_model(acfg)
        cb = carbonmod.embodied_carbon(area.total_mm2, node_nm, ci_fab)
        e = Evaluated(Genome(pe_idx, 0, 0, 2, 0), acfg, perf.fps, cb.total_g,
                      carbonmod.cdp(cb.total_g, perf.fps),
                      carbonmod.cdp(cb.total_g, perf.fps), area.total_mm2)
        if perf.fps >= fps_min and (best is None or e.carbon_g < best.carbon_g):
            best = e
    if best is None:  # nothing meets the bound: return the fastest
        acfg = accmod.nvdla_default(accmod.VALID_PE_COUNTS[-1], node_nm)
        perf = dfmod.workload_perf(workload, acfg)
        area = accmod.area_model(acfg)
        cb = carbonmod.embodied_carbon(area.total_mm2, node_nm, ci_fab)
        best = Evaluated(Genome(len(accmod.VALID_PE_COUNTS) - 1, 0, 0, 2, 0),
                         acfg, perf.fps, cb.total_g,
                         carbonmod.cdp(cb.total_g, perf.fps),
                         carbonmod.cdp(cb.total_g, perf.fps), area.total_mm2)
    return best


def approx_variant(base: accmod.AcceleratorConfig, mult: mm.ApproxMultiplier
                   ) -> Evaluated:
    """Same architecture, approximate multiplier swapped in (paper's
    'incorporating approximate units only, keeping the architecture
    unchanged')."""
    _register([mult])
    acfg = dataclasses.replace(base, multiplier=mult.name)
    # workload-independent carbon; FPS unchanged (same array/freq)
    area = accmod.area_model(acfg)
    cb = carbonmod.embodied_carbon(area.total_mm2, acfg.node_nm)
    return Evaluated(Genome(0, 0, 0, 0, 0), acfg, float("nan"), cb.total_g,
                     float("nan"), float("nan"), area.total_mm2)
