"""Approximate-multiplier abstraction: netlist config -> LUT + area + errors.

An `ApproxMultiplier` bundles everything downstream layers need:
  * its 256x256 product LUT (the ApproxTrain-style behavioral model),
  * its silicon area (live-gate NAND2-equivalents -> um^2 per node),
  * error statistics, and
  * the low-rank error factorization used by the TPU GEMM path.

The paper's two approximation knobs map to:
  * precision scaling  -> `truncated(ta, tb)`
  * gate-level pruning -> `pruned(mask)` over the prunable-gate list, with
    signal-probability-directed constants and dead-gate elimination.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import lut as lutmod
from . import netlist as nlmod


@dataclasses.dataclass(frozen=True)
class ApproxMultiplier:
    name: str
    lut: np.ndarray                      # (256,256) int32, [a&0xFF, b&0xFF]
    area_nand2eq: float
    stats: lutmod.ErrorStats
    trunc_a: int = 0
    trunc_b: int = 0
    pruned_gates: tuple[int, ...] = ()   # gate ids pruned (for provenance)

    def area_um2(self, node_nm: int) -> float:
        return self.area_nand2eq * nlmod.NAND2_UM2[node_nm]

    @property
    def is_exact(self) -> bool:
        return self.stats.wce == 0

    @functools.cached_property
    def lowrank(self) -> lutmod.LowRankError:
        return lutmod.choose_rank(self.lut, tol_nmed=1e-4, max_rank=8)

    def area_savings_vs_exact(self) -> float:
        return 1.0 - self.area_nand2eq / exact_multiplier().area_nand2eq


def _mk(name: str, pruned: dict[int, int], trunc_a: int = 0, trunc_b: int = 0,
        pruned_gates: tuple[int, ...] = ()) -> ApproxMultiplier:
    nl = nlmod.bw8()
    full = nlmod.constant_propagate(nl, pruned) if pruned else {}
    lut = nlmod.netlist_lut(nl, full)
    return ApproxMultiplier(
        name=name,
        lut=lut,
        area_nand2eq=nl.area_nand2eq(full),
        stats=lutmod.error_stats(lut),
        trunc_a=trunc_a, trunc_b=trunc_b, pruned_gates=pruned_gates,
    )


@functools.lru_cache(maxsize=1)
def exact_multiplier() -> ApproxMultiplier:
    m = _mk("exact", {})
    assert m.stats.wce == 0, "exact netlist must be exact"
    return m


@functools.lru_cache(maxsize=64)
def truncated(trunc_a: int, trunc_b: int) -> ApproxMultiplier:
    """Precision-scaled multiplier: k LSBs of each operand forced to zero."""
    nl = nlmod.bw8()
    pr = nlmod.truncation_pruning(nl, trunc_a, trunc_b)
    return _mk(f"trunc{trunc_a}x{trunc_b}", pr, trunc_a, trunc_b)


def pruned(mask: np.ndarray, name: str = "", trunc_a: int = 0, trunc_b: int = 0
           ) -> ApproxMultiplier:
    """Gate-level pruning: mask is a bool vector over `prunable_gates()`.

    Pruned gates output their most-probable constant (signal probability,
    as in [5]); optional operand truncation composes on top.
    """
    nl = nlmod.bw8()
    prunable = nl.prunable_gates()
    probs = _signal_probs()
    assert mask.shape == (len(prunable),)
    pr: dict[int, int] = {}
    chosen: list[int] = []
    for k, bit in enumerate(mask):
        if bit:
            gid = prunable[k]
            pr[gid] = int(probs[gid] >= 0.5)
            chosen.append(gid)
    pr.update(nlmod.truncation_pruning(nl, trunc_a, trunc_b))
    return _mk(name or f"pruned[{len(chosen)}g,t{trunc_a}{trunc_b}]", pr,
               trunc_a, trunc_b, tuple(chosen))


@functools.lru_cache(maxsize=1)
def _signal_probs() -> np.ndarray:
    return nlmod.signal_probabilities(nlmod.bw8())


# ---------------------------------------------------------------------------
# Library: the named multipliers the rest of the framework refers to.
# The "appx_*" entries come from the NSGA-II Pareto front (see pareto.py /
# codesign.py); the static entries below are always available and cheap.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def static_library() -> dict[str, ApproxMultiplier]:
    lib = {"exact": exact_multiplier()}
    for t in (1, 2, 3, 4):
        m = truncated(t, t)
        lib[m.name] = m
    for ta, tb in ((2, 0), (0, 2), (3, 1)):
        m = truncated(ta, tb)
        lib[m.name] = m
    return lib


def get_multiplier(name: str) -> ApproxMultiplier:
    lib = static_library()
    if name in lib:
        return lib[name]
    # Lazily extend with Pareto-searched multipliers by convention
    # "pareto:<nmed_band>" e.g. "pareto:0.005".
    if name.startswith("pareto:"):
        from . import pareto as paretomod
        band = float(name.split(":", 1)[1])
        front = paretomod.default_front()
        m = paretomod.pick_by_nmed(front, band)
        return m
    raise KeyError(f"unknown multiplier {name!r}; have {sorted(lib)}")
