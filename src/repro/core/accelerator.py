"""NVDLA-style accelerator configuration and silicon area model.

The design space follows the paper's evaluation setup: MAC arrays from 64 to
2048 PEs in powers of two, with local (per-PE accumulator/register-file) and
global (convolution buffer) SRAM scaling with the array, as in the NVDLA
primer.  Area is composed from:

  * MAC datapath: the (possibly approximate) 8x8 multiplier netlist area +
    a 32-bit accumulator adder + pipeline registers (NAND2-equivalents),
  * SRAM macros (um^2/bit per node, incl. periphery),
  * a fixed-fraction NoC/control/IO overhead.

The multiplier area is the *paper's lever*: swapping the exact multiplier for
a pruned/truncated one shrinks every MAC, which shrinks the die, which
shrinks embodied carbon (and frees area for memory at iso-carbon).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from . import multipliers as mm
from . import netlist as nlmod

# Non-multiplier MAC datapath cost, NAND2-equivalents:
# 32-bit accumulator adder (~32 full adders @ ~9.65) + 16-bit operand /
# pipeline registers (~24 flops @ 4.5) + mux/control (~40).
MAC_OVERHEAD_NAND2EQ = 32 * 9.65 + 24 * 4.5 + 40.0

# SRAM area per *bit*, including periphery [um^2/bit] (public ballpark:
# high-density 6T bitcell x ~1.6 periphery factor).
SRAM_UM2_PER_BIT = {7: 0.045, 14: 0.11, 28: 0.30}

# NoC + control + IO + PLL overhead as a fraction of (MAC + SRAM) area.
OVERHEAD_FRACTION = 0.18

VALID_PE_COUNTS = (64, 128, 256, 512, 1024, 2048)


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """A point in the paper's design space."""
    pe_rows: int            # input-channel parallelism (NVDLA Atomic-C)
    pe_cols: int            # output-channel parallelism (NVDLA Atomic-K)
    rf_bytes_per_pe: int    # per-PE accumulator/register file
    glb_kib: int            # global convolution buffer (CBUF)
    multiplier: str         # name in the multiplier library / Pareto front
    node_nm: int
    dram_gbps: float = 19.2  # LPDDR4x-class edge memory system

    @property
    def num_pes(self) -> int:
        return self.pe_rows * self.pe_cols

    def validate(self) -> None:
        if self.num_pes not in VALID_PE_COUNTS:
            raise ValueError(f"PE count {self.num_pes} not in {VALID_PE_COUNTS}")
        if self.node_nm not in SRAM_UM2_PER_BIT:
            raise ValueError(f"node {self.node_nm}nm unsupported")


def nvdla_default(num_pes: int, node_nm: int, multiplier: str = "exact"
                  ) -> AcceleratorConfig:
    """NVDLA-primer-style scaling: CBUF and RF scale with the MAC array
    (full NVDLA: 2048 MACs / 512 KiB CBUF -> 256 B per MAC)."""
    rows = 1
    while rows * rows < num_pes:
        rows *= 2
    cols = num_pes // rows
    return AcceleratorConfig(
        pe_rows=rows, pe_cols=cols,
        rf_bytes_per_pe=32,
        glb_kib=max(64, (num_pes * 256) // 1024),
        multiplier=multiplier, node_nm=node_nm)


@dataclasses.dataclass(frozen=True)
class AreaBreakdown:
    mult_mm2: float
    mac_other_mm2: float
    rf_mm2: float
    glb_mm2: float
    overhead_mm2: float
    total_mm2: float

    @property
    def mult_fraction(self) -> float:
        return self.mult_mm2 / self.total_mm2


def _area_components_um2(num_pes: float, rf_bytes_per_pe: float,
                         glb_kib: float, mult_area_nand2eq: float,
                         node_nm: int) -> tuple[float, float, float, float]:
    """(mult, mac_other, rf, glb) [um^2] — the ONE scalar source of the
    area formula (area_total_mm2_arr is its jnp twin)."""
    nand2_um2 = nlmod.NAND2_UM2[node_nm]
    sram_um2_bit = SRAM_UM2_PER_BIT[node_nm]
    return (mult_area_nand2eq * nand2_um2 * num_pes,
            MAC_OVERHEAD_NAND2EQ * nand2_um2 * num_pes,
            rf_bytes_per_pe * 8 * sram_um2_bit * num_pes,
            glb_kib * 1024 * 8 * sram_um2_bit)


def area_model(cfg: AcceleratorConfig) -> AreaBreakdown:
    cfg.validate()
    mult = mm.get_multiplier(cfg.multiplier)
    mult_um2, mac_other_um2, rf_um2, glb_um2 = _area_components_um2(
        cfg.num_pes, cfg.rf_bytes_per_pe, cfg.glb_kib, mult.area_nand2eq,
        cfg.node_nm)
    core = mult_um2 + mac_other_um2 + rf_um2 + glb_um2
    overhead_um2 = OVERHEAD_FRACTION * core
    to_mm2 = 1e-6
    return AreaBreakdown(
        mult_mm2=mult_um2 * to_mm2,
        mac_other_mm2=mac_other_um2 * to_mm2,
        rf_mm2=rf_um2 * to_mm2,
        glb_mm2=glb_um2 * to_mm2,
        overhead_mm2=overhead_um2 * to_mm2,
        total_mm2=(core + overhead_um2) * to_mm2,
    )


def die_area_mm2(cfg: AcceleratorConfig, n_dies: int = 1) -> float:
    """Area of ONE die of an `n_dies`-way split of `cfg`: num_pes/n MACs
    plus the per-die buffers (`cfg.rf_bytes_per_pe` per PE, `cfg.glb_kib`
    per die).  `n_dies == 1` equals `area_model(cfg).total_mm2` exactly.
    Unvalidated on purpose — the GA scores infeasible die splits (to mask
    them) where num_pes/n falls outside VALID_PE_COUNTS."""
    mult = mm.get_multiplier(cfg.multiplier)
    core = sum(_area_components_um2(
        cfg.num_pes / n_dies, cfg.rf_bytes_per_pe, cfg.glb_kib,
        mult.area_nand2eq, cfg.node_nm))
    return core * (1.0 + OVERHEAD_FRACTION) * 1e-6


def area_total_mm2_arr(num_pes: jnp.ndarray, rf_bytes_per_pe: jnp.ndarray,
                       glb_kib: jnp.ndarray, mult_area_nand2eq: jnp.ndarray,
                       node_nm: int) -> jnp.ndarray:
    """`area_model(...).total_mm2` as a pure elementwise array function —
    the population-parallel form used inside the jitted GA step.  Inputs
    are same-shaped arrays of physical quantities (the batched GA gathers
    them from its genome index tables)."""
    nand2_um2 = nlmod.NAND2_UM2[node_nm]
    sram_um2_bit = SRAM_UM2_PER_BIT[node_nm]
    mult_um2 = mult_area_nand2eq * nand2_um2 * num_pes
    mac_other_um2 = MAC_OVERHEAD_NAND2EQ * nand2_um2 * num_pes
    rf_um2 = rf_bytes_per_pe * 8.0 * sram_um2_bit * num_pes
    glb_um2 = glb_kib * 1024.0 * 8.0 * sram_um2_bit
    core = mult_um2 + mac_other_um2 + rf_um2 + glb_um2
    return core * (1.0 + OVERHEAD_FRACTION) * 1e-6
