"""Analytical loop-nest performance model (nn-dataflow / Tangram style).

Estimates per-layer cycles for an NVDLA-style accelerator:

  * compute: the MAC array is (pe_rows x pe_cols) = (C-parallel x K-parallel);
    one output spatial position per cycle per (C,K) tile pass;
  * memory: DRAM traffic under the best of two canonical loop orders
    (weight-stationary vs. output/ifmap-stationary) with a discrete tiling
    search constrained by the global buffer (double-buffered), exactly the
    trade-off nn-dataflow explores;
  * the layer runs at max(compute, memory) cycles (perfect double-buffer
    overlap — an optimistic but standard assumption).

FPS = freq / sum(layer cycles).  All operands int8, psums int32.
"""

from __future__ import annotations

import dataclasses
import functools
import math

from . import accelerator as accmod
from . import carbon as carbonmod
from . import workloads as wl


@dataclasses.dataclass(frozen=True)
class LayerPerf:
    name: str
    compute_cycles: float
    memory_cycles: float
    dram_bytes: float
    utilization: float

    @property
    def cycles(self) -> float:
        return max(self.compute_cycles, self.memory_cycles)


@dataclasses.dataclass(frozen=True)
class WorkloadPerf:
    layers: tuple[LayerPerf, ...]
    total_cycles: float
    fps: float
    avg_utilization: float
    dram_bytes: float


def _tile_candidates(total: int, par: int) -> list[int]:
    """Tile sizes: multiples of the parallel dim, plus the full extent."""
    cands = set()
    t = par
    while t < total:
        cands.add(t)
        t *= 2
    cands.add(total)
    return sorted(cands)


def _layer_perf(layer: wl.Layer, cfg: accmod.AcceleratorConfig,
                bytes_per_cycle: float) -> LayerPerf:
    rows, cols = cfg.pe_rows, cfg.pe_cols
    glb = cfg.glb_kib * 1024
    if isinstance(layer, wl.GemmLayer):
        c, k, hw = layer.k, layer.n, layer.m  # map GEMM onto the conv nest
        r = s = 1
        ifm, wgt, ofm = layer.ifmap_bytes, layer.weight_bytes, layer.ofmap_bytes
    else:
        c, k, hw = layer.c_in, layer.c_out, layer.h_out * layer.w_out
        r, s = layer.r, layer.s
        ifm, wgt, ofm = layer.ifmap_bytes, layer.weight_bytes, layer.ofmap_bytes

    compute = hw * r * s * math.ceil(c / rows) * math.ceil(k / cols)
    util = layer.macs / (compute * rows * cols)

    # --- DRAM traffic: best (loop order x tiling) under GLB capacity -------
    best = float("inf")
    for tk in _tile_candidates(k, cols):
        for tc in _tile_candidates(c, rows):
            w_tile = tk * tc * r * s
            i_tile = tc * max(1, ifm // max(c, 1))  # per-channel ifmap slice
            if 2 * (w_tile + i_tile) > glb:
                continue
            n_k = math.ceil(k / tk)
            n_c = math.ceil(c / tc)
            # weight-stationary: weights once; ifmap streamed per K tile
            ws = wgt + ifm * n_k + ofm * max(1, n_c)
            # ifmap-stationary: ifmap once; weights streamed per C tile pass
            is_ = ifm + wgt * 1 + ofm * max(1, n_c)  # weights fit pass-wise
            # ifmap-stationary only valid if a full K-slice of weights tiles
            # through GLB while the ifmap tile persists:
            if 2 * w_tile + i_tile <= glb:
                best = min(best, ws, is_)
            else:
                best = min(best, ws)
    if best == float("inf"):
        # degenerate: stream everything per smallest tile
        best = wgt * math.ceil(hw / 64) + ifm * math.ceil(k / cols) + ofm * 2
    mem_cycles = best / bytes_per_cycle
    return LayerPerf(layer.name, float(compute), float(mem_cycles),
                     float(best), float(util))


@functools.lru_cache(maxsize=4096)
def _workload_perf_cached(workload: str, cfg_key: tuple) -> WorkloadPerf:
    cfg = accmod.AcceleratorConfig(*cfg_key)
    layers = wl.WORKLOADS[workload]()
    freq = carbonmod.node_frequency(cfg.node_nm)
    bytes_per_cycle = cfg.dram_gbps * 1e9 / freq
    perfs = tuple(_layer_perf(l, cfg, bytes_per_cycle) for l in layers)
    total = sum(p.cycles for p in perfs)
    fps = freq / total
    avg_util = sum(p.utilization * p.compute_cycles for p in perfs) / \
        max(sum(p.compute_cycles for p in perfs), 1e-9)
    return WorkloadPerf(perfs, total, fps, avg_util,
                        sum(p.dram_bytes for p in perfs))


def workload_perf(workload: str, cfg: accmod.AcceleratorConfig) -> WorkloadPerf:
    key = (cfg.pe_rows, cfg.pe_cols, cfg.rf_bytes_per_pe, cfg.glb_kib,
           cfg.multiplier, cfg.node_nm, cfg.dram_gbps)
    return _workload_perf_cached(workload, key)


def fps(workload: str, cfg: accmod.AcceleratorConfig) -> float:
    return workload_perf(workload, cfg).fps
