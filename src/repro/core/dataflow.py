"""Analytical loop-nest performance model (nn-dataflow / Tangram style).

Estimates per-layer cycles for an NVDLA-style accelerator:

  * compute: the MAC array is (pe_rows x pe_cols) = (C-parallel x K-parallel);
    one output spatial position per cycle per (C,K) tile pass;
  * memory: DRAM traffic under the best of two canonical loop orders
    (weight-stationary vs. output/ifmap-stationary) with a discrete tiling
    search constrained by the global buffer (double-buffered), exactly the
    trade-off nn-dataflow explores;
  * the layer runs at max(compute, memory) cycles (perfect double-buffer
    overlap — an optimistic but standard assumption).

FPS = freq / sum(layer cycles).  All operands int8, psums int32.

Multi-die targets (`n_dies > 1`) partition the output channels (NVDLA
Atomic-K / the TP "model" axis) across identical dies: each die runs the
layer with K/n output channels on a (rows x cols/n) array, streams its
own weight/ofmap slice through its own DRAM channel (aggregate bandwidth
scales with the die count — the chiplet bandwidth lever), and replicates
the ifmap.  Between layers the channel-partitioned activations all-gather
over the D2D links (UCIe-class `D2D_GBPS`), modeled like the DRAM term
(overlapped: the layer runs at max(compute, memory, d2d)) plus a fixed
per-layer hop latency.  `n_dies == 1` is bit-for-bit the monolithic model.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import accelerator as accmod
from . import carbon as carbonmod
from . import workloads as wl


#: Die-to-die link bandwidth [GB/s] (UCIe-class, per neighbor link) and the
#: fixed per-layer synchronization latency paid once per all-gather.
D2D_GBPS = 32.0
D2D_HOP_CYCLES = 2000.0


@dataclasses.dataclass(frozen=True)
class LayerPerf:
    name: str
    compute_cycles: float
    memory_cycles: float
    dram_bytes: float
    utilization: float
    d2d_cycles: float = 0.0     # inter-die all-gather (overlapped)
    hop_cycles: float = 0.0     # fixed per-layer D2D sync latency (serial)

    @property
    def cycles(self) -> float:
        return max(self.compute_cycles, self.memory_cycles,
                   self.d2d_cycles) + self.hop_cycles


@dataclasses.dataclass(frozen=True)
class WorkloadPerf:
    layers: tuple[LayerPerf, ...]
    total_cycles: float
    fps: float
    avg_utilization: float
    dram_bytes: float


def _tile_candidates(total: float, par: float) -> list[float]:
    """Tile sizes: multiples of the parallel dim, plus the full extent."""
    cands = set()
    t = par
    while t < total:
        cands.add(t)
        t *= 2
    cands.add(total)
    return sorted(cands)


def _layer_perf(layer: wl.Layer, cfg: accmod.AcceleratorConfig,
                bytes_per_cycle: float, n_dies: int = 1) -> LayerPerf:
    """One layer on `n_dies` identical dies.  `cfg` describes the FULL
    (rows x cols) array; each die owns cols/n_dies output-channel columns,
    `cfg.glb_kib` of buffer, and one DRAM channel of `cfg.dram_gbps`.  The
    K dimension, weight bytes, and ofmap bytes scale by 1/n_dies per die;
    the ifmap is replicated (and all-gathered over D2D between layers)."""
    rows, cols = cfg.pe_rows, cfg.pe_cols
    glb = cfg.glb_kib * 1024
    if isinstance(layer, wl.GemmLayer):
        c, k, hw = layer.k, layer.n, layer.m  # map GEMM onto the conv nest
        r = s = 1
        ifm, wgt, ofm = layer.ifmap_bytes, layer.weight_bytes, layer.ofmap_bytes
    else:
        c, k, hw = layer.c_in, layer.c_out, layer.h_out * layer.w_out
        r, s = layer.r, layer.s
        ifm, wgt, ofm = layer.ifmap_bytes, layer.weight_bytes, layer.ofmap_bytes

    # per-die view: K-partitioned output channels on a cols/n sub-array
    cols_d = cols / n_dies
    k_d = k / n_dies
    wgt_d = wgt / n_dies
    ofm_d = ofm / n_dies
    compute = hw * r * s * math.ceil(c / rows) * math.ceil(k_d / cols_d)
    util = (layer.macs / n_dies) / (compute * rows * cols_d)

    # --- DRAM traffic: best (loop order x tiling) under GLB capacity -------
    best = float("inf")
    for tk in _tile_candidates(k_d, cols_d):
        for tc in _tile_candidates(c, rows):
            w_tile = tk * tc * r * s
            i_tile = tc * max(1, ifm // max(c, 1))  # per-channel ifmap slice
            if 2 * (w_tile + i_tile) > glb:
                continue
            n_k = math.ceil(k_d / tk)
            n_c = math.ceil(c / tc)
            # weight-stationary: weights once; ifmap streamed per K tile
            ws = wgt_d + ifm * n_k + ofm_d * max(1, n_c)
            # ifmap-stationary: ifmap once; weights streamed per C tile pass
            is_ = ifm + wgt_d * 1 + ofm_d * max(1, n_c)  # weights fit pass-wise
            # ifmap-stationary only valid if a full K-slice of weights tiles
            # through GLB while the ifmap tile persists:
            if 2 * w_tile + i_tile <= glb:
                best = min(best, ws, is_)
            else:
                best = min(best, ws)
    if best == float("inf"):
        # degenerate: stream everything per smallest tile
        best = wgt_d * math.ceil(hw / 64) + ifm * math.ceil(k_d / cols_d) \
            + ofm_d * 2
    mem_cycles = best / bytes_per_cycle
    d2d_cycles = hop = 0.0
    if n_dies > 1:
        # D2D bytes/cycle at the same clock as the DRAM bytes/cycle
        d2d_bpc = bytes_per_cycle * (D2D_GBPS / cfg.dram_gbps)
        d2d_cycles = ifm * (n_dies - 1) / n_dies / d2d_bpc
        hop = D2D_HOP_CYCLES
    return LayerPerf(layer.name, float(compute), float(mem_cycles),
                     float(best), float(util), float(d2d_cycles), float(hop))


def layers_perf(layers: list[wl.Layer], cfg: accmod.AcceleratorConfig,
                n_dies: int = 1) -> WorkloadPerf:
    """Perf of an explicit layer list (uncached): the calibration bridge
    uses this to evaluate ad-hoc workloads built from a served model's
    actual dimensions rather than a registered workload name."""
    freq = carbonmod.node_frequency(cfg.node_nm)
    bytes_per_cycle = cfg.dram_gbps * 1e9 / freq
    perfs = tuple(_layer_perf(l, cfg, bytes_per_cycle, n_dies)
                  for l in layers)
    total = sum(p.cycles for p in perfs)
    fps = freq / total
    avg_util = sum(p.utilization * p.compute_cycles for p in perfs) / \
        max(sum(p.compute_cycles for p in perfs), 1e-9)
    return WorkloadPerf(perfs, total, fps, avg_util,
                        sum(p.dram_bytes for p in perfs))


@functools.lru_cache(maxsize=4096)
def _workload_perf_cached(workload: str, cfg_key: tuple,
                          n_dies: int) -> WorkloadPerf:
    cfg = accmod.AcceleratorConfig(*cfg_key)
    return layers_perf(wl.WORKLOADS[workload](), cfg, n_dies)


def workload_perf(workload: str, cfg: accmod.AcceleratorConfig,
                  n_dies: int = 1) -> WorkloadPerf:
    key = (cfg.pe_rows, cfg.pe_cols, cfg.rf_bytes_per_pe, cfg.glb_kib,
           cfg.multiplier, cfg.node_nm, cfg.dram_gbps)
    return _workload_perf_cached(workload, key, n_dies)


def fps(workload: str, cfg: accmod.AcceleratorConfig,
        n_dies: int = 1) -> float:
    return workload_perf(workload, cfg, n_dies).fps


# ---------------------------------------------------------------------------
# Batched array form: the same loop-nest model as `_layer_perf`, expressed
# as pure jnp over (batch of configs) x (layer table) x (tile-candidate
# grid) — the population-parallel evaluator behind `core/ga_batched.py`.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerTable:
    """Struct-of-arrays layer description, one row per layer."""
    c: np.ndarray        # input channels (GEMM: K)
    k: np.ndarray        # output channels (GEMM: N)
    hw: np.ndarray       # output spatial positions (GEMM: M)
    rs: np.ndarray       # filter taps r*s (GEMM: 1)
    i_per_c: np.ndarray  # per-channel ifmap slice bytes, max(1, ifm//c)
    ifm: np.ndarray      # ifmap bytes
    wgt: np.ndarray      # weight bytes
    ofm: np.ndarray      # ofmap bytes


def layer_table(layers: list[wl.Layer]) -> LayerTable:
    rows = []
    for l in layers:
        if isinstance(l, wl.GemmLayer):
            c, k, hw, rs = l.k, l.n, l.m, 1
        else:
            c, k, hw, rs = l.c_in, l.c_out, l.h_out * l.w_out, l.r * l.s
        rows.append((c, k, hw, rs, max(1, l.ifmap_bytes // max(c, 1)),
                     l.ifmap_bytes, l.weight_bytes, l.ofmap_bytes))
    arr = np.asarray(rows, dtype=np.float32).T
    return LayerTable(*arr)


@functools.lru_cache(maxsize=32)
def workload_table(workload: str) -> LayerTable:
    return layer_table(wl.WORKLOADS[workload]())


# Tile candidates are {par * 2^j clamped at the full extent}; 15 levels
# cover every extent in WORKLOADS from the smallest parallel dim (4).
_TILE_LEVELS = 15


def _one_config_cycles(rows, cols, glb_bytes, dies, bpc, d2d_bpc,
                       t: LayerTable):
    """Total cycles for ONE config over every layer of the table; scalars
    `rows/cols/glb_bytes/dies` are traced (vmapped over the population).
    Mirrors `_layer_perf` exactly, including the per-die K partition
    (k/dies output channels on cols/dies columns per die, weight/ofmap
    bytes scaled, ifmap replicated + all-gathered over D2D)."""
    cols_d = cols / dies
    k_d = t.k / dies
    wgt_d = t.wgt / dies
    ofm_d = t.ofm / dies
    compute = t.hw * t.rs * jnp.ceil(t.c / rows) * jnp.ceil(k_d / cols_d)

    lvl = 2.0 ** jnp.arange(_TILE_LEVELS, dtype=jnp.float32)
    tk = jnp.minimum(cols_d * lvl[None, :], k_d[:, None])     # (L, J)
    tc = jnp.minimum(rows * lvl[None, :], t.c[:, None])       # (L, J)
    w_tile = tc[:, :, None] * tk[:, None, :] * t.rs[:, None, None]
    i_tile = (tc * t.i_per_c[:, None])[:, :, None]            # (L, Jc, 1)
    n_k = jnp.ceil(k_d[:, None] / tk)[:, None, :]             # (L, 1, Jk)
    n_c = jnp.ceil(t.c[:, None] / tc)[:, :, None]             # (L, Jc, 1)
    ws = (wgt_d[:, None, None] + t.ifm[:, None, None] * n_k
          + ofm_d[:, None, None] * n_c)
    is_ = (t.ifm[:, None, None] + wgt_d[:, None, None]
           + ofm_d[:, None, None] * n_c)
    feasible = 2.0 * (w_tile + i_tile) <= glb_bytes
    is_valid = 2.0 * w_tile + i_tile <= glb_bytes
    cand = jnp.where(feasible,
                     jnp.where(is_valid, jnp.minimum(ws, is_), ws),
                     jnp.inf)
    best = jnp.min(cand, axis=(1, 2))                         # (L,)
    fallback = (wgt_d * jnp.ceil(t.hw / 64.0)
                + t.ifm * jnp.ceil(k_d / cols_d) + ofm_d * 2.0)
    best = jnp.where(jnp.isinf(best), fallback, best)
    multi = dies > 1
    d2d = jnp.where(multi, t.ifm * (dies - 1.0) / dies / d2d_bpc, 0.0)
    hop = jnp.where(multi, D2D_HOP_CYCLES, 0.0)
    per_layer = jnp.maximum(jnp.maximum(compute, best / bpc), d2d) + hop
    return jnp.sum(per_layer)


@functools.partial(jax.jit, static_argnames=("workload", "node_nm",
                                             "dram_gbps"))
def batched_fps(workload: str, rows: jnp.ndarray, cols: jnp.ndarray,
                glb_kib: jnp.ndarray, node_nm: int,
                dram_gbps: float = 19.2,
                dies: jnp.ndarray | None = None) -> jnp.ndarray:
    """FPS for a whole batch of (pe_rows, pe_cols, glb_kib[, n_dies])
    configs at once.  Matches `workload_perf(...).fps` to f32 rounding
    (the numpy reference computes the identical candidate set in f64)."""
    t = workload_table(workload)
    freq = carbonmod.node_frequency(node_nm)
    bpc = dram_gbps * 1e9 / freq
    d2d_bpc = bpc * (D2D_GBPS / dram_gbps)
    rows = jnp.asarray(rows, jnp.float32)
    if dies is None:
        dies = jnp.ones_like(rows)
    total = jax.vmap(
        lambda r, c, g, d: _one_config_cycles(r, c, g * 1024.0, d, bpc,
                                              d2d_bpc, t)
    )(rows, jnp.asarray(cols, jnp.float32),
      jnp.asarray(glb_kib, jnp.float32), jnp.asarray(dies, jnp.float32))
    return freq / total
