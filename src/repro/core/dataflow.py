"""Analytical loop-nest performance model (nn-dataflow / Tangram style).

Estimates per-layer cycles for an NVDLA-style accelerator:

  * compute: the MAC array is (pe_rows x pe_cols) = (C-parallel x K-parallel);
    one output spatial position per cycle per (C,K) tile pass;
  * memory: DRAM traffic under the best of two canonical loop orders
    (weight-stationary vs. output/ifmap-stationary) with a discrete tiling
    search constrained by the global buffer (double-buffered), exactly the
    trade-off nn-dataflow explores;
  * the layer runs at max(compute, memory) cycles (perfect double-buffer
    overlap — an optimistic but standard assumption).

FPS = freq / sum(layer cycles).  All operands int8, psums int32.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import accelerator as accmod
from . import carbon as carbonmod
from . import workloads as wl


@dataclasses.dataclass(frozen=True)
class LayerPerf:
    name: str
    compute_cycles: float
    memory_cycles: float
    dram_bytes: float
    utilization: float

    @property
    def cycles(self) -> float:
        return max(self.compute_cycles, self.memory_cycles)


@dataclasses.dataclass(frozen=True)
class WorkloadPerf:
    layers: tuple[LayerPerf, ...]
    total_cycles: float
    fps: float
    avg_utilization: float
    dram_bytes: float


def _tile_candidates(total: int, par: int) -> list[int]:
    """Tile sizes: multiples of the parallel dim, plus the full extent."""
    cands = set()
    t = par
    while t < total:
        cands.add(t)
        t *= 2
    cands.add(total)
    return sorted(cands)


def _layer_perf(layer: wl.Layer, cfg: accmod.AcceleratorConfig,
                bytes_per_cycle: float) -> LayerPerf:
    rows, cols = cfg.pe_rows, cfg.pe_cols
    glb = cfg.glb_kib * 1024
    if isinstance(layer, wl.GemmLayer):
        c, k, hw = layer.k, layer.n, layer.m  # map GEMM onto the conv nest
        r = s = 1
        ifm, wgt, ofm = layer.ifmap_bytes, layer.weight_bytes, layer.ofmap_bytes
    else:
        c, k, hw = layer.c_in, layer.c_out, layer.h_out * layer.w_out
        r, s = layer.r, layer.s
        ifm, wgt, ofm = layer.ifmap_bytes, layer.weight_bytes, layer.ofmap_bytes

    compute = hw * r * s * math.ceil(c / rows) * math.ceil(k / cols)
    util = layer.macs / (compute * rows * cols)

    # --- DRAM traffic: best (loop order x tiling) under GLB capacity -------
    best = float("inf")
    for tk in _tile_candidates(k, cols):
        for tc in _tile_candidates(c, rows):
            w_tile = tk * tc * r * s
            i_tile = tc * max(1, ifm // max(c, 1))  # per-channel ifmap slice
            if 2 * (w_tile + i_tile) > glb:
                continue
            n_k = math.ceil(k / tk)
            n_c = math.ceil(c / tc)
            # weight-stationary: weights once; ifmap streamed per K tile
            ws = wgt + ifm * n_k + ofm * max(1, n_c)
            # ifmap-stationary: ifmap once; weights streamed per C tile pass
            is_ = ifm + wgt * 1 + ofm * max(1, n_c)  # weights fit pass-wise
            # ifmap-stationary only valid if a full K-slice of weights tiles
            # through GLB while the ifmap tile persists:
            if 2 * w_tile + i_tile <= glb:
                best = min(best, ws, is_)
            else:
                best = min(best, ws)
    if best == float("inf"):
        # degenerate: stream everything per smallest tile
        best = wgt * math.ceil(hw / 64) + ifm * math.ceil(k / cols) + ofm * 2
    mem_cycles = best / bytes_per_cycle
    return LayerPerf(layer.name, float(compute), float(mem_cycles),
                     float(best), float(util))


def layers_perf(layers: list[wl.Layer], cfg: accmod.AcceleratorConfig
                ) -> WorkloadPerf:
    """Perf of an explicit layer list (uncached): the calibration bridge
    uses this to evaluate ad-hoc workloads built from a served model's
    actual dimensions rather than a registered workload name."""
    freq = carbonmod.node_frequency(cfg.node_nm)
    bytes_per_cycle = cfg.dram_gbps * 1e9 / freq
    perfs = tuple(_layer_perf(l, cfg, bytes_per_cycle) for l in layers)
    total = sum(p.cycles for p in perfs)
    fps = freq / total
    avg_util = sum(p.utilization * p.compute_cycles for p in perfs) / \
        max(sum(p.compute_cycles for p in perfs), 1e-9)
    return WorkloadPerf(perfs, total, fps, avg_util,
                        sum(p.dram_bytes for p in perfs))


@functools.lru_cache(maxsize=4096)
def _workload_perf_cached(workload: str, cfg_key: tuple) -> WorkloadPerf:
    cfg = accmod.AcceleratorConfig(*cfg_key)
    return layers_perf(wl.WORKLOADS[workload](), cfg)


def workload_perf(workload: str, cfg: accmod.AcceleratorConfig) -> WorkloadPerf:
    key = (cfg.pe_rows, cfg.pe_cols, cfg.rf_bytes_per_pe, cfg.glb_kib,
           cfg.multiplier, cfg.node_nm, cfg.dram_gbps)
    return _workload_perf_cached(workload, key)


def fps(workload: str, cfg: accmod.AcceleratorConfig) -> float:
    return workload_perf(workload, cfg).fps


# ---------------------------------------------------------------------------
# Batched array form: the same loop-nest model as `_layer_perf`, expressed
# as pure jnp over (batch of configs) x (layer table) x (tile-candidate
# grid) — the population-parallel evaluator behind `core/ga_batched.py`.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerTable:
    """Struct-of-arrays layer description, one row per layer."""
    c: np.ndarray        # input channels (GEMM: K)
    k: np.ndarray        # output channels (GEMM: N)
    hw: np.ndarray       # output spatial positions (GEMM: M)
    rs: np.ndarray       # filter taps r*s (GEMM: 1)
    i_per_c: np.ndarray  # per-channel ifmap slice bytes, max(1, ifm//c)
    ifm: np.ndarray      # ifmap bytes
    wgt: np.ndarray      # weight bytes
    ofm: np.ndarray      # ofmap bytes


def layer_table(layers: list[wl.Layer]) -> LayerTable:
    rows = []
    for l in layers:
        if isinstance(l, wl.GemmLayer):
            c, k, hw, rs = l.k, l.n, l.m, 1
        else:
            c, k, hw, rs = l.c_in, l.c_out, l.h_out * l.w_out, l.r * l.s
        rows.append((c, k, hw, rs, max(1, l.ifmap_bytes // max(c, 1)),
                     l.ifmap_bytes, l.weight_bytes, l.ofmap_bytes))
    arr = np.asarray(rows, dtype=np.float32).T
    return LayerTable(*arr)


@functools.lru_cache(maxsize=32)
def workload_table(workload: str) -> LayerTable:
    return layer_table(wl.WORKLOADS[workload]())


# Tile candidates are {par * 2^j clamped at the full extent}; 15 levels
# cover every extent in WORKLOADS from the smallest parallel dim (4).
_TILE_LEVELS = 15


def _one_config_cycles(rows, cols, glb_bytes, bpc, t: LayerTable):
    """Total cycles for ONE config over every layer of the table; scalars
    `rows/cols/glb_bytes` are traced (vmapped over the population)."""
    compute = t.hw * t.rs * jnp.ceil(t.c / rows) * jnp.ceil(t.k / cols)

    lvl = 2.0 ** jnp.arange(_TILE_LEVELS, dtype=jnp.float32)
    tk = jnp.minimum(cols * lvl[None, :], t.k[:, None])       # (L, J)
    tc = jnp.minimum(rows * lvl[None, :], t.c[:, None])       # (L, J)
    w_tile = tc[:, :, None] * tk[:, None, :] * t.rs[:, None, None]
    i_tile = (tc * t.i_per_c[:, None])[:, :, None]            # (L, Jc, 1)
    n_k = jnp.ceil(t.k[:, None] / tk)[:, None, :]             # (L, 1, Jk)
    n_c = jnp.ceil(t.c[:, None] / tc)[:, :, None]             # (L, Jc, 1)
    ws = (t.wgt[:, None, None] + t.ifm[:, None, None] * n_k
          + t.ofm[:, None, None] * n_c)
    is_ = (t.ifm[:, None, None] + t.wgt[:, None, None]
           + t.ofm[:, None, None] * n_c)
    feasible = 2.0 * (w_tile + i_tile) <= glb_bytes
    is_valid = 2.0 * w_tile + i_tile <= glb_bytes
    cand = jnp.where(feasible,
                     jnp.where(is_valid, jnp.minimum(ws, is_), ws),
                     jnp.inf)
    best = jnp.min(cand, axis=(1, 2))                         # (L,)
    fallback = (t.wgt * jnp.ceil(t.hw / 64.0)
                + t.ifm * jnp.ceil(t.k / cols) + t.ofm * 2.0)
    best = jnp.where(jnp.isinf(best), fallback, best)
    return jnp.sum(jnp.maximum(compute, best / bpc))


@functools.partial(jax.jit, static_argnames=("workload", "node_nm",
                                             "dram_gbps"))
def batched_fps(workload: str, rows: jnp.ndarray, cols: jnp.ndarray,
                glb_kib: jnp.ndarray, node_nm: int,
                dram_gbps: float = 19.2) -> jnp.ndarray:
    """FPS for a whole batch of (pe_rows, pe_cols, glb_kib) configs at
    once.  Matches `workload_perf(...).fps` to f32 rounding (the numpy
    reference computes the identical candidate set in f64)."""
    t = workload_table(workload)
    freq = carbonmod.node_frequency(node_nm)
    bpc = dram_gbps * 1e9 / freq
    total = jax.vmap(
        lambda r, c, g: _one_config_cycles(r, c, g * 1024.0, bpc, t)
    )(jnp.asarray(rows, jnp.float32), jnp.asarray(cols, jnp.float32),
      jnp.asarray(glb_kib, jnp.float32))
    return freq / total
