"""End-to-end methodology driver (paper Fig. 1).

  step 1: generate area-aware approximate multipliers (NSGA-II Pareto front),
  step 2: GA over accelerator configs + mappings + multiplier choice with CDP
          fitness under FPS / accuracy-drop constraints,
  report: exact baseline, approx-only variant, GA-CDP design -- the three
          bars of the paper's Fig. 3 (and the points of Fig. 2).

Beyond the single-point reproduction, `scenario_grid` / `run_scenarios`
sweep the co-design over (technology node x fab grid carbon intensity x
workload — CNN frames and LM serving traces alike) with the
population-parallel engine (`core/ga_batched.py`), optionally reporting
serving-calibrated CDP next to the analytical figure
(`core/calibrate.py`).  `benchmarks/bench_codesign.py` drives the sweep
and emits `BENCH_codesign.json`.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from . import accelerator as accmod
from . import calibrate as calmod
from . import carbon as carbonmod
from . import dataflow as dfmod
from . import ga as gamod
from . import multipliers as mm
from . import pareto as paretomod


@dataclasses.dataclass(frozen=True)
class CodesignReport:
    workload: str
    node_nm: int
    fps_min: float
    max_accuracy_drop: float
    exact: gamod.Evaluated
    approx_only: gamod.Evaluated
    ga_cdp: gamod.Evaluated
    approx_only_reduction: float   # carbon vs exact, same architecture
    ga_reduction: float            # carbon vs exact baseline

    def summary(self) -> str:
        return (
            f"[{self.workload} @ {self.node_nm}nm, fps>={self.fps_min:.0f}, "
            f"drop<={self.max_accuracy_drop:.1f}%]\n"
            f"  exact     : {self.exact.config.num_pes:5d} PEs "
            f"{self.exact.area_mm2:7.3f} mm2  {self.exact.carbon_g:8.2f} g  "
            f"{self.exact.fps:6.1f} fps\n"
            f"  approx    : {self.approx_only.config.num_pes:5d} PEs "
            f"{self.approx_only.area_mm2:7.3f} mm2  "
            f"{self.approx_only.carbon_g:8.2f} g  (mult="
            f"{self.approx_only.config.multiplier})  "
            f"carbon -{100 * self.approx_only_reduction:.2f}%\n"
            f"  GA-CDP    : {self.ga_cdp.config.num_pes:5d} PEs "
            f"{self.ga_cdp.area_mm2:7.3f} mm2  {self.ga_cdp.carbon_g:8.2f} g  "
            f"{self.ga_cdp.fps:6.1f} fps  (mult={self.ga_cdp.config.multiplier})"
            f"  carbon -{100 * self.ga_reduction:.2f}%"
        )


def run_codesign(workload: str, node_nm: int, fps_min: float,
                 max_accuracy_drop: float,
                 mults: list[mm.ApproxMultiplier] | None = None,
                 accuracy_fn: gamod.AccuracyFn = gamod.proxy_accuracy_drop,
                 ga_cfg: gamod.GAConfig | None = None,
                 engine: str = "numpy",
                 batched_cfg=None) -> CodesignReport:
    """`engine="numpy"` runs the sequential reference GA; `"batched"` the
    population-parallel engine (`core/ga_batched.py`, configured by
    `batched_cfg`) — both report through the same reference evaluator."""
    if mults is None:
        mults = paretomod.default_front() + list(mm.static_library().values())

    exact = gamod.exact_baseline(workload, node_nm, fps_min)

    # approx-only: same architecture, best multiplier within the drop budget
    allowed = [m for m in mults if accuracy_fn(m) <= max_accuracy_drop
               and not m.is_exact]
    if allowed:
        best_mult = min(allowed, key=lambda m: m.area_nand2eq)
        approx_only = gamod.approx_variant(exact.config, best_mult)
    else:
        approx_only = exact

    if engine == "batched":
        from . import ga_batched as gbmod
        result = gbmod.run_ga_batched(
            workload, node_nm, fps_min, max_accuracy_drop, mults=mults,
            accuracy_fn=accuracy_fn, cfg=batched_cfg)
    elif engine == "numpy":
        result = gamod.run_ga(workload, node_nm, fps_min, max_accuracy_drop,
                              mults=mults, accuracy_fn=accuracy_fn,
                              cfg=ga_cfg)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    ga_best = result.best

    return CodesignReport(
        workload=workload, node_nm=node_nm, fps_min=fps_min,
        max_accuracy_drop=max_accuracy_drop,
        exact=exact, approx_only=approx_only, ga_cdp=ga_best,
        approx_only_reduction=1.0 - approx_only.carbon_g / exact.carbon_g,
        ga_reduction=1.0 - ga_best.carbon_g / exact.carbon_g,
    )


def sweep_exact_configs(workload: str, node_nm: int
                        ) -> list[gamod.Evaluated]:
    """The paper's Fig. 2 baseline curve: exact NVDLA configs 64..2048 PEs."""
    out = []
    for pes in accmod.VALID_PE_COUNTS:
        acfg = accmod.nvdla_default(pes, node_nm)
        perf = dfmod.workload_perf(workload, acfg)
        area = accmod.area_model(acfg)
        cb = carbonmod.embodied_carbon(area.total_mm2, node_nm)
        out.append(gamod.Evaluated(
            gamod.Genome(0, 0, 0, 0, 0), acfg, perf.fps, cb.total_g,
            carbonmod.cdp(cb.total_g, perf.fps),
            carbonmod.cdp(cb.total_g, perf.fps), area.total_mm2))
    return out


def approx_only_sweep(workload: str, node_nm: int, max_drop: float,
                      mults: list[mm.ApproxMultiplier],
                      accuracy_fn: gamod.AccuracyFn = gamod.proxy_accuracy_drop
                      ) -> list[gamod.Evaluated]:
    """Fig. 2 'Appx' curves: every exact config with the best multiplier
    within the accuracy budget swapped in."""
    allowed = [m for m in mults if accuracy_fn(m) <= max_drop
               and not m.is_exact]
    if not allowed:
        return sweep_exact_configs(workload, node_nm)
    best_mult = min(allowed, key=lambda m: m.area_nand2eq)
    out = []
    for e in sweep_exact_configs(workload, node_nm):
        out.append(gamod.approx_variant(e.config, best_mult))
    return out


# ---------------------------------------------------------------------------
# Scenario sweeps over (node x fab carbon intensity x workload) with the
# population-parallel engine.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    workload: str
    node_nm: int
    ci_fab: float = carbonmod.CI_FAB_G_PER_KWH  # fab grid [g CO2/kWh]
    fps_min: float = 30.0
    max_accuracy_drop: float = 2.0

    @property
    def name(self) -> str:
        return (f"{self.workload}@{self.node_nm}nm"
                f"/ci{self.ci_fab:.0f}/fps{self.fps_min:.0f}")


def scenario_grid(workloads: tuple[str, ...] = ("vgg16", "resnet50",
                                                "tiny_lm", "lm_serving"),
                  nodes: tuple[int, ...] = (7, 14, 28),
                  ci_fabs: tuple[float, ...] = (
                      50.0,                          # hydro/nuclear fab
                      carbonmod.CI_FAB_G_PER_KWH,    # ACT default mix
                      820.0),                        # coal-heavy grid
                  fps_min: float = 30.0,
                  max_accuracy_drop: float = 2.0) -> list[Scenario]:
    return [Scenario(w, n, ci, fps_min, max_accuracy_drop)
            for w in workloads for n in nodes for ci in ci_fabs]


def multi_die_scenarios(ci_fab: float = carbonmod.CI_FAB_G_PER_KWH,
                        max_accuracy_drop: float = 2.0) -> list[Scenario]:
    """Scenarios whose FPS floor sits ABOVE the monolithic design space's
    reach (one DRAM channel saturates) but within multi-die reach (one
    channel per die + inter-die all-gather): the partitioning gene has to
    fire for the GA to satisfy the application at all.  These are the
    points where `run_scenarios` records a >1-die winner next to the best
    monolithic design."""
    return [Scenario("vgg16", 7, ci_fab, 120.0, max_accuracy_drop),
            Scenario("vgg16", 14, ci_fab, 100.0, max_accuracy_drop),
            Scenario("resnet50", 7, ci_fab, 400.0, max_accuracy_drop)]


@dataclasses.dataclass(frozen=True)
class ScenarioResult:
    scenario: Scenario
    best: gamod.Evaluated
    exact: gamod.Evaluated
    ga_reduction: float            # carbon vs exact baseline
    cdp_calibrated: float | None   # CDP under measured (not modeled) delay
    wall_s: float
    mono: gamod.Evaluated | None = None   # best monolithic (die gene = 1)
    #: nondominated (carbon_g, delay_s) points of the final GA
    #: population (feasible designs only, <= _FRONTIER_MAX points) —
    #: the carbon/delay trade space behind the single CDP winner.
    frontier: list[dict] | None = None

    @staticmethod
    def _design_dict(e: gamod.Evaluated) -> dict:
        return {"num_pes": e.config.num_pes,
                "pe_rows": e.config.pe_rows,
                "pe_cols": e.config.pe_cols,
                "rf_bytes_per_pe": e.config.rf_bytes_per_pe,
                "glb_kib": e.config.glb_kib,
                "multiplier": e.config.multiplier,
                "area_mm2": e.area_mm2, "fps": e.fps,
                "carbon_g": e.carbon_g, "cdp": e.cdp,
                # the paper's fitness: CDP with fps capped at the floor
                # (+ superlinear penalty under it)
                "cdp_constrained": e.fitness,
                "n_dies": e.n_dies,
                "die_area_mm2": e.die_area_mm2,
                "die_yield": e.die_yield,
                "packaging_g": e.packaging_g}

    def to_dict(self) -> dict:
        sc = self.scenario
        return {
            "scenario": {"workload": sc.workload, "node_nm": sc.node_nm,
                         "ci_fab_g_per_kwh": sc.ci_fab,
                         "fps_min": sc.fps_min,
                         "max_accuracy_drop": sc.max_accuracy_drop},
            "best": self._design_dict(self.best),
            "best_monolithic": (self._design_dict(self.mono)
                                if self.mono is not None else None),
            "exact_baseline": {"num_pes": self.exact.config.num_pes,
                               "carbon_g": self.exact.carbon_g,
                               "fps": self.exact.fps,
                               "cdp": self.exact.cdp},
            "ga_reduction": self.ga_reduction,
            "cdp_calibrated": self.cdp_calibrated,
            "wall_s": self.wall_s,
            "frontier": self.frontier,
        }


_FRONTIER_MAX = 16


def population_frontier(metrics: dict, max_points: int = _FRONTIER_MAX
                        ) -> list[dict]:
    """(carbon_g, delay_s) nondominated front of a final GA population
    (`BatchedGAResult.metrics` arrays).  Feasible designs only; unique
    objective points; evenly thinned to `max_points`."""
    ok = (np.asarray(metrics["feasible"], bool)
          & np.isfinite(np.asarray(metrics["fitness"], float)))
    if not ok.any():
        return []
    carbon = np.asarray(metrics["carbon_g"], float)[ok]
    fps = np.asarray(metrics["fps"], float)[ok]
    pts = np.unique(np.stack(
        [carbon, 1.0 / np.maximum(fps, 1e-9)], axis=1), axis=0)
    idx = paretomod.nondominated_front(pts)
    if len(idx) > max_points:
        keep = np.unique(np.linspace(0, len(idx) - 1, max_points)
                         .round().astype(int))
        idx = idx[keep]
    return [{"carbon_g": float(pts[i, 0]), "delay_s": float(pts[i, 1]),
             "fps": float(1.0 / pts[i, 1]),
             "cdp": float(pts[i, 0] * pts[i, 1])} for i in idx]


def run_scenarios(scenarios: list[Scenario],
                  mults: list[mm.ApproxMultiplier] | None = None,
                  accuracy_fn: gamod.AccuracyFn = gamod.proxy_accuracy_drop,
                  cfg=None,
                  calibration: "calmod.DelayCalibration | None" = None
                  ) -> list[ScenarioResult]:
    """Population-parallel co-design across the scenario grid.  One
    batched GA per scenario; the DesignSpace (FPS lattice + accuracy_fn
    evaluations — the expensive parts, and independent of ci_fab) is
    built once per (workload, node, constraints) and reused across the
    carbon-intensity axis."""
    from . import ga_batched as gbmod
    if mults is None:
        mults = paretomod.default_front() + list(mm.static_library().values())
    spaces: dict[tuple, "gbmod.DesignSpace"] = {}
    out = []
    for sc in scenarios:
        t0 = time.perf_counter()
        key = (sc.workload, sc.node_nm, sc.fps_min, sc.max_accuracy_drop)
        if key not in spaces:
            spaces[key] = gbmod.build_space(
                sc.workload, sc.node_nm, sc.fps_min, sc.max_accuracy_drop,
                mults=mults, accuracy_fn=accuracy_fn)
        space = dataclasses.replace(spaces[key], ci_fab=sc.ci_fab)
        res = gbmod.run_ga_batched(
            sc.workload, sc.node_nm, sc.fps_min, sc.max_accuracy_drop,
            cfg=cfg, space=space)
        exact = gamod.exact_baseline(sc.workload, sc.node_nm, sc.fps_min,
                                     ci_fab=sc.ci_fab)
        # best monolithic design (die gene pinned to 1) via exhaustive
        # search — the baseline that shows when partitioning is the win
        fps_pen = (cfg.fps_penalty if cfg is not None
                   else gbmod.BatchedGAConfig().fps_penalty)
        mono_genome, _ = gbmod.exhaustive_best(space, fps_pen, max_dies=1)
        mono = gamod.evaluate(mono_genome, sc.workload, sc.node_nm,
                              list(space.mults), sc.fps_min,
                              gamod.GAConfig(fps_penalty=fps_pen),
                              ci_fab=sc.ci_fab)
        cdp_cal = None
        if calibration is not None and calibration.source != "identity":
            cdp_cal = calibration.calibrated_cdp(res.best.carbon_g,
                                                 res.best.fps)
        out.append(ScenarioResult(
            scenario=sc, best=res.best, exact=exact,
            ga_reduction=1.0 - res.best.carbon_g / exact.carbon_g,
            cdp_calibrated=cdp_cal, wall_s=time.perf_counter() - t0,
            mono=mono, frontier=population_frontier(res.metrics)))
    return out


# ---------------------------------------------------------------------------
# Total-carbon axis: embodied + operational, closing the fleet loop.
# ---------------------------------------------------------------------------

def run_total_carbon(scenarios: list[Scenario], op,
                     mults: list[mm.ApproxMultiplier] | None = None,
                     accuracy_fn: gamod.AccuracyFn =
                     gamod.proxy_accuracy_drop,
                     fps_penalty: float = 50.0) -> list[dict]:
    """Per scenario: the CDP winner vs the **total-carbon** winner
    (amortized embodied + operational gCO2e per inference under `op`,
    an `repro.fleet.total.OperationalModel`), both by exhaustive search
    over the design space, so a differing winner is a property of the
    objectives — not GA noise.  The same objective is available to the
    batched GA via `BatchedGAConfig(objective="total_carbon")`; this
    reporting path uses ground truth.

    The winners genuinely diverge because CDP caps the fps credit at the
    floor (speed headroom is worthless) while the operational term's
    race-to-idle rewards real speed, and chiplet designs cut embodied
    carbon (yield) but pay die-to-die link energy every inference."""
    from . import ga_batched as gbmod
    if mults is None:
        mults = paretomod.default_front() + list(mm.static_library().values())
    spaces: dict[tuple, "gbmod.DesignSpace"] = {}
    out = []
    tc_keys = ("total_g_per_inf", "operational_g_per_inf",
               "embodied_g_per_inf", "energy_j_per_inf")

    def design(space, sc, genome, met):
        ev = gamod.evaluate(genome, sc.workload, sc.node_nm,
                            list(space.mults), sc.fps_min,
                            gamod.GAConfig(fps_penalty=fps_penalty),
                            ci_fab=sc.ci_fab)
        d = ScenarioResult._design_dict(ev)
        d.update({k: float(met[k]) for k in tc_keys})
        return d

    for sc in scenarios:
        key = (sc.workload, sc.node_nm, sc.fps_min, sc.max_accuracy_drop)
        if key not in spaces:
            spaces[key] = gbmod.build_space(
                sc.workload, sc.node_nm, sc.fps_min, sc.max_accuracy_drop,
                mults=mults, accuracy_fn=accuracy_fn)
        space = dataclasses.replace(spaces[key], ci_fab=sc.ci_fab, op=op)
        g_cdp, m_cdp = gbmod.exhaustive_best(space, fps_penalty,
                                             objective="cdp")
        g_tot, m_tot = gbmod.exhaustive_best(space, fps_penalty,
                                             objective="total_carbon")
        differs = (dataclasses.astuple(g_cdp) != dataclasses.astuple(g_tot))
        out.append({
            "scenario": {"workload": sc.workload, "node_nm": sc.node_nm,
                         "ci_fab_g_per_kwh": sc.ci_fab,
                         "fps_min": sc.fps_min,
                         "max_accuracy_drop": sc.max_accuracy_drop},
            "op": {"ci_use_g_per_kwh": op.ci_use_g_per_kwh,
                   "lifetime_s": op.lifetime_s, "util": op.util,
                   "idle_frac": op.idle_frac, "die_w": op.die_w,
                   "energy_scale": op.energy_scale},
            "cdp_winner": design(space, sc, g_cdp, m_cdp),
            "total_winner": design(space, sc, g_tot, m_tot),
            "differs": differs,
            # what pricing operational carbon saves vs shipping the CDP
            # design into this deployment
            "total_reduction": float(
                1.0 - m_tot["total_g_per_inf"]
                / max(m_cdp["total_g_per_inf"], 1e-30)),
        })
    return out
