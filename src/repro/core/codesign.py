"""End-to-end methodology driver (paper Fig. 1).

  step 1: generate area-aware approximate multipliers (NSGA-II Pareto front),
  step 2: GA over accelerator configs + mappings + multiplier choice with CDP
          fitness under FPS / accuracy-drop constraints,
  report: exact baseline, approx-only variant, GA-CDP design -- the three
          bars of the paper's Fig. 3 (and the points of Fig. 2).
"""

from __future__ import annotations

import dataclasses

from . import accelerator as accmod
from . import carbon as carbonmod
from . import dataflow as dfmod
from . import ga as gamod
from . import multipliers as mm
from . import pareto as paretomod


@dataclasses.dataclass(frozen=True)
class CodesignReport:
    workload: str
    node_nm: int
    fps_min: float
    max_accuracy_drop: float
    exact: gamod.Evaluated
    approx_only: gamod.Evaluated
    ga_cdp: gamod.Evaluated
    approx_only_reduction: float   # carbon vs exact, same architecture
    ga_reduction: float            # carbon vs exact baseline

    def summary(self) -> str:
        return (
            f"[{self.workload} @ {self.node_nm}nm, fps>={self.fps_min:.0f}, "
            f"drop<={self.max_accuracy_drop:.1f}%]\n"
            f"  exact     : {self.exact.config.num_pes:5d} PEs "
            f"{self.exact.area_mm2:7.3f} mm2  {self.exact.carbon_g:8.2f} g  "
            f"{self.exact.fps:6.1f} fps\n"
            f"  approx    : {self.approx_only.config.num_pes:5d} PEs "
            f"{self.approx_only.area_mm2:7.3f} mm2  "
            f"{self.approx_only.carbon_g:8.2f} g  (mult="
            f"{self.approx_only.config.multiplier})  "
            f"carbon -{100 * self.approx_only_reduction:.2f}%\n"
            f"  GA-CDP    : {self.ga_cdp.config.num_pes:5d} PEs "
            f"{self.ga_cdp.area_mm2:7.3f} mm2  {self.ga_cdp.carbon_g:8.2f} g  "
            f"{self.ga_cdp.fps:6.1f} fps  (mult={self.ga_cdp.config.multiplier})"
            f"  carbon -{100 * self.ga_reduction:.2f}%"
        )


def run_codesign(workload: str, node_nm: int, fps_min: float,
                 max_accuracy_drop: float,
                 mults: list[mm.ApproxMultiplier] | None = None,
                 accuracy_fn: gamod.AccuracyFn = gamod.proxy_accuracy_drop,
                 ga_cfg: gamod.GAConfig | None = None) -> CodesignReport:
    if mults is None:
        mults = paretomod.default_front() + list(mm.static_library().values())

    exact = gamod.exact_baseline(workload, node_nm, fps_min)

    # approx-only: same architecture, best multiplier within the drop budget
    allowed = [m for m in mults if accuracy_fn(m) <= max_accuracy_drop
               and not m.is_exact]
    if allowed:
        best_mult = min(allowed, key=lambda m: m.area_nand2eq)
        approx_only = gamod.approx_variant(exact.config, best_mult)
    else:
        approx_only = exact

    result = gamod.run_ga(workload, node_nm, fps_min, max_accuracy_drop,
                          mults=mults, accuracy_fn=accuracy_fn, cfg=ga_cfg)
    ga_best = result.best

    return CodesignReport(
        workload=workload, node_nm=node_nm, fps_min=fps_min,
        max_accuracy_drop=max_accuracy_drop,
        exact=exact, approx_only=approx_only, ga_cdp=ga_best,
        approx_only_reduction=1.0 - approx_only.carbon_g / exact.carbon_g,
        ga_reduction=1.0 - ga_best.carbon_g / exact.carbon_g,
    )


def sweep_exact_configs(workload: str, node_nm: int
                        ) -> list[gamod.Evaluated]:
    """The paper's Fig. 2 baseline curve: exact NVDLA configs 64..2048 PEs."""
    out = []
    for pes in accmod.VALID_PE_COUNTS:
        acfg = accmod.nvdla_default(pes, node_nm)
        perf = dfmod.workload_perf(workload, acfg)
        area = accmod.area_model(acfg)
        cb = carbonmod.embodied_carbon(area.total_mm2, node_nm)
        out.append(gamod.Evaluated(
            gamod.Genome(0, 0, 0, 0, 0), acfg, perf.fps, cb.total_g,
            carbonmod.cdp(cb.total_g, perf.fps),
            carbonmod.cdp(cb.total_g, perf.fps), area.total_mm2))
    return out


def approx_only_sweep(workload: str, node_nm: int, max_drop: float,
                      mults: list[mm.ApproxMultiplier],
                      accuracy_fn: gamod.AccuracyFn = gamod.proxy_accuracy_drop
                      ) -> list[gamod.Evaluated]:
    """Fig. 2 'Appx' curves: every exact config with the best multiplier
    within the accuracy budget swapped in."""
    allowed = [m for m in mults if accuracy_fn(m) <= max_drop
               and not m.is_exact]
    if not allowed:
        return sweep_exact_configs(workload, node_nm)
    best_mult = min(allowed, key=lambda m: m.area_nand2eq)
    out = []
    for e in sweep_exact_configs(workload, node_nm):
        out.append(gamod.approx_variant(e.config, best_mult))
    return out
