"""LUT-level analysis of approximate multipliers.

An approximate 8-bit multiplier is fully characterized by its 256x256 product
LUT (indexed by the uint8 bit patterns of the two's-complement operands).
This module computes the standard error metrics used in the approximate-
computing literature and the *low-rank error factorization* that makes the
multiplier MXU-friendly on TPU (see DESIGN.md §3):

    E(a, b)  = a*b - m(a, b)                      (error surface)
    E       ~= sum_r  fu[r][ua] * fv[r][ub]       (truncated SVD)

so that  approx_matmul(A, B) ~= A@B - sum_r U_r(A) @ V_r(B)  with per-operand
256-entry table maps U_r, V_r -- no 2-D gathers, all matmuls.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import netlist as nlmod

MAX_ABS_PRODUCT = 128 * 128  # |a*b| <= 16384 for int8


@dataclasses.dataclass(frozen=True)
class ErrorStats:
    med: float          # mean |error|
    nmed: float         # med / max|product|
    mred: float         # mean relative error (over nonzero exact products)
    wce: int            # worst-case |error|
    error_rate: float   # fraction of (a,b) pairs with any error
    mse: float

    def as_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self)


def error_surface(lut: np.ndarray) -> np.ndarray:
    """E = exact - approx, (256, 256) int64."""
    return nlmod.exact_lut().astype(np.int64) - lut.astype(np.int64)


def error_stats(lut: np.ndarray) -> ErrorStats:
    e = error_surface(lut).astype(np.float64)
    exact = nlmod.exact_lut().astype(np.float64)
    ae = np.abs(e)
    nz = np.abs(exact) > 0
    mred = float(np.mean(ae[nz] / np.abs(exact[nz]))) if nz.any() else 0.0
    return ErrorStats(
        med=float(ae.mean()),
        nmed=float(ae.mean() / MAX_ABS_PRODUCT),
        mred=mred,
        wce=int(ae.max()),
        error_rate=float((ae > 0).mean()),
        mse=float((e * e).mean()),
    )


@dataclasses.dataclass(frozen=True)
class LowRankError:
    """E ~= fu.T-combination: E[ua, ub] ~= sum_r fu[r, ua] * fv[r, ub]."""
    fu: np.ndarray            # (rank, 256) float32
    fv: np.ndarray            # (rank, 256) float32
    residual_nmed: float      # NMED of (E - reconstruction)
    residual_wce: float
    rank: int

    def reconstruct(self) -> np.ndarray:
        return np.einsum("ru,rv->uv", self.fu.astype(np.float64),
                         self.fv.astype(np.float64))


def lowrank_error(lut: np.ndarray, rank: int) -> LowRankError:
    """Truncated SVD of the error surface, balanced factor scaling."""
    e = error_surface(lut).astype(np.float64)
    if rank <= 0 or not np.any(e):
        z = np.zeros((0, 256), dtype=np.float32)
        return LowRankError(z, z, 0.0 if not np.any(e) else float(
            np.abs(e).mean() / MAX_ABS_PRODUCT),
            float(np.abs(e).max()) if np.any(e) else 0.0, 0)
    u, s, vt = np.linalg.svd(e, full_matrices=False)
    r = min(rank, len(s))
    ss = np.sqrt(s[:r])
    fu = (u[:, :r] * ss).T.astype(np.float32)          # (r, 256)
    fv = (vt[:r, :] * ss[:, None]).astype(np.float32)  # (r, 256)
    rec = np.einsum("ru,rv->uv", fu.astype(np.float64), fv.astype(np.float64))
    resid = e - rec
    return LowRankError(
        fu=fu, fv=fv,
        residual_nmed=float(np.abs(resid).mean() / MAX_ABS_PRODUCT),
        residual_wce=float(np.abs(resid).max()),
        rank=r,
    )


def choose_rank(lut: np.ndarray, tol_nmed: float = 1e-4, max_rank: int = 8
                ) -> LowRankError:
    """Smallest rank whose residual NMED <= tol (capped at max_rank)."""
    best = lowrank_error(lut, 0)
    if best.residual_nmed <= tol_nmed:
        return best
    for r in range(1, max_rank + 1):
        best = lowrank_error(lut, r)
        if best.residual_nmed <= tol_nmed:
            return best
    return best


def effective_rank(lut: np.ndarray, tol_nmed: float = 1e-4, max_rank: int = 16
                   ) -> int:
    return choose_rank(lut, tol_nmed, max_rank).rank
