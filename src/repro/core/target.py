"""HardwareTarget: the single description of WHAT the stack runs on.

One frozen value ties the three layers of the repo together:

  * **die**: the per-die `AcceleratorConfig` (the paper's design point) —
    feeds the silicon area model and, through it, per-die Murphy yield;
  * **n_dies**: how many identical dies share the package — feeds the
    multi-die carbon model (`carbon.multi_die_carbon`: per-die yield +
    packaging/bonding overhead) and the dataflow model's inter-die
    communication delay (`dataflow` `n_dies` argument);
  * **mesh_axes**: the serving mesh (name, size) pairs — feeds the JAX
    device mesh the `repro.serving.Engine` shards its state and weights
    over (`sharding/rules.py`).  By construction the "model" axis size
    equals `n_dies`: one die = one tensor-parallel shard, so the carbon
    model, the analytical delay model, and the measured serving engine
    all describe the same partitioning.

The co-design GA emits targets (`ga.Genome.to_target`); the serving /
calibration layers consume them (`Engine(..., mesh=target.make_mesh())`,
`calibrate.calibrate_serving(target=...)`).
"""

from __future__ import annotations

import dataclasses

from . import accelerator as accmod
from . import carbon as carbonmod

#: Mesh axis names the serving stack understands (sharding/rules.py).
MESH_AXIS_NAMES = ("pod", "data", "model")


def parse_mesh_spec(spec: str) -> tuple[tuple[str, int], ...]:
    """Parse a ``"model=4,data=2"``-style mesh spec into (name, size)
    pairs.  Axis names must come from `MESH_AXIS_NAMES`; sizes must be
    positive ints.  The empty string parses to an empty tuple (caller
    falls back to its default mesh)."""
    spec = (spec or "").strip()
    if not spec:
        return ()
    axes = []
    seen = set()
    for part in spec.split(","):
        name, _, size = part.partition("=")
        name = name.strip()
        if name not in MESH_AXIS_NAMES:
            raise ValueError(
                f"unknown mesh axis {name!r} in {spec!r}; "
                f"expected axes from {MESH_AXIS_NAMES}")
        if name in seen:
            raise ValueError(f"duplicate mesh axis {name!r} in {spec!r}")
        seen.add(name)
        try:
            n = int(size)
        except ValueError:
            raise ValueError(f"bad size for mesh axis {name!r} in {spec!r}")
        if n < 1:
            raise ValueError(f"mesh axis {name!r} must be >= 1, got {n}")
        axes.append((name, n))
    # canonical pod -> data -> model order (device-locality convention)
    axes.sort(key=lambda a: MESH_AXIS_NAMES.index(a[0]))
    return tuple(axes)


@dataclasses.dataclass(frozen=True)
class HardwareTarget:
    """mesh shape x die count x per-die accelerator config."""
    die: accmod.AcceleratorConfig
    n_dies: int = 1
    mesh_axes: tuple[tuple[str, int], ...] = ()

    def __post_init__(self):
        if self.n_dies < 1:
            raise ValueError(f"n_dies must be >= 1, got {self.n_dies}")
        for name, _ in self.mesh_axes:
            if name not in MESH_AXIS_NAMES:
                raise ValueError(
                    f"unknown mesh axis {name!r}; expected axes from "
                    f"{MESH_AXIS_NAMES}")
        if self.mesh_axes:
            # an absent model axis means size 1, so a typo'd or missing
            # axis cannot silently serve monolithically while the carbon/
            # delay models charge for n_dies
            model = dict(self.mesh_axes).get("model", 1)
            if model != self.n_dies:
                raise ValueError(
                    f"mesh model axis ({model}) must equal n_dies "
                    f"({self.n_dies}): one die == one TP shard")

    # --- construction -----------------------------------------------------

    @classmethod
    def monolithic(cls, die: accmod.AcceleratorConfig,
                   data: int = 1) -> "HardwareTarget":
        return cls(die=die, n_dies=1,
                   mesh_axes=(("data", data), ("model", 1)))

    @classmethod
    def from_mesh_spec(cls, die: accmod.AcceleratorConfig,
                       spec: str) -> "HardwareTarget":
        axes = parse_mesh_spec(spec)
        return cls(die=die, n_dies=dict(axes).get("model", 1),
                   mesh_axes=axes)

    # --- derived hardware quantities --------------------------------------

    @property
    def total_pes(self) -> int:
        return self.die.num_pes * self.n_dies

    @property
    def die_area_mm2(self) -> float:
        return accmod.area_model(self.die).total_mm2

    @property
    def total_area_mm2(self) -> float:
        """Total patterned silicon across dies (excl. interposer)."""
        return self.n_dies * self.die_area_mm2

    def carbon(self, ci_fab: float | None = None
               ) -> carbonmod.MultiDieBreakdown:
        return carbonmod.multi_die_carbon(self.die_area_mm2, self.n_dies,
                                          self.die.node_nm, ci_fab)

    def fps(self, workload: str) -> float:
        """Analytical FPS of the full package (all dies cooperating),
        including inter-die all-gather delay."""
        from . import dataflow as dfmod
        full = dataclasses.replace(
            self.die, pe_cols=self.die.pe_cols * self.n_dies)
        return dfmod.workload_perf(workload, full, self.n_dies).fps

    # --- serving-side surface ---------------------------------------------

    @property
    def tp_degree(self) -> int:
        return dict(self.mesh_axes).get("model", self.n_dies)

    def mesh_spec(self) -> str:
        return ",".join(f"{n}={s}" for n, s in self.mesh_axes)

    def make_mesh(self):
        """Concrete JAX device mesh for this target (lazy jax import —
        `core` consumers that only want the carbon model never touch
        device state)."""
        from repro.launch import mesh as meshmod
        if not self.mesh_axes:
            return meshmod.make_host_mesh(model=self.n_dies)
        return meshmod.mesh_from_axes(self.mesh_axes)
