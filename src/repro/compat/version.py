"""Version probe for the installed JAX.

Every shim in this package keys off either the parsed version tuple or a
direct feature probe (hasattr / trial construction).  Feature probes are
preferred — they survive backports and dev builds whose version strings
don't parse cleanly — but the tuple is exposed for docs/diagnostics and
coarse gating.
"""

from __future__ import annotations

import functools

import jax

#: Oldest JAX this codebase is tested against (see README "Supported JAX
#: versions").  Not enforced at import time; compat probes do the real work.
MIN_SUPPORTED = (0, 4, 30)


@functools.lru_cache(maxsize=None)
def jax_version() -> tuple[int, ...]:
    """Installed JAX version as a tuple of ints, e.g. (0, 4, 37).

    Non-numeric suffixes (".dev", "rc1") are dropped from the component in
    which they appear; parsing never raises.
    """
    parts: list[int] = []
    for piece in jax.__version__.split("."):
        digits = ""
        for ch in piece:
            if not ch.isdigit():
                break
            digits += ch
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts[:3])


def at_least(*want: int) -> bool:
    """True when the installed JAX is >= the given version components."""
    return jax_version() >= tuple(want)


@functools.lru_cache(maxsize=None)
def backend() -> str:
    """Default JAX backend name ("cpu" / "gpu" / "tpu").

    Cached: calling this initializes JAX's backends, so keep it out of
    module import paths (the dry-run must set XLA_FLAGS before any jax
    device-state touch — same rule as launch/mesh.py).
    """
    return jax.default_backend()


def is_tpu_backend() -> bool:
    """True when the default backend is a real TPU (Pallas compiles through
    Mosaic); False means Pallas TPU kernels must run with interpret=True."""
    return backend() == "tpu"
