"""Mesh-construction shims.

Two APIs drifted across JAX releases:

* `jax.sharding.AbstractMesh` — newer JAX takes `(axis_sizes, axis_names)`
  as two sequences; 0.4.x takes a single tuple of `(name, size)` pairs.
  The constructor style is feature-probed once (trial construction of a
  1-element mesh) and cached.
* `jax.make_mesh` — present since 0.4.35; older versions need a manual
  device reshape into `jax.sharding.Mesh`.

Everything here is callable-only (no module-level device probes): importing
this module never initializes JAX device state.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh

# AbstractMesh appeared mid-0.4.x; importing it unconditionally would break
# this package on the oldest JAX the make_mesh fallback below exists for.
_AbstractMesh = getattr(jax.sharding, "AbstractMesh", None)


@functools.lru_cache(maxsize=None)
def _abstract_mesh_style() -> str:
    """"split" = AbstractMesh(sizes, names); "pairs" = 0.4.x pair-tuples."""
    try:
        _AbstractMesh((1,), ("_compat_probe",))
        return "split"
    except TypeError:
        pass
    _AbstractMesh((("_compat_probe", 1),))
    return "pairs"


def make_abstract_mesh(axis_shapes: Sequence[int],
                       axis_names: Sequence[str]):
    """Device-free mesh for sharding-rule evaluation, on any JAX that has
    AbstractMesh (raises a targeted error on ones that predate it)."""
    if _AbstractMesh is None:
        raise NotImplementedError(
            f"jax {jax.__version__} has no jax.sharding.AbstractMesh; "
            "build a concrete mesh via repro.compat.make_mesh instead")
    sizes = tuple(int(s) for s in axis_shapes)
    names = tuple(str(n) for n in axis_names)
    if len(sizes) != len(names):
        raise ValueError(f"{len(sizes)} axis sizes vs {len(names)} names")
    if _abstract_mesh_style() == "split":
        return _AbstractMesh(sizes, names)
    return _AbstractMesh(tuple(zip(names, sizes)))


def shard_map_fn():
    """`jax.shard_map` (0.6+) or `jax.experimental.shard_map.shard_map`
    (0.4.x) — the per-device programming surface the mesh-aware kernel
    dispatch uses.  Callers use the 0.4.x `check_rep` keyword; newer JAX
    renamed it to `check_vma`, so the shim translates when the native
    signature lacks `check_rep`."""
    import inspect

    if hasattr(jax, "shard_map"):
        native = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as native
    try:
        has_check_rep = "check_rep" in inspect.signature(native).parameters
    except (TypeError, ValueError):  # C-level / wrapped signature
        has_check_rep = True
    if has_check_rep:
        return native

    def shard_map_compat(f, **kwargs):
        if "check_rep" in kwargs:
            kwargs["check_vma"] = kwargs.pop("check_rep")
        return native(f, **kwargs)

    return shard_map_compat


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Sequence | None = None) -> Mesh:
    """`jax.make_mesh` where available, manual Mesh construction otherwise.

    With `devices=None` on a make_mesh-capable JAX this defers entirely to
    jax.make_mesh (which picks a contiguous, locality-aware device order);
    the fallback uses jax.devices() order.
    """
    sizes = tuple(int(s) for s in axis_shapes)
    names = tuple(str(n) for n in axis_names)
    n = math.prod(sizes)
    if devices is None and hasattr(jax, "make_mesh"):
        return jax.make_mesh(sizes, names)
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n:
        raise ValueError(f"mesh {dict(zip(names, sizes))} needs {n} devices, "
                         f"have {len(devs)}")
    return Mesh(np.asarray(devs[:n], dtype=object).reshape(sizes), names)
