"""Pallas TPU API shims.

JAX renamed the TPU compiler-params dataclass across releases
(`pltpu.TPUCompilerParams` on 0.4.x / early 0.5.x, `pltpu.CompilerParams`
after the rename; very old versions took a plain dict keyed by backend).
Kernel modules must not spell any of these directly — they call
`tpu_compiler_params(...)` and get whatever the installed JAX accepts.

Dimension-semantics strings are normalized too: the Mosaic vocabulary is
("parallel", "arbitrary"); "sequential" is accepted as an alias for
"arbitrary" since some external kernel code uses that spelling.
"""

from __future__ import annotations

from typing import Any, Sequence

from jax.experimental.pallas import tpu as pltpu

_DIM_SEMANTICS_ALIASES = {
    "parallel": "parallel",
    "arbitrary": "arbitrary",
    "sequential": "arbitrary",
}

# Feature probe, newest spelling first.
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)


def normalize_dimension_semantics(sem: Sequence[str]) -> tuple[str, ...]:
    """Map each grid-dimension semantic onto the Mosaic vocabulary."""
    out = []
    for s in sem:
        canon = _DIM_SEMANTICS_ALIASES.get(str(s).lower())
        if canon is None:
            raise ValueError(
                f"unknown dimension semantic {s!r}; expected one of "
                f"{sorted(_DIM_SEMANTICS_ALIASES)}")
        out.append(canon)
    return tuple(out)


def tpu_compiler_params(*, dimension_semantics: Sequence[str] | None = None,
                        **kwargs: Any) -> Any:
    """Build the `compiler_params=` argument for a TPU `pl.pallas_call`.

    Returns the params dataclass the installed JAX exposes; on ancient
    versions with neither class, falls back to the dict form pallas_call
    accepted there.
    """
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = \
            normalize_dimension_semantics(dimension_semantics)
    if _PARAMS_CLS is None:
        return dict(mosaic=kwargs)
    return _PARAMS_CLS(**kwargs)


def compiler_params_cls() -> Any:
    """The resolved params class (None on dict-form JAX). For tests/docs."""
    return _PARAMS_CLS
