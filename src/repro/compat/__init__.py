"""Single home for every version-sensitive JAX API this codebase touches.

JAX's experimental surfaces (Pallas TPU params, AbstractMesh, make_mesh)
have renamed or re-signatured across the 0.4.x -> 0.5+ line; instead of
patching call sites each time, all drift is absorbed here behind stable
functions.  Rules of the road:

* No module outside `repro.compat` may reference `pltpu.*CompilerParams`
  or construct `jax.sharding.AbstractMesh` directly (enforced by
  tests/test_compat.py).
* Shims feature-probe (hasattr / trial construction), not version-compare,
  wherever possible.
* Importing this package never initializes JAX device state.
"""

from repro.compat.mesh import make_abstract_mesh, make_mesh, shard_map_fn
from repro.compat.pallas import (compiler_params_cls,
                                 normalize_dimension_semantics,
                                 tpu_compiler_params)
from repro.compat.version import (MIN_SUPPORTED, at_least, backend,
                                  is_tpu_backend, jax_version)

__all__ = [
    "MIN_SUPPORTED",
    "at_least",
    "backend",
    "compiler_params_cls",
    "is_tpu_backend",
    "jax_version",
    "make_abstract_mesh",
    "make_mesh",
    "normalize_dimension_semantics",
    "shard_map_fn",
    "tpu_compiler_params",
]
