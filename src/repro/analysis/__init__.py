"""JAX-aware static analysis & sanitizers for the repro codebase.

Four checkers behind one CLI (`python -m repro.analysis`):

* ``jit`` (lint.py) — AST lint for jit hazards: host syncs, Python
  control flow on traced values, numpy on tracers, mutable static-arg
  defaults;
* ``retrace`` (retrace.py) — runtime compile-budget sanitizer over the
  serving engine, the batched GA, and the Pallas kernels;
* ``sharding`` (coverage.py) — every family's param/cache/batch pytree
  leaf must match a sharding rule or an explicit exemption;
* ``pallas`` (contracts.py) — declared VMEM models, grid divisibility,
  dispatch-budget consistency, and K-tail masking checked against the
  kernels' actual BlockSpecs.

See docs/ANALYSIS.md for finding codes and suppression formats.
"""

from repro.analysis.findings import (  # noqa: F401
    CODES, Baseline, Finding, apply_suppressions, inline_allowed)
from repro.analysis.retrace import (  # noqa: F401
    RetraceSanitizer, instrument_engine)
