"""RT: runtime retrace/recompile sanitizer.

The repo's throughput claims assume strict compile budgets: the serving
Engine compiles decode exactly once and prefill once per prompt bucket
per (config, phase); the batched GA compiles one step function; a Pallas
kernel compiles once per (shape, rank, backend).  Nothing guards those
budgets — a sharding drift or a non-static argument silently turns one
compile into one-per-step and the "fast path" quietly becomes a
recompile storm.

`RetraceSanitizer` wraps jitted entry points, counts real backend
compiles per watched name (JAX's monitoring event, one per
`backend.compile`; jit `_cache_size()` deltas as the fallback, and for
watches whose fn is driven indirectly rather than through the proxy)
with the call sites that triggered them, and enforces declared budgets:

* RT201 — total compiles exceeded the declared budget;
* RT202 — a *repeat* call (same watched fn, after its warmup calls)
  triggered a fresh trace: the recompile-storm signature.

Exposed three ways: `instrument_engine(...)` for the serving engine
(used by `bench_serving.py --sanitize-retrace`), the `retrace_sanitizer`
pytest fixture (tests/conftest.py), and the CLI `retrace` checker which
drives a micro serving trace + GA + kernel workload and asserts every
budget (see `check()`).
"""

from __future__ import annotations

import dataclasses
import traceback
from typing import Any, Callable

from repro.analysis.findings import Finding


def cache_size(fn: Any) -> int:
    """Compile-cache entry count of a jit-wrapped callable (0 if the
    running JAX does not expose it — the sanitizer then degrades to a
    no-op rather than failing the build)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return 0
    try:
        return int(probe())
    except Exception:
        return 0


_BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class _CompileCounter:
    """Process-global count of real XLA backend compiles, via JAX's
    monitoring event.  `_cache_size()` alone over-counts on multi-device
    meshes: the C++ jit fastpath can add a second cache key for the same
    executable (observed on a forced-host mesh: entry 2 appears on the
    second decode call with no "Compiling ..." log), so a proxied call
    instead attributes monitoring events — one per actual
    `backend.compile` — to the in-flight watch."""

    count = 0
    _registered = False

    @classmethod
    def ensure(cls) -> bool:
        if cls._registered:
            return True
        try:
            from jax._src import monitoring

            def _on_event(event, duration, **kwargs):
                if event == _BACKEND_COMPILE_EVENT:
                    cls.count += 1

            monitoring.register_event_duration_secs_listener(_on_event)
            cls._registered = True
        except Exception:
            return False
        return True


def _callsite() -> str:
    for frame in reversed(traceback.extract_stack()[:-3]):
        if "repro/analysis/retrace" not in frame.filename:
            return f"{frame.filename}:{frame.lineno}"
    return "<unknown>"


@dataclasses.dataclass
class _Watch:
    name: str
    fn: Any                       # the jitted callable being observed
    budget: int                   # max total compiles
    warmup: int                   # calls allowed to trace before RT202 arms
    calls: int = 0
    compile_events: list = dataclasses.field(default_factory=list)
    base: int = 0                 # cache size when watching began
    proxied_compiles: int = 0     # backend compiles seen during proxy calls

    @property
    def compiles(self) -> int:
        if self.calls and _CompileCounter._registered:
            return self.proxied_compiles
        # fn driven outside the proxy (e.g. the kernel check watches the
        # jit an ops.* entry point calls internally): cache-size delta
        return cache_size(self.fn) - self.base


class _Proxy:
    """Callable wrapper recording compile deltas per call."""

    def __init__(self, watch: _Watch):
        self._watch = watch

    def __call__(self, *args, **kwargs):
        w = self._watch
        events = _CompileCounter.ensure()
        before = _CompileCounter.count if events else cache_size(w.fn)
        out = w.fn(*args, **kwargs)
        w.calls += 1
        after = _CompileCounter.count if events else cache_size(w.fn)
        if after > before:
            w.proxied_compiles += after - before
            w.compile_events.append(
                {"call": w.calls, "site": _callsite(),
                 "compiles": after - before})
        return out

    def __getattr__(self, name):  # pass jit attrs (lower, _cache_size, ...)
        return getattr(self._watch.fn, name)


class RetraceSanitizer:
    """Watch jitted entry points against declared compile budgets."""

    def __init__(self):
        self._watches: dict[str, _Watch] = {}

    def watch(self, name: str, fn: Any, budget: int,
              warmup: int | None = None) -> Callable:
        """Register `fn` under `budget` total compiles; returns a proxy
        to call instead of `fn` (per-callsite attribution).  `warmup`
        (default: `budget`) is the number of leading calls allowed to
        trace before a fresh compile counts as a retrace (RT202)."""
        if name in self._watches:
            raise ValueError(f"duplicate watch {name!r}")
        w = _Watch(name, fn, budget, budget if warmup is None else warmup,
                   base=cache_size(fn))
        self._watches[name] = w
        return _Proxy(w)

    def findings(self) -> list[Finding]:
        out: list[Finding] = []
        for w in self._watches.values():
            sites = "; ".join(
                f"call #{e['call']} at {e['site']}"
                for e in w.compile_events) or "no attributed sites"
            if w.compiles > w.budget:
                out.append(Finding(
                    "RT201", w.name,
                    f"{w.compiles} compiles (budget {w.budget}) over "
                    f"{w.calls} calls — {sites}"))
            late = [e for e in w.compile_events if e["call"] > w.warmup]
            if late and w.compiles > w.budget:
                pass  # already reported as RT201; don't double-count
            elif late:
                out.append(Finding(
                    "RT202", w.name,
                    f"retrace on repeat call(s) "
                    f"{[e['call'] for e in late]} after {w.warmup} warmup "
                    f"call(s) — {sites}"))
        return out

    def report(self) -> dict:
        return {w.name: {"calls": w.calls, "compiles": w.compiles,
                         "budget": w.budget,
                         "events": list(w.compile_events)}
                for w in self._watches.values()}

    def assert_ok(self) -> None:
        bad = self.findings()
        if bad:
            raise AssertionError(
                "retrace sanitizer: " + "; ".join(f.render() for f in bad))


# --------------------------------------------------------------------------
# serving-engine instrumentation
# --------------------------------------------------------------------------

def _tier_watch_names(engine) -> dict[str, tuple[str, str]]:
    """Watch name per (phase, tier).  Single-tier engines keep the
    historical unsuffixed names (the schema checks key on them);
    multi-tier engines get one watch per tier — each tier's decode and
    prefill compile once, and a tier *switch* must not retrace."""
    out = {}
    tiers = getattr(engine, "tiers", ("exact",))  # duck-typed engines
    multi = len(tiers) > 1
    for t in tiers:
        suffix = f"[{t}]" if multi else ""
        out[t] = (f"serving/engine:decode{suffix}",
                  f"serving/engine:prefill{suffix}")
    return out


def engine_budgets(engine) -> dict[str, int]:
    """Declared compile budgets for one Engine's jitted phases: decode
    compiles once (per tier), prefill once per prompt bucket (per
    tier), the first-token sampler and the arena slot-insert once
    each.  The paged engine widens the contract, not the budgets: one
    extra prefill shape when chunking uses a non-bucket chunk length
    (`_prefill_shapes`), chunk/verify once per tier, draft once —
    paged + chunked + speculative serving must not retrace per step
    either."""
    b = {"serving/engine:first_token": 1,
         "serving/arena:insert": 1}
    prefill_shapes = getattr(engine, "_prefill_shapes", len(engine.buckets))
    for tier, (dec_name, pre_name) in _tier_watch_names(engine).items():
        b[dec_name] = 1
        b[pre_name] = prefill_shapes
        suffix = dec_name[len("serving/engine:decode"):]
        if getattr(engine, "_tier_chunk_fns", None):
            b[f"serving/paged:chunk{suffix}"] = 1
        if tier in getattr(engine, "_tier_verify_fns", {}):
            b[f"serving/paged:verify{suffix}"] = 1
    if getattr(engine, "_draft", None) is not None:
        b["serving/paged:draft"] = 1
    return b


def instrument_engine(engine, sanitizer: RetraceSanitizer | None = None
                      ) -> RetraceSanitizer:
    """Swap an Engine's jitted entry points for watched proxies.  Must
    run before the engine serves traffic (budgets count from here).
    Proxies are installed in the engine's per-tier tables (then
    re-activated), so they stay live across `set_tier` switches."""
    s = sanitizer or RetraceSanitizer()
    b = engine_budgets(engine)
    for tier, (dec_name, pre_name) in _tier_watch_names(engine).items():
        engine._tier_decode_fns[tier] = s.watch(
            dec_name, engine._tier_decode_fns[tier], b[dec_name])
        engine._tier_prefill_fns[tier] = s.watch(
            pre_name, engine._tier_prefill_fns[tier], b[pre_name])
        suffix = dec_name[len("serving/engine:decode"):]
        chunk_fns = getattr(engine, "_tier_chunk_fns", None)
        if chunk_fns:
            chunk_fns[tier] = s.watch(f"serving/paged:chunk{suffix}",
                                      chunk_fns[tier],
                                      b[f"serving/paged:chunk{suffix}"])
        verify_fns = getattr(engine, "_tier_verify_fns", {})
        if tier in verify_fns:
            verify_fns[tier] = s.watch(f"serving/paged:verify{suffix}",
                                       verify_fns[tier],
                                       b[f"serving/paged:verify{suffix}"])
    engine._activate(engine._tier)
    if getattr(engine, "_draft", None) is not None:
        engine._draft = s.watch("serving/paged:draft", engine._draft,
                                b["serving/paged:draft"])
    engine._first = s.watch("serving/engine:first_token", engine._first,
                            b["serving/engine:first_token"])
    engine._arena._insert = s.watch("serving/arena:insert",
                                    engine._arena._insert,
                                    b["serving/arena:insert"])
    return s


# --------------------------------------------------------------------------
# CLI checker: micro workloads that prove the budgets hold end to end
# --------------------------------------------------------------------------

def _check_serving() -> list[Finding]:
    from repro import configs
    from repro.serving import Engine, Request, SamplingParams

    cfg = configs.apply_overrides(configs.get_config("tinyllama-1.1b"),
                                  reduced=True)
    eng = Engine(cfg, capacity=2, max_len=48, seed=0)
    s = instrument_engine(eng)
    for i, (n, temp) in enumerate([(4, 0.0), (9, 0.8), (6, 0.0),
                                   (12, 1.1)]):
        eng.submit(Request(
            f"rt{i}", list(range(1, n + 1)),
            SamplingParams(max_new_tokens=4, temperature=temp,
                           top_k=8 if temp else 0, seed=i),
            arrival=float(i)))
    eng.run_until_complete()
    return s.findings()


def _check_ga() -> list[Finding]:
    import jax
    from repro.core import ga_batched

    s = RetraceSanitizer()
    step = s.watch("core/ga_batched:step", ga_batched._ga_step, budget=1,
                   warmup=1)
    ev = s.watch("core/ga_batched:evaluate",
                 ga_batched.evaluate_population, budget=1, warmup=1)
    space = ga_batched.build_space("vgg16", node_nm=14, fps_min=0.0,
                                  max_accuracy_drop=0.02)
    tables = space.tables()
    key = jax.random.key(0)
    pop = ga_batched._random_genes(jax.random.key(1), 32,
                                   space.gene_sizes, tables["allowed"])
    pop = ga_batched._snap_die_gene(pop, tables["die_ok"])
    for _gen in range(3):  # one step fn across all generations
        key, sub = jax.random.split(key)
        pop, _, _ = step(sub, pop, tables, 14, space.gene_sizes, 3, 2,
                         0.9, 0.1, 50.0)
    ev(pop, tables, 14)
    ev(pop, tables, 14)  # repeat: must not retrace
    return s.findings()


def _check_kernels() -> list[Finding]:
    import jax
    import numpy as np
    from repro.approx import gemm as gemm_mod
    from repro.core import multipliers as mm
    from repro.core import netlist as nl
    from repro.kernels import approx_qgemm as qk
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    a = jax.numpy.asarray(rng.integers(-127, 128, (128, 128), np.int8))
    b = jax.numpy.asarray(rng.integers(-127, 128, (128, 128), np.int8))
    mask = rng.random(len(nl.bw8().prunable_gates())) < 0.03
    spec = gemm_mod.from_multiplier(mm.pruned(mask, name="rt_check"),
                                    rank=1)
    base_fused = cache_size(qk.approx_qgemm_fused)
    ops.approx_qgemm(a, b, spec)   # prime: one compile per (shape, rank)
    watch = RetraceSanitizer()
    # the kernel contract: repeat calls at identical (shape, rank,
    # backend) must hit the jit cache — budget 0 NEW compiles from here
    watch.watch("kernels/approx_qgemm:fused(128x128x128,r1)",
                qk.approx_qgemm_fused, budget=0, warmup=0)
    ops.approx_qgemm(a, b, spec)   # identical shapes: zero new compiles
    ops.approx_qgemm(a, b, spec)
    if cache_size(qk.approx_qgemm_fused) == base_fused == 0:
        return []  # _cache_size unavailable on this JAX: degrade quietly
    return watch.findings()


def check(root: str | None = None) -> list[Finding]:
    """CLI entry: run the micro serving/GA/kernel workloads under watch.

    Runtime sanitization, not static analysis — but the budgets it
    enforces are the repo's documented compile contracts, so a failure
    here is a correctness regression, not flakiness."""
    findings: list[Finding] = []
    findings.extend(_check_serving())
    findings.extend(_check_ga())
    findings.extend(_check_kernels())
    return findings
