"""Finding model + suppression/baseline machinery for `repro.analysis`.

Every checker emits `Finding` records with a stable per-class code
(JHxxx jit-hazard lint, RTxxx retrace sanitizer, SCxxx sharding
coverage, PCxxx Pallas contracts).  Two suppression channels exist:

* an inline comment on the flagged line — ``# analysis: allow[JH102]
  optional reason`` — for file-anchored lint findings;
* a checked-in baseline file (``analysis-baseline.json`` at the repo
  root): a list of ``{"code", "path", "reason"}`` entries matched on
  (code, path).  ``path`` is the repo-relative file for lint findings
  and a logical location (e.g. ``serving/engine:decode``) for runtime
  checkers.

The CLI exits non-zero on any *unsuppressed* finding; suppressed ones
still appear in the JSON report with their reasons, so nothing is
silently dropped.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re

#: code -> one-line description, the authoritative registry (docs/ANALYSIS.md
#: mirrors this table; tests assert the two stay in sync).
CODES = {
    # jit-hazard lint (lint.py)
    "JH101": "host-sync call (.item()/float()/np.asarray/jax.device_get) "
             "inside a jit-reachable function",
    "JH102": "Python control flow on a traced value inside a "
             "jit-reachable function",
    "JH103": "numpy op applied to a potentially traced argument inside a "
             "jit-reachable function",
    "JH104": "unhashable/mutable default for a static jit argument",
    # retrace sanitizer (retrace.py)
    "RT201": "jit compile budget exceeded for a watched entry point",
    "RT202": "retrace on a repeated call with unchanged shapes "
             "(recompile storm)",
    # sharding coverage (coverage.py)
    "SC301": "param leaf matches no sharding rule and no exemption",
    "SC302": "decode-cache leaf matches no cache sharding rule",
    "SC303": "batch leaf left unsharded on a data-parallel mesh",
    # Pallas contracts (contracts.py)
    "PC401": "declared VMEM model drifted from the kernel's actual "
             "BlockSpecs",
    "PC402": "kernel grid/block shape does not tile the operands",
    "PC403": "dispatch admits a shape whose recomputed working set busts "
             "the VMEM budget",
    "PC404": "K-tail masking contract violated (padded fused GEMM is not "
             "bit-exact)",
    "PC405": "kernel-tuning cache entry busts the VMEM budget it was "
             "tuned under",
}

_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\[([A-Z]{2}\d{3})\]")


@dataclasses.dataclass
class Finding:
    code: str
    path: str            # repo-relative file, or logical location
    message: str
    line: int = 0        # 1-based; 0 when not file-anchored
    checker: str = ""
    suppressed: bool = False
    suppress_reason: str = ""

    def __post_init__(self):
        assert self.code in CODES, f"unregistered finding code {self.code}"
        if not self.checker:
            self.checker = {"JH": "jit", "RT": "retrace", "SC": "sharding",
                            "PC": "pallas"}[self.code[:2]]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.code}{tag} {loc}: {self.message}"


def inline_allowed(source_line: str) -> str | None:
    """Code allowed by an inline ``# analysis: allow[CODE]`` comment."""
    m = _ALLOW_RE.search(source_line)
    return m.group(1) if m else None


class Baseline:
    """Checked-in (code, path) suppression list."""

    def __init__(self, entries: list[dict]):
        for e in entries:
            missing = {"code", "path", "reason"} - set(e)
            if missing:
                raise ValueError(f"baseline entry {e} missing {missing}")
            if e["code"] not in CODES:
                raise ValueError(f"baseline entry {e}: unknown code")
        self.entries = entries
        self.hits: set[int] = set()

    @classmethod
    def load(cls, path: str | None) -> "Baseline":
        if path is None or not os.path.exists(path):
            return cls([])
        with open(path) as f:
            return cls(json.load(f))

    def match(self, finding: Finding) -> str | None:
        for i, e in enumerate(self.entries):
            if e["code"] == finding.code and e["path"] == finding.path:
                self.hits.add(i)
                return e["reason"]
        return None

    def unused(self) -> list[dict]:
        """Stale entries (reported so the baseline cannot rot silently)."""
        return [e for i, e in enumerate(self.entries) if i not in self.hits]


def apply_suppressions(findings: list[Finding], baseline: Baseline,
                       root: str) -> list[Finding]:
    """Mark findings covered by the baseline or an inline allow comment."""
    cache: dict[str, list[str]] = {}
    for f in findings:
        reason = baseline.match(f)
        if reason is not None:
            f.suppressed, f.suppress_reason = True, f"baseline: {reason}"
            continue
        if not f.line:
            continue
        if f.path not in cache:
            full = os.path.join(root, f.path)
            try:
                with open(full) as fh:
                    cache[f.path] = fh.read().splitlines()
            except OSError:
                cache[f.path] = []
        lines = cache[f.path]
        if 0 < f.line <= len(lines) and \
                inline_allowed(lines[f.line - 1]) == f.code:
            f.suppressed, f.suppress_reason = True, "inline allow"
    return findings
