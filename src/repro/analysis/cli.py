"""`python -m repro.analysis` — run the JAX-aware checkers and report.

Exit codes: 0 clean (or all findings suppressed), 1 unsuppressed
findings, 2 a checker itself crashed (infrastructure failure, distinct
from "the repo has findings" so CI can tell them apart).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from repro.analysis.findings import Baseline, apply_suppressions

#: checker name -> module path; each module exposes `check(root) ->
#: list[Finding]`.  Import lazily: the static checkers must not pay jax
#: startup, and a broken runtime checker must not take down `--checks jit`.
CHECKERS = {
    "jit": "repro.analysis.lint",
    "sharding": "repro.analysis.coverage",
    "pallas": "repro.analysis.contracts",
    "retrace": "repro.analysis.retrace",
}

DEFAULT_BASELINE = "analysis-baseline.json"


def _repo_root() -> str:
    # src/repro/analysis/cli.py -> repo root is three dirs above src/
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def run(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis & sanitizers")
    ap.add_argument("--checks", default=",".join(CHECKERS),
                    help="comma-separated subset of: " + ",".join(CHECKERS))
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="write the report here as well as stdout")
    ap.add_argument("--root", default=_repo_root(),
                    help="repo root (default: auto-detected)")
    ap.add_argument("--baseline", default=None,
                    help=f"suppression file (default: "
                         f"<root>/{DEFAULT_BASELINE})")
    args = ap.parse_args(argv)

    names = [c.strip() for c in args.checks.split(",") if c.strip()]
    unknown = [c for c in names if c not in CHECKERS]
    if unknown:
        ap.error(f"unknown checker(s) {unknown}; known: {list(CHECKERS)}")

    baseline_path = args.baseline or os.path.join(args.root,
                                                  DEFAULT_BASELINE)
    baseline = Baseline.load(baseline_path)

    findings, errors = [], []
    for name in names:
        import importlib
        try:
            mod = importlib.import_module(CHECKERS[name])
            findings.extend(mod.check(args.root))
        except Exception:
            errors.append({"checker": name,
                           "error": traceback.format_exc()})
    apply_suppressions(findings, baseline, args.root)
    open_findings = [f for f in findings if not f.suppressed]

    report = {
        "checks": names,
        "findings": [f.as_dict() for f in findings],
        "open": len(open_findings),
        "suppressed": len(findings) - len(open_findings),
        "stale_baseline_entries": baseline.unused(),
        "errors": errors,
    }
    if args.format == "json":
        text = json.dumps(report, indent=2)
    else:
        lines = [f.render() for f in findings]
        for e in errors:
            lines.append(f"ERROR {e['checker']}: checker crashed\n"
                         f"{e['error']}")
        for e in report["stale_baseline_entries"]:
            lines.append(f"stale baseline entry: {e}")
        lines.append(f"analysis: {len(open_findings)} open, "
                     f"{report['suppressed']} suppressed "
                     f"({', '.join(names)})")
        text = "\n".join(lines)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    if errors:
        return 2
    return 1 if open_findings else 0


def main() -> None:
    sys.exit(run())
