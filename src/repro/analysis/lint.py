"""JH: jit-hazard AST lint over `src/repro/`.

Statically flags the recompile-storm and tracer-leak bug classes on any
function that is *jit-reachable* — decorated with `jax.jit` /
`functools.partial(jax.jit, ...)`, wrapped by a `jax.jit(fn)` call
expression anywhere in the package (the serving engine's
`jax.jit(self._decode_impl)` pattern), or reachable from such a function
through the package call graph:

  JH101  host-sync calls: `.item()`, `float(param)`, `np.asarray(...)`,
         `jax.device_get(...)` — each forces a device round-trip per call
         under trace, or leaks a tracer to the host;
  JH102  Python `if`/`while`/ternary whose test computes on a traced
         value (`jnp.any(x)`, `x.sum() > 0`, ...) — a trace-time
         ConcretizationError or, with static inputs, a silent per-value
         recompile;
  JH103  numpy ops applied to potentially traced arguments — numpy
         silently materializes the tracer;
  JH104  a parameter named in `static_argnames` with a mutable default
         (list/dict/set) — unhashable static args fail the jit cache
         lookup on every call.

Reachability is intentionally an over-approximation resolved by name
(bare calls within a module, `self.method`, and imported-module
attributes); the family-dispatch indirection in `models/api.py`
(`family_module(cfg).forward(...)`) is bridged by the explicit
DYNAMIC_EDGES table so model code stays in scope.  False positives are
suppressed inline (`# analysis: allow[JHxxx] reason`) or via the
baseline file — never by weakening the pass.
"""

from __future__ import annotations

import ast
import dataclasses
import os

from repro.analysis.findings import Finding

#: api-level entry points dispatch on cfg.family at runtime; the static
#: call graph cannot see through `family_module(cfg).<name>(...)`, so these
#: edges are declared: api.<name> -> every family module's <name>.
_FAMILY_MODULES = ("transformer", "mamba2", "rglru", "encdec")
_FAMILY_API = ("forward", "prefill", "decode_step", "init_cache",
               "init_params")
DYNAMIC_EDGES = {
    (os.path.join("src", "repro", "models", "api.py"), name): [
        (os.path.join("src", "repro", "models", f"{mod}.py"), name)
        for mod in _FAMILY_MODULES]
    for name in _FAMILY_API
}

_HOST_SYNC_NP = {"asarray", "array", "copy", "save", "savez", "tolist"}
_ARRAY_BOOL_METHODS = {"any", "all", "sum", "max", "min", "mean", "item",
                       "argmax", "argmin"}


@dataclasses.dataclass
class FunctionInfo:
    module: str                   # repo-relative path
    qualname: str                 # e.g. "Engine._decode_impl"
    node: ast.AST
    params: list[str]
    static_names: set[str]
    jit_entry: bool = False
    lineno: int = 0

    @property
    def key(self) -> tuple[str, str]:
        return (self.module, self.qualname)


def _is_jit_attr(node: ast.AST, jax_aliases: set[str],
                 jit_names: set[str]) -> bool:
    """`jax.jit` / bare `jit` (imported from jax)."""
    if isinstance(node, ast.Attribute) and node.attr == "jit" and \
            isinstance(node.value, ast.Name) and \
            node.value.id in jax_aliases:
        return True
    return isinstance(node, ast.Name) and node.id in jit_names


def _static_argnames(call: ast.Call) -> set[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return {e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
            if isinstance(kw.value, ast.Constant) and \
                    isinstance(kw.value.value, str):
                return {kw.value.value}
    return set()


class _ModuleIndex(ast.NodeVisitor):
    """One module's functions, imports, and jit registration sites."""

    def __init__(self, module: str, tree: ast.Module):
        self.module = module
        self.functions: dict[str, FunctionInfo] = {}
        self.import_mod: dict[str, str] = {}    # alias -> dotted module
        self.import_from: dict[str, tuple[str, str]] = {}  # name -> (mod, nm)
        self.jax_aliases: set[str] = set()
        self.np_aliases: set[str] = set()
        self.jnp_aliases: set[str] = set()
        self.jit_names: set[str] = set()        # `from jax import jit`
        self.partial_names: set[str] = set()    # functools.partial aliases
        # qualnames jit-wrapped via call expressions (`jax.jit(fn)`), with
        # the static names the wrapping declared
        self.wrapped: dict[str, set[str]] = {}
        self._stack: list[str] = []
        self.visit(tree)

    # --- imports ---------------------------------------------------------

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            alias = a.asname or a.name.split(".")[0]
            self.import_mod[alias] = a.name
            if a.name == "jax":
                self.jax_aliases.add(alias)
            if a.name == "numpy":
                self.np_aliases.add(alias)
            if a.name == "jax.numpy":
                self.jnp_aliases.add(alias)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        for a in node.names:
            alias = a.asname or a.name
            self.import_from[alias] = (node.module or "", a.name)
            if node.module == "jax" and a.name == "numpy":
                self.jnp_aliases.add(alias)
            if node.module == "jax" and a.name == "jit":
                self.jit_names.add(alias)
            if node.module == "functools" and a.name == "partial":
                self.partial_names.add(alias)
            if (node.module or "").startswith("repro"):
                self.import_mod[alias] = f"{node.module}.{a.name}"

    # --- function defs ---------------------------------------------------

    def _is_partial_jit(self, call: ast.Call) -> bool:
        f = call.func
        is_partial = (
            isinstance(f, ast.Attribute) and f.attr == "partial" and
            isinstance(f.value, ast.Name) and f.value.id == "functools"
        ) or (isinstance(f, ast.Name) and f.id in self.partial_names)
        return is_partial and call.args and _is_jit_attr(
            call.args[0], self.jax_aliases, self.jit_names)

    def _handle_def(self, node):
        qual = ".".join(self._stack + [node.name])
        params = [a.arg for a in (node.args.posonlyargs + node.args.args +
                                  node.args.kwonlyargs)]
        static: set[str] = set()
        entry = False
        for dec in node.decorator_list:
            if _is_jit_attr(dec, self.jax_aliases, self.jit_names):
                entry = True
            elif isinstance(dec, ast.Call):
                if _is_jit_attr(dec.func, self.jax_aliases, self.jit_names):
                    entry = True
                    static |= _static_argnames(dec)
                elif self._is_partial_jit(dec):
                    entry = True
                    static |= _static_argnames(dec)
        info = FunctionInfo(self.module, qual, node, params, static,
                            jit_entry=entry, lineno=node.lineno)
        self.functions[qual] = info
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _handle_def
    visit_AsyncFunctionDef = _handle_def

    # --- jax.jit(fn) call-expression registration ------------------------

    def visit_Call(self, node: ast.Call):
        target = None
        if _is_jit_attr(node.func, self.jax_aliases, self.jit_names) and \
                node.args:
            target = node.args[0]
        elif isinstance(node.func, ast.Call) and \
                self._is_partial_jit(node.func) and node.args:
            target = node.args[0]
        if target is not None:
            static = _static_argnames(node)
            if isinstance(target, ast.Name):
                self.wrapped.setdefault(target.id, set()).update(static)
            elif isinstance(target, ast.Attribute):
                # `jax.jit(self._decode_impl)` -> any same-module method
                self.wrapped.setdefault(target.attr, set()).update(static)
        self.generic_visit(node)


def _iter_py(root: str, subdir: str):
    base = os.path.join(root, subdir)
    for dirpath, _, names in os.walk(base):
        for n in sorted(names):
            if n.endswith(".py"):
                yield os.path.relpath(os.path.join(dirpath, n), root)


def build_index(root: str, subdir: str = os.path.join("src", "repro")
                ) -> dict[str, _ModuleIndex]:
    out = {}
    for rel in _iter_py(root, subdir):
        with open(os.path.join(root, rel)) as f:
            src = f.read()
        try:
            tree = ast.parse(src, filename=rel)
        except SyntaxError:
            continue
        out[rel] = _ModuleIndex(rel, tree)
    return out


def _apply_wrapped(index: dict[str, _ModuleIndex]) -> None:
    for mod in index.values():
        for name, static in mod.wrapped.items():
            for info in mod.functions.values():
                if info.qualname == name or \
                        info.qualname.endswith("." + name):
                    info.jit_entry = True
                    info.static_names |= static


def _dotted_to_rel(dotted: str) -> str:
    return os.path.join("src", *dotted.split(".")) + ".py"


def _callees(info: FunctionInfo, mod: _ModuleIndex,
             index: dict[str, _ModuleIndex]) -> set[tuple[str, str]]:
    """Resolve this function's outgoing call edges (+ nested defs)."""
    edges: set[tuple[str, str]] = set()

    def local(name: str):
        for q, fi in mod.functions.items():
            if q == name or q.endswith("." + name):
                edges.add(fi.key)

    for node in ast.walk(info.node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node is not info.node:
            # nested defs (scan bodies, shard_map lambdas' helpers) run
            # under the parent's trace
            local(node.name)
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name):
            if f.id in mod.import_from:
                fmod, fname = mod.import_from[f.id]
                rel = _dotted_to_rel(fmod)
                if rel in index and fname in index[rel].functions:
                    edges.add((rel, fname))
            local(f.id)
        elif isinstance(f, ast.Attribute):
            if isinstance(f.value, ast.Name):
                base = f.value.id
                if base == "self":
                    local(f.attr)
                elif base in mod.import_mod:
                    rel = _dotted_to_rel(mod.import_mod[base])
                    if rel in index and f.attr in index[rel].functions:
                        edges.add((rel, f.attr))
    for (dmod, dname), targets in DYNAMIC_EDGES.items():
        if dmod == info.module and info.qualname.split(".")[-1] == dname:
            for t in targets:
                if t[0] in index and t[1] in index[t[0]].functions:
                    edges.add(t)
    return edges


def reachable_set(index: dict[str, _ModuleIndex]) -> set[tuple[str, str]]:
    """BFS over the call graph from every jit entry point."""
    _apply_wrapped(index)
    frontier = [fi for m in index.values() for fi in m.functions.values()
                if fi.jit_entry]
    seen = {fi.key for fi in frontier}
    while frontier:
        fi = frontier.pop()
        for key in _callees(fi, index[fi.module], index):
            if key in seen:
                continue
            seen.add(key)
            frontier.append(index[key[0]].functions[key[1]])
    return seen


# --------------------------------------------------------------------------
# hazard detection within one jit-reachable function
# --------------------------------------------------------------------------

def _expr_has_traced_test(node: ast.AST, mod: _ModuleIndex,
                          traced: set[str]) -> bool:
    """Does this test expression compute on an array value?"""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        f = sub.func
        if isinstance(f, ast.Attribute):
            if isinstance(f.value, ast.Name) and \
                    f.value.id in mod.jnp_aliases:
                return True
            if f.attr in _ARRAY_BOOL_METHODS and \
                    isinstance(f.value, ast.Name) and f.value.id in traced:
                return True
    return False


def _uses_traced(node: ast.AST, traced: set[str]) -> bool:
    return any(isinstance(s, ast.Name) and s.id in traced
               for s in ast.walk(node))


def _scan_function(info: FunctionInfo, mod: _ModuleIndex
                   ) -> list[Finding]:
    out: list[Finding] = []
    traced = set(info.params) - info.static_names - {"self", "cls"}
    own = {n for n in ast.walk(info.node)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
           and n is not info.node}
    skip = {id(d) for fn in own for d in ast.walk(fn)}

    def emit(code, node, msg):
        out.append(Finding(code, info.module, msg, line=node.lineno))

    for node in ast.walk(info.node):
        if id(node) in skip:
            continue  # nested defs are scanned as their own functions
        if isinstance(node, (ast.If, ast.While)):
            if _expr_has_traced_test(node.test, mod, traced):
                emit("JH102", node,
                     f"`{info.qualname}` branches on a traced value "
                     f"(trace-time control flow; use lax.cond/jnp.where)")
        elif isinstance(node, ast.IfExp):
            if _expr_has_traced_test(node.test, mod, traced):
                emit("JH102", node,
                     f"`{info.qualname}` ternary on a traced value")
        elif isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr == "item" and not node.args:
                    emit("JH101", node,
                         f"`.item()` in jit-reachable `{info.qualname}` "
                         f"forces a host sync per trace")
                elif isinstance(f.value, ast.Name) and \
                        f.value.id in mod.jax_aliases and \
                        f.attr == "device_get":
                    emit("JH101", node,
                         f"`jax.device_get` inside jit-reachable "
                         f"`{info.qualname}`")
                elif isinstance(f.value, ast.Name) and \
                        f.value.id in mod.np_aliases and \
                        any(_uses_traced(a, traced) for a in node.args):
                    code = "JH101" if f.attr in _HOST_SYNC_NP else "JH103"
                    what = ("host-syncs" if code == "JH101"
                            else "silently materializes")
                    emit(code, node,
                         f"`np.{f.attr}` on a potentially traced arg in "
                         f"`{info.qualname}` {what} the tracer")
            elif isinstance(f, ast.Name) and f.id in ("float", "int",
                                                      "bool"):
                if node.args and isinstance(node.args[0], ast.Name) and \
                        node.args[0].id in traced:
                    emit("JH101", node,
                         f"`{f.id}({node.args[0].id})` on a traced "
                         f"parameter of `{info.qualname}`")
    return out


def _scan_static_defaults(info: FunctionInfo) -> list[Finding]:
    if not (info.jit_entry and info.static_names):
        return []
    out = []
    node = info.node
    args = node.args.posonlyargs + node.args.args
    defaults = node.args.defaults
    pairs = list(zip(args[len(args) - len(defaults):], defaults))
    pairs += [(a, d) for a, d in zip(node.args.kwonlyargs,
                                     node.args.kw_defaults) if d is not None]
    for arg, default in pairs:
        if arg.arg in info.static_names and \
                isinstance(default, (ast.List, ast.Dict, ast.Set)):
            out.append(Finding(
                "JH104", info.module,
                f"static arg `{arg.arg}` of `{info.qualname}` has a "
                f"mutable default (unhashable jit cache key)",
                line=default.lineno))
    return out


def check(root: str, subdir: str = os.path.join("src", "repro")
          ) -> list[Finding]:
    """Run the jit-hazard lint over `root/subdir`."""
    index = build_index(root, subdir)
    reach = reachable_set(index)
    findings: list[Finding] = []
    for rel, qual in sorted(reach):
        info = index[rel].functions[qual]
        findings.extend(_scan_function(info, index[rel]))
    for mod in index.values():
        for info in mod.functions.values():
            findings.extend(_scan_static_defaults(info))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings
