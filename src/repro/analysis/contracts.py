"""PC: Pallas kernel contract checker.

`kernels/dispatch.py` admits a GEMM to the fused Pallas path when
`fused_vmem_bytes(bm, bk, bn, planes) <= vmem_budget_bytes()` — a
hand-maintained analytical model of the kernel's VMEM working set.  If
someone edits a BlockSpec or adds a kernel operand without updating the
model, dispatch happily schedules kernels that bust VMEM on real TPUs
(or conservatively rejects ones that fit).  This checker recomputes the
working set from the kernels' *actual* BlockSpecs — captured by
intercepting `pl.pallas_call` while the wrappers trace — and
cross-checks the declared model, plus the grid and K-tail contracts:

  PC401  declared VMEM bytes drifted from the BlockSpec-derived working
         set by more than the scalar-operand tolerance;
  PC402  a captured grid/block pair does not tile its operands;
  PC403  a shape dispatch admits under the budget whose recomputed
         working set busts it;
  PC404  the fused kernel with K padding is not bit-identical to the
         unpadded XLA reference (the k_valid tail mask regressed);
  PC405  a fused entry in the kernel-tuning cache (kernels/autotune.py)
         carries a working set that busts the VMEM budget it was keyed
         under — the cache is poisoned (dispatch re-validates at lookup,
         so this flags the producer, not a live scheduling hazard).

PC401/PC402 also sweep the decode-specialized skinny-M kernel
(`skinny_vmem_bytes` vs its captured BlockSpecs: the A tile and the
accumulator scale with the TRUE row count, never a 128-padded bm).

VMEM accounting model (matches `fused_vmem_bytes`'s conventions):
pipelined inputs/outputs are double-buffered (x2), scratch is
single-buffered.  Tiny scalar operands the declared model ignores (the
(P, 1) plane-scale vector: 8*P bytes) are covered by `TOLERANCE_BYTES`.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.analysis.findings import Finding

#: slack for deliberately-unmodeled scalar operands (plane scales).
TOLERANCE_BYTES = 1024

#: (m, k, n, rank) shapes the dispatch-consistency sweep (PC403) probes:
#: the default blocks, the rank-8 case the fused budget newly admits, and
#: K-tail / minimum-tile edges.
PROBE_SHAPES = (
    (256, 512, 256, 0),
    (256, 512, 256, 2),
    (256, 512, 256, 8),
    (512, 2048, 512, 4),
    (128, 128, 128, 1),
    (1024, 4096, 1024, 8),
)

#: (m, k, n, rank) decode-shaped probes for the skinny-M kernel (m is the
#: true row count; K/N must be multiples of the default skinny blocks).
SKINNY_PROBE_SHAPES = (
    (1, 512, 256, 2),
    (8, 512, 256, 0),
    (32, 2048, 512, 8),
)


@dataclasses.dataclass
class PallasCapture:
    """One intercepted `pl.pallas_call` invocation."""
    kernel_name: str
    grid: tuple[int, ...]
    in_blocks: list[tuple[tuple[int, ...], int]]    # (block_shape, itemsize)
    out_blocks: list[tuple[tuple[int, ...], int]]
    scratch_bytes: int
    operand_shapes: list[tuple[int, ...]]

    def vmem_bytes(self) -> int:
        """BlockSpec-derived working set: 2x pipelined ins/outs + scratch."""
        total = 0
        for shape, itemsize in self.in_blocks + self.out_blocks:
            numel = 1
            for d in shape:
                numel *= d
            total += 2 * numel * itemsize
        return total + self.scratch_bytes


def _block_entry(spec, operand) -> tuple[tuple[int, ...], int]:
    shape = tuple(spec.block_shape) if spec.block_shape is not None \
        else tuple(operand.shape)
    return shape, operand.dtype.itemsize


def _scratch_nbytes(scratch_shapes) -> int:
    import numpy as np
    total = 0
    for s in scratch_shapes or ():
        numel = 1
        for d in s.shape:
            numel *= d
        total += numel * np.dtype(s.dtype).itemsize
    return total


class _Interceptor:
    """Swaps `pl.pallas_call` for a recorder inside the kernel modules.

    The stub returns zeros of `out_shape`, so the wrappers run eagerly
    end to end (padding, reshapes, slicing) without compiling anything —
    the capture sees exactly the specs a real trace would emit."""

    def __init__(self):
        self.captures: list[PallasCapture] = []
        self._saved: list[tuple[Any, Any]] = []

    def _fake_pallas_call(self, kernel, *, grid=None, in_specs=None,
                          out_specs=None, out_shape=None,
                          scratch_shapes=None, **kw):
        import jax.numpy as jnp

        name = getattr(kernel, "func", kernel)
        name = getattr(name, "__name__", str(name))

        def runner(*operands):
            cap = PallasCapture(
                kernel_name=name,
                grid=tuple(int(g) for g in (grid or ())),
                in_blocks=[_block_entry(s, o)
                           for s, o in zip(in_specs or [], operands)],
                out_blocks=[],
                scratch_bytes=_scratch_nbytes(scratch_shapes),
                operand_shapes=[tuple(o.shape) for o in operands])
            outs = out_shape if isinstance(out_shape, (list, tuple)) \
                else [out_shape]
            specs = out_specs if isinstance(out_specs, (list, tuple)) \
                else [out_specs]
            for s, o in zip(specs, outs):
                cap.out_blocks.append(_block_entry(s, o))
            self.captures.append(cap)
            zeros = [jnp.zeros(o.shape, o.dtype) for o in outs]
            return zeros if isinstance(out_shape, (list, tuple)) \
                else zeros[0]

        return runner

    def __enter__(self):
        from repro.kernels import approx_qgemm as qk
        from repro.kernels import quantize as qz
        for mod in (qk, qz):
            self._saved.append((mod.pl, mod.pl.pallas_call))
        # both modules import the same `pallas` module object; patch once
        # per distinct object
        for plmod, _orig in {id(p): (p, o)
                             for p, o in self._saved}.values():
            plmod.pallas_call = self._fake_pallas_call
        return self

    def __exit__(self, *exc):
        for plmod, orig in self._saved:
            plmod.pallas_call = orig
        return False


def _unjitted(fn):
    return getattr(fn, "__wrapped__", fn)


def _capture_fused(m: int, k: int, n: int, rank: int
                   ) -> tuple[PallasCapture, tuple[int, int, int]]:
    """Trace the fused (or plane0) wrapper at (m, k, n, rank) under the
    interceptor and return its capture + chosen blocks."""
    import jax.numpy as jnp
    from repro.kernels import approx_qgemm as qk

    bm, bk, bn = qk.choose_blocks(m, k, n)
    a = jnp.zeros((m, k), jnp.int8)
    b = jnp.zeros((k, n), jnp.int8)
    with _Interceptor() as icept:
        if rank:
            fu = jnp.zeros((rank, 256), jnp.int8)
            scales = jnp.zeros((rank + 1, 1), jnp.float32)
            _unjitted(qk.approx_qgemm_fused)(
                a, b, fu, fu, scales, trunc_a=0, trunc_b=0, k_valid=k,
                bm=bm, bk=bk, bn=bn, interpret=True)
        else:
            _unjitted(qk.approx_qgemm_plane0)(
                a, b, trunc_a=0, trunc_b=0, bm=bm, bk=bk, bn=bn,
                interpret=True)
    assert len(icept.captures) == 1, [c.kernel_name for c in icept.captures]
    return icept.captures[0], (bm, bk, bn)


def _loc(kernel: str) -> str:
    mod = "quantize" if kernel.startswith("_kernel") else "approx_qgemm"
    return f"kernels/{mod}:{kernel}"


def _check_grid(cap: PallasCapture) -> list[Finding]:
    out = []
    for (block, _), oshape in zip(cap.in_blocks + cap.out_blocks,
                                  cap.operand_shapes +
                                  [None] * len(cap.out_blocks)):
        ref = oshape
        if ref is None:
            continue  # outputs tile by construction of out_shape
        if len(block) != len(ref) or any(s % b for s, b in zip(ref, block)):
            out.append(Finding(
                "PC402", _loc(cap.kernel_name),
                f"block {block} does not tile operand {ref} "
                f"(grid {cap.grid})"))
    return out


def _check_vmem_models() -> list[Finding]:
    from repro.kernels import approx_qgemm as qk

    out: list[Finding] = []
    for m, k, n, rank in PROBE_SHAPES:
        cap, (bm, bk, bn) = _capture_fused(m, k, n, rank)
        out.extend(_check_grid(cap))
        actual = cap.vmem_bytes()
        declared = qk.fused_vmem_bytes(bm, bk, bn, rank + 1)
        if abs(declared - actual) > TOLERANCE_BYTES:
            out.append(Finding(
                "PC401", _loc(cap.kernel_name),
                f"fused_vmem_bytes({bm},{bk},{bn},planes={rank + 1}) = "
                f"{declared} but BlockSpecs give {actual} "
                f"(drift {declared - actual:+d}B > {TOLERANCE_BYTES}B "
                f"tolerance) for gemm {(m, k, n)}"))
    # skinny-M decode kernel
    for m, k, n, rank in SKINNY_PROBE_SHAPES:
        cap, (bk, bn) = _capture_skinny(m, k, n, rank)
        out.extend(_check_grid(cap))
        actual = cap.vmem_bytes()
        declared = qk.skinny_vmem_bytes(m, bk, bn, rank + 1)
        if abs(declared - actual) > TOLERANCE_BYTES:
            out.append(Finding(
                "PC401", _loc(cap.kernel_name),
                f"skinny_vmem_bytes(m={m},{bk},{bn},planes={rank + 1}) = "
                f"{declared} but BlockSpecs give {actual} "
                f"(drift {declared - actual:+d}B > {TOLERANCE_BYTES}B "
                f"tolerance) for decode gemm {(m, k, n)}"))
    # stacked twin
    cap = _capture_stacked(256, 512, 256, rank=2)
    out.extend(_check_grid(cap))
    declared = qk.stacked_vmem_bytes(256, 512, 256, 3)
    actual = cap.vmem_bytes()
    if abs(declared - actual) > TOLERANCE_BYTES:
        out.append(Finding(
            "PC401", _loc(cap.kernel_name),
            f"stacked_vmem_bytes(256,512,256,planes=3) = {declared} but "
            f"BlockSpecs give {actual}"))
    return out


def _capture_skinny(m: int, k: int, n: int, rank: int
                    ) -> tuple[PallasCapture, tuple[int, int]]:
    """Trace the skinny-M decode wrapper under the interceptor."""
    import jax.numpy as jnp
    from repro.kernels import approx_qgemm as qk

    bk, bn = qk.choose_skinny_blocks(k, n)
    a = jnp.zeros((m, k), jnp.int8)
    b = jnp.zeros((k, n), jnp.int8)
    fu = jnp.zeros((rank, 256), jnp.int8)
    scales = jnp.zeros((rank + 1, 1), jnp.float32)
    with _Interceptor() as icept:
        _unjitted(qk.approx_qgemm_skinny)(
            a, b, fu, fu, scales, trunc_a=0, trunc_b=0, k_valid=k,
            bk=bk, bn=bn, interpret=True)
    assert len(icept.captures) == 1, [c.kernel_name for c in icept.captures]
    return icept.captures[0], (bk, bn)


def _capture_stacked(m: int, k: int, n: int, rank: int) -> PallasCapture:
    import jax.numpy as jnp
    from repro.kernels import approx_qgemm as qk

    p = rank + 1
    a = jnp.zeros((p, m, k), jnp.int8)
    b = jnp.zeros((p, k, n), jnp.int8)
    s = jnp.zeros((p, 1), jnp.float32)
    with _Interceptor() as icept:
        _unjitted(qk.approx_qgemm_stacked)(a, b, s, bm=m, bk=k, bn=n,
                                           interpret=True)
    assert len(icept.captures) == 1
    return icept.captures[0]


def _check_quantize() -> list[Finding]:
    import jax.numpy as jnp
    from repro.kernels import quantize as qz

    with _Interceptor() as icept:
        _unjitted(qz.quantize_rows)(jnp.zeros((256, 192), jnp.float32),
                                    bm=128, trunc=2, interpret=True)
    assert len(icept.captures) == 1
    return _check_grid(icept.captures[0])


def _check_dispatch_consistency() -> list[Finding]:
    from repro.kernels import approx_qgemm as qk
    from repro.kernels import dispatch

    out = []
    budget = dispatch.vmem_budget_bytes()
    for m, k, n, rank in PROBE_SHAPES:
        bm, bk, bn = qk.choose_blocks(m, k, n)
        declared = qk.fused_vmem_bytes(bm, bk, bn, rank + 1)
        if declared > budget:
            continue  # dispatch rejects it; nothing to cross-check
        cap, _ = _capture_fused(m, k, n, rank)
        if cap.vmem_bytes() > budget + TOLERANCE_BYTES:
            out.append(Finding(
                "PC403", "kernels/dispatch:use_pallas_gemm",
                f"dispatch admits gemm {(m, k, n)} rank {rank} "
                f"(declared {declared}B <= budget {budget}B) but the "
                f"BlockSpec working set is {cap.vmem_bytes()}B"))
    for m, k, n, rank in SKINNY_PROBE_SHAPES:
        bk, bn = qk.choose_skinny_blocks(k, n)
        declared = qk.skinny_vmem_bytes(m, bk, bn, rank + 1)
        if declared > budget:
            continue
        cap, _ = _capture_skinny(m, k, n, rank)
        if cap.vmem_bytes() > budget + TOLERANCE_BYTES:
            out.append(Finding(
                "PC403", "kernels/dispatch:choose_gemm_path",
                f"dispatch admits decode gemm {(m, k, n)} rank {rank} to "
                f"the skinny kernel (declared {declared}B <= budget "
                f"{budget}B) but the BlockSpec working set is "
                f"{cap.vmem_bytes()}B"))
    return out


def _check_tuning_cache() -> list[Finding]:
    """PC405: fused entries in the active kernel-tuning cache must fit the
    VMEM budget embedded in their own key.  `dispatch._tuned_plan`
    re-validates admission at lookup (a poisoned entry is IGNORED, not
    executed), so a finding here flags the cache producer — a bench or
    tuner run that persisted a plan the admission model rejects."""
    from repro.kernels import approx_qgemm as qk
    from repro.kernels import autotune

    out: list[Finding] = []
    for key, d in autotune.load_cache().get("entries", {}).items():
        if not isinstance(d, dict) or d.get("path") != "fused":
            continue
        try:
            plan = autotune.TunedPlan.from_dict(d)
        except TypeError:
            continue
        budget = None
        rank = None
        for part in key.split("|"):
            if part.startswith("vmem"):
                budget = int(part[4:])
            elif part.startswith("r") and part[1:].isdigit():
                rank = int(part[1:])
        if budget is None or rank is None:
            continue  # malformed key: lookup can never serve it
        planes = rank + 1
        if plan.skinny:
            ws = qk.skinny_vmem_bytes(plan.bm, plan.bk, plan.bn, planes)
        else:
            ws = qk.fused_vmem_bytes(plan.bm, plan.bk, plan.bn, planes)
        if ws > budget:
            out.append(Finding(
                "PC405", "kernels/autotune:put",
                f"tuning-cache entry {key} records a fused plan "
                f"(bm={plan.bm}, bk={plan.bk}, bn={plan.bn}, "
                f"skinny={plan.skinny}) whose working set {ws}B busts "
                f"the {budget}B budget it was tuned under"))
    return out


def _check_ktail() -> list[Finding]:
    """PC404: the fused kernel with K padding must be bit-identical to
    the stacked reference twin (which pads AFTER table mapping, so its
    pad elements are exactly zero in every plane).  The in-kernel
    k_valid tail mask is the only thing standing between the fused
    path's zero-padding and nonzero table garbage."""
    import jax.numpy as jnp
    import numpy as np
    from repro.approx import gemm as gemm_mod
    from repro.core import multipliers as mm
    from repro.core import netlist as nl
    from repro.kernels import ops

    rng = np.random.default_rng(7)
    mask = rng.random(len(nl.bw8().prunable_gates())) < 0.03
    spec = gemm_mod.from_multiplier(mm.pruned(mask, name="pc_ktail"),
                                    rank=2)
    m, k, n = 16, 130, 24          # K=130 forces a padded tail block
    a = jnp.asarray(rng.integers(-127, 128, (m, k), np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (k, n), np.int8))
    fused = np.asarray(ops.approx_qgemm(a, b, spec, fused=True))
    ref = np.asarray(ops.approx_qgemm(a, b, spec, fused=False))
    if not np.array_equal(fused, ref):
        bad = int(np.sum(fused != ref))
        return [Finding(
            "PC404", "kernels/approx_qgemm:_fused_kernel",
            f"K-padded fused gemm {(m, k, n)} differs from the stacked "
            f"reference at {bad}/{fused.size} positions — the k_valid "
            f"tail mask is not masking table-mapped pad columns")]
    return []


def check(root: str | None = None) -> list[Finding]:
    findings: list[Finding] = []
    findings.extend(_check_vmem_models())
    findings.extend(_check_quantize())
    findings.extend(_check_dispatch_consistency())
    findings.extend(_check_ktail())
    findings.extend(_check_tuning_cache())
    return findings
