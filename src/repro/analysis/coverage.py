"""SC: sharding-rule coverage over every model family's pytrees.

PR 5's bug class was "a param leaf silently missed a rule": a weight
that should shard under tensor parallelism fell through
`rules.param_pspec`'s replicated default and nobody noticed until TP
decode diverged.  This checker walks the *actual* param / decode-cache /
batch pytrees of one representative (reduced) config per family —
resolved exactly the way `sharding/rules.py` resolves them — and fails
on any leaf that neither matches a rule nor appears in the explicit
exemption table below:

  SC301  a matrix-shaped param leaf with no partition rule and no
         exemption (the PR 5 class);
  SC302  a decode-cache leaf whose key has no batch-dim rule;
  SC303  a batch leaf whose leading axis stays unsharded on a mesh whose
         data axes divide it.

Vectors/scalars (ndim < 2) are structurally replicated and auto-exempt.
Every exemption entry names WHY the leaf is replicated — adding a new
model weight means either giving it a rule in `sharding/rules.py` or
arguing its replication here; silence is no longer an option.
"""

from __future__ import annotations

from typing import Any

from repro.analysis.findings import Finding

#: one representative architecture per family (reduced configs keep the
#: checker fast; rule resolution is shape-independent by name).
FAMILY_ARCHS = {
    "lm": "tinyllama-1.1b",
    "ssm": "mamba2-370m",
    "hybrid": "recurrentgemma-9b",
    "encdec": "whisper-medium",
}

#: param leaves (ndim >= 2) that are DELIBERATELY replicated.  Keyed by
#: resolved leaf name (the same name `rules.param_pspec` matches on);
#: the value is the reason carried into the report.
PARAM_EXEMPTIONS: dict[str, str] = {
    # layer-stacked norm scales/biases: (layers, d) — per-layer vectors
    "ln1": "stacked RMSNorm scales: per-layer vectors, no matrix dim",
    "ln2": "stacked RMSNorm scales: per-layer vectors, no matrix dim",
    "ln": "stacked norm scales: per-layer vectors",
    "mln": "stacked MLP norm scales: per-layer vectors",
    "ln1b": "stacked LayerNorm biases: per-layer vectors",
    "ln2b": "stacked LayerNorm biases: per-layer vectors",
    "xln": "cross-attention norm scales: per-layer vectors",
    "xlnb": "cross-attention norm biases: per-layer vectors",
    "norm_gate": "mamba2 gated-norm scale: per-layer vector",
    # mamba2 SSD internals: tiny per-head vectors / depthwise taps whose
    # channel-sharded output XLA's CPU SPMD partitioner miscompiles
    # (see the in_proj-only TP rule in sharding/rules.py)
    "A_log": "mamba2 per-head decay: (layers, heads) vector",
    "D": "mamba2 skip gain: (layers, heads) vector",
    "dt_bias": "mamba2 dt bias: (layers, heads) vector",
    "conv_w": "depthwise conv taps: vector-unit arrays, deliberately "
              "replicated (rules.py mamba2/rg-lru comment)",
    "conv_b": "depthwise conv bias: per-channel vector",
    "lam": "rg-lru lambda: per-channel vector",
    # whisper biases: (layers, d) per-layer vectors
    "bq": "attention biases: per-layer vectors",
    "bv": "attention biases: per-layer vectors",
    "bo": "attention biases: per-layer vectors",
    "xbq": "cross-attention biases: per-layer vectors",
    "xbv": "cross-attention biases: per-layer vectors",
    "xbo": "cross-attention biases: per-layer vectors",
    "mb_up": "MLP biases: per-layer vectors",
    "mb_down": "MLP biases: per-layer vectors",
}

#: batch keys whose leading dim is NOT the batch axis (never sharded).
BATCH_EXEMPTIONS: dict[str, str] = {}


def _leaf_name(path: tuple) -> str | None:
    """Resolve a pytree path to its rule-matching name — the SAME walk
    as rules.param_pspec (skipping int8 {"q","s"} wrapper levels and
    PreparedWeight attr fields), so checker and rules cannot diverge on
    name resolution."""
    from repro.sharding import rules
    for part in reversed(path):
        is_attr = not hasattr(part, "key") and hasattr(part, "name")
        key = getattr(part, "key", None) or getattr(part, "name", None) or \
            (part if isinstance(part, str) else None)
        if key is None or str(key) in ("q", "s"):
            continue
        if is_attr and str(key) in rules._PREPARED_ATTRS:
            continue
        return str(key)
    return None


def _check_params(cfg, shapes: Any) -> list[Finding]:
    import jax
    from repro.sharding import rules

    known = rules.known_param_rule_names()
    out: list[Finding] = []

    def visit(path, leaf):
        name = _leaf_name(path)
        if getattr(leaf, "ndim", 0) < 2:
            return leaf  # vectors/scalars: structurally replicated
        if name in known or name in PARAM_EXEMPTIONS:
            return leaf
        out.append(Finding(
            "SC301", f"sharding/rules:{cfg.family}",
            f"param leaf `{name}` {tuple(leaf.shape)} of {cfg.name} has "
            f"no partition rule and no exemption — give it a rule in "
            f"rules._param_rules or justify replication in "
            f"coverage.PARAM_EXEMPTIONS"))
        return leaf

    jax.tree_util.tree_map_with_path(visit, shapes)
    return out


def _check_cache(cfg, cache_shapes: Any) -> list[Finding]:
    import jax
    from repro.sharding import rules

    known = rules.known_cache_keys()
    out: list[Finding] = []

    def visit(path, leaf):
        key = None
        for part in reversed(path):
            k = getattr(part, "key", None)
            if k is not None:
                key = str(k)
                break
        if key not in known:
            out.append(Finding(
                "SC302", f"sharding/rules:{cfg.family}",
                f"decode-cache leaf `{key}` {tuple(leaf.shape)} of "
                f"{cfg.name} has no batch-dim rule in "
                f"rules._CACHE_BATCH_DIM"))
        return leaf

    jax.tree_util.tree_map_with_path(visit, cache_shapes)
    return out


def _check_batch(cfg, mesh) -> list[Finding]:
    from repro.sharding import rules

    out: list[Finding] = []
    batch = 8  # divisible by any reasonable data-axis product
    keys = {"tokens": (batch, 16), "labels": (batch, 16),
            "mask": (batch, 16)}
    if cfg.family == "encdec":
        keys["frames"] = (batch, cfg.enc_seq, cfg.d_model)
    if cfg.cross_every:
        keys["img"] = (batch, cfg.n_img_tokens, cfg.d_model)
    for key, shape in keys.items():
        if key in BATCH_EXEMPTIONS:
            continue
        spec = rules.batch_pspec(key, shape, mesh)
        lead = spec[0] if len(spec) else None
        if lead is None:
            out.append(Finding(
                "SC303", f"sharding/rules:{cfg.family}",
                f"batch leaf `{key}` {shape} of {cfg.name} stays "
                f"replicated on mesh {dict(mesh.shape)} although its "
                f"batch dim divides the data axes"))
    return out


def _abstract_mesh():
    """A (model=2, data=2) mesh for rule resolution.  Rules only consult
    `mesh.shape` / `mesh.axis_names`, so an AbstractMesh works without 4
    physical devices; fall back to a trivial host mesh if this JAX
    predates AbstractMesh."""
    from repro import compat
    try:
        return compat.make_abstract_mesh((2, 2), ("data", "model"))
    except Exception:
        from repro.launch.mesh import make_host_mesh
        return make_host_mesh()


def check(root: str | None = None) -> list[Finding]:
    import jax
    from repro import configs
    from repro.models import api

    mesh = _abstract_mesh()
    findings: list[Finding] = []
    for family, arch in FAMILY_ARCHS.items():
        cfg = configs.apply_overrides(configs.get_config(arch),
                                      reduced=True)
        shapes = jax.eval_shape(
            lambda c=cfg: api.init_params(c, jax.random.key(0)))
        findings.extend(_check_params(cfg, shapes))
        cache = jax.eval_shape(lambda c=cfg: api.init_cache(c, 2, 32))
        findings.extend(_check_cache(cfg, cache))
        findings.extend(_check_batch(cfg, mesh))
    return findings
