"""Roofline-term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective bytes
are parsed out of the post-SPMD optimized HLO text (operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
per the brief.  Hardware constants: TPU v5e-class chip.
"""

from __future__ import annotations

import dataclasses
import re

# --- hardware constants (TPU v5e-class target) -------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# --- approximate-GEMM kernel-path model (consumed by kernels/autotune) -------
#: int8 MXU peak: 2x the bf16 rate on v5e-class parts.
PEAK_OPS_INT8 = 2 * PEAK_FLOPS_BF16
#: VPU table-gather throughput (elements/s): the fused kernel's per-plane
#: (256,)-table maps run on the VPU, 8x128 lanes at ~940 MHz.
GATHER_ELEMS_PER_S = 0.9e12
#: Fixed cost per grid step (pipeline bubble + index bookkeeping).
GRID_STEP_OVERHEAD_S = 1.5e-6
#: Fixed per-call launch overhead (dispatch + output touch).
LAUNCH_OVERHEAD_S = {"fused": 5e-6, "stacked": 5e-6, "xla": 2e-6}

GEMM_PATHS = ("fused", "stacked", "xla")


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class GemmPathCost:
    """Roofline terms for one execution path of one approximate GEMM.

    All byte/flop counts follow the tiled-GEMM re-read model: with output
    tiling (bm, bn), the A operand streams from HBM once per N-block column
    and B once per M-block row — the quantity the tile autotuner actually
    trades against VMEM footprint.
    """
    path: str                 # "fused" | "stacked" | "xla"
    mac_ops: float            # int8 MACs across all planes (padded shape)
    hbm_bytes: float          # operand + intermediate + output traffic
    gather_elems: float       # in-kernel VPU table-map element count
    grid_steps: int           # pallas grid size (0 for the XLA path)

    @property
    def compute_s(self) -> float:
        mxu = 2.0 * self.mac_ops / PEAK_OPS_INT8
        vpu = self.gather_elems / GATHER_ELEMS_PER_S
        return mxu + vpu

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def time_s(self) -> float:
        """Roofline time: overlapped compute/memory + fixed overheads."""
        return (max(self.compute_s, self.memory_s)
                + self.grid_steps * GRID_STEP_OVERHEAD_S
                + LAUNCH_OVERHEAD_S[self.path])

    def as_dict(self) -> dict:
        return {"path": self.path, "mac_ops": self.mac_ops,
                "hbm_bytes": self.hbm_bytes,
                "gather_elems": self.gather_elems,
                "grid_steps": self.grid_steps, "compute_s": self.compute_s,
                "memory_s": self.memory_s, "time_s": self.time_s}


def gemm_path_cost(path: str, m: int, k: int, n: int, n_planes: int, *,
                   bm: int = 256, bk: int = 512, bn: int = 256,
                   skinny: bool = False) -> GemmPathCost:
    """Roofline terms for an (m, k, n) approximate GEMM with `n_planes`
    operand planes on `path` at tile (bm, bk, bn).

    `skinny=True` models the decode-specialized kernel: the whole (un-
    padded) M rides in every grid step, so a batch-of-8 decode GEMM does
    8 rows of MXU work instead of a 128-row padded tile."""
    assert path in GEMM_PATHS, path
    r = max(n_planes - 1, 0)
    if path == "xla":
        # XLA runs the plane matmuls from HBM-resident mapped operands:
        # the table-map pass reads the raw operands and writes R mapped
        # copies, each plane matmul re-reads its operands, and the f32
        # accumulator is updated per correction plane.
        mapped = r * (m * k + k * n)
        traffic = (m * k + k * n) + 2 * mapped + n_planes * (m * k + k * n) \
            + (1 + 2 * r) * 4 * m * n
        return GemmPathCost(path, m * k * n * n_planes, traffic, 0.0, 0)
    kp, np_ = _ceil_to(k, bk), _ceil_to(n, bn)
    if skinny:
        mp, grid_m = m, 1
    else:
        mp = _ceil_to(m, bm)
        grid_m = mp // bm
    grid = grid_m * (np_ // bn) * (kp // bk)
    mac = float(mp) * kp * np_ * n_planes
    # tiled re-reads: A once per N-block column, B once per M-block row
    a_reads = mp * kp * (np_ // bn)
    b_reads = kp * np_ * grid_m
    out = 4 * mp * np_
    if path == "fused":
        tables = 2 * 256 * r
        gathers = float(r) * grid * (mp // grid_m * bk + bk * bn)
        return GemmPathCost(path, mac, a_reads + b_reads + tables + out,
                            gathers, grid)
    # stacked: ops.build_stacks writes (and the kernel re-reads) per-plane
    # operand copies through HBM
    stack_build = n_planes * (m * k + k * n) + (m * k + k * n)
    return GemmPathCost(path, mac,
                        stack_build + n_planes * (a_reads + b_reads) + out,
                        0.0, grid)


def predicted_gemm_winner(m: int, k: int, n: int, n_planes: int, *,
                          bm: int = 256, bk: int = 512, bn: int = 256,
                          skinny: bool = False,
                          on_tpu: bool = True) -> tuple[str, dict]:
    """(winner path, per-path predicted seconds) for an approximate GEMM.

    Off-TPU the Pallas kernels run interpret mode — a correctness
    vehicle, orders of magnitude off — so the prediction pins XLA unless
    a measurement (tuning cache) says otherwise."""
    costs = {p: gemm_path_cost(p, m, k, n, n_planes, bm=bm, bk=bk, bn=bn,
                               skinny=skinny and p == "fused").time_s
             for p in GEMM_PATHS}
    if not on_tpu:
        return "xla", costs
    return min(costs, key=costs.get), costs

# matches e.g.  f32[16,4096,128]{2,1,0}  or  bf16[]  (scalars)
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line:  %name = TYPE kind(args...)
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)")


def _type_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    numel = 1
    if dims:
        for d in dims.split(","):
            numel *= int(d)
    return numel * nbytes


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out: dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for m in _INSTR_RE.finditer(hlo_text):
        kind, args = m.group(1), m.group(2)
        total = 0
        for tm in _TYPE_RE.finditer(args):
            total += _type_bytes(tm.group(1), tm.group(2))
        out[kind] += total
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    flops: float                   # whole-program HLO flops
    hbm_bytes: float               # whole-program bytes accessed
    collective_bytes: float        # summed collective operand bytes
    collectives: dict              # per-kind bytes
    chips: int
    model_flops: float             # 6*N*D (or inference analogue)
    # Pallas-kernel deployment model: traffic of vmem_kernel-tagged scopes
    # (materialized by the XLA-CPU lowering, VMEM-resident in the Mosaic
    # kernel) and the kernel's true HBM I/O to swap in instead.
    tagged_bytes: float = 0.0
    kernel_io_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def hbm_bytes_kernel_adj(self) -> float:
        """HBM bytes with tagged scopes replaced by Pallas-kernel I/O."""
        return max(self.hbm_bytes - self.tagged_bytes, 0.0) + \
            self.kernel_io_bytes

    @property
    def memory_kernel_adj_s(self) -> float:
        return self.hbm_bytes_kernel_adj / (self.chips * HBM_BW)

    @property
    def roofline_fraction_kernel_adj(self) -> float:
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        worst = max(self.compute_s, self.memory_kernel_adj_s,
                    self.collective_s)
        return ideal / worst if worst > 0 else 0.0

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: <1 means remat/overhead; >1 means the
        compiler sees fewer flops than the analytic model (e.g. int8)."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the step's roofline-limited time:
        model_flops/(chips*peak) / max(term)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        worst = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / worst if worst > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": dict(self.collectives), "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "tagged_bytes": self.tagged_bytes,
            "kernel_io_bytes": self.kernel_io_bytes,
            "memory_kernel_adj_s": self.memory_kernel_adj_s,
            "roofline_fraction_kernel_adj":
                self.roofline_fraction_kernel_adj,
        }


def model_flops_for_cell(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for one step of a cell.

    train:   6 * N_active * tokens          (fwd+bwd)
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch           (one token per sequence)
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def kernel_io_bytes_for_cell(cfg, shape) -> float:
    """Analytic HBM I/O of the Pallas attention kernels for one step
    (q/k/v or cache reads + out writes, x passes: fwd / remat / bwd)."""
    if cfg.family == "ssm":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.hd
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // 3
        s_kv = min(s, cfg.window)
    else:
        n_attn = cfg.n_layers + cfg.n_enc_layers
        s_kv = s
    if shape.kind == "decode":
        # fused decode attention streams the KV cache once per layer
        cache = 2 * b * s_kv * cfg.n_kv_heads * hd * 2
        return n_attn * cache
    qo = b * s * cfg.n_heads * hd * 2
    kv = 2 * b * s * cfg.n_kv_heads * hd * 2
    passes = 4.0 if shape.kind == "train" else 2.0
    return n_attn * passes * (2 * qo + kv)


def terms_from_compiled(compiled, cfg, shape, chips: int) -> RooflineTerms:
    """Preferred path: the while-aware HLO module analyzer (hlo_parse.py).
    XLA's cost_analysis undercounts scanned layers (bodies counted once) —
    it is recorded in the dry-run JSON for cross-checking only."""
    from repro.roofline import hlo_parse
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    stats = hlo_parse.analyze_module(hlo)
    # the SPMD-partitioned module is per-device; the roofline formulas want
    # whole-program totals (they divide by `chips` again)
    return RooflineTerms(
        flops=stats.flops * chips, hbm_bytes=stats.traffic_bytes * chips,
        collective_bytes=stats.collective_bytes * chips,
        collectives={k: v * chips for k, v in stats.collectives.items()},
        chips=chips, model_flops=model_flops_for_cell(cfg, shape),
        tagged_bytes=stats.tagged_traffic_bytes * chips,
        kernel_io_bytes=kernel_io_bytes_for_cell(cfg, shape))


def analytic_memory_per_device(cfg, shape, mesh_shape: dict,
                               accum: int = 1, fsdp: bool | None = None,
                               moment_bytes: float = 8.0) -> dict:
    """TPU-side per-device memory estimate (bytes).

    The CPU-backend compile inflates temp memory by materializing f32 copies
    of bf16 layer-stacked saves (XLA-CPU computes bf16 in f32 and hoists the
    converts); TPUs have native bf16, so this analytic model is the honest
    HBM estimate that accompanies the raw memory_analysis() numbers.
    """
    model_par = mesh_shape.get("model", 1)
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh_shape.get(ax, 1)
    n = cfg.param_count()
    if fsdp is None:
        fsdp = n >= 20e9
    wshard = model_par * (dp if fsdp else 1)
    params = 2.0 * n / wshard
    grads = 2.0 * n / wshard
    moments = moment_bytes * n / wshard
    out = {"params": params, "grads": 0.0, "opt": 0.0, "activations": 0.0,
           "cache": 0.0, "logits": 0.0}
    if shape.kind == "train":
        mb_local = max(shape.global_batch // dp // accum, 1)
        out["grads"] = grads
        out["opt"] = moments
        out["activations"] = (cfg.n_layers * mb_local * shape.seq_len
                              * cfg.d_model * 2.0)
        out["logits"] = (mb_local * shape.seq_len
                         * max(cfg.vocab // model_par, 1) * 4.0)
    elif shape.kind == "prefill":
        b_local = max(shape.global_batch // dp, 1)
        out["activations"] = (b_local * shape.seq_len * cfg.d_model * 2.0
                              * 4)
        out["cache"] = (cfg.n_layers * b_local * shape.seq_len
                        * cfg.n_kv_heads * cfg.hd * 2 * 2.0)
    else:  # decode
        b_local = max(shape.global_batch // dp, 1)
        if cfg.family == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            h = cfg.ssm_heads or 32
            p = d_in // h
            out["cache"] = cfg.n_layers * b_local * (
                h * p * cfg.ssm_state * 4.0 + 3 * (d_in + 2 * cfg.ssm_state))
        elif cfg.family == "hybrid":
            n_attn = cfg.n_layers // 3
            w = cfg.lru_width or cfg.d_model
            out["cache"] = (n_attn * b_local * cfg.window * cfg.n_kv_heads
                            * cfg.hd * 2 * 2.0
                            + cfg.n_layers * b_local * w * 6.0)
        else:
            kvshard = model_par if (cfg.n_kv_heads * cfg.hd) % model_par \
                == 0 else 1
            out["cache"] = (cfg.n_layers * b_local * shape.seq_len
                            * cfg.n_kv_heads * cfg.hd * 2 * 2.0 / kvshard)
    out["total"] = sum(v for k, v in out.items())
    return out


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        val = getattr(ma, attr, None)
        if val is not None:
            out[attr] = int(val)
    return out
