"""Roofline-term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective bytes
are parsed out of the post-SPMD optimized HLO text (operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
per the brief.  Hardware constants: TPU v5e-class chip.
"""

from __future__ import annotations

import dataclasses
import re

# --- hardware constants (TPU v5e-class target) -------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# matches e.g.  f32[16,4096,128]{2,1,0}  or  bf16[]  (scalars)
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line:  %name = TYPE kind(args...)
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)")


def _type_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    numel = 1
    if dims:
        for d in dims.split(","):
            numel *= int(d)
    return numel * nbytes


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out: dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for m in _INSTR_RE.finditer(hlo_text):
        kind, args = m.group(1), m.group(2)
        total = 0
        for tm in _TYPE_RE.finditer(args):
            total += _type_bytes(tm.group(1), tm.group(2))
        out[kind] += total
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    flops: float                   # whole-program HLO flops
    hbm_bytes: float               # whole-program bytes accessed
    collective_bytes: float        # summed collective operand bytes
    collectives: dict              # per-kind bytes
    chips: int
    model_flops: float             # 6*N*D (or inference analogue)
    # Pallas-kernel deployment model: traffic of vmem_kernel-tagged scopes
    # (materialized by the XLA-CPU lowering, VMEM-resident in the Mosaic
    # kernel) and the kernel's true HBM I/O to swap in instead.
    tagged_bytes: float = 0.0
    kernel_io_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def hbm_bytes_kernel_adj(self) -> float:
        """HBM bytes with tagged scopes replaced by Pallas-kernel I/O."""
        return max(self.hbm_bytes - self.tagged_bytes, 0.0) + \
            self.kernel_io_bytes

    @property
    def memory_kernel_adj_s(self) -> float:
        return self.hbm_bytes_kernel_adj / (self.chips * HBM_BW)

    @property
    def roofline_fraction_kernel_adj(self) -> float:
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        worst = max(self.compute_s, self.memory_kernel_adj_s,
                    self.collective_s)
        return ideal / worst if worst > 0 else 0.0

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: <1 means remat/overhead; >1 means the
        compiler sees fewer flops than the analytic model (e.g. int8)."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the step's roofline-limited time:
        model_flops/(chips*peak) / max(term)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        worst = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / worst if worst > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": dict(self.collectives), "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "tagged_bytes": self.tagged_bytes,
            "kernel_io_bytes": self.kernel_io_bytes,
            "memory_kernel_adj_s": self.memory_kernel_adj_s,
            "roofline_fraction_kernel_adj":
                self.roofline_fraction_kernel_adj,
        }


def model_flops_for_cell(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for one step of a cell.

    train:   6 * N_active * tokens          (fwd+bwd)
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch           (one token per sequence)
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def kernel_io_bytes_for_cell(cfg, shape) -> float:
    """Analytic HBM I/O of the Pallas attention kernels for one step
    (q/k/v or cache reads + out writes, x passes: fwd / remat / bwd)."""
    if cfg.family == "ssm":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.hd
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // 3
        s_kv = min(s, cfg.window)
    else:
        n_attn = cfg.n_layers + cfg.n_enc_layers
        s_kv = s
    if shape.kind == "decode":
        # fused decode attention streams the KV cache once per layer
        cache = 2 * b * s_kv * cfg.n_kv_heads * hd * 2
        return n_attn * cache
    qo = b * s * cfg.n_heads * hd * 2
    kv = 2 * b * s * cfg.n_kv_heads * hd * 2
    passes = 4.0 if shape.kind == "train" else 2.0
    return n_attn * passes * (2 * qo + kv)


def terms_from_compiled(compiled, cfg, shape, chips: int) -> RooflineTerms:
    """Preferred path: the while-aware HLO module analyzer (hlo_parse.py).
    XLA's cost_analysis undercounts scanned layers (bodies counted once) —
    it is recorded in the dry-run JSON for cross-checking only."""
    from repro.roofline import hlo_parse
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    stats = hlo_parse.analyze_module(hlo)
    # the SPMD-partitioned module is per-device; the roofline formulas want
    # whole-program totals (they divide by `chips` again)
    return RooflineTerms(
        flops=stats.flops * chips, hbm_bytes=stats.traffic_bytes * chips,
        collective_bytes=stats.collective_bytes * chips,
        collectives={k: v * chips for k, v in stats.collectives.items()},
        chips=chips, model_flops=model_flops_for_cell(cfg, shape),
        tagged_bytes=stats.tagged_traffic_bytes * chips,
        kernel_io_bytes=kernel_io_bytes_for_cell(cfg, shape))


def analytic_memory_per_device(cfg, shape, mesh_shape: dict,
                               accum: int = 1, fsdp: bool | None = None,
                               moment_bytes: float = 8.0) -> dict:
    """TPU-side per-device memory estimate (bytes).

    The CPU-backend compile inflates temp memory by materializing f32 copies
    of bf16 layer-stacked saves (XLA-CPU computes bf16 in f32 and hoists the
    converts); TPUs have native bf16, so this analytic model is the honest
    HBM estimate that accompanies the raw memory_analysis() numbers.
    """
    model_par = mesh_shape.get("model", 1)
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh_shape.get(ax, 1)
    n = cfg.param_count()
    if fsdp is None:
        fsdp = n >= 20e9
    wshard = model_par * (dp if fsdp else 1)
    params = 2.0 * n / wshard
    grads = 2.0 * n / wshard
    moments = moment_bytes * n / wshard
    out = {"params": params, "grads": 0.0, "opt": 0.0, "activations": 0.0,
           "cache": 0.0, "logits": 0.0}
    if shape.kind == "train":
        mb_local = max(shape.global_batch // dp // accum, 1)
        out["grads"] = grads
        out["opt"] = moments
        out["activations"] = (cfg.n_layers * mb_local * shape.seq_len
                              * cfg.d_model * 2.0)
        out["logits"] = (mb_local * shape.seq_len
                         * max(cfg.vocab // model_par, 1) * 4.0)
    elif shape.kind == "prefill":
        b_local = max(shape.global_batch // dp, 1)
        out["activations"] = (b_local * shape.seq_len * cfg.d_model * 2.0
                              * 4)
        out["cache"] = (cfg.n_layers * b_local * shape.seq_len
                        * cfg.n_kv_heads * cfg.hd * 2 * 2.0)
    else:  # decode
        b_local = max(shape.global_batch // dp, 1)
        if cfg.family == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            h = cfg.ssm_heads or 32
            p = d_in // h
            out["cache"] = cfg.n_layers * b_local * (
                h * p * cfg.ssm_state * 4.0 + 3 * (d_in + 2 * cfg.ssm_state))
        elif cfg.family == "hybrid":
            n_attn = cfg.n_layers // 3
            w = cfg.lru_width or cfg.d_model
            out["cache"] = (n_attn * b_local * cfg.window * cfg.n_kv_heads
                            * cfg.hd * 2 * 2.0
                            + cfg.n_layers * b_local * w * 6.0)
        else:
            kvshard = model_par if (cfg.n_kv_heads * cfg.hd) % model_par \
                == 0 else 1
            out["cache"] = (cfg.n_layers * b_local * shape.seq_len
                            * cfg.n_kv_heads * cfg.hd * 2 * 2.0 / kvshard)
    out["total"] = sum(v for k, v in out.items())
    return out


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        val = getattr(ma, attr, None)
        if val is not None:
            out[attr] = int(val)
    return out
