"""Render the dry-run JSON into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.roofline.report dryrun_results.json
"""

from __future__ import annotations

import json
import sys


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if v >= 1e4 or v < 1e-3:
        return f"{v:.2e}"
    return f"{v:.3f}"


def roofline_table(results: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck"
        " | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r["mesh"] != mesh:
            continue
        if r.get("skip_reason"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | *skipped* |"
                f" — | — |")
            continue
        if not r["ok"]:
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                         f"{r['error'][:60]} | | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(rf['compute_s'])} | "
            f"{_fmt(rf['memory_s'])} | {_fmt(rf['collective_s'])} | "
            f"{rf['bottleneck']} | {_fmt(rf['useful_flops_ratio'])} | "
            f"{_fmt(rf['roofline_fraction'])} |")
    return "\n".join(lines)


def dryrun_table(results: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compile s | HLO flops | HLO bytes | "
        "collective bytes | per-dev args GB | per-dev temps GB | "
        "TPU-est GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("skip_reason"):
            if r["mesh"] == "single":
                lines.append(f"| {r['arch']} | {r['shape']} | — | *skip:* "
                             f"{r['skip_reason'][:50]}… | | | | | | |")
            continue
        if not r["ok"]:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL | | | | | | |")
            continue
        rf = r["roofline"]
        mem = r["memory"]
        args = mem.get("argument_size_in_bytes", 0) / 1e9
        temps = mem.get("temp_size_in_bytes", 0) / 1e9
        tpu = mem.get("tpu_estimate", {}).get("total", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.0f} | {rf['flops']:.2e} | "
            f"{rf['hbm_bytes']:.2e} | {rf['collective_bytes']:.2e} | "
            f"{args:.2f} | {temps:.2f} | {tpu:.2f} |")
    return "\n".join(lines)


def bottleneck_summary(results: list[dict]) -> str:
    picks = {"worst_fraction": None, "most_collective": None}
    for r in results:
        if not r["ok"] or r["mesh"] != "single":
            continue
        rf = r["roofline"]
        key = (r["arch"], r["shape"])
        if picks["worst_fraction"] is None or rf["roofline_fraction"] < \
                picks["worst_fraction"][1]:
            picks["worst_fraction"] = (key, rf["roofline_fraction"])
        ratio = rf["collective_s"] / max(
            rf["compute_s"], rf["memory_s"], 1e-12)
        if picks["most_collective"] is None or ratio > \
                picks["most_collective"][1]:
            picks["most_collective"] = (key, ratio)
    out = []
    if picks["worst_fraction"]:
        out.append(f"* worst roofline fraction: "
                   f"{picks['worst_fraction'][0]} "
                   f"({picks['worst_fraction'][1]:.4f})")
    if picks["most_collective"]:
        out.append(f"* most collective-bound: "
                   f"{picks['most_collective'][0]} "
                   f"(coll/max(other) = {picks['most_collective'][1]:.2f})")
    return "\n".join(out)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    results = json.load(open(path))
    print("## Roofline (single pod, 256 chips)\n")
    print(roofline_table(results, "single"))
    print("\n## Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(results, "multi"))
    print("\n## Dry-run detail\n")
    print(dryrun_table(results))
    print("\n## Hillclimb candidates\n")
    print(bottleneck_summary(results))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
