"""While-loop-aware analyzer for optimized HLO text.

XLA's built-in `compiled.cost_analysis()` counts while-loop bodies ONCE
(verified in this repo — a 10-trip scan of a matmul reports 1/10th of the
unrolled flops).  Our models scan over layers, so per-step roofline terms
must scale loop bodies by their trip counts.  This module parses the
optimized HLO text into computations and walks the call graph:

  * `while` ops: body/condition computations scaled by the trip count from
    `backend_config={"known_trip_count":{"n":...}}` (fallback: the largest
    integer constant in the condition computation);
  * `fusion`/`call`/`to_apply` references: recursed at x1;
  * dot flops: 2 * numel(result) * prod(lhs contracting dims);
  * convolution flops: 2 * numel(result) * prod(kernel spatial) * C_in/g;
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (resolved through the
    per-computation symbol table);
  * HBM-traffic proxy: sum over materialized ops of (result + operand)
    bytes — at optimized-HLO level every op output is a real buffer, so
    producer-write + consumer-read approximates DRAM traffic on an
    accelerator (fusion internals are already collapsed).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
    "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->")
_TYPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")

_SKIP_TRAFFIC_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                     "bitcast", "iota", "after-all", "custom-call"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE.finditer(type_str):
        nb = _DTYPE_BYTES.get(m.group(1))
        if nb is None:
            continue
        numel = 1
        if m.group(2):
            for d in m.group(2).split(","):
                numel *= int(d)
        total += numel * nb
    return total


def _type_dims(type_str: str) -> tuple[list[int], int]:
    m = _TYPE.search(type_str)
    if not m:
        return [], 0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, _DTYPE_BYTES.get(m.group(1), 0)


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    types: dict     # %name -> type string


def _parse_instr(line: str) -> Instr | None:
    """Procedural parse of `[ROOT] %name = TYPE op(args...), attrs...` —
    robust to tuple result types containing `/*index=N*/` comments."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):  # tuple type: balance parens
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str = rest[:end + 1]
        rest = rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:]
    par = rest.find("(")
    if par < 0:
        return None
    op = rest[:par].strip()
    if not op or not op[0].isalpha():
        return None
    return Instr(name, type_str, op, rest[par + 1:])


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        if stripped == "}" or stripped.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
            cur.types["%" + ins.name] = ins.type_str
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _operand_names(rest: str) -> list[str]:
    """Operand names up to the closing paren of the op's argument list."""
    depth = 1
    end = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = rest[:end] if end else rest
    return re.findall(r"%[\w\.\-]+", args)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims, _ = _type_dims(ins.type_str)
    numel = 1
    for d in out_dims:
        numel *= d
    ops = _operand_names(ins.rest)
    if not ops:
        return 0.0
    lhs_type = comp.types.get(ops[0], "")
    lhs_dims, _ = _type_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    k = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * numel * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_dims, _ = _type_dims(ins.type_str)
    numel = 1
    for d in out_dims:
        numel *= d
    ops = _operand_names(ins.rest)
    if len(ops) < 2:
        return 0.0
    ker_dims, _ = _type_dims(comp.types.get(ops[1], ""))
    if not ker_dims:
        return 0.0
    # dim_labels=...: kernel = spatial... x in x out; approximate K as
    # prod(kernel dims) / out_channels (largest dim heuristic is fragile;
    # use total/out where out = last label dim).  Convs only appear in CNN
    # benches; LM dry-runs have none.
    total = 1
    for d in ker_dims:
        total *= d
    out_ch = out_dims[-1] if out_dims else 1
    groups = 1
    gm = re.search(r"feature_group_count=(\d+)", ins.rest)
    if gm:
        groups = int(gm.group(1))
    k = total / max(out_ch, 1) * groups
    return 2.0 * numel * k


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    # traffic attributable to ops inside a jax.named_scope tagged
    # "vmem_kernel_*": on real TPU these lower to a Pallas kernel whose
    # intermediates never leave VMEM, so the §Perf kernel-adjusted memory
    # term subtracts this and adds back the kernel's analytic HBM I/O.
    tagged_traffic_bytes: float = 0.0

    def scaled(self, f: float) -> "HloStats":
        return HloStats(self.flops * f, self.traffic_bytes * f,
                        self.collective_bytes * f,
                        {k: v * f for k, v in self.collectives.items()},
                        self.tagged_traffic_bytes * f)

    def add(self, other: "HloStats") -> None:
        self.flops += other.flops
        self.traffic_bytes += other.traffic_bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.collectives.items():
            self.collectives[k] += v
        self.tagged_traffic_bytes += other.tagged_traffic_bytes


def _fusion_dus_update_bytes(ins: Instr, comps: dict) -> float | None:
    """If a fusion's root is a dynamic-update-slice (possibly behind
    dtype converts/copies — the XLA-CPU bf16-in-f32 pattern), return the
    update payload bytes (else None)."""
    cm = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
    if not cm or cm.group(1) not in comps:
        return None
    called = comps[cm.group(1)]
    if not called.instrs:
        return None
    by_name = {"%" + i.name: i for i in called.instrs}
    root = called.instrs[-1]
    for _ in range(4):  # look through convert/copy/bitcast wrappers
        if root.op == "dynamic-update-slice":
            ops_ = _operand_names(root.rest)
            if len(ops_) < 2:
                return 0.0
            return float(_type_bytes(called.types.get(ops_[1], "")))
        if root.op in ("convert", "copy", "bitcast"):
            ops_ = _operand_names(root.rest)
            nxt = by_name.get(ops_[0]) if ops_ else None
            if nxt is None:
                return None
            root = nxt
            continue
        return None
    return None


def _trip_count(ins: Instr, comps: dict) -> int:
    m = _TRIP.search(ins.rest)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
    if cm and cm.group(1) in comps:
        consts = [int(c) for i2 in comps[cm.group(1)].instrs
                  for c in _CONST_INT.findall(i2.rest)]
        if consts:
            return max(consts)
    return 1


def _analyze_comp(name: str, comps: dict, memo: dict,
                  include_traffic: bool = True) -> HloStats:
    """include_traffic=False inside fusion-called computations: fused
    internals live in registers/VMEM and must not count as HBM traffic."""
    key = (name, include_traffic)
    if key in memo:
        return memo[key]
    memo[key] = HloStats()  # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return memo[key]
    stats = HloStats()
    for ins in comp.instrs:
        if ins.op == "dot":
            stats.flops += _dot_flops(ins, comp)
        elif ins.op == "convolution":
            stats.flops += _conv_flops(ins, comp)
        if ins.op in COLLECTIVE_KINDS or \
                any(ins.op == k + "-start" for k in COLLECTIVE_KINDS):
            kind = ins.op.removesuffix("-start")
            nbytes = sum(_type_bytes(comp.types.get(o, ""))
                         for o in _operand_names(ins.rest))
            stats.collective_bytes += nbytes
            stats.collectives[kind] += nbytes
        if include_traffic and ins.op not in _SKIP_TRAFFIC_OPS:
            # Traffic model: every materialized HLO buffer is written once
            # and read ~once (2x result bytes).  Counting operand reads
            # directly would charge whole layer-stacked buffers on every
            # loop iteration whenever a fusion slices from them.
            nbytes = 0.0
            if ins.op == "dynamic-update-slice":
                ops_ = _operand_names(ins.rest)
                ub = _type_bytes(comp.types.get(ops_[1], "")) if \
                    len(ops_) > 1 else 0
                nbytes = 2 * ub
            elif ins.op == "fusion":
                # in-place update fusions (root = dynamic-update-slice)
                # alias their output buffer on TPU; count the update
                # payload, not the whole (layer-stacked KV cache) result —
                # the XLA-CPU lowering's full copy is a backend artifact.
                dus = _fusion_dus_update_bytes(ins, comps)
                nbytes = 2 * dus if dus is not None else \
                    2 * _type_bytes(ins.type_str)
            elif ins.op in ("while", "conditional"):
                pass  # body internals are counted via recursion
            else:
                nbytes = 2 * _type_bytes(ins.type_str)
            stats.traffic_bytes += nbytes
            if nbytes and "vmem_kernel" in ins.rest:
                stats.tagged_traffic_bytes += nbytes
        if ins.op == "while":
            trip = _trip_count(ins, comps)
            bm = re.search(r"body=%?([\w\.\-]+)", ins.rest)
            if bm:
                stats.add(_analyze_comp(bm.group(1), comps, memo,
                                        include_traffic).scaled(trip))
        elif ins.op in ("fusion", "call", "conditional", "map", "reduce",
                        "reduce-window", "scatter", "sort", "async-start"):
            for cname in _CALLED.findall(ins.rest):
                stats.add(_analyze_comp(cname, comps, memo,
                                        include_traffic=False))
    memo[key] = stats
    return stats


def analyze_module(text: str, entry: str | None = None) -> HloStats:
    comps = parse_module(text)
    if not comps:
        return HloStats()
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
        entry = m.group(1) if m else next(iter(comps))
    stats = _analyze_comp(entry, comps, {})
    # entry parameters = weights/state read from HBM once per step
    ec = comps.get(entry)
    if ec is not None:
        for ins in ec.instrs:
            if ins.op == "parameter":
                stats.traffic_bytes += _type_bytes(ins.type_str)
    return stats
