"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Used by the serving/eval substrate for 32k-token prefill, where naive
attention would materialize (B, H, S, S) score tensors (at S=32k that is
4 GiB per head-batch in f32 — the memory-roofline killer the §Perf log
attacks).  Grid is (batch*heads, q_blocks, kv_blocks) with kv innermost;
running max/denominator/accumulator live in VMEM scratch across the kv loop.

Causal masking uses global q/kv indices; fully-masked kv blocks are skipped
(`pl.when`), which for causal attention halves the MXU work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

DEFAULT_BQ = 512
DEFAULT_BKV = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, kv_blocks: int, bq: int, bkv: int):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # For causal attention, kv blocks strictly above the diagonal contribute
    # nothing: skip their flops entirely.
    needed = (not causal) or (ikv * bkv <= iq * bq + bq - 1)

    @pl.when(needed)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bkv, d)
        v = v_ref[0].astype(jnp.float32)            # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)
        if causal:
            qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            ki = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ikv == kv_blocks - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = DEFAULT_BQ,
                    bkv: int = DEFAULT_BKV, interpret: bool = False
                    ) -> jax.Array:
    """q (bh, sq, d), k/v (bh, skv, d) -> (bh, sq, d).

    sq % bq == 0 and skv % bkv == 0 required (ops.py pads).  For causal use
    sq == skv (self-attention prefill)."""
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    grid = (bh, sq // bq, skv // bkv)
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          kv_blocks=grid[2], bq=bq, bkv=bkv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
