"""Roofline-guided tile autotuner for the approximate-GEMM kernels.

`kernels/dispatch.py` used to pick the fused Pallas path from a hand-made
VMEM admission check alone, at one hand-picked prefill-shaped tile —
BENCH_gemm.json showed that losing to XLA/stacked in exact and lowrank-r2
modes despite the fused path's 2-4x HBM reduction.  This module closes the
loop the way the delay model itself is closed (core/calibrate.py anchors
analytical FPS to measured serving): tile choice and path choice come from
MEASUREMENT, with the roofline model pruning the search so only plausibly-
winning candidates are ever timed.

Three pieces:

* **candidate generation** — `candidate_plans` enumerates (bm, bk, bn,
  plane-unroll) tiles for the fused kernel (plus the skinny-M decode
  kernel when m <= SKINNY_MAX_M), filters them through the same
  `fused_vmem_bytes`/`skinny_vmem_bytes` admission dispatch enforces, ranks
  them by the roofline cost model (`roofline/analysis.gemm_path_cost`:
  tiled operand re-reads vs MXU/VPU work per grid step), and keeps the top
  few — the measurement budget goes where the model says it matters.

* **measurement** — `tune_gemm` times each surviving candidate (untimed
  warm-up rep, median of reps) plus the stacked and XLA paths, and records
  the winner.  The measurement function is injectable, so tests drive the
  tuner with a seeded deterministic stub and CI never depends on timer
  noise.

* **a versioned on-disk cache** — winners persist to a JSON file
  (`$REPRO_TUNING_CACHE`, default ./TUNING_gemm.json) keyed by
  (backend, shape-bucket, mode, rank, VMEM budget) and stamped with the
  cache schema and `approx_qgemm.KERNEL_VERSION`.  Any mismatch —
  different backend, budget, kernel schedule, or a corrupt file — makes
  an entry invisible, so dispatch silently falls back to its static
  roofline prediction rather than trusting stale timings.  Writes are
  atomic (tmp + os.replace) and reads tolerate concurrent writers.

`dispatch.choose_gemm_path` consults `lookup()` per GEMM at trace time
(memoized per file mtime — no JSON parse on the hot path), which is what
turns the `auto` policy into a measured three-way fused/stacked/xla
predicted-winner choice.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

from repro.kernels import approx_qgemm as qk

#: Bump when the cache layout changes (entries under old schemas are
#: discarded wholesale).
CACHE_SCHEMA = 1

_ENV_VAR = "REPRO_TUNING_CACHE"
DEFAULT_CACHE_FILENAME = "TUNING_gemm.json"

PATHS = ("fused", "stacked", "xla")

#: Tile-search axes.  Kept small on purpose: the roofline pruner ranks the
#: cross product, and only MAX_MEASURED_CANDIDATES are ever timed.
BM_CANDIDATES = (128, 256)
BK_CANDIDATES = (128, 256, 512)
BN_CANDIDATES = (128, 256)
UNROLL_CANDIDATES = (1, 2)
MAX_MEASURED_CANDIDATES = 4


def cache_path() -> str:
    """Active tuning-cache path: $REPRO_TUNING_CACHE or ./TUNING_gemm.json."""
    return os.environ.get(_ENV_VAR, "").strip() or DEFAULT_CACHE_FILENAME


def _pow2_ceil(x: int, cap: int) -> int:
    return min(cap, max(1, 1 << max(x - 1, 0).bit_length()))


def shape_bucket(m: int, k: int, n: int) -> str:
    """Shape-bucket key: pow2-ceiling per dim.  Decode GEMMs (m <= 32) get
    per-pow2 m buckets — m=1 and m=32 decode steps genuinely want
    different plans — while K/N bucket coarsely (cap 8192)."""
    return f"m{_pow2_ceil(m, 8192)}_k{_pow2_ceil(k, 8192)}" \
           f"_n{_pow2_ceil(n, 8192)}"


def entry_key(backend: str, bucket: str, mode: str, rank: int,
              vmem_budget: int) -> str:
    return f"{backend}|{bucket}|{mode}|r{rank}|vmem{vmem_budget}"


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """One cache entry: the measured winner for a (backend, bucket, mode,
    rank, budget) cell, plus the per-path medians that elected it."""
    path: str                 # "fused" | "stacked" | "xla"
    bm: int                   # fused tile (ignored for path="xla")
    bk: int
    bn: int
    unroll: int = 1
    skinny: bool = False      # fused path ran the skinny-M decode kernel
    us: dict = dataclasses.field(default_factory=dict)  # per-path medians
    source: str = "measured"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunedPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


# ---------------------------------------------------------------------------
# cache persistence
# ---------------------------------------------------------------------------

def _empty_cache() -> dict:
    return {"schema": CACHE_SCHEMA,
            "kernel_version": qk.KERNEL_VERSION, "entries": {}}


def load_cache(path: str | None = None) -> dict:
    """Read the tuning cache; corrupt/missing/stale files yield an empty
    cache (defaults win — never an exception on the dispatch path)."""
    path = path or cache_path()
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return _empty_cache()
    if not isinstance(raw, dict) \
            or raw.get("schema") != CACHE_SCHEMA \
            or raw.get("kernel_version") != qk.KERNEL_VERSION \
            or not isinstance(raw.get("entries"), dict):
        return _empty_cache()
    return raw


def save_cache(cache: dict, path: str | None = None) -> str:
    """Atomic write (tmp + rename): concurrent readers see either the old
    or the new file, never a torn one."""
    path = path or cache_path()
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".tuning.", suffix=".json", dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _MEMO.pop(os.path.abspath(path), None)
    return path


def put(plan: TunedPlan, m: int, k: int, n: int, mode: str, rank: int, *,
        backend: str, vmem_budget: int, path: str | None = None) -> str:
    """Merge one winner into the on-disk cache (read-modify-replace)."""
    path = path or cache_path()
    cache = load_cache(path)
    key = entry_key(backend, shape_bucket(m, k, n), mode, rank, vmem_budget)
    cache["entries"][key] = plan.as_dict()
    return save_cache(cache, path)


#: path -> (mtime_ns, entries) — dispatch consults the cache at trace time,
#: so the JSON parse must not be on the per-GEMM path.
_MEMO: dict[str, tuple[int, dict]] = {}


def _entries(path: str) -> dict:
    apath = os.path.abspath(path)
    try:
        mtime = os.stat(apath).st_mtime_ns
    except OSError:
        _MEMO.pop(apath, None)
        return {}
    hit = _MEMO.get(apath)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    entries = load_cache(apath)["entries"]
    _MEMO[apath] = (mtime, entries)
    return entries


def lookup(m: int, k: int, n: int, mode: str, rank: int, *, backend: str,
           vmem_budget: int, path: str | None = None) -> TunedPlan | None:
    """Cache hit for this GEMM's bucket, or None (dispatch falls back to
    the roofline prediction)."""
    entries = _entries(path or cache_path())
    if not entries:
        return None
    key = entry_key(backend, shape_bucket(m, k, n), mode, rank, vmem_budget)
    d = entries.get(key)
    if not isinstance(d, dict) or d.get("path") not in PATHS:
        return None
    try:
        return TunedPlan.from_dict(d)
    except TypeError:
        return None


# ---------------------------------------------------------------------------
# candidate generation (roofline-pruned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    bm: int
    bk: int
    bn: int
    unroll: int = 1
    skinny: bool = False


def candidate_plans(m: int, k: int, n: int, n_planes: int, *,
                    vmem_budget: int,
                    max_candidates: int = MAX_MEASURED_CANDIDATES
                    ) -> list[Candidate]:
    """Fused-kernel tile candidates for an (m, k, n) GEMM, VMEM-admitted
    and ranked by the roofline cost model (best predicted first).

    Plane-unroll only enters the space when there are >= 2 correction
    planes to group; the skinny kernel only when m is decode-shaped."""
    from repro.roofline import analysis as rfa

    unrolls = [u for u in UNROLL_CANDIDATES if u <= max(n_planes - 1, 1)]
    seen: set[Candidate] = set()
    scored: list[tuple[float, Candidate]] = []

    def consider(c: Candidate) -> None:
        if c in seen:
            return
        seen.add(c)
        if c.skinny:
            vmem = qk.skinny_vmem_bytes(m, c.bk, c.bn, n_planes)
        else:
            vmem = qk.fused_vmem_bytes(c.bm, c.bk, c.bn, n_planes)
        if vmem > vmem_budget:
            return
        cost = rfa.gemm_path_cost("fused", m, k, n, n_planes, bm=c.bm,
                                  bk=c.bk, bn=c.bn, skinny=c.skinny)
        scored.append((cost.time_s, c))

    kb = [b for b in BK_CANDIDATES if b < 2 * k] or [BK_CANDIDATES[0]]
    nb = [b for b in BN_CANDIDATES if b < 2 * n] or [BN_CANDIDATES[0]]
    if m <= qk.SKINNY_MAX_M:
        for bk in kb:
            for bn in nb:
                for u in unrolls:
                    consider(Candidate(m, bk, bn, u, skinny=True))
    mb = [b for b in BM_CANDIDATES if b < 2 * m] or [BM_CANDIDATES[0]]
    for bm in mb:
        for bk in kb:
            for bn in nb:
                for u in unrolls:
                    consider(Candidate(bm, bk, bn, u))
    # default blocks always compete (the pre-autotuner behavior is never
    # pruned away, so tuning can only tie or win)
    consider(Candidate(*qk.choose_blocks(m, k, n)))
    scored.sort(key=lambda t: (t[0], dataclasses.astuple(t[1])))
    return [c for _, c in scored[:max_candidates]]


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _median(xs: list[float]) -> float:
    ys = sorted(xs)
    h = len(ys) // 2
    return ys[h] if len(ys) % 2 else 0.5 * (ys[h - 1] + ys[h])


def measure_real(spec, *, reps: int = 3, seed: int = 0):
    """Build the default measurement fn for a MultSpec: times the actual
    kernels (one untimed warm-up/compile rep, then median of `reps`).
    Returns seconds.  The signature is the stub contract for tests:
    measure(path, m, k, n, bm, bk, bn, unroll, skinny) -> float."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.approx import gemm as gemm_mod
    from repro.kernels import ops

    def measure(path: str, m: int, k: int, n: int, bm: int, bk: int,
                bn: int, unroll: int, skinny: bool) -> float:
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        if path == "xla":
            fn = jax.jit(lambda x, y: gemm_mod.approx_qgemm(x, y, spec))
        elif path == "stacked":
            fn = jax.jit(
                lambda x, y: ops.approx_qgemm(x, y, spec, fused=False))
        else:
            fn = jax.jit(lambda x, y: ops.approx_qgemm(
                x, y, spec, bm=bm, bk=bk, bn=bn, unroll=unroll,
                skinny=skinny))
        jax.block_until_ready(fn(a, b))  # warm-up: compile + first touch
        samples = []
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(a, b))
            samples.append(time.perf_counter() - t0)
        return _median(samples)

    return measure


def tune_gemm(m: int, k: int, n: int, spec=None, *, mode: str | None = None,
              rank: int | None = None, measure=None, reps: int = 3,
              seed: int = 0, backend: str | None = None,
              vmem_budget: int | None = None,
              persist: bool = True, path: str | None = None) -> TunedPlan:
    """Tune one (shape, spec) cell: roofline-pruned fused candidates plus
    the stacked and XLA paths, measured, winner persisted.

    Pass `spec` (a MultSpec) for real measurement, or `mode`/`rank` plus a
    `measure` stub for deterministic testing."""
    from repro.kernels import dispatch

    if spec is not None:
        mode, rank = spec.mode, spec.rank
        n_planes = spec.n_planes
    else:
        assert mode is not None and rank is not None and measure is not None
        n_planes = 1 + rank
    backend = backend or _default_backend()
    vmem_budget = vmem_budget or dispatch.vmem_budget_bytes()
    measure = measure or measure_real(spec, reps=reps, seed=seed)

    cands = candidate_plans(m, k, n, n_planes, vmem_budget=vmem_budget)
    best_fused: tuple[float, Candidate] | None = None
    for c in cands:
        t = measure("fused", m, k, n, c.bm, c.bk, c.bn, c.unroll, c.skinny)
        if best_fused is None or t < best_fused[0]:
            best_fused = (t, c)
    dbm, dbk, dbn = qk.choose_blocks(m, k, n)
    us = {}
    if best_fused is not None:
        us["fused"] = best_fused[0] * 1e6
    us["stacked"] = measure("stacked", m, k, n, dbm, dbk, dbn, 1,
                            False) * 1e6
    us["xla"] = measure("xla", m, k, n, dbm, dbk, dbn, 1, False) * 1e6

    winner = min(us, key=lambda p: (us[p], PATHS.index(p)))
    if winner == "fused":
        c = best_fused[1]
        plan = TunedPlan("fused", c.bm, c.bk, c.bn, c.unroll, c.skinny, us)
    else:
        plan = TunedPlan(winner, dbm, dbk, dbn, 1, False, us)
    if persist:
        put(plan, m, k, n, mode, rank, backend=backend,
            vmem_budget=vmem_budget, path=path)
    return plan


def record_winner(m: int, k: int, n: int, mode: str, rank: int,
                  us: dict, *, fused_plan: Candidate | None = None,
                  backend: str | None = None,
                  vmem_budget: int | None = None,
                  path: str | None = None) -> TunedPlan:
    """Elect + persist a winner from EXTERNALLY measured per-path medians
    (e.g. bench_gemm's own timing loop) — the cache accepts any
    measurement source, it only insists the entry be measurement-backed."""
    from repro.kernels import dispatch

    backend = backend or _default_backend()
    vmem_budget = vmem_budget or dispatch.vmem_budget_bytes()
    winner = min(us, key=lambda p: (us[p], PATHS.index(p)))
    if winner == "fused" and fused_plan is not None:
        c = fused_plan
        plan = TunedPlan("fused", c.bm, c.bk, c.bn, c.unroll, c.skinny,
                         dict(us))
    else:
        bm, bk, bn = qk.choose_blocks(m, k, n)
        plan = TunedPlan(winner, bm, bk, bn, 1, False, dict(us))
    put(plan, m, k, n, mode, rank, backend=backend,
        vmem_budget=vmem_budget, path=path)
    return plan


def _default_backend() -> str:
    import jax
    return jax.default_backend()
