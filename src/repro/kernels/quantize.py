"""Pallas TPU kernel: fused per-row symmetric int8 quantization.

Row-blocked: each grid step loads a (bm, K) f32 tile, computes per-row
absmax, scales, rounds, and emits the int8 tile plus (bm, 1) f32 scales in a
single VMEM pass (one read of x instead of XLA's reduce + broadcast-divide
two-pass).  Feeds approx_qgemm's activation quantization on the hot path
(routed via kernels/dispatch.py).

An optional LSB-truncation mask fuses into the same pass as an epilogue
(`trunc` static arg): trunc-mode approximate GEMMs get their masked
activations straight out of the quantizer, with no extra elementwise pass.
The mask is applied after rounding, so the result is bit-identical to
`_trunc_mask(quantize(x))`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.kernels import approx_qgemm as qk

INT8_MAX = 127.0
DEFAULT_BM = 256


def _kernel(x_ref, q_ref, s_ref, *, trunc: int):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX - 1, INT8_MAX)
    qi = q.astype(jnp.int8)
    if trunc > 0:
        qi = jnp.bitwise_and(qi, jnp.int8(qk.signed_trunc_mask(trunc)))
    q_ref[...] = qi
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bm", "trunc", "interpret"))
def quantize_rows(x: jax.Array, *, bm: int = DEFAULT_BM, trunc: int = 0,
                  interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x (M, K) float -> (q (M, K) int8, scale (M, 1) f32); M % bm == 0."""
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    q, s = pl.pallas_call(
        functools.partial(_kernel, trunc=trunc),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return q, s
