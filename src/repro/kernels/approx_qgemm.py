"""Pallas TPU kernel: approximate int8 GEMM as (R+1) MXU matmuls.

Computes  C[m,n] = sum_k m(a[m,k], b[k,n])  for an approximate multiplier m,
in the low-rank formulation (DESIGN.md §3):

    C = A.B - sum_r s_r * U_r(A).V_r(B)

ops.py pre-maps the operands through the per-rank 256-entry int8 tables,
producing stacks  a_stack (R+1, M, K) int8  and  b_stack (R+1, K, N) int8
(plane 0 = raw/truncated operands; planes 1..R = table-mapped).  The kernel
is then pure MXU work: per (m,n,k) tile it accumulates

    acc += sum_r scales[r] * dot_int8(a_stack[r], b_stack[r])

with an f32 VMEM accumulator, K innermost ("arbitrary") so the accumulator
lives across the K loop, and M/N parallel.

Block shapes default to (bm, bk, bn) = (256, 512, 256): MXU-aligned
(multiples of 128 / int8 lane tiling) and, with R<=4 planes double-buffered,
~3.8 MiB of VMEM — comfortably under a v5e core's ~16 MiB budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256


def _kernel(a_ref, b_ref, s_ref, out_ref, acc_ref, *, n_planes: int,
            k_blocks: int):
    """One (i, j, k) grid step.

    a_ref: (n_planes, bm, bk) int8 VMEM
    b_ref: (n_planes, bk, bn) int8 VMEM
    s_ref: (n_planes, 1) f32 VMEM   (plane scales; s[0]=1, s[r]=-s_r)
    out_ref: (bm, bn) f32 VMEM
    acc_ref: (n_planes, bm, bn) int32 VMEM scratch

    Per-plane int32 accumulation with scales applied once at flush keeps the
    kernel bit-identical to the XLA reference semantics (no f32 partial-sum
    drift across the K loop).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for r in range(n_planes):  # static unroll over correction planes
        acc_ref[r] += jnp.dot(a_ref[r], b_ref[r],
                              preferred_element_type=jnp.int32)

    @pl.when(k == k_blocks - 1)
    def _flush():
        acc = jnp.zeros(out_ref.shape, jnp.float32)
        for r in range(n_planes):
            acc = acc + s_ref[r, 0] * acc_ref[r].astype(jnp.float32)
        out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def approx_qgemm_stacked(a_stack: jax.Array, b_stack: jax.Array,
                         scales: jax.Array, *, bm: int = DEFAULT_BM,
                         bk: int = DEFAULT_BK, bn: int = DEFAULT_BN,
                         interpret: bool = False) -> jax.Array:
    """a_stack (P, M, K) int8, b_stack (P, K, N) int8, scales (P, 1) f32
    -> (M, N) f32.  M, K, N must be multiples of the block shape (ops.py
    pads; padding is inserted *after* table mapping so padded elements
    contribute exactly zero in every plane)."""
    p, m, k = a_stack.shape
    p2, k2, n = b_stack.shape
    assert p == p2 and k == k2, (a_stack.shape, b_stack.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_kernel, n_planes=p, k_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, bm, bk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((p, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((p, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, bm, bn), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_stack, b_stack, scales)
