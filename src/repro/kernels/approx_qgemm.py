"""Pallas TPU kernels: approximate int8 GEMM as (R+1) MXU matmuls.

Computes  C[m,n] = sum_k m(a[m,k], b[k,n])  for an approximate multiplier m,
in the low-rank formulation (DESIGN.md §3):

    C = A.B - sum_r s_r * U_r(A).V_r(B)

Two kernels implement this:

`approx_qgemm_fused` (the hot path): consumes the *raw* quantized operands.
The (R, 256) int8 factor tables live in VMEM alongside the operand tiles;
each (bm, bk) / (bk, bn) tile is table-mapped in-register per correction
plane, and the truncation mask (precision-scaled multipliers) is applied
in-kernel as a bitwise AND.  HBM reads both operands exactly once —
`(R+1)x` less operand traffic than the stacked kernel, and no `(P, M, K)` /
`(P, K, N)` intermediates ever materialize.

`approx_qgemm_stacked` (reference / A-B twin): ops.py pre-maps the operands
through the tables in XLA, producing stacks  a_stack (R+1, M, K) int8  and
b_stack (R+1, K, N) int8, and the kernel is pure MXU work.  Kept for the
fused-vs-stacked parity tests and the BENCH_gemm trajectory.

Both kernels accumulate per-plane in int32 with the f32 plane scales applied
once at flush, so they are bit-identical to each other and to the XLA
reference semantics (no f32 partial-sum drift across the K loop).  K is
innermost ("arbitrary") so the accumulator lives across the K loop; M/N are
parallel.

Block shapes default to (bm, bk, bn) = (256, 512, 256): MXU-aligned
(multiples of 128 / int8 lane tiling).  `fused_vmem_bytes` /
`stacked_vmem_bytes` give the VMEM working set per grid step —
kernels/dispatch.py checks the fused budget in its auto policy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256

#: Largest M the decode-specialized skinny kernel accepts: one decode step
#: of a continuous-batching arena (m = batch).  Above this, padding to an
#: MXU tile stops being the dominant cost and the regular fused kernel wins.
SKINNY_MAX_M = 32

#: Bump when a kernel's schedule/layout changes in a way that invalidates
#: measured tile timings (kernels/autotune.py keys its cache on this).
KERNEL_VERSION = 2


def choose_blocks(m: int, k: int, n: int, bm: int | None = None,
                  bk: int | None = None, bn: int | None = None
                  ) -> tuple[int, int, int]:
    """Default block shape for an (m, k, n) GEMM: the standard blocks capped
    below by one MXU tile and above by the defaults (small operands round up
    to a single 128-multiple block instead of padding to 256/512)."""
    bm = bm or min(DEFAULT_BM, max(128, 1 << max(m - 1, 0).bit_length()))
    bk = bk or min(DEFAULT_BK, max(128, 1 << max(k - 1, 0).bit_length()))
    bn = bn or min(DEFAULT_BN, max(128, 1 << max(n - 1, 0).bit_length()))
    return bm, bk, bn


def choose_skinny_blocks(k: int, n: int, bk: int | None = None,
                         bn: int | None = None) -> tuple[int, int]:
    """Default (bk, bn) for the skinny-M decode kernel (M is never
    blocked — the whole row batch rides in every grid step)."""
    bk = bk or min(DEFAULT_BK, max(128, 1 << max(k - 1, 0).bit_length()))
    bn = bn or min(DEFAULT_BN, max(128, 1 << max(n - 1, 0).bit_length()))
    return bk, bn


def fused_vmem_bytes(bm: int, bk: int, bn: int, n_planes: int) -> int:
    """VMEM working set of one fused-kernel grid step: double-buffered raw
    int8 operand tiles (plane count does NOT multiply them — that is the
    point), the factor tables, the per-plane int32 accumulator, and the
    double-buffered f32 output tile."""
    operands = 2 * (bm * bk + bk * bn)
    tables = 2 * 2 * max(n_planes - 1, 0) * 256
    acc = n_planes * bm * bn * 4
    out = 2 * bm * bn * 4
    return operands + tables + acc + out


def stacked_vmem_bytes(bm: int, bk: int, bn: int, n_planes: int) -> int:
    """Same for the stacked kernel: operand tiles scale with the plane
    count (the pre-mapped stacks are streamed from HBM)."""
    operands = 2 * n_planes * (bm * bk + bk * bn)
    acc = n_planes * bm * bn * 4
    out = 2 * bm * bn * 4
    return operands + acc + out


def skinny_vmem_bytes(m: int, bk: int, bn: int, n_planes: int) -> int:
    """VMEM working set of one skinny-kernel grid step: the whole (un-
    padded) M dimension rides in every block, so the A tile and the
    accumulator scale with the true row count, not a 128-padded bm.
    Rank 0 still ships one dummy table row per side (a BlockSpec dim may
    not be 0), so the table term floors at one row."""
    operands = 2 * (m * bk + bk * bn)
    tables = 2 * 2 * max(n_planes - 1, 1) * 256
    acc = n_planes * m * bn * 4
    out = 2 * m * bn * 4
    return operands + tables + acc + out


def signed_trunc_mask(t: int) -> int:
    """Two's-complement signed value of the uint8 LSB-truncation mask
    0xFF & ~((1<<t)-1); -1 (all bits set) when t <= 0 (no truncation)."""
    if t <= 0:
        return -1
    return ((0xFF & ~((1 << t) - 1)) ^ 0x80) - 0x80


# ---------------------------------------------------------------------------
# stacked kernel (reference twin; operands pre-mapped in XLA by ops.py)
# ---------------------------------------------------------------------------

def _stacked_kernel(a_ref, b_ref, s_ref, out_ref, acc_ref, *, n_planes: int,
                    k_blocks: int):
    """One (i, j, k) grid step.

    a_ref: (n_planes, bm, bk) int8 VMEM
    b_ref: (n_planes, bk, bn) int8 VMEM
    s_ref: (n_planes, 1) f32 VMEM   (plane scales; s[0]=1, s[r]=-s_r)
    out_ref: (bm, bn) f32 VMEM
    acc_ref: (n_planes, bm, bn) int32 VMEM scratch
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for r in range(n_planes):  # static unroll over correction planes
        acc_ref[r] += jnp.dot(a_ref[r], b_ref[r],
                              preferred_element_type=jnp.int32)

    @pl.when(k == k_blocks - 1)
    def _flush():
        acc = jnp.zeros(out_ref.shape, jnp.float32)
        for r in range(n_planes):
            acc = acc + s_ref[r, 0] * acc_ref[r].astype(jnp.float32)
        out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def approx_qgemm_stacked(a_stack: jax.Array, b_stack: jax.Array,
                         scales: jax.Array, *, bm: int = DEFAULT_BM,
                         bk: int = DEFAULT_BK, bn: int = DEFAULT_BN,
                         interpret: bool = False) -> jax.Array:
    """a_stack (P, M, K) int8, b_stack (P, K, N) int8, scales (P, 1) f32
    -> (M, N) f32.  M, K, N must be multiples of the block shape (ops.py
    pads; padding is inserted *after* table mapping so padded elements
    contribute exactly zero in every plane)."""
    p, m, k = a_stack.shape
    p2, k2, n = b_stack.shape
    assert p == p2 and k == k2, (a_stack.shape, b_stack.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_stacked_kernel, n_planes=p, k_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, bm, bk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((p, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((p, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, bm, bn), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_stack, b_stack, scales)


# ---------------------------------------------------------------------------
# fused kernel: raw operands in, table map + trunc mask in-kernel
# ---------------------------------------------------------------------------

def _correction_dots(a, b, fu_ref, fv_ref, acc_ref, in_k, *, n_corr: int,
                     unroll: int):
    """Table-map + matmul the `n_corr` correction planes into acc_ref[1:].

    `unroll` groups planes: each group's mapped tiles are stacked and run
    as ONE batched int8 dot_general (a single MXU dispatch per group
    instead of per plane).  Integer accumulation, so the result is
    bit-identical at every unroll factor — it is purely a schedule knob,
    which is what lets the autotuner search it.
    """
    idx_a = jnp.bitwise_and(a.astype(jnp.int32), 0xFF)
    idx_b = jnp.bitwise_and(b.astype(jnp.int32), 0xFF)
    for r0 in range(0, n_corr, unroll):
        u = min(unroll, n_corr - r0)
        uas, vbs = [], []
        for r in range(r0, r0 + u):
            ua = jnp.take(fu_ref[r], idx_a, axis=0)
            if in_k is not None:
                ua = jnp.where(in_k, ua, jnp.int8(0))
            uas.append(ua)
            vbs.append(jnp.take(fv_ref[r], idx_b, axis=0))
        if u == 1:
            acc_ref[r0 + 1] += jnp.dot(uas[0], vbs[0],
                                       preferred_element_type=jnp.int32)
        else:
            batched = jax.lax.dot_general(
                jnp.stack(uas), jnp.stack(vbs),
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.int32)
            acc_ref[r0 + 1:r0 + 1 + u] += batched


def _fused_kernel(a_ref, b_ref, fu_ref, fv_ref, s_ref, out_ref, acc_ref, *,
                  n_planes: int, k_blocks: int, bk: int, k_valid: int,
                  mask_a: int, mask_b: int, unroll: int):
    """One (i, j, k) grid step over RAW operand tiles.

    a_ref: (bm, bk) int8 VMEM      raw quantized activations
    b_ref: (bk, bn) int8 VMEM      raw quantized weights
    fu_ref/fv_ref: (R, 256) int8 VMEM   per-rank factor tables (whole table
        resident; the index map is constant so it is fetched once)
    s_ref: (n_planes, 1) f32 VMEM  plane scales (s[0]=1, s[r]=-s_r)
    out_ref: (bm, bn) f32 VMEM
    acc_ref: (n_planes, bm, bn) int32 VMEM scratch

    `k_valid` is the un-padded contraction length: K-pad zeros are inert in
    plane 0 (0*0 == 0) but map through the tables to tbl[0], which is in
    general nonzero — mapped a-tiles are therefore masked past k_valid
    (zeroing one side of the product suffices).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    a0 = a if mask_a == -1 else jnp.bitwise_and(a, jnp.int8(mask_a))
    b0 = b if mask_b == -1 else jnp.bitwise_and(b, jnp.int8(mask_b))
    acc_ref[0] += jnp.dot(a0, b0, preferred_element_type=jnp.int32)

    if n_planes > 1:
        in_k = None
        if k_valid < k_blocks * bk:  # static: any K padding at all
            kpos = k * bk + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
            in_k = kpos < k_valid  # all-true except past the K tail
        _correction_dots(a, b, fu_ref, fv_ref, acc_ref, in_k,
                         n_corr=n_planes - 1, unroll=unroll)

    @pl.when(k == k_blocks - 1)
    def _flush():
        acc = jnp.zeros(out_ref.shape, jnp.float32)
        for r in range(n_planes):
            acc = acc + s_ref[r, 0] * acc_ref[r].astype(jnp.float32)
        out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "trunc_a", "trunc_b", "k_valid", "bm", "bk", "bn", "unroll",
    "interpret"))
def approx_qgemm_fused(a_q: jax.Array, b_q: jax.Array, fu_q: jax.Array,
                       fv_q: jax.Array, scales: jax.Array, *,
                       trunc_a: int = 0, trunc_b: int = 0, k_valid: int,
                       bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                       bn: int = DEFAULT_BN, unroll: int = 1,
                       interpret: bool = False) -> jax.Array:
    """Low-rank fused path: a_q (M, K) int8, b_q (K, N) int8, fu_q/fv_q
    (R, 256) int8 tables, scales (R+1, 1) f32 -> (M, N) f32.

    M, K, N must be block multiples (ops.py zero-pads the raw operands);
    `k_valid` is the true contraction length before padding."""
    m, k = a_q.shape
    k2, n = b_q.shape
    r = fu_q.shape[0]
    assert k == k2 and fv_q.shape == fu_q.shape == (r, 256)
    assert scales.shape == (r + 1, 1), scales.shape
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    assert 0 < k_valid <= k, (k_valid, k)
    grid = (m // bm, n // bn, k // bk)
    p = r + 1

    return pl.pallas_call(
        functools.partial(
            _fused_kernel, n_planes=p, k_blocks=grid[2], bk=bk,
            k_valid=k_valid, mask_a=signed_trunc_mask(trunc_a),
            mask_b=signed_trunc_mask(trunc_b), unroll=unroll),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((r, 256), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((r, 256), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((p, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, bm, bn), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_q, b_q, fu_q, fv_q, scales)


def _plane0_kernel(a_ref, b_ref, out_ref, acc_ref, *, k_blocks: int,
                   mask_a: int, mask_b: int):
    """Single-plane (exact / trunc) grid step: trunc masks in-kernel."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    a0 = a if mask_a == -1 else jnp.bitwise_and(a, jnp.int8(mask_a))
    b0 = b if mask_b == -1 else jnp.bitwise_and(b, jnp.int8(mask_b))
    acc_ref[...] += jnp.dot(a0, b0, preferred_element_type=jnp.int32)

    @pl.when(k == k_blocks - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "trunc_a", "trunc_b", "bm", "bk", "bn", "interpret"))
def approx_qgemm_plane0(a_q: jax.Array, b_q: jax.Array, *, trunc_a: int = 0,
                        trunc_b: int = 0, bm: int = DEFAULT_BM,
                        bk: int = DEFAULT_BK, bn: int = DEFAULT_BN,
                        interpret: bool = False) -> jax.Array:
    """Exact / truncation-only fused path: a_q (M, K) x b_q (K, N) -> f32
    (M, N) with the LSB masks applied in-kernel.  K-pad zeros are inert
    (masked zero stays zero), so no k_valid is needed."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_plane0_kernel, k_blocks=grid[2],
                          mask_a=signed_trunc_mask(trunc_a),
                          mask_b=signed_trunc_mask(trunc_b)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_q, b_q)


# ---------------------------------------------------------------------------
# skinny-M kernel: decode-shaped GEMMs (m = batch <= SKINNY_MAX_M)
# ---------------------------------------------------------------------------

def _skinny_kernel(a_ref, b_ref, fu_ref, fv_ref, s_ref, out_ref, acc_ref, *,
                   n_planes: int, k_blocks: int, bk: int, k_valid: int,
                   mask_a: int, mask_b: int, unroll: int):
    """One (j, k) grid step of the decode-specialized GEMV-style kernel.

    a_ref: (m, bk) int8 VMEM — the WHOLE row batch, broadcast to every
        N-block (index map constant in j, so the tile re-fetches only
        across K steps); m is the true batch, never padded to an MXU tile.
    b_ref: (bk, bn) int8 VMEM — K-major streaming of the weight.
    acc_ref: (n_planes, m, bn) int32 VMEM scratch.

    Grid is (N-blocks, K-blocks) with K innermost ("arbitrary") so the
    accumulator lives across the contraction, same discipline as the
    prefill-shaped fused kernel; there is no M grid axis at all.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    a0 = a if mask_a == -1 else jnp.bitwise_and(a, jnp.int8(mask_a))
    b0 = b if mask_b == -1 else jnp.bitwise_and(b, jnp.int8(mask_b))
    acc_ref[0] += jnp.dot(a0, b0, preferred_element_type=jnp.int32)

    if n_planes > 1:
        in_k = None
        if k_valid < k_blocks * bk:
            kpos = k * bk + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
            in_k = kpos < k_valid
        _correction_dots(a, b, fu_ref, fv_ref, acc_ref, in_k,
                         n_corr=n_planes - 1, unroll=unroll)

    @pl.when(k == k_blocks - 1)
    def _flush():
        acc = jnp.zeros(out_ref.shape, jnp.float32)
        for r in range(n_planes):
            acc = acc + s_ref[r, 0] * acc_ref[r].astype(jnp.float32)
        out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "trunc_a", "trunc_b", "k_valid", "bk", "bn", "unroll", "interpret"))
def approx_qgemm_skinny(a_q: jax.Array, b_q: jax.Array, fu_q: jax.Array,
                        fv_q: jax.Array, scales: jax.Array, *,
                        trunc_a: int = 0, trunc_b: int = 0, k_valid: int,
                        bk: int = DEFAULT_BK, bn: int = DEFAULT_BN,
                        unroll: int = 1,
                        interpret: bool = False) -> jax.Array:
    """Decode path: a_q (m, K) int8 with m <= SKINNY_MAX_M, b_q (K, N)
    int8, fu_q/fv_q (R, 256) tables (R may be 0 for exact/trunc), scales
    (R+1, 1) f32 -> (m, N) f32.

    K, N must be block multiples (ops.py pads); m is consumed AS IS — the
    whole point is that a batch-8 decode GEMM does 8 rows of MXU work
    instead of a 128-row padded tile.  Bit-identical to the fused/stacked
    kernels and the XLA reference on every plane."""
    m, k = a_q.shape
    k2, n = b_q.shape
    r = fu_q.shape[0]
    assert k == k2 and fv_q.shape == fu_q.shape == (r, 256)
    assert scales.shape == (r + 1, 1), scales.shape
    assert 0 < m <= SKINNY_MAX_M, m
    assert k % bk == 0 and n % bn == 0, (k, n, bk, bn)
    assert 0 < k_valid <= k, (k_valid, k)
    grid = (n // bn, k // bk)
    p = r + 1
    if r == 0:
        # Exact/trunc: the kernel never touches the tables (n_planes == 1),
        # but a BlockSpec dim of 0 is illegal — ship a 1-row dummy.
        fu_q = jnp.zeros((1, 256), jnp.int8)
        fv_q = jnp.zeros((1, 256), jnp.int8)
    ru = max(r, 1)

    return pl.pallas_call(
        functools.partial(
            _skinny_kernel, n_planes=p, k_blocks=grid[1], bk=bk,
            k_valid=k_valid, mask_a=signed_trunc_mask(trunc_a),
            mask_b=signed_trunc_mask(trunc_b), unroll=unroll),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk), lambda j, kk: (0, kk)),
            pl.BlockSpec((bk, bn), lambda j, kk: (kk, j)),
            pl.BlockSpec((ru, 256), lambda j, kk: (0, 0)),
            pl.BlockSpec((ru, 256), lambda j, kk: (0, 0)),
            pl.BlockSpec((p, 1), lambda j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, m, bn), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a_q, b_q, fu_q, fv_q, scales)
