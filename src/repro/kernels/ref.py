"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantic definitions*; kernels must match them (bit-exact for
integer paths, allclose for float paths).  `lut_matmul` is the ground-truth
ApproxTrain semantic: per-element 256x256-LUT product, accumulated exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.approx import gemm as gemm_mod
from repro.approx import quant


def lut_matmul(a_q: jax.Array, b_q: jax.Array, lut: jax.Array) -> jax.Array:
    """Exact approximate-multiplier GEMM by 2-D LUT gather.

    a_q (m,k) int8, b_q (k,n) int8, lut (256,256) int32 indexed by the uint8
    bit patterns.  Returns (m,n) int32: sum_k lut[a[mk], b[kn]].
    O(mkn) memory — use small shapes (it is the oracle, not the fast path).
    """
    ua = jnp.bitwise_and(a_q.astype(jnp.int32), 0xFF)   # (m, k)
    ub = jnp.bitwise_and(b_q.astype(jnp.int32), 0xFF)   # (k, n)
    prod = lut[ua[:, :, None], ub[None, :, :]]          # (m, k, n)
    return prod.sum(axis=1).astype(jnp.int32)


def ref_approx_qgemm(a_q: jax.Array, b_q: jax.Array,
                     spec: gemm_mod.MultSpec) -> jax.Array:
    """The XLA-path semantic the Pallas kernel must reproduce exactly."""
    return gemm_mod.approx_qgemm(a_q, b_q, spec)


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q,k,v (bh, s, d) f32/bf16 -> (bh, s, d).  Plain softmax attention."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if causal:
        sq, sk = q.shape[-2], k.shape[-2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)


def ref_quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization of (m, k) f32."""
    return quant.quantize(x, axis=0)
