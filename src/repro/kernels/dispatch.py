"""Kernel-dispatch policy: Pallas kernels vs the XLA reference path.

Replaces the old `use_kernel: bool` threaded through approx/gemm.py with a
named policy resolved per GEMM at trace time:

  "xla"    — never use the Pallas kernels (pure jnp/lax path);
  "pallas" — always use them (interpret mode off-TPU, Mosaic on TPU);
  "auto"   — use them when they plausibly win: real TPU backend, operand
             dims at least one MXU tile (padding a tiny GEMM to 128-multiples
             costs more than it saves), and the fused kernel's VMEM working
             set within budget.  Off-TPU, auto picks XLA — interpret-mode
             Pallas is a correctness vehicle, not a fast path.

The fused GEMM data path changed the auto accounting: operand HBM traffic
no longer scales with the plane count (raw operands are read once and
table-mapped in-register), so plane count only costs VMEM accumulator
space.  `fused_vmem_bytes` is checked against VMEM_BUDGET_BYTES directly —
rank-8 multipliers (9 planes) now pass at the default block shape, where
the old stacked-traffic cap (MAX_PLANES=8) rejected them.

The policy rides on `MultSpec.policy` (a static/meta pytree field, so a
policy change is a new jit cache key — no stale-trace footgun), is settable
per model via `ModelConfig.kernel_policy`, per run via the `--kernel-policy`
flag on launch/train.py and launch/serve.py, and process-wide via the
`REPRO_KERNEL_POLICY` environment variable.
"""

from __future__ import annotations

import dataclasses
import os

from repro import compat

POLICIES = ("auto", "pallas", "xla")
GEMM_PATHS = ("fused", "stacked", "xla")

#: Below one MXU tile on any operand dim, block padding dominates.
MIN_DIM = 128
#: Per-grid-step VMEM working-set budget for the fused kernel: ~16 MiB/core
#: minus compiler headroom.  Override per process with $REPRO_VMEM_BUDGET
#: (bytes, decimal or 0x-hex) for parts with different VMEM — the override
#: feeds every budget consumer (auto dispatch and the repro.analysis Pallas
#: contract checker) through `vmem_budget_bytes()`.
VMEM_BUDGET_BYTES = 14 << 20

_ENV_VAR = "REPRO_KERNEL_POLICY"
_VMEM_ENV_VAR = "REPRO_VMEM_BUDGET"


def vmem_budget_bytes() -> int:
    """Effective fused-kernel VMEM budget: $REPRO_VMEM_BUDGET (positive
    integer bytes; "0x..." hex accepted) or VMEM_BUDGET_BYTES."""
    raw = os.environ.get(_VMEM_ENV_VAR, "").strip()
    if not raw:
        return VMEM_BUDGET_BYTES
    try:
        val = int(raw, 0)
    except ValueError:
        raise ValueError(
            f"${_VMEM_ENV_VAR}={raw!r} is not an integer byte count")
    if val <= 0:
        raise ValueError(f"${_VMEM_ENV_VAR}={raw!r} must be positive")
    return val


def default_policy() -> str:
    """Process-wide default: $REPRO_KERNEL_POLICY or "auto"."""
    p = os.environ.get(_ENV_VAR, "auto").strip().lower()
    return p if p in POLICIES else "auto"


def resolve(policy: str | None) -> str:
    """Normalize a user-supplied policy.

    None/"" and "auto" both resolve through the process default, so
    $REPRO_KERNEL_POLICY can pin "pallas"/"xla" process-wide for any run
    that didn't explicitly choose a non-auto policy.
    """
    p = "auto" if policy in (None, "") else str(policy).lower()
    if p not in POLICIES:
        raise ValueError(f"unknown kernel policy {policy!r}; "
                         f"expected one of {POLICIES}")
    return default_policy() if p == "auto" else p


def interpret_mode() -> bool:
    """Pallas TPU kernels must run interpret=True off-TPU (CPU containers);
    on a real TPU the same pallas_call lowers through Mosaic."""
    return not compat.is_tpu_backend()


def tp_degree(mesh) -> int:
    """Model-axis size of a mesh (1 when absent / no mesh): the tensor-
    parallel fan-out a GEMM's output dimension is split across."""
    if mesh is None:
        return 1
    try:
        return int(mesh.shape.get("model", 1))
    except AttributeError:
        return 1


def tp_split(n: int, tp: int) -> int:
    """Shard-local output dimension under `tp`-way column parallelism
    (the whole dim when it does not divide — that GEMM stays unsplit)."""
    return n // tp if tp > 1 and n % tp == 0 else n


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Trace-time execution plan for one approximate GEMM.

    `path` is the three-way choice ("fused" / "stacked" Pallas kernels, or
    the "xla" reference); `bm/bk/bn/unroll` are the fused tile (tuned or
    default); `skinny=True` routes a decode-shaped GEMM (m <= SKINNY_MAX_M)
    to the skinny-M kernel, in which case bm is the true row count.
    `source` records why: "policy" (pinned), "tuned" (autotune cache hit),
    "roofline" (cost-model prediction), "default" (static fallback)."""
    path: str
    bm: int
    bk: int
    bn: int
    unroll: int = 1
    skinny: bool = False
    source: str = "default"

    @property
    def use_pallas(self) -> bool:
        return self.path != "xla"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _fused_admissible(m: int, k: int, n: int, n_planes: int, *,
                      skinny: bool, bm: int, bk: int, bn: int) -> bool:
    from repro.kernels import approx_qgemm as qk
    if skinny:
        return (m <= qk.SKINNY_MAX_M and min(k, n) >= MIN_DIM and
                qk.skinny_vmem_bytes(m, bk, bn, n_planes)
                <= vmem_budget_bytes())
    return (min(m, k, n) >= MIN_DIM and
            qk.fused_vmem_bytes(bm, bk, bn, n_planes)
            <= vmem_budget_bytes())


def choose_gemm_path(policy: str | None, *, m: int, k: int, n: int,
                     mode: str = "exact", rank: int = 0,
                     n_planes: int | None = None, tp: int = 1,
                     multi_device: bool = False) -> GemmPlan:
    """The three-way GEMM dispatch: fused / stacked / xla, with tiles.

    Resolution order under "auto" (single-device):

      1. the autotune cache — a MEASURED winner for this (backend,
         shape-bucket, mode, rank, VMEM budget) cell wins outright, tiles
         included;
      2. the roofline cost model (on TPU) — predicted-winner across the
         three paths at default tiles, with the skinny-M kernel standing
         in for fused on decode-shaped GEMMs.  `auto` therefore never
         picks fused where the model predicts stacked/XLA wins — the
         exact-mode regression BENCH_gemm used to show;
      3. off-TPU with no cache entry: XLA (interpret-mode Pallas is a
         correctness vehicle, not a fast path).

    Under tensor parallelism the plan applies to the SHARD-LOCAL shape
    (m, k, n/tp); stacked/skinny are not offered there (the shard_map
    wrappers run the regular fused kernel), so TP keeps the PR5-era
    binary fused/xla choice."""
    from repro.kernels import approx_qgemm as qk

    p = resolve(policy)
    n_planes = n_planes if n_planes is not None else 1 + rank
    n_local = tp_split(n, tp)
    bm, bk, bn = qk.choose_blocks(m, k, n_local)
    if p == "xla":
        return GemmPlan("xla", bm, bk, bn, source="policy")
    sharded = tp > 1 or multi_device
    if p == "pallas":
        plan = None if sharded else _tuned_plan(m, k, n_local, mode, rank,
                                                n_planes)
        if plan is not None and plan.path == "fused":
            return plan
        if not sharded and m <= qk.SKINNY_MAX_M:
            sbk, sbn = qk.choose_skinny_blocks(k, n_local)
            return GemmPlan("fused", m, sbk, sbn, skinny=True,
                            source="policy")
        return GemmPlan("fused", bm, bk, bn, source="policy")
    # auto
    if sharded:
        if (compat.is_tpu_backend()
                and _fused_admissible(m, k, n_local, n_planes, skinny=False,
                                      bm=bm, bk=bk, bn=bn)):
            return GemmPlan("fused", bm, bk, bn, source="roofline")
        return GemmPlan("xla", bm, bk, bn, source="default")
    plan = _tuned_plan(m, k, n_local, mode, rank, n_planes)
    if plan is not None:
        return plan
    if not compat.is_tpu_backend():
        return GemmPlan("xla", bm, bk, bn, source="default")
    return _roofline_plan(m, k, n_local, n_planes, bm, bk, bn)


def _tuned_plan(m: int, k: int, n: int, mode: str, rank: int,
                n_planes: int) -> GemmPlan | None:
    """Autotune-cache hit -> GemmPlan, re-validated against the CURRENT
    admission model (a tuned fused entry that no longer fits the budget —
    e.g. after a kernel edit — is ignored, not trusted)."""
    import jax

    from repro.kernels import autotune

    hit = autotune.lookup(m, k, n, mode, rank,
                          backend=jax.default_backend(),
                          vmem_budget=vmem_budget_bytes())
    if hit is None:
        return None
    if hit.path == "fused":
        bm = m if hit.skinny else hit.bm
        if not _fused_admissible(m, k, n, n_planes, skinny=hit.skinny,
                                 bm=bm, bk=hit.bk, bn=hit.bn):
            return None
        return GemmPlan("fused", bm, hit.bk, hit.bn, hit.unroll,
                        hit.skinny, source="tuned")
    from repro.kernels import approx_qgemm as qk
    bm, bk, bn = qk.choose_blocks(m, k, n)
    return GemmPlan(hit.path, bm, bk, bn, source="tuned")


def _roofline_plan(m: int, k: int, n: int, n_planes: int,
                   bm: int, bk: int, bn: int) -> GemmPlan:
    """On-TPU, no measurement: the roofline model's predicted winner."""
    from repro.kernels import approx_qgemm as qk
    from repro.roofline import analysis as rfa

    skinny = m <= qk.SKINNY_MAX_M
    if skinny:
        sbk, sbn = qk.choose_skinny_blocks(k, n)
        fbm, fbk, fbn = m, sbk, sbn
    else:
        fbm, fbk, fbn = bm, bk, bn
    if not _fused_admissible(m, k, n, n_planes, skinny=skinny,
                             bm=fbm, bk=fbk, bn=fbn):
        return GemmPlan("xla", bm, bk, bn, source="roofline")
    winner, _ = rfa.predicted_gemm_winner(m, k, n, n_planes, bm=fbm,
                                          bk=fbk, bn=fbn, skinny=skinny,
                                          on_tpu=True)
    if winner == "fused":
        return GemmPlan("fused", fbm, fbk, fbn, skinny=skinny,
                        source="roofline")
    return GemmPlan(winner, bm, bk, bn, source="roofline")


def use_pallas_gemm(policy: str | None, *, m: int, k: int, n: int,
                    n_planes: int = 1, tp: int = 1) -> bool:
    """Should this (m, k, n) approximate GEMM with `n_planes` operand planes
    run on a Pallas kernel?  Back-compat boolean view of the three-way
    `choose_gemm_path` plan (fused OR stacked -> True).

    Under `tp`-way tensor parallelism the kernel runs per shard (via
    shard_map, kernels/ops.approx_qgemm_tp), so both the minimum-tile
    check and the VMEM budget apply to the SHARD-LOCAL shape
    (m, k, n/tp) — a GEMM whose fused working set busts VMEM globally can
    still run fused when each die's slice fits; one that doesn't falls
    back to XLA per-shard."""
    rank = max(n_planes - 1, 0)
    mode = "lowrank" if rank else "exact"
    return choose_gemm_path(policy, m=m, k=k, n=n, mode=mode, rank=rank,
                            n_planes=n_planes, tp=tp).use_pallas


def use_pallas_attention(policy: str | None, *, seq: int,
                         head_dim: int) -> bool:
    """Same decision for flash attention (kv-blocked kernel vs the XLA
    blockwise custom-VJP twin in models/attention.py)."""
    p = resolve(policy)
    if p == "xla":
        return False
    if p == "pallas":
        return True
    return compat.is_tpu_backend() and seq >= MIN_DIM and head_dim >= 64
