"""Kernel-dispatch policy: Pallas kernels vs the XLA reference path.

Replaces the old `use_kernel: bool` threaded through approx/gemm.py with a
named policy resolved per GEMM at trace time:

  "xla"    — never use the Pallas kernels (pure jnp/lax path);
  "pallas" — always use them (interpret mode off-TPU, Mosaic on TPU);
  "auto"   — use them when they plausibly win: real TPU backend, operand
             dims at least one MXU tile (padding a tiny GEMM to 128-multiples
             costs more than it saves), and the fused kernel's VMEM working
             set within budget.  Off-TPU, auto picks XLA — interpret-mode
             Pallas is a correctness vehicle, not a fast path.

The fused GEMM data path changed the auto accounting: operand HBM traffic
no longer scales with the plane count (raw operands are read once and
table-mapped in-register), so plane count only costs VMEM accumulator
space.  `fused_vmem_bytes` is checked against VMEM_BUDGET_BYTES directly —
rank-8 multipliers (9 planes) now pass at the default block shape, where
the old stacked-traffic cap (MAX_PLANES=8) rejected them.

The policy rides on `MultSpec.policy` (a static/meta pytree field, so a
policy change is a new jit cache key — no stale-trace footgun), is settable
per model via `ModelConfig.kernel_policy`, per run via the `--kernel-policy`
flag on launch/train.py and launch/serve.py, and process-wide via the
`REPRO_KERNEL_POLICY` environment variable.
"""

from __future__ import annotations

import os

from repro import compat

POLICIES = ("auto", "pallas", "xla")

#: Below one MXU tile on any operand dim, block padding dominates.
MIN_DIM = 128
#: Per-grid-step VMEM working-set budget for the fused kernel: ~16 MiB/core
#: minus compiler headroom.  Override per process with $REPRO_VMEM_BUDGET
#: (bytes, decimal or 0x-hex) for parts with different VMEM — the override
#: feeds every budget consumer (auto dispatch and the repro.analysis Pallas
#: contract checker) through `vmem_budget_bytes()`.
VMEM_BUDGET_BYTES = 14 << 20

_ENV_VAR = "REPRO_KERNEL_POLICY"
_VMEM_ENV_VAR = "REPRO_VMEM_BUDGET"


def vmem_budget_bytes() -> int:
    """Effective fused-kernel VMEM budget: $REPRO_VMEM_BUDGET (positive
    integer bytes; "0x..." hex accepted) or VMEM_BUDGET_BYTES."""
    raw = os.environ.get(_VMEM_ENV_VAR, "").strip()
    if not raw:
        return VMEM_BUDGET_BYTES
    try:
        val = int(raw, 0)
    except ValueError:
        raise ValueError(
            f"${_VMEM_ENV_VAR}={raw!r} is not an integer byte count")
    if val <= 0:
        raise ValueError(f"${_VMEM_ENV_VAR}={raw!r} must be positive")
    return val


def default_policy() -> str:
    """Process-wide default: $REPRO_KERNEL_POLICY or "auto"."""
    p = os.environ.get(_ENV_VAR, "auto").strip().lower()
    return p if p in POLICIES else "auto"


def resolve(policy: str | None) -> str:
    """Normalize a user-supplied policy.

    None/"" and "auto" both resolve through the process default, so
    $REPRO_KERNEL_POLICY can pin "pallas"/"xla" process-wide for any run
    that didn't explicitly choose a non-auto policy.
    """
    p = "auto" if policy in (None, "") else str(policy).lower()
    if p not in POLICIES:
        raise ValueError(f"unknown kernel policy {policy!r}; "
                         f"expected one of {POLICIES}")
    return default_policy() if p == "auto" else p


def interpret_mode() -> bool:
    """Pallas TPU kernels must run interpret=True off-TPU (CPU containers);
    on a real TPU the same pallas_call lowers through Mosaic."""
    return not compat.is_tpu_backend()


def tp_degree(mesh) -> int:
    """Model-axis size of a mesh (1 when absent / no mesh): the tensor-
    parallel fan-out a GEMM's output dimension is split across."""
    if mesh is None:
        return 1
    try:
        return int(mesh.shape.get("model", 1))
    except AttributeError:
        return 1


def tp_split(n: int, tp: int) -> int:
    """Shard-local output dimension under `tp`-way column parallelism
    (the whole dim when it does not divide — that GEMM stays unsplit)."""
    return n // tp if tp > 1 and n % tp == 0 else n


def use_pallas_gemm(policy: str | None, *, m: int, k: int, n: int,
                    n_planes: int = 1, tp: int = 1) -> bool:
    """Should this (m, k, n) approximate GEMM with `n_planes` operand planes
    run on the Pallas kernel?  Resolved at trace time (shapes are static).

    Under `tp`-way tensor parallelism the kernel runs per shard (via
    shard_map, kernels/ops.approx_qgemm_tp), so both the minimum-tile
    check and the VMEM budget apply to the SHARD-LOCAL shape
    (m, k, n/tp) — a GEMM whose fused working set busts VMEM globally can
    still run fused when each die's slice fits; one that doesn't falls
    back to XLA per-shard."""
    p = resolve(policy)
    if p == "xla":
        return False
    n_local = tp_split(n, tp)
    if p == "pallas":
        return True
    # auto
    if not compat.is_tpu_backend():
        return False
    if min(m, k, n_local) < MIN_DIM:
        return False
    from repro.kernels import approx_qgemm as qk
    bm, bk, bn = qk.choose_blocks(m, k, n_local)
    return qk.fused_vmem_bytes(bm, bk, bn, n_planes) <= vmem_budget_bytes()


def use_pallas_attention(policy: str | None, *, seq: int,
                         head_dim: int) -> bool:
    """Same decision for flash attention (kv-blocked kernel vs the XLA
    blockwise custom-VJP twin in models/attention.py)."""
    p = resolve(policy)
    if p == "xla":
        return False
    if p == "pallas":
        return True
    return compat.is_tpu_backend() and seq >= MIN_DIM and head_dim >= 64
