"""jit'd public wrappers around the Pallas kernels.

Handles: padding to block multiples, reshaping, and the interpret-mode
switch (CPU containers run kernels with interpret=True; on real TPU the
same code compiles to Mosaic).

The approximate GEMM runs FUSED by default: raw quantized operands go
straight into the kernel, which applies the truncation mask and the
per-rank table maps in-register (kernels/approx_qgemm.py).  The legacy
stacked path — `build_stacks` pre-maps the operands in XLA into (P, M, K)
/ (P, K, N) HBM intermediates — is kept behind `fused=False` as the
reference twin for parity tests and the BENCH_gemm trajectory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.approx import gemm as gemm_mod
from repro.kernels import approx_qgemm as qk
from repro.kernels import dispatch
from repro.kernels import flash_attention as fk
from repro.kernels import quantize as qz


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def build_stacks(a_q: jax.Array, b_q: jax.Array, spec: gemm_mod.MultSpec
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Build (P, M, K) / (P, K, N) int8 operand stacks + (P, 1) f32 scales.

    Plane 0 carries the raw (or truncation-masked) operands with scale +1;
    planes 1..R carry the table-mapped correction operands with scale -s_r.
    """
    if spec.mode == "trunc":
        a0 = gemm_mod._trunc_mask(a_q, spec.trunc_a)
        b0 = gemm_mod._trunc_mask(b_q, spec.trunc_b)
        return (a0[None], b0[None], jnp.ones((1, 1), jnp.float32))
    planes_a = [a_q]
    planes_b = [b_q]
    scales = [jnp.ones((), jnp.float32)]
    for r in range(spec.rank):
        planes_a.append(gemm_mod._table_map(spec.fu_q[r], a_q))
        planes_b.append(gemm_mod._table_map(spec.fv_q[r], b_q))
        scales.append(-spec.s_r[r])
    return (jnp.stack(planes_a), jnp.stack(planes_b),
            jnp.stack(scales)[:, None])


def _spec_kernel_args(spec: gemm_mod.MultSpec):
    """(trunc_a, trunc_b, rank) as the kernels consume them."""
    trunc_a = spec.trunc_a if spec.mode == "trunc" else 0
    trunc_b = spec.trunc_b if spec.mode == "trunc" else 0
    rank = spec.rank if spec.mode == "lowrank" else 0
    return trunc_a, trunc_b, rank


def approx_qgemm(a_q: jax.Array, b_q: jax.Array, spec: gemm_mod.MultSpec,
                 *, bm: int | None = None, bk: int | None = None,
                 bn: int | None = None, fused: bool = True,
                 skinny: bool = False, unroll: int = 1) -> jax.Array:
    """int8 (m, k) x int8 (k, n) -> f32 (m, n) via the Pallas kernels.

    `fused=True` (default) streams the raw operands once and maps/masks
    them in-kernel; `fused=False` runs the stacked reference twin (XLA
    pre-maps `(R+1)x` operand copies through HBM).  `skinny=True` routes
    a decode-shaped GEMM (m <= SKINNY_MAX_M) to the skinny-M kernel: the
    row batch is consumed unpadded, so `bm` is ignored.  `unroll` is the
    plane-unroll schedule knob (bit-identical at every value)."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2
    interpret = dispatch.interpret_mode()
    trunc_a, trunc_b, rank = _spec_kernel_args(spec)
    if fused and skinny:
        assert m <= qk.SKINNY_MAX_M, (m, qk.SKINNY_MAX_M)
        bk, bn = qk.choose_skinny_blocks(k, n, bk, bn)
        ap = _pad_to(a_q, 1, bk)
        bp = _pad_to(_pad_to(b_q, 0, bk), 1, bn)
        scales = jnp.concatenate(
            [jnp.ones((1,), jnp.float32), -spec.s_r])[:, None] if rank \
            else jnp.ones((1, 1), jnp.float32)
        out = qk.approx_qgemm_skinny(
            ap, bp, spec.fu_q[:rank], spec.fv_q[:rank], scales,
            trunc_a=trunc_a, trunc_b=trunc_b, k_valid=k, bk=bk, bn=bn,
            unroll=unroll, interpret=interpret)
        return out[:, :n]
    bm, bk, bn = qk.choose_blocks(m, k, n, bm, bk, bn)
    if not fused:
        a_s, b_s, s = build_stacks(a_q, b_q, spec)
        a_s = _pad_to(_pad_to(a_s, 1, bm), 2, bk)
        b_s = _pad_to(_pad_to(b_s, 1, bk), 2, bn)
        out = qk.approx_qgemm_stacked(a_s, b_s, s, bm=bm, bk=bk, bn=bn,
                                      interpret=interpret)
        return out[:m, :n]
    ap = _pad_to(_pad_to(a_q, 0, bm), 1, bk)
    bp = _pad_to(_pad_to(b_q, 0, bk), 1, bn)
    if rank:
        scales = jnp.concatenate(
            [jnp.ones((1,), jnp.float32), -spec.s_r])[:, None]
        out = qk.approx_qgemm_fused(
            ap, bp, spec.fu_q, spec.fv_q, scales, trunc_a=trunc_a,
            trunc_b=trunc_b, k_valid=k, bm=bm, bk=bk, bn=bn, unroll=unroll,
            interpret=interpret)
    else:
        out = qk.approx_qgemm_plane0(ap, bp, trunc_a=trunc_a,
                                     trunc_b=trunc_b, bm=bm, bk=bk, bn=bn,
                                     interpret=interpret)
    return out[:m, :n]


def approx_qgemm_planned(a_q: jax.Array, b_q: jax.Array,
                         spec: gemm_mod.MultSpec,
                         plan: dispatch.GemmPlan) -> jax.Array:
    """Execute a GEMM per a `dispatch.choose_gemm_path` plan (Pallas
    paths; the XLA path belongs to approx/gemm.py, which knows about
    prepared weights)."""
    assert plan.path in ("fused", "stacked"), plan
    if plan.path == "stacked":
        return approx_qgemm(a_q, b_q, spec, fused=False)
    if plan.skinny:
        return approx_qgemm(a_q, b_q, spec, bk=plan.bk, bn=plan.bn,
                            skinny=True, unroll=plan.unroll)
    return approx_qgemm(a_q, b_q, spec, bm=plan.bm, bk=plan.bk, bn=plan.bn,
                        unroll=plan.unroll)


def approx_qgemm_tp(a_q: jax.Array, b_q: jax.Array,
                    spec: gemm_mod.MultSpec, mesh, *,
                    axis: str = "model", fused: bool = True) -> jax.Array:
    """Column-parallel tensor-parallel fused GEMM: the weight is sharded
    on its output dim over the mesh's `axis`, activations are replicated,
    and each shard runs the SAME fused Pallas kernel on its shard-local
    (m, k, n/tp) slice — the (R, 256) LUT factor tables ride into every
    shard's VMEM (they are spec constants, replicated by closure).  A
    full-K contraction per shard means no cross-shard reduction, so the
    result is bit-identical to the single-device kernel.

    Inside jit, the shard_map in_specs double as sharding constraints:
    weights prepared/committed with sharding/rules.py (col-parallel on
    "model") flow in without movement; anything else is resharded once by
    GSPMD."""
    from jax.sharding import PartitionSpec as P

    n = b_q.shape[1]
    tp = dispatch.tp_degree(mesh)
    assert tp > 1 and n % tp == 0, (n, tp)
    shard_map = compat.shard_map_fn()

    def per_shard(a, b):
        return approx_qgemm(a, b, spec, fused=fused)

    run = shard_map(per_shard, mesh=mesh,
                    in_specs=(P(), P(None, axis)),
                    out_specs=P(None, axis), check_rep=False)
    return run(a_q, b_q)


def approx_qgemm_replicated(a_q: jax.Array, b_q: jax.Array,
                            spec: gemm_mod.MultSpec, mesh, *,
                            fused: bool = True) -> jax.Array:
    """Fully-replicated shard_map wrapper: every device runs the whole
    fused kernel.  The escape hatch for a pallas-pinned policy on a
    multi-device mesh when the output dim does not divide the model axis
    (pallas_call is opaque to GSPMD, so it must run under manual
    partitioning either way)."""
    from jax.sharding import PartitionSpec as P

    shard_map = compat.shard_map_fn()
    run = shard_map(
        lambda a, b: approx_qgemm(a, b, spec, fused=fused), mesh=mesh,
        in_specs=(P(), P()), out_specs=P(), check_rep=False)
    return run(a_q, b_q)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int | None = None,
                    bkv: int | None = None) -> jax.Array:
    """q (bh, sq, d), k/v (bh, skv, d) -> (bh, sq, d)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = bq or min(fk.DEFAULT_BQ, sq)
    bkv = bkv or min(fk.DEFAULT_BKV, skv)
    assert sq % bq == 0 and skv % bkv == 0, \
        "pad sequence to block multiples before calling"
    return fk.flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv,
                              interpret=dispatch.interpret_mode())


def quantize_rows(x: jax.Array, *, bm: int | None = None, trunc: int = 0
                  ) -> tuple[jax.Array, jax.Array]:
    """(M, K) float -> int8 rows + scales via the fused kernel.

    `trunc` > 0 additionally masks the bottom LSBs of the quantized rows
    in the same VMEM pass — the prologue fusion for trunc-mode GEMMs
    (saves the separate XLA mask pass on the activation side)."""
    m, k = x.shape
    bm = bm or min(qz.DEFAULT_BM, max(8, 1 << (m - 1).bit_length()))
    xp = _pad_to(x, 0, bm)
    q, s = qz.quantize_rows(xp, bm=bm, trunc=trunc,
                            interpret=dispatch.interpret_mode())
    return q[:m], s[:m]
