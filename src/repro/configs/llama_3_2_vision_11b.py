"""Llama-3.2-Vision-11B — cross-attn image layers every 5th decoder layer;
patch frontend is a stub (input_specs supplies patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="lm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, head_dim=128, rope_theta=500000.0,
    cross_every=5, n_img_tokens=1600,
)
