"""Llama4-Maverick-400B-A17B — 128 routed experts top-1, MoE on alternating
layers with a shared expert, dense interleave FFN 2x wider
[hf:meta-llama/Llama-4 family; unverified].  With these settings the config
lands at ~402B total / ~18B active parameters, matching the nameplate."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="lm",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, rope_theta=500000.0,
    n_experts=128, top_k=1, moe_every=2, d_ff_dense=16384,
    shared_expert=True,
)
