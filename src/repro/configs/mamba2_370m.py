"""Mamba2-370M — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280, ssm_state=128, ssm_heads=32, ssm_head_dim=64,
    ssm_expand=2, conv_width=4, ssd_chunk=256,
)
