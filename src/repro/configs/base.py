"""Config schema: model architecture, input shapes, mesh, run options."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # "lm" | "ssm" | "hybrid" | "encdec"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    mlp_style: str = "swiglu"   # "swiglu" (3-matrix) | "gelu" (2-matrix)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1          # 1 = every layer MoE; 2 = alternating
    d_ff_dense: int = 0         # dense-interleave FFN width (0 -> d_ff)
    shared_expert: bool = False
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssd_chunk: int = 256
    # hybrid (recurrentgemma: RG-LRU + local attention, pattern 2:1)
    window: int = 0
    lru_width: int = 0
    # encoder-decoder (whisper: conv frontend is a stub; encoder consumes
    # precomputed frame embeddings per the brief)
    n_enc_layers: int = 0
    enc_seq: int = 0
    # vision-language (llama-3.2-vision: patch frontend is a stub; cross
    # attention blocks every `cross_every` decoder layers)
    n_img_tokens: int = 0
    cross_every: int = 0
    # numerics / execution
    dtype: str = "bfloat16"
    mult: str = "exact"         # approximate-multiplier library name
    kernel_policy: str = "auto"  # "auto" | "pallas" | "xla" (kernels/dispatch)
    attn_impl: str = "chunked"  # "naive" | "chunked" | "flash"
    attn_chunk: int = 512
    remat: bool = True
    # technique applicability (see DESIGN.md §Arch-applicability)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameters N (for 6*N*D model-flops accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            per = (d * (2 * d_in + 2 * self.ssm_heads * 0)  # in_proj core
                   + d * (2 * self.ssm_state * 1)           # B, C proj
                   + d * self.ssm_heads                      # dt proj
                   + d_in * d                                # out proj
                   + 2 * d)                                  # norms
            return self.n_layers * per + 2 * v * d
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        nmat = 3 if self.mlp_style == "swiglu" else 2
        if self.is_moe:
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            fd = self.d_ff_dense or f
            mlp_total = n_moe * (self.n_experts * 3 * d * f
                                 + d * self.n_experts
                                 + (3 * d * f if self.shared_expert else 0))
            mlp_total += n_dense * nmat * d * fd
        else:
            mlp_total = self.n_layers * nmat * d * f
        total = self.n_layers * (att + 2 * d) + mlp_total
        total += (1 if self.tie_embeddings else 2) * v * d
        if self.cross_every:
            n_cross = self.n_layers // self.cross_every
            total += n_cross * (2 * att + d)
        if self.n_enc_layers:
            total += self.n_enc_layers * (att + nmat * d * f + 2 * d)
        return total

    def active_param_count(self) -> int:
        """N_active for MoE (6*N_active*D in the roofline MODEL_FLOPS)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        hd = self.hd
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        n_moe = self.n_layers // self.moe_every
        n_dense = self.n_layers - n_moe
        fd = self.d_ff_dense or f
        mlp_total = n_moe * (self.top_k * 3 * d * f + d * self.n_experts
                             + (3 * d * f if self.shared_expert else 0))
        mlp_total += n_dense * 3 * d * fd
        return self.n_layers * (att + 2 * d) + mlp_total + 2 * self.vocab * d


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell of the evaluation matrix."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    small: dict = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.cross_every else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_dense=256 if cfg.d_ff_dense else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        ssm_head_dim=16 if cfg.ssm_heads else 64,
        window=min(cfg.window, 32) if cfg.window else 0,
        lru_width=128 if cfg.lru_width else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=min(cfg.enc_seq, 16) if cfg.enc_seq else 0,
        n_img_tokens=min(cfg.n_img_tokens, 16) if cfg.n_img_tokens else 0,
        cross_every=2 if cfg.cross_every else 0,
        dtype="float32",
        attn_chunk=16,
        remat=False,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
