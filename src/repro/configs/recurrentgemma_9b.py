"""RecurrentGemma-9B — RG-LRU + local attention, pattern (rec, rec, attn)
[arXiv:2402.19427; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256, window=2048, lru_width=4096,
)
