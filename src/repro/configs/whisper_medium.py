"""Whisper-medium — enc-dec, conv frontend stubbed (frame embeddings in)
[arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64,
    n_enc_layers=24, enc_seq=1500, mlp_style="gelu", tie_embeddings=True,
)
