"""Architecture registry: one module per assigned architecture.

`get_config(arch)` resolves ids like "tinyllama-1.1b" (dashes/dots map to
underscores in module names).  `input_specs(cfg, shape)` builds
ShapeDtypeStruct stand-ins for every model input of a cell — weak-type
correct, shardable, zero allocation (the dry-run pattern).
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, reduced  # noqa: F401

ARCH_IDS = [
    "tinyllama-1.1b",
    "qwen1.5-32b",
    "starcoder2-7b",
    "mistral-large-123b",
    "mamba2-370m",
    "llama-3.2-vision-11b",
    "grok-1-314b",
    "llama4-maverick-400b-a17b",
    "recurrentgemma-9b",
    "whisper-medium",
]

# the paper's own workloads (CNNs) live in core/workloads.py + models/cnn.py
PAPER_WORKLOADS = ["vgg16", "vgg19", "resnet50", "resnet152"]


def _module_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch)}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def apply_overrides(cfg: ModelConfig, *, reduced: bool = False,
                    mult: str = "", kernel_policy: str = "",
                    **extra) -> ModelConfig:
    """The CLI config-override dance shared by launch/train and
    launch/serve: optional tiny same-family config, approximate
    multiplier, kernel-dispatch policy, plus arbitrary ModelConfig field
    overrides.  `mult` / `kernel_policy` treat "" as "flag not given"
    (argparse defaults); extras are applied unless None, so falsy values
    like `window=0` or `tie_embeddings=False` are honored."""
    import dataclasses
    from repro.configs import base
    if reduced:
        cfg = base.reduced(cfg)
    over = {}
    if mult:
        over["mult"] = mult
    if kernel_policy:
        over["kernel_policy"] = kernel_policy
    over.update({k: v for k, v in extra.items() if v is not None})
    if over:
        cfg = dataclasses.replace(cfg, **over)
    return cfg


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch x shape) is a runnable cell; else the documented skip."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("full attention at 512k context is quadratic-prefill/"
                       "unbounded-KV; skipped per brief (sub-quadratic archs "
                       "only)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for one cell's inputs."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": tok((b, s), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = tok((b, s), jnp.int32)
    else:  # decode
        specs = {"tokens": tok((b, 1), jnp.int32)}
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["frames"] = tok((b, cfg.enc_seq, cfg.d_model),
                              jnp.dtype(cfg.dtype))
    if cfg.cross_every and shape.kind != "decode":
        specs["img"] = tok((b, cfg.n_img_tokens, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs of the decode cache for a cell (no allocation)."""
    from repro.models import api
    return jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
