"""StarCoder2-7B — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="lm",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab=49152, head_dim=128, rope_theta=1000000.0, mlp_style="gelu",
)
