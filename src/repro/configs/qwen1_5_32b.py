"""Qwen1.5-32B — QKV bias [hf:Qwen/Qwen1.5 family; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="lm",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
    vocab=152064, head_dim=128, qkv_bias=True, rope_theta=1000000.0,
)
