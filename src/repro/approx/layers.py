"""Model-facing approximate compute layers.

Every matmul in every model in this framework routes through `dense` /
`conv2d` / `gemm` here, so any architecture can be evaluated under any
candidate approximate multiplier (the accuracy-constraint substrate of the
paper's GA).  With `spec=None` or an exact spec the layer is a plain bf16/f32
matmul — that is the dry-run / roofline baseline mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.approx import gemm as gemm_mod


def _as_weight(w, dtype):
    """Accepts a plain array, an int8-serving {"q","s"} dict leaf, or a
    serving `PreparedWeight` (degrades to its original float weight)."""
    from repro.approx import quant
    if gemm_mod.is_prepared(w):
        return w.w
    if quant.is_qweight(w):
        return quant.dequantize_weight(w, dtype)
    return w


def gemm(x: jax.Array, w,
         spec: gemm_mod.MultSpec | None = None,
         policy: str | None = None) -> jax.Array:
    """x (..., k) @ w (k, n), approximate if spec says so.

    `w` may be a raw array, an int8-serving {"q","s"} dict leaf, or a
    `PreparedWeight` (the serving weight-plane cache, api.prepare_params):
    prepared weights skip the per-call weight quantize/table-map entirely
    and are bit-identical to the fresh path.

    `policy` overrides the spec-carried kernel-dispatch policy for this
    call ("auto" | "pallas" | "xla"); None keeps `spec.policy`.
    """
    if spec is None or spec.is_exact:
        return jnp.einsum("...k,kn->...n", x, _as_weight(w, x.dtype))
    if policy is not None:
        spec = spec.with_policy(policy)
    if gemm_mod.is_prepared(w):
        return gemm_mod.approx_matmul_prepared(x, w, spec)
    return gemm_mod.approx_matmul(x, _as_weight(w, x.dtype), spec)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
          spec: gemm_mod.MultSpec | None = None,
          policy: str | None = None) -> jax.Array:
    """Linear layer.  The bias add stays exact (the paper approximates the
    MAC multipliers; accumulators/adders are exact)."""
    y = gemm(x, w, spec, policy)
    if b is not None:
        y = y + b
    return y


def _im2col(x: jax.Array, r: int, s: int, stride: int, padding: int
            ) -> tuple[jax.Array, int, int]:
    """x (n, h, w, c) -> patches (n, ho, wo, r*s*c)."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    ho = (h + 2 * padding - r) // stride + 1
    wo = (w + 2 * padding - s) // stride + 1
    idx_h = stride * jnp.arange(ho)[:, None] + jnp.arange(r)[None, :]  # ho,r
    idx_w = stride * jnp.arange(wo)[:, None] + jnp.arange(s)[None, :]  # wo,s
    # gather rows then cols
    patches = xp[:, idx_h]              # (n, ho, r, w+2p, c)
    patches = patches[:, :, :, idx_w]   # (n, ho, r, wo, s, c)
    patches = patches.transpose(0, 1, 3, 2, 4, 5)  # (n, ho, wo, r, s, c)
    return patches.reshape(n, ho, wo, r * s * c), ho, wo


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 1,
           spec: gemm_mod.MultSpec | None = None,
           policy: str | None = None) -> jax.Array:
    """NHWC conv via im2col + (approximate) GEMM.

    x (n, h, w, c_in), w (r, s, c_in, c_out).  im2col is exactly how the
    NVDLA-style accelerator maps conv onto its MAC array, so simulated
    approximation composes correctly per-MAC.
    """
    w = _as_weight(w, x.dtype)
    r, s, c_in, c_out = w.shape
    if spec is None or spec.is_exact:
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(padding, padding), (padding, padding)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    patches, ho, wo = _im2col(x, r, s, stride, padding)
    w2 = w.reshape(r * s * c_in, c_out)
    y = gemm(patches, w2, spec, policy)
    return y.reshape(x.shape[0], ho, wo, c_out)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """Embedding lookups are reads, not MACs — always exact."""
    return jnp.take(table, tokens, axis=0)
