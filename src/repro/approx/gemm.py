"""Approximate-multiplier GEMM for TPU (the framework's core compute path).

A `MultSpec` is the JAX-side artifact compiled from a gate-level
`ApproxMultiplier` (core/multipliers.py).  Three execution modes, chosen at
spec-build time from the multiplier's structure (DESIGN.md §3):

  exact    m(a,b) == a*b          -> one int8 MXU matmul
  trunc    m(a,b) == t(a)*t(b)    -> mask LSBs, one int8 MXU matmul
           (pure precision scaling; bit-exact)
  lowrank  m(a,b) == a*b - E(a,b) -> (R+1) int8 MXU matmuls:
           E ~= sum_r s_r * fu_q[r][a] * fv_q[r][b]  (SVD of the error
           surface, factors themselves int8-quantized so every matmul stays
           on the MXU int8 path).  The residual NMED of the quantized
           factorization is measured at build time and carried on the spec.

The exact LUT path (`lut_matmul` in kernels/ref.py) is the oracle: tests
assert `trunc` is bit-exact and `lowrank` is within the recorded residual.

Gradients: straight-through (ApproxTrain's approach) — forward runs the
approximate quantized GEMM, backward uses the float operands.  This is what
makes *training under approximation* (and therefore accuracy-constrained
co-design) work at scale.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import quant

MODES = ("exact", "trunc", "lowrank")


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("fu_q", "fv_q", "s_r"),
    meta_fields=("name", "mode", "trunc_a", "trunc_b", "rank",
                 "residual_nmed", "nmed", "policy"),
)
@dataclasses.dataclass(frozen=True)
class MultSpec:
    """JAX-friendly approximate-multiplier spec (pytree)."""
    name: str
    mode: str                 # "exact" | "trunc" | "lowrank"
    trunc_a: int
    trunc_b: int
    rank: int
    residual_nmed: float      # NMED of (E - quantized low-rank reconstruction)
    nmed: float               # NMED of the multiplier itself
    fu_q: jax.Array           # (R, 256) int8   (row r of U factor, by a&0xFF)
    fv_q: jax.Array           # (R, 256) int8
    s_r: jax.Array            # (R,) f32        (per-rank dequant scale)
    # Kernel-dispatch policy ("auto" | "pallas" | "xla"), a *meta* field:
    # it is part of the treedef, so changing it is a new jit cache key.
    policy: str = "auto"

    @property
    def is_exact(self) -> bool:
        return self.mode == "exact"

    @property
    def n_planes(self) -> int:
        """Operand planes the stacked kernel runs: raw + R corrections."""
        return 1 + self.rank

    def with_policy(self, policy: str | None) -> "MultSpec":
        """Same spec under a different kernel-dispatch policy (validated)."""
        from repro.kernels import dispatch
        p = dispatch.resolve(policy)
        if p == self.policy:
            return self
        return dataclasses.replace(self, policy=p)


def exact_spec() -> MultSpec:
    z = jnp.zeros((0, 256), dtype=jnp.int8)
    return MultSpec("exact", "exact", 0, 0, 0, 0.0, 0.0, z, z,
                    jnp.zeros((0,), jnp.float32))


def from_multiplier(m: Any, rank: int | None = None,
                    tol_nmed: float = 1e-4) -> MultSpec:
    """Compile a core.multipliers.ApproxMultiplier into a MultSpec.

    Imports core lazily: the JAX side only needs numpy artifacts.
    """
    from repro.core import lut as lutmod

    if m.stats.wce == 0:
        return dataclasses.replace(exact_spec(), name=m.name)

    pure_trunc = (len(m.pruned_gates) == 0 and (m.trunc_a or m.trunc_b))
    if pure_trunc:
        z = jnp.zeros((0, 256), dtype=jnp.int8)
        return MultSpec(m.name, "trunc", m.trunc_a, m.trunc_b, 0, 0.0,
                        m.stats.nmed, z, z, jnp.zeros((0,), jnp.float32))

    lr = (lutmod.lowrank_error(m.lut, rank) if rank is not None
          else lutmod.choose_rank(m.lut, tol_nmed=tol_nmed, max_rank=8))
    # int8-quantize each rank-1 factor pair; fold quant scales into s_r.
    r = lr.rank
    fu_q = np.zeros((r, 256), np.int8)
    fv_q = np.zeros((r, 256), np.int8)
    s_r = np.zeros((r,), np.float32)
    for i in range(r):
        su = max(np.abs(lr.fu[i]).max(), 1e-12) / 127.0
        sv = max(np.abs(lr.fv[i]).max(), 1e-12) / 127.0
        fu_q[i] = np.clip(np.round(lr.fu[i] / su), -128, 127).astype(np.int8)
        fv_q[i] = np.clip(np.round(lr.fv[i] / sv), -128, 127).astype(np.int8)
        s_r[i] = su * sv
    # measured residual of the *quantized* reconstruction
    e = lutmod.error_surface(m.lut).astype(np.float64)
    rec = np.einsum("ru,rv,r->uv", fu_q.astype(np.float64),
                    fv_q.astype(np.float64), s_r.astype(np.float64))
    resid_nmed = float(np.abs(e - rec).mean() / lutmod.MAX_ABS_PRODUCT)
    return MultSpec(m.name, "lowrank", m.trunc_a, m.trunc_b, r, resid_nmed,
                    m.stats.nmed, jnp.asarray(fu_q), jnp.asarray(fv_q),
                    jnp.asarray(s_r))


# ---------------------------------------------------------------------------
# int8 GEMM primitives (XLA path; the Pallas kernel in kernels/ is the
# TPU-tiled version of exactly this computation)
# ---------------------------------------------------------------------------

def _trunc_mask(q: jax.Array, t: int) -> jax.Array:
    if t <= 0:
        return q
    # two's-complement signed value of the uint8 mask 0xFF & ~((1<<t)-1)
    signed = (((0xFF & ~((1 << t) - 1)) ^ 0x80) - 0x80)
    return jnp.bitwise_and(q, jnp.int8(signed))


def _table_map(tbl: jax.Array, q: jax.Array) -> jax.Array:
    """tbl: (256,) int8; q: int8 array -> int8 array, indexed by q & 0xFF."""
    idx = jnp.bitwise_and(q.astype(jnp.int32), 0xFF)
    return jnp.take(tbl, idx, axis=0)


def qgemm_int32(a_q: jax.Array, b_q: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul (contraction over last/first axes)."""
    return jax.lax.dot_general(
        a_q, b_q, (((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def approx_qgemm(a_q: jax.Array, b_q: jax.Array, spec: MultSpec
                 ) -> jax.Array:
    """Quantized approximate GEMM: int8 (m,k) x int8 (k,n) -> f32 (m,n),
    implementing sum_k m(a[mk], b[kn]) for the spec'd multiplier."""
    if spec.mode == "trunc":
        a_q = _trunc_mask(a_q, spec.trunc_a)
        b_q = _trunc_mask(b_q, spec.trunc_b)
        return qgemm_int32(a_q, b_q).astype(jnp.float32)
    acc = qgemm_int32(a_q, b_q).astype(jnp.float32)
    for r in range(spec.rank):
        ua = _table_map(spec.fu_q[r], a_q)
        vb = _table_map(spec.fv_q[r], b_q)
        acc = acc - spec.s_r[r] * qgemm_int32(ua, vb).astype(jnp.float32)
    return acc


# ---------------------------------------------------------------------------
# Float-in / float-out approximate matmul with straight-through gradients
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def approx_matmul(x: jax.Array, w: jax.Array, spec: MultSpec) -> jax.Array:
    """x (..., k) @ w (k, n) through the approximate multiplier.

    Activations quantize per-tensor, weights per-output-channel (standard
    int8 accelerator setup).  Whether the O(mkn) work runs on the Pallas
    TPU kernel (kernels/approx_qgemm.py) or the XLA reference path is
    decided per GEMM by `spec.policy` (kernels/dispatch.py) from the
    backend, the trace-time shapes, and the spec's plane count.
    """
    return _approx_matmul_fwd(x, w, spec)[0]


def _approx_matmul_fwd(x, w, spec: MultSpec):
    from repro.kernels import dispatch
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    # Per-row (per-token) activation scales: more accurate than per-tensor
    # AND shard-local — a per-tensor absmax over a model-sharded dim lowers
    # to an all-reduce per GEMM (measured +3x collective bytes on the
    # tinyllama train_4k approx cell; see EXPERIMENTS.md §Perf).
    xq, sx = quant.quantize(x2, axis=0)       # (m, k) -> scales (m, 1)
    wq, sw = quant.quantize(w, axis=1)        # (k, n) -> per-n scales (1, n)
    if dispatch.use_pallas_gemm(spec.policy, m=x2.shape[0], k=k,
                                n=w.shape[1], n_planes=spec.n_planes):
        from repro.kernels import ops as kops
        acc = kops.approx_qgemm(xq, wq, spec)
    else:
        acc = approx_qgemm(xq, wq, spec)
    out = acc * (sx * sw)                     # (m, n) * scalar * (1, n)
    return out.reshape(*lead, w.shape[1]).astype(x.dtype), (x, w)


def _approx_matmul_bwd(spec: MultSpec, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", gf, wf).astype(x.dtype)
    dw = jnp.einsum("...k,...n->kn", xf, gf).astype(w.dtype)
    return dx, dw


approx_matmul.defvjp(_approx_matmul_fwd, _approx_matmul_bwd)


def spec_from_name(name: str, rank: int | None = None) -> MultSpec:
    """Resolve a multiplier by library name -> MultSpec.

    A ':r<k>' suffix caps the error-correction rank (perf/accuracy knob,
    e.g. "pareto:0.02:r2"); the residual NMED of the truncation is recorded
    on the spec."""
    if name in (None, "", "exact", "none"):
        return exact_spec()
    if ":r" in name:
        base, rstr = name.rsplit(":r", 1)
        return spec_from_name(base, rank=int(rstr))
    from repro.core import multipliers as mm
    return from_multiplier(mm.get_multiplier(name), rank=rank)
