"""Approximate-multiplier GEMM for TPU (the framework's core compute path).

A `MultSpec` is the JAX-side artifact compiled from a gate-level
`ApproxMultiplier` (core/multipliers.py).  Three execution modes, chosen at
spec-build time from the multiplier's structure (DESIGN.md §3):

  exact    m(a,b) == a*b          -> one int8 MXU matmul
  trunc    m(a,b) == t(a)*t(b)    -> mask LSBs, one int8 MXU matmul
           (pure precision scaling; bit-exact)
  lowrank  m(a,b) == a*b - E(a,b) -> (R+1) int8 MXU matmuls:
           E ~= sum_r s_r * fu_q[r][a] * fv_q[r][b]  (SVD of the error
           surface, factors themselves int8-quantized so every matmul stays
           on the MXU int8 path).  The residual NMED of the quantized
           factorization is measured at build time and carried on the spec.

The exact LUT path (`lut_matmul` in kernels/ref.py) is the oracle: tests
assert `trunc` is bit-exact and `lowrank` is within the recorded residual.

Gradients: straight-through (ApproxTrain's approach) — forward runs the
approximate quantized GEMM, backward uses the float operands.  This is what
makes *training under approximation* (and therefore accuracy-constrained
co-design) work at scale.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import quant

MODES = ("exact", "trunc", "lowrank")


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("fu_q", "fv_q", "s_r"),
    meta_fields=("name", "mode", "trunc_a", "trunc_b", "rank",
                 "residual_nmed", "nmed", "policy"),
)
@dataclasses.dataclass(frozen=True)
class MultSpec:
    """JAX-friendly approximate-multiplier spec (pytree)."""
    name: str
    mode: str                 # "exact" | "trunc" | "lowrank"
    trunc_a: int
    trunc_b: int
    rank: int
    residual_nmed: float      # NMED of (E - quantized low-rank reconstruction)
    nmed: float               # NMED of the multiplier itself
    fu_q: jax.Array           # (R, 256) int8   (row r of U factor, by a&0xFF)
    fv_q: jax.Array           # (R, 256) int8
    s_r: jax.Array            # (R,) f32        (per-rank dequant scale)
    # Kernel-dispatch policy ("auto" | "pallas" | "xla"), a *meta* field:
    # it is part of the treedef, so changing it is a new jit cache key.
    policy: str = "auto"

    @property
    def is_exact(self) -> bool:
        return self.mode == "exact"

    @property
    def n_planes(self) -> int:
        """Operand planes the stacked kernel runs: raw + R corrections."""
        return 1 + self.rank

    def with_policy(self, policy: str | None) -> "MultSpec":
        """Same spec under a different kernel-dispatch policy (validated)."""
        from repro.kernels import dispatch
        p = dispatch.resolve(policy)
        if p == self.policy:
            return self
        return dataclasses.replace(self, policy=p)


def exact_spec() -> MultSpec:
    z = jnp.zeros((0, 256), dtype=jnp.int8)
    return MultSpec("exact", "exact", 0, 0, 0, 0.0, 0.0, z, z,
                    jnp.zeros((0,), jnp.float32))


def from_multiplier(m: Any, rank: int | None = None,
                    tol_nmed: float = 1e-4) -> MultSpec:
    """Compile a core.multipliers.ApproxMultiplier into a MultSpec.

    Imports core lazily: the JAX side only needs numpy artifacts.
    """
    from repro.core import lut as lutmod

    if m.stats.wce == 0:
        return dataclasses.replace(exact_spec(), name=m.name)

    pure_trunc = (len(m.pruned_gates) == 0 and (m.trunc_a or m.trunc_b))
    if pure_trunc:
        z = jnp.zeros((0, 256), dtype=jnp.int8)
        return MultSpec(m.name, "trunc", m.trunc_a, m.trunc_b, 0, 0.0,
                        m.stats.nmed, z, z, jnp.zeros((0,), jnp.float32))

    lr = (lutmod.lowrank_error(m.lut, rank) if rank is not None
          else lutmod.choose_rank(m.lut, tol_nmed=tol_nmed, max_rank=8))
    # int8-quantize each rank-1 factor pair; fold quant scales into s_r.
    r = lr.rank
    fu_q = np.zeros((r, 256), np.int8)
    fv_q = np.zeros((r, 256), np.int8)
    s_r = np.zeros((r,), np.float32)
    for i in range(r):
        su = max(np.abs(lr.fu[i]).max(), 1e-12) / 127.0
        sv = max(np.abs(lr.fv[i]).max(), 1e-12) / 127.0
        fu_q[i] = np.clip(np.round(lr.fu[i] / su), -128, 127).astype(np.int8)
        fv_q[i] = np.clip(np.round(lr.fv[i] / sv), -128, 127).astype(np.int8)
        s_r[i] = su * sv
    # measured residual of the *quantized* reconstruction
    e = lutmod.error_surface(m.lut).astype(np.float64)
    rec = np.einsum("ru,rv,r->uv", fu_q.astype(np.float64),
                    fv_q.astype(np.float64), s_r.astype(np.float64))
    resid_nmed = float(np.abs(e - rec).mean() / lutmod.MAX_ABS_PRODUCT)
    return MultSpec(m.name, "lowrank", m.trunc_a, m.trunc_b, r, resid_nmed,
                    m.stats.nmed, jnp.asarray(fu_q), jnp.asarray(fv_q),
                    jnp.asarray(s_r))


# ---------------------------------------------------------------------------
# int8 GEMM primitives (XLA path; the Pallas kernel in kernels/ is the
# TPU-tiled version of exactly this computation)
# ---------------------------------------------------------------------------

def _trunc_mask(q: jax.Array, t: int) -> jax.Array:
    if t <= 0:
        return q
    # single source of truth for the signed-uint8 mask bit-trick (shared
    # with the in-kernel masks in approx_qgemm.py and quantize.py)
    from repro.kernels.approx_qgemm import signed_trunc_mask
    return jnp.bitwise_and(q, jnp.int8(signed_trunc_mask(t)))


def _table_map(tbl: jax.Array, q: jax.Array) -> jax.Array:
    """tbl: (256,) int8; q: int8 array -> int8 array, indexed by q & 0xFF."""
    idx = jnp.bitwise_and(q.astype(jnp.int32), 0xFF)
    return jnp.take(tbl, idx, axis=0)


def qgemm_int32(a_q: jax.Array, b_q: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul (contraction over last/first axes)."""
    return jax.lax.dot_general(
        a_q, b_q, (((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


# ---------------------------------------------------------------------------
# Persistent weight-plane cache (serving-time)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("w", "wq", "sw", "planes"),
    meta_fields=("mode", "mult"),
)
@dataclasses.dataclass(frozen=True)
class PreparedWeight:
    """Per-(weight, MultSpec) serving-time cache (pytree).

    Weights are static at inference, so quantization and — for the XLA
    fallback path — the per-rank table maps are paid once here instead of
    on every decode step:

      wq      int8 (..., k, n)    per-output-channel quantized weight (the
                                  fused Pallas kernel consumes this raw and
                                  maps it in-register)
      sw      f32  (..., 1, n)    dequant scales
      planes  int8 (..., P', k, n) pre-mapped weight planes for the XLA
                                  path: the R table-mapped corrections
                                  (lowrank) or the LSB-masked weight
                                  (trunc, P'=1)
      w       original float weight, same buffer as the source params —
              exact consumers (spec=None paths) and fallbacks use it, so a
              prepared tree degrades losslessly

    Leading stack dims (layer-scanned params) are preserved: lax.scan
    slices the cache per layer exactly like the raw param leaves.
    Training must NOT use prepared weights (weights change every step);
    `approx_matmul_prepared` raises on differentiation.
    """
    w: jax.Array
    wq: jax.Array
    sw: jax.Array
    planes: jax.Array
    mode: str
    mult: str


def is_prepared(w) -> bool:
    return isinstance(w, PreparedWeight)


def prepare_weight(w: jax.Array, spec: MultSpec | None):
    """Quantize (per-output-channel) and pre-map a static weight for the
    spec.  Identity for exact/absent specs.  Accepts stacked (..., k, n)
    leaves; scales reduce over the contraction dim only.

    The pre-mapped planes serve the XLA fallback only (the fused Pallas
    kernel maps `wq` in-register), so a policy pinned to "pallas" skips
    them — R extra int8 weight copies of dead device memory otherwise.
    `approx_qgemm_prepared` live-maps when planes are absent."""
    if spec is None or spec.is_exact or is_prepared(w):
        return w
    from repro.kernels import dispatch
    keep = tuple(i for i in range(w.ndim) if i != w.ndim - 2)
    wq, sw = quant.quantize(w, axis=keep)
    no_planes = jnp.zeros((*w.shape[:-2], 0, *w.shape[-2:]), jnp.int8)
    if dispatch.resolve(spec.policy) == "pallas":
        planes = no_planes
    elif spec.mode == "trunc":
        planes = _trunc_mask(wq, spec.trunc_b)[..., None, :, :]
    elif spec.mode == "lowrank" and spec.rank:
        planes = jnp.stack([_table_map(spec.fv_q[r], wq)
                            for r in range(spec.rank)], axis=-3)
    else:  # lowrank rank 0 degenerates to the raw plane
        planes = no_planes
    return PreparedWeight(w=w, wq=wq, sw=sw.astype(jnp.float32),
                          planes=planes, mode=spec.mode, mult=spec.name)


def approx_qgemm_prepared(a_q: jax.Array, pw: PreparedWeight,
                          spec: MultSpec) -> jax.Array:
    """XLA path against cached weight planes — bit-identical to
    `approx_qgemm(a_q, wq, spec)` with wq freshly quantized, but the
    weight-side table maps / masks are reads, not recomputation.

    Planes may be absent (prepared under a pallas-pinned policy, then
    re-dispatched to XLA): the weight side is then mapped live from the
    cached `wq` — same values, just not cached."""
    cached = pw.planes.shape[-3] > 0
    if spec.mode == "trunc":
        a_q = _trunc_mask(a_q, spec.trunc_a)
        wb = pw.planes[0] if cached else _trunc_mask(pw.wq, spec.trunc_b)
        return qgemm_int32(a_q, wb).astype(jnp.float32)
    acc = qgemm_int32(a_q, pw.wq).astype(jnp.float32)
    for r in range(spec.rank):
        ua = _table_map(spec.fu_q[r], a_q)
        vb = pw.planes[r] if cached else _table_map(spec.fv_q[r], pw.wq)
        acc = acc - spec.s_r[r] * qgemm_int32(ua, vb).astype(jnp.float32)
    return acc


def approx_qgemm(a_q: jax.Array, b_q: jax.Array, spec: MultSpec
                 ) -> jax.Array:
    """Quantized approximate GEMM: int8 (m,k) x int8 (k,n) -> f32 (m,n),
    implementing sum_k m(a[mk], b[kn]) for the spec'd multiplier."""
    if spec.mode == "trunc":
        a_q = _trunc_mask(a_q, spec.trunc_a)
        b_q = _trunc_mask(b_q, spec.trunc_b)
        return qgemm_int32(a_q, b_q).astype(jnp.float32)
    acc = qgemm_int32(a_q, b_q).astype(jnp.float32)
    for r in range(spec.rank):
        ua = _table_map(spec.fu_q[r], a_q)
        vb = _table_map(spec.fv_q[r], b_q)
        acc = acc - spec.s_r[r] * qgemm_int32(ua, vb).astype(jnp.float32)
    return acc


# ---------------------------------------------------------------------------
# Float-in / float-out approximate matmul with straight-through gradients
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def approx_matmul(x: jax.Array, w: jax.Array, spec: MultSpec) -> jax.Array:
    """x (..., k) @ w (k, n) through the approximate multiplier.

    Activations quantize per-tensor, weights per-output-channel (standard
    int8 accelerator setup).  Whether the O(mkn) work runs on the Pallas
    TPU kernel (kernels/approx_qgemm.py) or the XLA reference path is
    decided per GEMM by `spec.policy` (kernels/dispatch.py) from the
    backend, the trace-time shapes, and the spec's plane count.
    """
    return _approx_matmul_fwd(x, w, spec)[0]


def _quantize_activations(x2: jax.Array, spec: MultSpec, use_pallas: bool,
                          mesh=None) -> tuple[jax.Array, jax.Array]:
    """Per-row (per-token) activation scales: more accurate than per-tensor
    AND shard-local — a per-tensor absmax over a model-sharded dim lowers
    to an all-reduce per GEMM (measured +3x collective bytes on the
    tinyllama train_4k approx cell; see EXPERIMENTS.md §Perf).

    When the dispatch policy already picked Pallas for the GEMM, the fused
    `quantize_rows` kernel runs as its prologue (single VMEM pass, with the
    trunc mask folded in for trunc-mode specs).  f32 activations only: the
    kernel computes in f32, so for bf16 inputs it would round differently
    than the reference quantizer and the dispatch policy would become a
    numerics knob — lower precisions keep the XLA quantizer on every
    policy.  Where both run, (q, scale) are bit-identical.

    Multi-device meshes keep the XLA quantizer too: a bare pallas_call is
    opaque to the SPMD partitioner (the reason the GEMM itself routes
    through shard_map), and wrapping this small per-row pass in shard_map
    is not worth the extra manual-partitioning surface."""
    single_dev = mesh is None or mesh.size == 1
    if use_pallas and single_dev and x2.dtype == jnp.float32:
        from repro.kernels import ops as kops
        trunc = spec.trunc_a if spec.mode == "trunc" else 0
        return kops.quantize_rows(x2, trunc=trunc)
    return quant.quantize(x2, axis=0)         # (m, k) -> scales (m, 1)


def _tp_mesh(n: int):
    """(mesh, tp) for the active sharding context: tp > 1 only when a
    multi-device model axis exists AND the output dim splits evenly
    (column parallelism; uneven dims stay whole, mirroring the
    divisibility-drop rule in sharding/rules.py)."""
    from repro.kernels import dispatch
    from repro.sharding import ctx as shctx
    active = shctx.active()
    mesh = active[0] if active is not None else None
    tp = dispatch.tp_degree(mesh)
    return mesh, (tp if tp > 1 and n % tp == 0 else 1)


def _dispatch_pallas_qgemm(xq, wq, spec: MultSpec, mesh, tp: int, plan):
    """Route a Pallas-bound GEMM by mesh context: shard_map column-
    parallel under TP, shard_map-replicated on any other multi-device
    mesh (pallas_call is opaque to GSPMD; both run the regular fused
    kernel), and per the dispatch plan (fused tiles / skinny / stacked)
    on a single device."""
    from repro.kernels import ops as kops
    if tp > 1:
        return kops.approx_qgemm_tp(xq, wq, spec, mesh)
    if mesh is not None and mesh.size > 1:
        return kops.approx_qgemm_replicated(xq, wq, spec, mesh)
    return kops.approx_qgemm_planned(xq, wq, spec, plan)


def _gemm_plan(spec: MultSpec, m: int, k: int, n: int, mesh, tp: int):
    from repro.kernels import dispatch
    rank = spec.rank if spec.mode == "lowrank" else 0
    return dispatch.choose_gemm_path(
        spec.policy, m=m, k=k, n=n, mode=spec.mode, rank=rank,
        n_planes=spec.n_planes, tp=tp,
        multi_device=mesh is not None and mesh.size > 1)


def _approx_matmul_fwd(x, w, spec: MultSpec):
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[1]
    x2 = x.reshape(-1, k)
    mesh, tp = _tp_mesh(n)
    plan = _gemm_plan(spec, x2.shape[0], k, n, mesh, tp)
    xq, sx = _quantize_activations(x2, spec, plan.use_pallas, mesh)
    wq, sw = quant.quantize(w, axis=1)        # (k, n) -> per-n scales (1, n)
    if plan.use_pallas:
        acc = _dispatch_pallas_qgemm(xq, wq, spec, mesh, tp, plan)
    else:
        acc = approx_qgemm(xq, wq, spec)
    out = acc * (sx * sw)                     # (m, n) * scalar * (1, n)
    return out.reshape(*lead, n).astype(x.dtype), (x, w)


def _approx_matmul_bwd(spec: MultSpec, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", gf, wf).astype(x.dtype)
    dw = jnp.einsum("...k,...n->kn", xf, gf).astype(w.dtype)
    return dx, dw


approx_matmul.defvjp(_approx_matmul_fwd, _approx_matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def approx_matmul_prepared(x: jax.Array, pw: PreparedWeight,
                           spec: MultSpec) -> jax.Array:
    """x (..., k) @ cached weight through the approximate multiplier.

    The inference twin of `approx_matmul`: activations quantize live, the
    weight side comes entirely from the `PreparedWeight` cache (quantized
    once per (weight, spec); XLA fallback reuses the pre-mapped planes,
    the fused Pallas kernel maps the cached int8 weight in-register).
    Outputs are bit-identical to the fresh-quantize path.

    Serving only: differentiation raises — training weights change every
    step, so the live re-quantize path (`approx_matmul`) must be used.
    """
    return _approx_matmul_prepared_fwd(x, pw, spec)[0]


def _approx_matmul_prepared_fwd(x, pw: PreparedWeight, spec: MultSpec):
    if pw.mult != spec.name or pw.mode != spec.mode:
        raise ValueError(
            f"PreparedWeight was built for multiplier {pw.mult!r} "
            f"(mode {pw.mode!r}) but is being used with {spec.name!r} "
            f"(mode {spec.mode!r}); re-run prepare_weight for this spec")
    assert pw.wq.ndim == 2, (
        "prepared weights must be per-matrix at use time (scan slices "
        f"stacked leaves); got wq shape {pw.wq.shape}")
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = pw.wq.shape[-1]
    x2 = x.reshape(-1, k)
    mesh, tp = _tp_mesh(n)
    plan = _gemm_plan(spec, x2.shape[0], k, n, mesh, tp)
    xq, sx = _quantize_activations(x2, spec, plan.use_pallas, mesh)
    if plan.use_pallas:
        acc = _dispatch_pallas_qgemm(xq, pw.wq, spec, mesh, tp, plan)
    else:
        acc = approx_qgemm_prepared(xq, pw, spec)
    out = acc * (sx * pw.sw)
    return out.reshape(*lead, n).astype(x.dtype), None


def _approx_matmul_prepared_bwd(spec, res, g):
    raise NotImplementedError(
        "approx_matmul_prepared is a serving-time path: the weight-plane "
        "cache is stale the moment weights update.  Training must use "
        "approx_matmul on the raw float weight (live re-quantize).")


approx_matmul_prepared.defvjp(_approx_matmul_prepared_fwd,
                              _approx_matmul_prepared_bwd)


def spec_from_name(name: str, rank: int | None = None) -> MultSpec:
    """Resolve a multiplier by library name -> MultSpec.

    A ':r<k>' suffix caps the error-correction rank (perf/accuracy knob,
    e.g. "pareto:0.02:r2"); the residual NMED of the truncation is recorded
    on the spec."""
    if name in (None, "", "exact", "none"):
        return exact_spec()
    if ":r" in name:
        base, rstr = name.rsplit(":r", 1)
        return spec_from_name(base, rank=int(rstr))
    from repro.core import multipliers as mm
    return from_multiplier(mm.get_multiplier(name), rank=rank)
