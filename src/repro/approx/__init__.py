"""ApproxTrain-for-TPU: simulate approximate int8 multipliers inside JAX
models at MXU speed (see DESIGN.md §3 for the low-rank reformulation)."""

from repro.approx.gemm import MultSpec, approx_matmul, from_multiplier  # noqa: F401
from repro.approx.quant import quantize, dequantize  # noqa: F401
