"""Symmetric int8 post-training quantization (the paper's accelerators are
int8 MAC arrays; all approximate-multiplier simulation runs on int8 tensors).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def quantize(x: jax.Array, axis: int | tuple[int, ...] | None = None,
             eps: float = 1e-8) -> tuple[jax.Array, jax.Array]:
    """Symmetric quantization to int8.

    axis=None  -> per-tensor scale (scalar)
    axis=k     -> scale is reduced over all *other* axes (per-channel along k)
    Returns (q int8, scale f32) with x ~= q * scale.
    """
    if axis is None:
        absmax = jnp.max(jnp.abs(x))
    else:
        axes = (axis,) if isinstance(axis, int) else axis
        reduce_over = tuple(i for i in range(x.ndim) if i not in
                            tuple(a % x.ndim for a in axes))
        absmax = jnp.max(jnp.abs(x), axis=reduce_over, keepdims=True)
    scale = jnp.maximum(absmax, eps) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX - 1, INT8_MAX)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jax.Array, axis: int | None = None) -> jax.Array:
    """Quantize-dequantize (for QAT-style error injection without approx)."""
    q, s = quantize(x, axis)
    return dequantize(q, s)


# --- int8 weight storage for serving -----------------------------------------
# The paper's accelerators hold int8 weights; serving the simulation the same
# way halves weight HBM traffic (the dominant term of every decode cell —
# see EXPERIMENTS.md §Perf).  A quantized weight is a {"q": int8, "s": f32}
# dict leaf; approx/layers dequantizes at use (XLA fuses the convert into
# the consuming dot, so only the int8 bytes cross HBM).

# weights consumed outside the GEMM layers (lookups, slices, conv taps)
_QSKIP = ("embed", "dec_pos", "conv_w")


def leaf_name(path) -> str:
    """Innermost dict key of a tree_map_with_path key path ("" if none) —
    the param-leaf name used by the serving weight caches."""
    for part in reversed(path):
        k = getattr(part, "key", None)
        if k is not None:
            return str(k)
    return ""


def quantize_param_tree(params, min_size: int = 1 << 16):
    """Per-output-channel int8 quantization of every large >=2-D weight."""
    def q(path, leaf):
        if leaf_name(path) in _QSKIP:
            return leaf
        if not hasattr(leaf, "ndim") or leaf.ndim < 2 or \
                leaf.size < min_size or not jnp.issubdtype(
                    leaf.dtype, jnp.floating):
            return leaf
        if leaf.shape[-1] < 512 or leaf.shape[-2] < 512:
            return leaf  # true GEMM matrices only (not stacked vectors)
        # scales per (stack-dims x out-channel): reduce only over the
        # contraction dim (-2), so layer-stacked weights stay scannable
        keep = tuple(i for i in range(leaf.ndim) if i != leaf.ndim - 2)
        qv, s = quantize(leaf, axis=keep)
        return {"q": qv, "s": s.astype(jnp.float32)}
    return jax.tree_util.tree_map_with_path(q, params)


def is_qweight(w) -> bool:
    return isinstance(w, dict) and set(w) == {"q", "s"}


def dequantize_weight(w, dtype=jnp.bfloat16) -> jax.Array:
    return (w["q"].astype(jnp.float32) * w["s"]).astype(dtype)
