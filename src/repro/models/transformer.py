"""Decoder-only transformer LM: dense, MoE, and vision-cross-attention
variants (tinyllama / qwen1.5 / starcoder2 / mistral-large / grok-1 /
llama4-maverick / llama-3.2-vision).

Layer-stacked parameters + lax.scan over layers (compile-time stays flat in
depth: mistral-large's 88 layers lower as one scanned block).  For VLM, the
scan unit is a superblock of `cross_every` self-attention layers followed by
one cross-attention layer, so the 3:1 interleave is exact without per-layer
branching.

All projections route through the approximate-GEMM layer (`spec`).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.approx import layers as AL
from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models.moe import moe_ffn
from repro.sharding.ctx import hint

Params = dict[str, Any]

#: Param leaves consumed exclusively through AL.gemm/AL.dense with the
#: model's MultSpec — eligible for the serving weight-plane cache
#: (api.prepare_params).  Excluded: the embedding (lookup / tied head
#: transpose), the MoE router (exact f32 control logic), and the expert
#: stacks (re-gathered per token slot through _as_weight).
PREPARED_GEMM_WEIGHTS = frozenset({
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    "ws_gate", "ws_up", "ws_down", "lm_head",
    "xwq", "xwk", "xwv", "xwo",
})


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_param_shapes(cfg: ModelConfig, moe: bool | None = None
                        ) -> dict[str, tuple]:
    """moe=None: follow cfg.is_moe for every layer; True/False pin the
    layer kind (for interleaved dense/MoE stacks)."""
    d, hd = cfg.d_model, cfg.hd
    h, kv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    moe = cfg.is_moe if moe is None else moe
    shapes = {
        "ln1": (d,), "ln2": (d,),
        "wq": (d, h * hd), "wk": (d, kv * hd), "wv": (d, kv * hd),
        "wo": (h * hd, d),
    }
    if cfg.qkv_bias:
        shapes |= {"bq": (h * hd,), "bk": (kv * hd,), "bv": (kv * hd,)}
    if moe:
        e = cfg.n_experts
        shapes |= {"router": (d, e), "we_gate": (e, d, f),
                   "we_up": (e, d, f), "we_down": (e, f, d)}
        if cfg.shared_expert:
            shapes |= {"ws_gate": (d, f), "ws_up": (d, f), "ws_down": (f, d)}
    else:
        fd = (cfg.d_ff_dense or f) if cfg.is_moe else f
        if cfg.mlp_style == "swiglu":
            shapes |= {"w_gate": (d, fd), "w_up": (d, fd), "w_down": (fd, d)}
        else:
            shapes |= {"w_up": (d, fd), "w_down": (fd, d),
                       "mb_up": (fd,), "mb_down": (d,)}
    return shapes


def _cross_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    return {"xln": (d,), "xln_kv": (d,),
            "xwq": (d, h * hd), "xwk": (d, kv * hd), "xwv": (d, kv * hd),
            "xwo": (h * hd, d), "xgate": (1,)}


def _init_stack(key, shapes: dict[str, tuple], stack: tuple[int, ...],
                dtype) -> Params:
    out = {}
    keys = C.split_keys(key, len(shapes))
    for k_, (name, shp) in zip(keys, sorted(shapes.items())):
        full = (*stack, *shp)
        if name.startswith(("ln", "xln", "b", "mb", "xgate")):
            out[name] = jnp.zeros(full, dtype)
        else:
            scale = shp[-2] ** -0.5 if len(shp) >= 2 else 0.02
            out[name] = (jax.random.normal(k_, full, jnp.float32) * scale
                         ).astype(dtype)
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_layers, k_cross, k_head = jax.random.split(key, 4)
    p: Params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.cross_every:
        assert not (cfg.is_moe and cfg.moe_every > 1)
        n_super = cfg.n_layers // cfg.cross_every
        p["layers"] = _init_stack(k_layers, _layer_param_shapes(cfg),
                                  (n_super, cfg.cross_every), dtype)
        p["cross"] = _init_stack(k_cross, _cross_param_shapes(cfg),
                                 (n_super,), dtype)
    elif cfg.is_moe and cfg.moe_every > 1:
        # interleaved dense/MoE: superblock = (moe_every-1) dense + 1 MoE
        n_super = cfg.n_layers // cfg.moe_every
        k_d, k_m = jax.random.split(k_layers)
        p["layers"] = _init_stack(
            k_d, _layer_param_shapes(cfg, moe=False),
            (n_super, cfg.moe_every - 1), dtype)
        p["moe"] = _init_stack(k_m, _layer_param_shapes(cfg, moe=True),
                               (n_super,), dtype)
    else:
        p["layers"] = _init_stack(k_layers, _layer_param_shapes(cfg),
                                  (cfg.n_layers,), dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = C.dense_init(k_head, cfg.d_model, cfg.vocab, dtype,
                                    scale=0.02)
    return p


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _qkv(h, lp, cfg: ModelConfig, spec, positions):
    b, s, d = h.shape
    hd = cfg.hd
    q = AL.dense(h, lp["wq"], lp.get("bq"), spec).reshape(
        b, s, cfg.n_heads, hd)
    k = AL.dense(h, lp["wk"], lp.get("bk"), spec).reshape(
        b, s, cfg.n_kv_heads, hd)
    v = AL.dense(h, lp["wv"], lp.get("bv"), spec).reshape(
        b, s, cfg.n_kv_heads, hd)
    q = C.apply_rope(q, positions, cfg.rope_theta)
    k = C.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(h, lp, cfg: ModelConfig, spec):
    if "router" in lp:
        b, s, d = h.shape
        out, aux = moe_ffn(h.reshape(b * s, d), lp["router"], lp["we_gate"],
                           lp["we_up"], lp["we_down"], cfg.top_k,
                           cfg.capacity_factor, spec)
        out = out.reshape(b, s, d)
        if cfg.shared_expert:
            out = out + C.swiglu(h, lp["ws_gate"], lp["ws_up"],
                                 lp["ws_down"], spec)
        return out, aux
    if "w_gate" in lp:
        return C.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"], spec), 0.0
    return C.gelu_mlp(h, lp["w_up"], lp["mb_up"], lp["w_down"],
                      lp["mb_down"], spec), 0.0


def decoder_block(h, lp, cfg: ModelConfig, spec, positions):
    """Standard pre-norm block; returns (h, aux)."""
    x = C.rmsnorm(h, lp["ln1"])
    q, k, v = _qkv(x, lp, cfg, spec, positions)
    attn = C.attention(q, k, v, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                       causal=True, window=0,
                       policy=spec.policy if spec is not None else None)
    attn = hint(attn, "batch", None, "heads", None)
    h = h + AL.dense(attn.reshape(*h.shape[:2], -1), lp["wo"], None, spec)
    x = C.rmsnorm(h, lp["ln2"])
    ff, aux = _ffn(x, lp, cfg, spec)
    h = h + ff
    return hint(h, "batch", None, None), aux


def cross_block(h, xp, img, cfg: ModelConfig, spec):
    """Gated cross-attention to image embeddings (llama-3.2-vision style)."""
    x = C.rmsnorm(h, xp["xln"])
    b, s, d = x.shape
    hd = cfg.hd
    q = AL.gemm(x, xp["xwq"], spec).reshape(b, s, cfg.n_heads, hd)
    ikv = C.rmsnorm(img, xp["xln_kv"])
    k = AL.gemm(ikv, xp["xwk"], spec).reshape(b, -1, cfg.n_kv_heads, hd)
    v = AL.gemm(ikv, xp["xwv"], spec).reshape(b, -1, cfg.n_kv_heads, hd)
    from repro.models.attention import blockwise_attention
    attn = C.naive_attention(q, k, v, causal=False) \
        if img.shape[1] * s <= 1 << 20 else blockwise_attention(
            q, k, v, cfg.attn_chunk, False, 0)
    o = AL.gemm(attn.reshape(b, s, -1), xp["xwo"], spec)
    return h + jnp.tanh(xp["xgate"]).astype(h.dtype) * o


# --------------------------------------------------------------------------
# forward (training)
# --------------------------------------------------------------------------

def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            spec=None, img_embeds: jax.Array | None = None) -> tuple:
    """tokens (b, s) -> (logits (b, s, v), aux_loss)."""
    b, s = tokens.shape
    h = AL.embed(tokens, params["embed"])
    h = hint(h, "batch", None, None)
    positions = jnp.arange(s)[None, :]

    def block(h, lp):
        return decoder_block(h, lp, cfg, spec, positions)

    if cfg.cross_every:
        img = img_embeds if img_embeds is not None else jnp.zeros(
            (b, cfg.n_img_tokens, cfg.d_model), h.dtype)

        def superblock(carry, sp):
            h, aux = carry
            lp, xp = sp

            def inner(carry2, lp_i):
                h2, a2 = carry2
                h2, ai = C.maybe_remat(block, cfg.remat)(h2, lp_i)
                return (h2, a2 + ai), None

            (h, aux), _ = jax.lax.scan(inner, (h, aux), lp)
            h = C.maybe_remat(
                lambda hh, xx: cross_block(hh, xx, img, cfg, spec),
                cfg.remat)(h, xp)
            return (h, aux), None

        (h, aux), _ = jax.lax.scan(superblock, (h, 0.0),
                                   (params["layers"], params["cross"]))
    elif "moe" in params:
        def superblock_moe(carry, sp):
            h, aux = carry
            lp_dense, lp_moe = sp

            def inner(carry2, lp_i):
                h2, a2 = carry2
                h2, ai = C.maybe_remat(block, cfg.remat)(h2, lp_i)
                return (h2, a2 + ai), None

            (h, aux), _ = jax.lax.scan(inner, (h, aux), lp_dense)
            h, ai = C.maybe_remat(block, cfg.remat)(h, lp_moe)
            return (h, aux + ai), None

        (h, aux), _ = jax.lax.scan(superblock_moe, (h, 0.0),
                                   (params["layers"], params["moe"]))
    else:
        def scan_block(carry, lp):
            h, aux = carry
            h, ai = C.maybe_remat(block, cfg.remat)(h, lp)
            return (h, aux + ai), None

        (h, aux), _ = jax.lax.scan(scan_block, (h, 0.0), params["layers"])

    h = C.rmsnorm(h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = AL.gemm(h, head, spec)
    logits = hint(logits, "batch", None, "vocab")
    return logits, aux


# --------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None
               ) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    if cfg.cross_every:
        n_super = cfg.n_layers // cfg.cross_every
        shape = (n_super, cfg.cross_every, batch, max_len, kv, hd)
    elif cfg.is_moe and cfg.moe_every > 1:
        n_super = cfg.n_layers // cfg.moe_every
        shape = (n_super, cfg.moe_every, batch, max_len, kv, hd)
    else:
        shape = (cfg.n_layers, batch, max_len, kv, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def _decode_block(h, lp, ck, cv, lengths, cfg: ModelConfig, spec):
    """Single-token block against cache slices ck/cv (b, smax, kv, hd).

    `lengths` is per-row (b,): rows may sit at different positions, which
    is what lets the serving engine run mixed-length requests lock-free in
    one decode batch."""
    b = h.shape[0]
    x = C.rmsnorm(h, lp["ln1"])
    pos = lengths[:, None]
    q, k, v = _qkv(x, lp, cfg, spec, pos)
    ck = C.rowwise_cache_update(ck, k, lengths)
    cv = C.rowwise_cache_update(cv, v, lengths)
    attn = C.decode_attention(q, ck, cv, lengths + 1)
    h = h + AL.dense(attn.reshape(b, 1, -1), lp["wo"], None, spec)
    x = C.rmsnorm(h, lp["ln2"])
    ff, _ = _ffn(x, lp, cfg, spec)
    return h + ff, ck, cv


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                cfg: ModelConfig, spec=None,
                img_embeds: jax.Array | None = None) -> tuple:
    """tokens (b, 1) -> (logits (b, 1, v), updated cache).

    cache["length"] may be a scalar (lock-step batch) or per-row (b,)
    (continuous batching: each slot at its own position)."""
    b = tokens.shape[0]
    h = AL.embed(tokens, params["embed"])
    length = C.cache_lengths(cache, b)

    if cfg.cross_every:
        img = img_embeds if img_embeds is not None else jnp.zeros(
            (b, cfg.n_img_tokens, cfg.d_model), h.dtype)

        def superblock(h, sp):
            lp, xp, ck_s, cv_s = sp

            def inner(h2, inner_sp):
                lp_i, ck, cv = inner_sp
                h2, ck, cv = _decode_block(h2, lp_i, ck, cv, length, cfg,
                                           spec)
                return h2, (ck, cv)

            h, (ck_s, cv_s) = jax.lax.scan(inner, h, (lp, ck_s, cv_s))
            h = cross_block(h, xp, img, cfg, spec)
            return h, (ck_s, cv_s)

        h, (ck, cv) = jax.lax.scan(
            superblock, h,
            (params["layers"], params["cross"], cache["k"], cache["v"]))
    elif "moe" in params:
        m = cfg.moe_every

        def superblock_moe(h, sp):
            lp_dense, lp_moe, ck_s, cv_s = sp

            def inner(h2, inner_sp):
                lp_i, ck, cv = inner_sp
                h2, ck, cv = _decode_block(h2, lp_i, ck, cv, length, cfg,
                                           spec)
                return h2, (ck, cv)

            h, (ck_d, cv_d) = jax.lax.scan(
                inner, h, (lp_dense, ck_s[:m - 1], cv_s[:m - 1]))
            h, ck_m, cv_m = _decode_block(h, lp_moe, ck_s[m - 1],
                                          cv_s[m - 1], length, cfg, spec)
            return h, (jnp.concatenate([ck_d, ck_m[None]], 0),
                       jnp.concatenate([cv_d, cv_m[None]], 0))

        h, (ck, cv) = jax.lax.scan(
            superblock_moe, h,
            (params["layers"], params["moe"], cache["k"], cache["v"]))
    else:
        def scan_block(h, sp):
            lp, ck, cv = sp
            h, ck, cv = _decode_block(h, lp, ck, cv, length, cfg, spec)
            return h, (ck, cv)

        h, (ck, cv) = jax.lax.scan(
            scan_block, h, (params["layers"], cache["k"], cache["v"]))

    h = C.rmsnorm(h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = AL.gemm(h, head, spec)
    new_cache = {"k": ck, "v": cv, "length": cache["length"] + 1}
    return logits, new_cache


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            max_len: int | None = None,
            img_embeds: jax.Array | None = None,
            true_len: jax.Array | None = None) -> tuple:
    """tokens (b, s) -> (logits of the last valid position (b, v), cache).

    `true_len` (b,) marks right-padded prompts: logits come from position
    true_len - 1 and the cache length is per-row.  Causality keeps the
    valid KV rows exact; pad rows are masked out by decode_attention."""
    b, s = tokens.shape
    max_len = max_len or s
    h = AL.embed(tokens, params["embed"])
    positions = jnp.arange(s)[None, :]

    def block_collect(h, lp):
        x = C.rmsnorm(h, lp["ln1"])
        q, k, v = _qkv(x, lp, cfg, spec, positions)
        attn = C.attention(q, k, v, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                           policy=spec.policy if spec is not None else None)
        h = h + AL.dense(attn.reshape(b, s, -1), lp["wo"], None, spec)
        x = C.rmsnorm(h, lp["ln2"])
        ff, _ = _ffn(x, lp, cfg, spec)
        return h + ff, (k, v)

    img = None
    if cfg.cross_every:
        img = img_embeds if img_embeds is not None else jnp.zeros(
            (b, cfg.n_img_tokens, cfg.d_model), h.dtype)

        def superblock(h, sp):
            lp, xp = sp
            h, kvs = jax.lax.scan(
                lambda h2, lp_i: block_collect(h2, lp_i), h, lp)
            h = cross_block(h, xp, img, cfg, spec)
            return h, kvs

        h, (ks, vs) = jax.lax.scan(superblock, h,
                                   (params["layers"], params["cross"]))
    elif "moe" in params:
        def superblock_moe(h, sp):
            lp_dense, lp_moe = sp
            h, (kd, vd) = jax.lax.scan(block_collect, h, lp_dense)
            h, (km, vm) = block_collect(h, lp_moe)
            return h, (jnp.concatenate([kd, km[None]], 0),
                       jnp.concatenate([vd, vm[None]], 0))

        h, (ks, vs) = jax.lax.scan(superblock_moe, h,
                                   (params["layers"], params["moe"]))
    else:
        h, (ks, vs) = jax.lax.scan(block_collect, h, params["layers"])

    h = C.rmsnorm(C.last_valid_slice(h, true_len), params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = AL.gemm(h, head, spec)[:, 0]

    pad = max_len - s
    if pad > 0:
        widths = [(0, 0)] * ks.ndim
        widths[-3] = (0, pad)
        ks = jnp.pad(ks, widths)
        vs = jnp.pad(vs, widths)
    cache = {"k": ks.astype(jnp.dtype(cfg.dtype)),
             "v": vs.astype(jnp.dtype(cfg.dtype)),
             "length": C.prefill_length(true_len, s)}
    return logits, cache
