"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local sliding-
window attention, repeating pattern (recurrent, recurrent, local-attention)
(arXiv:2402.19427).

The RG-LRU is a gated linear recurrence h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*
(i_t*x_t) — evaluated with an associative scan (O(s) work, O(log s) depth)
for training/prefill and a single fused update for decode.  Decode keeps an
O(window) rolling KV cache for the attention blocks and O(1) state for the
recurrences, which is what makes the long_500k cell runnable.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.approx import layers as AL
from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.sharding.ctx import hint

Params = dict[str, Any]
C_EXPONENT = 8.0  # RG-LRU exponent scale

#: Serving weight-plane cache eligibility (api.prepare_params).  The
#: RG-LRU gate projections w_rg/w_in are NOT listed: they run exact
#: (error-sensitive recurrence control, spec-less AL.gemm), so caching a
#: quantized copy would change their math.  Conv taps and lam are direct
#: vector-unit consumers.
PREPARED_GEMM_WEIGHTS = frozenset({
    "w_x", "w_gate_br", "w_out", "m_gate", "m_up", "m_down",
    "wq", "wk", "wv", "wo", "lm_head",
})


def _pattern(cfg: ModelConfig) -> tuple[int, int]:
    """(n_super, n_tail_recurrent): layers = n_super*(2 rec + 1 attn) + tail
    recurrent blocks."""
    n_super = cfg.n_layers // 3
    tail = cfg.n_layers - 3 * n_super
    return n_super, tail


def _rec_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "ln": (d,),
        "w_x": (d, w), "w_gate_br": (d, w),
        "conv_w": (4, w), "conv_b": (w,),
        "w_rg": (w, w), "w_in": (w, w),     # recurrence/input gates
        "lam": (w,),                        # a = sigmoid(lam)
        "w_out": (w, d),
        "mln": (d,), "m_gate": (d, cfg.d_ff), "m_up": (d, cfg.d_ff),
        "m_down": (cfg.d_ff, d),
    }


def _attn_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, hd = cfg.d_model, cfg.hd
    return {
        "ln": (d,),
        "wq": (d, cfg.n_heads * hd), "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd), "wo": (cfg.n_heads * hd, d),
        "mln": (d,), "m_gate": (d, cfg.d_ff), "m_up": (d, cfg.d_ff),
        "m_down": (cfg.d_ff, d),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    n_super, tail = _pattern(cfg)
    ks = C.split_keys(key, 6)

    def init_block(k_, shapes, stack):
        out = {}
        kk = C.split_keys(k_, len(shapes))
        for k2, (name, shp) in zip(kk, sorted(shapes.items())):
            full = (*stack, *shp)
            if name in ("ln", "mln", "conv_b"):
                out[name] = jnp.zeros(full, dtype)
            elif name == "lam":
                # init a ~ uniform in [0.9, 0.999]: lam = logit(a^ (1/c))?
                # standard RG-LRU init: lam such that a^c ~ U(0.9, 0.999)
                u = jax.random.uniform(k2, full, jnp.float32, 0.9, 0.999)
                out[name] = jnp.log(u / (1 - u))
            else:
                scale = (shp[-2] if len(shp) >= 2 else 1) ** -0.5
                out[name] = (jax.random.normal(k2, full, jnp.float32)
                             * scale).astype(dtype)
        return out

    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "rec": init_block(ks[1], _rec_shapes(cfg), (n_super, 2)),
        "attn": init_block(ks[2], _attn_shapes(cfg), (n_super,)),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": C.dense_init(ks[3], cfg.d_model, cfg.vocab, dtype, 0.02),
    }
    if tail:
        p["rec_tail"] = init_block(ks[4], _rec_shapes(cfg), (tail,))
    return p


# --- RG-LRU core --------------------------------------------------------------

def _rglru_scan(x: jax.Array, a: jax.Array, init: jax.Array | None
                ) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + x_t via associative scan.  x, a (b, s, w)."""
    if init is not None:
        # fold the initial state into the first step
        x = x.at[:, 0].add(a[:, 0] * init)

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, x1 * a2 + x2

    a_c, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h, h[:, -1]


def rglru(x: jax.Array, rp, init_state: jax.Array | None = None,
          mask: jax.Array | None = None):
    """RG-LRU over a sequence.  x (b, s, w) post-conv branch input.

    `mask` (b, s) marks valid positions of right-padded rows: pads get
    a = 1 and zero input, i.e. identity updates, so the carried state is
    exactly the state after each row's last valid token."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(AL.gemm(xf, rp["w_rg"]))
    i = jax.nn.sigmoid(AL.gemm(xf, rp["w_in"]))
    log_a = -C_EXPONENT * r * jax.nn.softplus(rp["lam"])   # log a_t <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    if mask is not None:
        a = jnp.where(mask[..., None] > 0, a, 1.0)
        gated = gated * mask[..., None]
    h, last = _rglru_scan(gated, a, init_state)
    return h.astype(x.dtype), last


def _recurrent_block(hstate, rp, cfg: ModelConfig, spec,
                     conv_state=None, lru_state=None, decode=False,
                     true_len=None):
    x = C.rmsnorm(hstate, rp["ln"])
    branch = AL.gemm(x, rp["w_x"], spec)
    gate = jax.nn.gelu(AL.gemm(x, rp["w_gate_br"], spec))
    if decode:
        window = jnp.concatenate([conv_state, branch], axis=1)
        conv = ((window.astype(jnp.float32)
                 * rp["conv_w"].astype(jnp.float32)[None]).sum(1)
                + rp["conv_b"].astype(jnp.float32))[:, None]
        conv = conv.astype(hstate.dtype)
        new_conv = window[:, 1:]
        xf = conv[:, 0].astype(jnp.float32)
        r = jax.nn.sigmoid(AL.gemm(xf, rp["w_rg"]))
        i = jax.nn.sigmoid(AL.gemm(xf, rp["w_in"]))
        a = jnp.exp(-C_EXPONENT * r * jax.nn.softplus(rp["lam"]))
        new_lru = a * lru_state + jnp.sqrt(
            jnp.maximum(1 - a * a, 1e-12)) * (i * xf)
        lru_out = new_lru[:, None].astype(hstate.dtype)
    else:
        from repro.models.mamba2 import _causal_conv
        conv = _causal_conv(branch, rp["conv_w"], rp["conv_b"])
        mask = C.valid_mask(true_len, *hstate.shape[:2])
        lru_out, last = rglru(conv, rp, lru_state, mask)
        new_conv = C.tail_window(branch, true_len, 3)
        new_lru = last
    out = AL.gemm(lru_out * gate, rp["w_out"], spec)
    hstate = hstate + out
    x = C.rmsnorm(hstate, rp["mln"])
    ff = _geglu(x, rp, spec)
    return hstate + ff, new_conv, new_lru


def _geglu(x, p, spec):
    g = jax.nn.gelu(AL.gemm(x, p["m_gate"], spec))
    u = AL.gemm(x, p["m_up"], spec)
    return AL.gemm(g * u, p["m_down"], spec)


def _attention_block(hstate, ap, cfg: ModelConfig, spec, positions):
    b, s, d = hstate.shape
    hd = cfg.hd
    x = C.rmsnorm(hstate, ap["ln"])
    q = AL.gemm(x, ap["wq"], spec).reshape(b, s, cfg.n_heads, hd)
    k = AL.gemm(x, ap["wk"], spec).reshape(b, s, cfg.n_kv_heads, hd)
    v = AL.gemm(x, ap["wv"], spec).reshape(b, s, cfg.n_kv_heads, hd)
    q = C.apply_rope(q, positions, cfg.rope_theta)
    k = C.apply_rope(k, positions, cfg.rope_theta)
    from repro.models.attention import blockwise_attention
    attn = blockwise_attention(q, k, v, cfg.attn_chunk, True, cfg.window)
    hstate = hstate + AL.gemm(attn.reshape(b, s, -1), ap["wo"], spec)
    x = C.rmsnorm(hstate, ap["mln"])
    return hstate + _geglu(x, ap, spec)


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            **_) -> tuple:
    b, s = tokens.shape
    h = AL.embed(tokens, params["embed"])
    h = hint(h, "batch", None, None)
    positions = jnp.arange(s)[None, :]

    def superblock(hh, sp):
        rp2, ap = sp

        # scan over the 2 recurrent blocks
        def rec_step(h2, rp):
            out, _, _ = C.maybe_remat(
                lambda a, b_: _recurrent_block(a, b_, cfg, spec),
                cfg.remat)(h2, rp)
            return out, None

        hh, _ = jax.lax.scan(rec_step, hh, rp2)
        hh = C.maybe_remat(
            lambda a, b_: _attention_block(a, b_, cfg, spec, positions),
            cfg.remat)(hh, ap)
        return hh, None

    h, _ = jax.lax.scan(superblock, h, (params["rec"], params["attn"]))
    if "rec_tail" in params:
        def rec_step2(h2, rp):
            out, _, _ = _recurrent_block(h2, rp, cfg, spec)
            return out, None
        h, _ = jax.lax.scan(rec_step2, h, params["rec_tail"])

    h = C.rmsnorm(h, params["final_norm"])
    logits = AL.gemm(h, params["lm_head"], spec)
    return hint(logits, "batch", None, "vocab"), 0.0


# --- serving -------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None
               ) -> dict:
    """O(window) attention cache + O(1) recurrent state (long_500k-safe)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_super, tail = _pattern(cfg)
    w = cfg.lru_width or cfg.d_model
    win = cfg.window
    cache = {
        "rec_conv": jnp.zeros((n_super, 2, batch, 3, w), dtype),
        "rec_lru": jnp.zeros((n_super, 2, batch, w), jnp.float32),
        "att_k": jnp.zeros((n_super, batch, win, cfg.n_kv_heads, cfg.hd),
                           dtype),
        "att_v": jnp.zeros((n_super, batch, win, cfg.n_kv_heads, cfg.hd),
                           dtype),
        "length": jnp.zeros((), jnp.int32),
    }
    if tail:
        cache["tail_conv"] = jnp.zeros((tail, batch, 3, w), dtype)
        cache["tail_lru"] = jnp.zeros((tail, batch, w), jnp.float32)
    return cache


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                cfg: ModelConfig, spec=None, **_) -> tuple:
    b = tokens.shape[0]
    h = AL.embed(tokens, params["embed"])
    length = C.cache_lengths(cache, b)
    win = cfg.window

    def attn_decode(hh, ap, ck, cv):
        x = C.rmsnorm(hh, ap["ln"])
        hd = cfg.hd
        pos = length[:, None]
        q = AL.gemm(x, ap["wq"], spec).reshape(b, 1, cfg.n_heads, hd)
        k = AL.gemm(x, ap["wk"], spec).reshape(b, 1, cfg.n_kv_heads, hd)
        v = AL.gemm(x, ap["wv"], spec).reshape(b, 1, cfg.n_kv_heads, hd)
        q = C.apply_rope(q, pos, cfg.rope_theta)
        k = C.apply_rope(k, pos, cfg.rope_theta)
        slot = jnp.mod(length, win)
        ck = C.rowwise_cache_update(ck, k, slot)
        cv = C.rowwise_cache_update(cv, v, slot)
        # rolling-window validity: all slots valid once length >= win
        n_valid = jnp.minimum(length + 1, win)
        attn = C.decode_attention(q, ck, cv, n_valid)
        hh = hh + AL.gemm(attn.reshape(b, 1, -1), ap["wo"], spec)
        x = C.rmsnorm(hh, ap["mln"])
        return hh + _geglu(x, ap, spec), ck, cv

    def superblock(hh, sp):
        rp2, ap, rc, rl, ck, cv = sp

        def rec_step(h2, inner):
            rp, conv_st, lru_st = inner
            out, nc, nl = _recurrent_block(h2, rp, cfg, spec, conv_st,
                                           lru_st, decode=True)
            return out, (nc, nl)

        hh, (rc, rl) = jax.lax.scan(rec_step, hh, (rp2, rc, rl))
        hh, ck, cv = attn_decode(hh, ap, ck, cv)
        return hh, (rc, rl, ck, cv)

    h, (rc, rl, ck, cv) = jax.lax.scan(
        superblock, h,
        (params["rec"], params["attn"], cache["rec_conv"],
         cache["rec_lru"], cache["att_k"], cache["att_v"]))

    new_cache = dict(cache, rec_conv=rc, rec_lru=rl, att_k=ck, att_v=cv,
                     length=cache["length"] + 1)
    if "rec_tail" in params:
        def rec_step2(h2, inner):
            rp, conv_st, lru_st = inner
            out, nc, nl = _recurrent_block(h2, rp, cfg, spec, conv_st,
                                           lru_st, decode=True)
            return out, (nc, nl)
        h, (tc, tl) = jax.lax.scan(
            rec_step2, h,
            (params["rec_tail"], cache["tail_conv"], cache["tail_lru"]))
        new_cache["tail_conv"] = tc
        new_cache["tail_lru"] = tl

    h = C.rmsnorm(h, params["final_norm"])
    logits = AL.gemm(h, params["lm_head"], spec)
    return logits, new_cache


def _rolling_slots(s: int, win: int) -> tuple[jax.Array, jax.Array]:
    """Map rolling-cache slots -> absolute positions after s prefilled
    tokens; invalid slots marked."""
    slots = jnp.arange(win)
    pos = (s - 1) - jnp.mod((s - 1) - slots, win)
    valid = (pos >= 0) & (pos > s - 1 - win)
    return pos, valid


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            max_len: int | None = None, true_len=None, **_) -> tuple:
    """Full-sequence pass capturing decode state: final RG-LRU states, conv
    tails, and the last-`window` KV laid out in rolling-slot order so
    decode_step continues seamlessly at absolute position s.

    With `true_len` (b,) the rolling-slot layout, conv tails, LRU states,
    and last-position logits are all taken at each row's own boundary."""
    b, s = tokens.shape
    h = AL.embed(tokens, params["embed"])
    positions = jnp.arange(s)[None, :]
    win = cfg.window
    if true_len is None:
        pos_map, valid = _rolling_slots(s, win)        # (win,) shared
        pos_map, valid = pos_map[None], valid[None]    # broadcast over b
    else:
        slots = jnp.arange(win)[None, :]
        last = true_len[:, None] - 1                   # (b, 1)
        pos_map = last - jnp.mod(last - slots, win)    # (b, win)
        valid = (pos_map >= 0) & (pos_map > last - win)
    pos_map_c = jnp.clip(pos_map, 0, s - 1)

    def attn_collect(hh, ap):
        bsz, ss, d = hh.shape
        hd = cfg.hd
        x = C.rmsnorm(hh, ap["ln"])
        q = AL.gemm(x, ap["wq"], spec).reshape(bsz, ss, cfg.n_heads, hd)
        k = AL.gemm(x, ap["wk"], spec).reshape(bsz, ss, cfg.n_kv_heads, hd)
        v = AL.gemm(x, ap["wv"], spec).reshape(bsz, ss, cfg.n_kv_heads, hd)
        q = C.apply_rope(q, positions, cfg.rope_theta)
        k = C.apply_rope(k, positions, cfg.rope_theta)
        from repro.models.attention import blockwise_attention
        attn = blockwise_attention(q, k, v, cfg.attn_chunk, True, win)
        hh = hh + AL.gemm(attn.reshape(bsz, ss, -1), ap["wo"], spec)
        x = C.rmsnorm(hh, ap["mln"])
        hh = hh + _geglu(x, ap, spec)
        idx = jnp.broadcast_to(pos_map_c[..., None, None],
                               (bsz, win, cfg.n_kv_heads, hd))
        ck = jnp.where(valid[..., None, None],
                       jnp.take_along_axis(k, idx, axis=1), 0)
        cv = jnp.where(valid[..., None, None],
                       jnp.take_along_axis(v, idx, axis=1), 0)
        return hh, ck.astype(jnp.dtype(cfg.dtype)), \
            cv.astype(jnp.dtype(cfg.dtype))

    def superblock(hh, sp):
        rp2, ap = sp

        def rec_step(h2, rp):
            out, conv_tail, lru_last = _recurrent_block(h2, rp, cfg, spec,
                                                        true_len=true_len)
            return out, (conv_tail, lru_last)

        hh, (rc, rl) = jax.lax.scan(rec_step, hh, rp2)
        hh, ck, cv = attn_collect(hh, ap)
        return hh, (rc, rl, ck, cv)

    h, (rc, rl, ck, cv) = jax.lax.scan(superblock, h,
                                       (params["rec"], params["attn"]))
    cache = {
        "rec_conv": rc.astype(jnp.dtype(cfg.dtype)), "rec_lru": rl,
        "att_k": ck, "att_v": cv,
        "length": C.prefill_length(true_len, s),
    }
    if "rec_tail" in params:
        def rec_step2(h2, rp):
            out, conv_tail, lru_last = _recurrent_block(h2, rp, cfg, spec,
                                                        true_len=true_len)
            return out, (conv_tail, lru_last)
        h, (tc, tl) = jax.lax.scan(rec_step2, h, params["rec_tail"])
        cache["tail_conv"] = tc.astype(jnp.dtype(cfg.dtype))
        cache["tail_lru"] = tl

    h = C.rmsnorm(C.last_valid_slice(h, true_len), params["final_norm"])
    logits = AL.gemm(h, params["lm_head"], spec)[:, 0]
    return logits, cache
