"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the brief the audio frontend (log-mel + conv downsampling) is a STUB:
`input_specs()` supplies precomputed frame embeddings (b, enc_seq, d) and the
model consumes them directly.  Whisper specifics kept: LayerNorm (with bias),
biased attention projections (q, v, out — no k bias), GELU MLP with biases,
sinusoidal encoder positions, learned decoder positions.  The assigned
shapes (4k/32k decoder contexts) exceed real whisper's 448-token decoder —
we follow the assigned shapes on the backbone, as instructed.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.approx import layers as AL
from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.sharding.ctx import hint

Params = dict[str, Any]
MAX_DEC_POS = 32768  # learned decoder positions table

#: Serving weight-plane cache eligibility (api.prepare_params): attention
#: and MLP projections of both stacks (self- and cross-attention share
#: the "x"-prefixed names).  The tied head reuses the embedding transpose
#: and stays on the live path.
PREPARED_GEMM_WEIGHTS = frozenset({
    "wq", "wk", "wv", "wo", "xwq", "xwk", "xwv", "xwo", "m_up", "m_down",
})


def _attn_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    return {"wq": (d, h * hd), "bq": (h * hd,),
            "wk": (d, kv * hd),
            "wv": (d, kv * hd), "bv": (kv * hd,),
            "wo": (h * hd, d), "bo": (d,)}


def _block_shapes(cfg: ModelConfig, cross: bool) -> dict[str, tuple]:
    d, f = cfg.d_model, cfg.d_ff
    shapes = {"ln1": (d,), "ln1b": (d,)}
    shapes |= {k: v for k, v in _attn_shapes(cfg).items()}
    if cross:
        shapes |= {"xln": (d,), "xlnb": (d,)}
        shapes |= {"x" + k: v for k, v in _attn_shapes(cfg).items()}
    shapes |= {"ln2": (d,), "ln2b": (d,), "m_up": (d, f), "mb_up": (f,),
               "m_down": (f, d), "mb_down": (d,)}
    return shapes


def _init_stack(key, shapes, stack, dtype):
    out = {}
    ks = C.split_keys(key, len(shapes))
    for k_, (name, shp) in zip(ks, sorted(shapes.items())):
        full = (*stack, *shp)
        if name.startswith(("ln", "xln", "b", "mb", "xb")) or \
                name in ("xlnb", "ln1b", "ln2b"):
            out[name] = jnp.zeros(full, dtype)
        else:
            scale = shp[-2] ** -0.5 if len(shp) >= 2 else 0.0
            out[name] = (jax.random.normal(k_, full, jnp.float32) * scale
                         ).astype(dtype)
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = C.split_keys(key, 5)
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(ks[1], (MAX_DEC_POS, cfg.d_model),
                                      jnp.float32) * 0.01).astype(dtype),
        "enc_layers": _init_stack(ks[2], _block_shapes(cfg, cross=False),
                                  (cfg.n_enc_layers,), dtype),
        "dec_layers": _init_stack(ks[3], _block_shapes(cfg, cross=True),
                                  (cfg.n_layers,), dtype),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "enc_normb": jnp.zeros((cfg.d_model,), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "final_normb": jnp.zeros((cfg.d_model,), dtype),
        # whisper ties the output head to the token embedding
    }


def _mha(x, kv_src, p, cfg: ModelConfig, spec, prefix="", causal=True,
         positions=None):
    b, s, d = x.shape
    hd = cfg.hd
    q = AL.dense(x, p[prefix + "wq"], p[prefix + "bq"], spec).reshape(
        b, s, cfg.n_heads, hd)
    k = AL.dense(kv_src, p[prefix + "wk"], None, spec).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = AL.dense(kv_src, p[prefix + "wv"], p[prefix + "bv"], spec).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd)
    impl = cfg.attn_impl if x.shape[1] == kv_src.shape[1] else "naive"
    if x.shape[1] * kv_src.shape[1] > (1 << 22) and impl == "naive":
        impl = "chunked"
    if impl == "chunked" and x.shape[1] != kv_src.shape[1]:
        impl = "naive"  # cross-attention (small enc side): direct
    attn = C.attention(q, k, v, impl=impl, chunk=cfg.attn_chunk,
                       causal=causal,
                       policy=spec.policy if spec is not None else None)
    return AL.dense(attn.reshape(b, s, -1), p[prefix + "wo"],
                    p[prefix + "bo"], spec)


def _enc_block(h, lp, cfg, spec):
    x = C.layernorm(h, lp["ln1"], lp["ln1b"])
    h = h + _mha(x, x, lp, cfg, spec, causal=False)
    x = C.layernorm(h, lp["ln2"], lp["ln2b"])
    return h + C.gelu_mlp(x, lp["m_up"], lp["mb_up"], lp["m_down"],
                          lp["mb_down"], spec)


def _dec_block(h, enc_out, lp, cfg, spec):
    x = C.layernorm(h, lp["ln1"], lp["ln1b"])
    h = h + _mha(x, x, lp, cfg, spec, causal=True)
    x = C.layernorm(h, lp["xln"], lp["xlnb"])
    h = h + _mha(x, enc_out, lp, cfg, spec, prefix="x", causal=False)
    x = C.layernorm(h, lp["ln2"], lp["ln2b"])
    return h + C.gelu_mlp(x, lp["m_up"], lp["mb_up"], lp["m_down"],
                          lp["mb_down"], spec)


def encode(params: Params, frames: jax.Array, cfg: ModelConfig, spec=None
           ) -> jax.Array:
    """frames (b, enc_seq, d) — precomputed frame embeddings (stub)."""
    h = frames + C.sinusoid_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)

    def blk(hh, lp):
        return C.maybe_remat(lambda a, b_: _enc_block(a, b_, cfg, spec),
                             cfg.remat)(hh, lp), None

    h, _ = jax.lax.scan(blk, h, params["enc_layers"])
    return C.layernorm(h, params["enc_norm"], params["enc_normb"])


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            frames: jax.Array | None = None, **_) -> tuple:
    """Teacher-forced decoder over (b, s) tokens given encoder frames."""
    b, s = tokens.shape
    if frames is None:
        frames = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    enc_out = encode(params, frames, cfg, spec)
    h = AL.embed(tokens, params["embed"]) + params["dec_pos"][:s][None]
    h = hint(h, "batch", None, None)

    def blk(hh, lp):
        return C.maybe_remat(
            lambda a, b_: _dec_block(a, enc_out, b_, cfg, spec),
            cfg.remat)(hh, lp), None

    h, _ = jax.lax.scan(blk, h, params["dec_layers"])
    h = C.layernorm(h, params["final_norm"], params["final_normb"])
    logits = AL.gemm(h, params["embed"].T, spec)
    return hint(logits, "batch", None, "vocab"), 0.0


# --- serving -------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None
               ) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, kv, hd), dtype),
        # cross-attention K/V computed once from the encoder output
        "xk": jnp.zeros((L, batch, cfg.enc_seq, kv, hd), dtype),
        "xv": jnp.zeros((L, batch, cfg.enc_seq, kv, hd), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def precompute_cross(params: Params, enc_out: jax.Array, cfg: ModelConfig,
                     spec=None) -> tuple[jax.Array, jax.Array]:
    """Per-layer cross K/V from encoder output: (L, b, enc_seq, kv, hd)."""
    b = enc_out.shape[0]
    hd = cfg.hd

    def per_layer(lp):
        k = AL.dense(enc_out, lp["xwk"], None, spec)
        v = AL.dense(enc_out, lp["xwv"], lp["xbv"], spec)
        return (k.reshape(b, -1, cfg.n_kv_heads, hd),
                v.reshape(b, -1, cfg.n_kv_heads, hd))

    return jax.lax.map(per_layer, params["dec_layers"])


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            max_len: int | None = None, frames: jax.Array | None = None,
            true_len=None, **_) -> tuple:
    """Encode frames + teacher-forced decoder pass collecting self-KV and
    precomputing cross-KV.  `true_len` (b,) supports right-padded prompts
    (causal self-attention keeps valid rows exact; pads are masked at
    decode time via per-row cache lengths)."""
    b, s = tokens.shape
    max_len = max_len or s
    if frames is None:
        frames = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    enc_out = encode(params, frames, cfg, spec)
    h = AL.embed(tokens, params["embed"]) + params["dec_pos"][:s][None]
    hd = cfg.hd

    def blk(hh, lp):
        x = C.layernorm(hh, lp["ln1"], lp["ln1b"])
        q = AL.dense(x, lp["wq"], lp["bq"], spec).reshape(
            b, s, cfg.n_heads, hd)
        k = AL.dense(x, lp["wk"], None, spec).reshape(
            b, s, cfg.n_kv_heads, hd)
        v = AL.dense(x, lp["wv"], lp["bv"], spec).reshape(
            b, s, cfg.n_kv_heads, hd)
        attn = C.attention(q, k, v, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                           policy=spec.policy if spec is not None else None)
        hh = hh + AL.dense(attn.reshape(b, s, -1), lp["wo"], lp["bo"], spec)
        x = C.layernorm(hh, lp["xln"], lp["xlnb"])
        hh = hh + _mha(x, enc_out, lp, cfg, spec, prefix="x", causal=False)
        x = C.layernorm(hh, lp["ln2"], lp["ln2b"])
        hh = hh + C.gelu_mlp(x, lp["m_up"], lp["mb_up"], lp["m_down"],
                             lp["mb_down"], spec)
        return hh, (k, v)

    h, (ks, vs) = jax.lax.scan(blk, h, params["dec_layers"])
    xk, xv = precompute_cross(params, enc_out, cfg, spec)
    h = C.layernorm(C.last_valid_slice(h, true_len), params["final_norm"],
                    params["final_normb"])
    logits = AL.gemm(h, params["embed"].T, spec)[:, 0]
    pad = max_len - s
    if pad > 0:
        widths = [(0, 0)] * ks.ndim
        widths[2] = (0, pad)
        ks = jnp.pad(ks, widths)
        vs = jnp.pad(vs, widths)
    dtype = jnp.dtype(cfg.dtype)
    cache = {"k": ks.astype(dtype), "v": vs.astype(dtype),
             "xk": xk.astype(dtype), "xv": xv.astype(dtype),
             "length": C.prefill_length(true_len, s)}
    return logits, cache


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                cfg: ModelConfig, spec=None, **_) -> tuple:
    b = tokens.shape[0]
    length = C.cache_lengths(cache, b)
    pos_emb = jnp.take(params["dec_pos"], length, axis=0)    # (b, d)
    h = AL.embed(tokens, params["embed"]) + pos_emb[:, None]
    hd = cfg.hd

    def blk(hh, sp):
        lp, ck, cv, xk, xv = sp
        x = C.layernorm(hh, lp["ln1"], lp["ln1b"])
        q = AL.dense(x, lp["wq"], lp["bq"], spec).reshape(
            b, 1, cfg.n_heads, hd)
        k = AL.dense(x, lp["wk"], None, spec).reshape(
            b, 1, cfg.n_kv_heads, hd)
        v = AL.dense(x, lp["wv"], lp["bv"], spec).reshape(
            b, 1, cfg.n_kv_heads, hd)
        ck = C.rowwise_cache_update(ck, k, length)
        cv = C.rowwise_cache_update(cv, v, length)
        attn = C.decode_attention(q, ck, cv, length + 1)
        hh = hh + AL.dense(attn.reshape(b, 1, -1), lp["wo"], lp["bo"], spec)
        # cross attention against precomputed enc K/V
        x = C.layernorm(hh, lp["xln"], lp["xlnb"])
        qx = AL.dense(x, lp["xwq"], lp["xbq"], spec).reshape(
            b, 1, cfg.n_heads, hd)
        full = jnp.full((b,), xk.shape[1], jnp.int32)
        xattn = C.decode_attention(qx, xk, xv, full)
        hh = hh + AL.dense(xattn.reshape(b, 1, -1), lp["xwo"], lp["xbo"],
                           spec)
        x = C.layernorm(hh, lp["ln2"], lp["ln2b"])
        hh = hh + C.gelu_mlp(x, lp["m_up"], lp["mb_up"], lp["m_down"],
                             lp["mb_down"], spec)
        return hh, (ck, cv)

    h, (ck, cv) = jax.lax.scan(
        blk, h, (params["dec_layers"], cache["k"], cache["v"],
                 cache["xk"], cache["xv"]))
    h = C.layernorm(h, params["final_norm"], params["final_normb"])
    logits = AL.gemm(h, params["embed"].T, spec)
    return logits, dict(cache, k=ck, v=cv, length=cache["length"] + 1)
