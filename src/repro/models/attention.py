"""Blockwise (flash-style) attention with a custom VJP, pure JAX.

Plain `lax.scan` online-softmax attention is O(chunk*s) memory in the
FORWARD pass only: under autodiff, the scan saves its per-block probability
residuals, re-materializing the full O(s^2) score tensor in the backward
pass (a 4k-token tinyllama train step showed 21 GB/device of temps in the
dry-run memory analysis before this module existed).  The fix is the
standard flash backward: save only (out, logsumexp) per row and recompute
block scores in the backward sweep.

Handles: causal masking, GQA (kv-head grouping), sliding windows (true
O(s*window) flops via static-span dynamic slices), cross-attention
(q-len != kv-len), and internal padding to chunk multiples.

This is the XLA twin of kernels/flash_attention.py (which targets the TPU
Mosaic path); the dry-run and CPU tests lower this one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def _pad_seq(x, c):
    pad = (-x.shape[1]) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x, pad


def _mask_for(iq, jk, c_q, c_k, s_q, s_k, causal, window, q_off=0):
    """(c_q, c_k) bool mask for q chunk iq vs kv chunk positions jk
    (jk = global start of the kv slice)."""
    qi = iq * c_q + jnp.arange(c_q) + q_off
    ki = jk + jnp.arange(c_k)
    m = (ki[None, :] < s_k) & (qi[:, None] < s_q + q_off)
    if causal:
        m &= qi[:, None] >= ki[None, :]
    if window:
        m &= (qi[:, None] - ki[None, :]) <= window
        m &= ki[None, :] >= 0
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        chunk: int = 512, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """q (b, sq, h, d); k, v (b, skv, kvh, d) -> (b, sq, h, d)."""
    out, _ = _fwd(q, k, v, chunk, causal, window)
    return out


def _shape5(q, kvh):
    b, s, h, d = q.shape
    g = h // kvh
    return q.reshape(b, s, kvh, g, d)


def _hint_qkv(x):
    """Pin (batch, seq, heads, hd) sharding at the kernel boundary: without
    this GSPMD un-shards the batch dim through the chunked q/kv loops
    (measured 32x attention over-compute on qwen prefill)."""
    from repro.sharding.ctx import hint
    return hint(x, "batch", None, "heads", None)


def _fwd(q, k, v, chunk, causal, window):
    with jax.named_scope("vmem_kernel_attention"):
        return _fwd_inner(_hint_qkv(q), _hint_qkv(k), _hint_qkv(v), chunk,
                          causal, window)


def _fwd_inner(q, k, v, chunk, causal, window):
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    c = max(1, min(chunk, sq))
    qp, _ = _pad_seq(q, c)
    kp, _ = _pad_seq(k, c)
    vp, _ = _pad_seq(v, c)
    spq, spk = qp.shape[1], kp.shape[1]
    nq, nk = spq // c, spk // c
    qg = _shape5(qp, kvh)                                  # (b,sp,kv,g,d)
    scale = d ** -0.5

    if window:
        w = min(window, skv)
        kp2 = jnp.pad(kp, ((0, 0), (w, 0), (0, 0), (0, 0)))
        vp2 = jnp.pad(vp, ((0, 0), (w, 0), (0, 0), (0, 0)))

    def q_block(iq):
        qs = jax.lax.dynamic_slice_in_dim(qg, iq * c, c, 1)
        qs = qs.astype(jnp.float32) * scale               # (b,c,kv,g,d)

        def attend(ks, vs, jk_start):
            sc = jnp.einsum("bqkgd,bmkd->bkgqm", qs,
                            ks.astype(jnp.float32))
            m = _mask_for(iq, jk_start, c, ks.shape[1], sq, skv, causal,
                          window)
            return jnp.where(m[None, None, None], sc, NEG), vs

        if window:
            start = iq * c  # padded coords
            ks = jax.lax.dynamic_slice_in_dim(kp2, start, w + c, 1)
            vs = jax.lax.dynamic_slice_in_dim(vp2, start, w + c, 1)
            sc, vs = attend(ks, vs, iq * c - w)
            mx = sc.max(-1)
            p = jnp.exp(sc - mx[..., None])
            l = p.sum(-1)
            o = jnp.einsum("bkgqm,bmkd->bkgqd", p, vs.astype(jnp.float32))
            o = o / jnp.maximum(l, 1e-30)[..., None]
            lse = mx + jnp.log(jnp.maximum(l, 1e-30))
            return o.transpose(0, 3, 1, 2, 4), lse        # (b,c,kv,g,*)

        def kv_step(carry, jk):
            m_p, l_p, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(kp, jk * c, c, 1)
            vs = jax.lax.dynamic_slice_in_dim(vp, jk * c, c, 1)
            sc, vs = attend(ks, vs, jk * c)
            m_c = sc.max(-1)
            m_n = jnp.maximum(m_p, m_c)
            p = jnp.exp(sc - m_n[..., None])
            alpha = jnp.exp(m_p - m_n)
            l_n = alpha * l_p + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqm,bmkd->bkgqd", p, vs.astype(jnp.float32))
            return (m_n, l_n, acc), None

        init = (jnp.full((b, kvh, h // kvh, c), NEG, jnp.float32),
                jnp.zeros((b, kvh, h // kvh, c), jnp.float32),
                jnp.zeros((b, kvh, h // kvh, c, d), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]
        lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))
        return o.transpose(0, 3, 1, 2, 4), lse

    outs, lses = jax.lax.map(q_block, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, spq, h, d)
    out = out[:, :sq].astype(q.dtype)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(
        b, kvh, h // kvh, spq)                             # (b,kv,g,sp)
    return out, (q, k, v, out, lse)


def _fwd_rule(q, k, v, chunk, causal, window):
    out, res = _fwd(q, k, v, chunk, causal, window)
    return out, res


def _bwd_rule(chunk, causal, window, res, g):
    with jax.named_scope("vmem_kernel_attention"):
        return _bwd_inner(chunk, causal, window, res, g)


def _bwd_inner(chunk, causal, window, res, g):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    c = max(1, min(chunk, sq))
    qp, _ = _pad_seq(q, c)
    kp, _ = _pad_seq(k, c)
    vp, _ = _pad_seq(v, c)
    gp, _ = _pad_seq(g.astype(jnp.float32), c)
    op, _ = _pad_seq(out.astype(jnp.float32), c)
    spq, spk = qp.shape[1], kp.shape[1]
    nq, nk = spq // c, spk // c
    scale = d ** -0.5
    qg = _shape5(qp, kvh).astype(jnp.float32)
    gg = _shape5(gp, kvh)
    og = _shape5(op, kvh)
    lse_p = jnp.pad(lse, ((0, 0),) * 3 + ((0, spq - lse.shape[-1]),)) \
        if lse.shape[-1] != spq else lse

    w = min(window, skv) if window else 0
    if window:
        kp2 = jnp.pad(kp, ((0, 0), (w, 0), (0, 0), (0, 0)))
        vp2 = jnp.pad(vp, ((0, 0), (w, 0), (0, 0), (0, 0)))

    def q_block(carry, iq):
        dk_acc, dv_acc = carry                      # padded (b,spk[+w],kv,d)
        qs = jax.lax.dynamic_slice_in_dim(qg, iq * c, c, 1) * scale
        gs = jax.lax.dynamic_slice_in_dim(gg, iq * c, c, 1)
        os_ = jax.lax.dynamic_slice_in_dim(og, iq * c, c, 1)
        lse_i = jax.lax.dynamic_slice_in_dim(lse_p, iq * c, c, 3)
        di = jnp.einsum("bqkgd,bqkgd->bkgq", gs, os_)   # rowsum(dO * O)

        def block_grads(ks, vs, jk_start):
            sc = jnp.einsum("bqkgd,bmkd->bkgqm", qs,
                            ks.astype(jnp.float32))
            m = _mask_for(iq, jk_start, c, ks.shape[1], sq, skv, causal,
                          window)
            sc = jnp.where(m[None, None, None], sc, NEG)
            p = jnp.exp(sc - lse_i[..., None])           # (b,kv,g,q,m)
            dv = jnp.einsum("bkgqm,bqkgd->bmkd", p, gs)
            dp = jnp.einsum("bqkgd,bmkd->bkgqm", gs, vs.astype(jnp.float32))
            ds = p * (dp - di[..., None]) * scale
            dq = jnp.einsum("bkgqm,bmkd->bqkgd", ds, ks.astype(jnp.float32))
            dk = jnp.einsum("bkgqm,bqkgd->bmkd", ds, qs) / scale
            return dq, dk, dv

        if window:
            start = iq * c
            ks = jax.lax.dynamic_slice_in_dim(kp2, start, w + c, 1)
            vs = jax.lax.dynamic_slice_in_dim(vp2, start, w + c, 1)
            dq_i, dk_b, dv_b = block_grads(ks, vs, iq * c - w)
            old_k = jax.lax.dynamic_slice_in_dim(dk_acc, start, w + c, 1)
            old_v = jax.lax.dynamic_slice_in_dim(dv_acc, start, w + c, 1)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, old_k + dk_b, start, 1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, old_v + dv_b, start, 1)
        else:
            def kv_step(carry2, jk):
                dk_a, dv_a, dq_a = carry2
                ks = jax.lax.dynamic_slice_in_dim(kp, jk * c, c, 1)
                vs = jax.lax.dynamic_slice_in_dim(vp, jk * c, c, 1)
                dq_b, dk_b, dv_b = block_grads(ks, vs, jk * c)
                old_k = jax.lax.dynamic_slice_in_dim(dk_a, jk * c, c, 1)
                old_v = jax.lax.dynamic_slice_in_dim(dv_a, jk * c, c, 1)
                dk_a = jax.lax.dynamic_update_slice_in_dim(
                    dk_a, old_k + dk_b, jk * c, 1)
                dv_a = jax.lax.dynamic_update_slice_in_dim(
                    dv_a, old_v + dv_b, jk * c, 1)
                return (dk_a, dv_a, dq_a + dq_b), None

            zero_dq = jnp.zeros((b, c, kvh, h // kvh, d), jnp.float32)
            (dk_acc, dv_acc, dq_i), _ = jax.lax.scan(
                kv_step, (dk_acc, dv_acc, zero_dq), jnp.arange(nk))

        return (dk_acc, dv_acc), dq_i

    pad_w = w if window else 0
    dk0 = jnp.zeros((b, spk + pad_w, kvh, d), jnp.float32)
    dv0 = jnp.zeros((b, spk + pad_w, kvh, d), jnp.float32)
    (dk_f, dv_f), dqs = jax.lax.scan(q_block, (dk0, dv0), jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, spq, h, d)[:, :sq]
    dk = dk_f[:, pad_w:pad_w + skv]
    dv = dv_f[:, pad_w:pad_w + skv]
    return (_hint_qkv(dq).astype(q.dtype), _hint_qkv(dk).astype(k.dtype),
            _hint_qkv(dv).astype(v.dtype))


blockwise_attention.defvjp(_fwd_rule, _bwd_rule)
