"""Mixture-of-Experts FFN with capacity-based top-k routing (Switch/GShard
style) and expert-parallel sharding hints.

Dispatch/combine use scatter/gather (not the dense one-hot einsum) so the
dispatched activation tensor is (E, capacity, D) — the EP-shardable layout —
rather than the O(T*E*C) dense dispatch mask.  Router stays exact f32 (it is
error-sensitive control logic; the paper approximates MAC arrays only).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.approx import layers as AL
from repro.models.common import MultSpec
from repro.sharding.ctx import hint


def moe_ffn(x: jax.Array, router: jax.Array, we_gate: jax.Array,
            we_up: jax.Array, we_down: jax.Array, top_k: int,
            capacity_factor: float, spec: MultSpec | None
            ) -> tuple[jax.Array, jax.Array]:
    """x (t, d); router (d, e); we_* (e, d, f) / (e, f, d).

    Returns (out (t, d), aux_loss scalar) — aux is the standard load-balance
    loss (mean_e density_e * mean_e router_prob_e * E).
    """
    from repro.approx.layers import _as_weight
    router = _as_weight(router, jnp.float32)
    we_gate = _as_weight(we_gate, x.dtype)
    we_up = _as_weight(we_up, x.dtype)
    we_down = _as_weight(we_down, x.dtype)
    t, d = x.shape
    e = router.shape[1]
    capacity = max(1, int(capacity_factor * top_k * t / e))

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (t, e)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)   # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    out = jnp.zeros((t, d), jnp.float32)
    density = jnp.zeros((e,), jnp.float32)
    for slot in range(top_k):
        idx = expert_idx[:, slot]                          # (t,)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)   # (t, e)
        pos = jnp.cumsum(onehot, axis=0) - onehot          # position in expert
        position = (pos * onehot).sum(-1)                  # (t,)
        keep = position < capacity
        density = density + onehot.sum(0).astype(jnp.float32) / t

        # dispatch: (e, capacity, d)
        x_e = jnp.zeros((e, capacity, d), x.dtype)
        x_e = x_e.at[idx, position].add(
            jnp.where(keep[:, None], x, 0).astype(x.dtype),
            mode="drop")
        # EP hint: experts on "model" when divisible; capacity is
        # batch-like -> shard on the data axes so the expert GEMM
        # partitions even when n_experts < model-parallel degree (grok).
        x_e = hint(x_e, "experts", "batch", None)

        # Compute-time weight sharding: gather the FSDP (d-sharded) expert
        # weights per layer instead of psum-ing (E, C, f) activations —
        # ZeRO-3 semantics.  The contraction dim stays unsharded; TP moves
        # to f (dropped automatically when "experts" already takes the
        # model axis).  Measured on grok train_4k: all-reduce bytes 1.5e15
        # -> collective term 148s -> 3.4s (see EXPERIMENTS.md §Perf).
        w_gate = hint(we_gate, "experts", None, "ff")
        w_up = hint(we_up, "experts", None, "ff")
        w_down = hint(we_down, "experts", "ff", None)

        # expert FFN (SwiGLU), batched over experts
        g = jnp.einsum("ecd,edf->ecf", x_e, w_gate) if spec is None or \
            spec.is_exact else _expert_gemm(x_e, w_gate, spec)
        u = jnp.einsum("ecd,edf->ecf", x_e, w_up) if spec is None or \
            spec.is_exact else _expert_gemm(x_e, w_up, spec)
        h = jax.nn.silu(g) * u
        h = hint(h, "experts", "batch", "ff")
        o_e = jnp.einsum("ecf,efd->ecd", h, w_down) if spec is None or \
            spec.is_exact else _expert_gemm(h, w_down, spec)
        o_e = hint(o_e, "experts", "batch", None)

        # combine.  NOTE (measured, llama4 prefill): the dominant collective
        # of EP MoE is the all-reduce GSPMD emits for this gather-from-
        # sharded o_e; pre-reducing in bf16 was tried and did NOT change
        # the emitted collective (see EXPERIMENTS.md §Perf) — a true
        # all-to-all dispatch/combine (ragged shard_map path) is the
        # identified next lever.
        gathered = o_e[idx, position]                      # (t, d)
        out = out + jnp.where(keep[:, None],
                              gathered.astype(jnp.float32), 0) \
            * gate_vals[:, slot][:, None]

    mean_prob = probs.mean(0)
    aux = (density / top_k * mean_prob).sum() * e
    return out.astype(x.dtype), aux


def _expert_gemm(x_e: jax.Array, w_e: jax.Array, spec: MultSpec
                 ) -> jax.Array:
    """Per-expert approximate GEMM: vmap the approx path over experts."""
    return jax.vmap(lambda xe, we: AL.gemm(xe, we, spec))(x_e, w_e)
