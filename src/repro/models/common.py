"""Shared model components: norms, RoPE, attention (naive / chunked /
windowed / decode), SwiGLU MLP, initializers.

All matmuls route through repro.approx.layers.gemm so every architecture can
run under a candidate approximate multiplier (`spec`).  Softmax, norms and
rotary math stay in f32 (they map to the accelerator's exact vector unit,
not the approximate MAC array — see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.approx import layers as AL
from repro.approx import gemm as gemm_mod
from repro.sharding.ctx import hint

MultSpec = gemm_mod.MultSpec
Params = dict[str, Any]


# --- init -------------------------------------------------------------------

def dense_init(key: jax.Array, n_in: int, n_out: int, dtype,
               scale: float | None = None) -> jax.Array:
    s = scale if scale is not None else n_in ** -0.5
    return (jax.random.normal(key, (n_in, n_out), jnp.float32) * s
            ).astype(dtype)


def stacked_dense_init(key: jax.Array, n: int, n_in: int, n_out: int, dtype,
                       scale: float | None = None) -> jax.Array:
    s = scale if scale is not None else n_in ** -0.5
    return (jax.random.normal(key, (n, n_in, n_out), jnp.float32) * s
            ).astype(dtype)


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


# --- norms ------------------------------------------------------------------

def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * \
        (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def sinusoid_positions(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# --- rotary embeddings --------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., s, h, hd), positions (..., s) -> same shape.

    The heads hint is load-bearing under tensor parallelism, not an
    optimization: x arrives reshaped from a column-parallel projection
    ((..., h*hd) sharded on "model"), and re-expressing that sharding on
    the heads dim (the same device-local bytes when h divides the model
    axis) keeps the rotate-half split/concat below OFF the sharded axis —
    XLA's CPU SPMD partitioner miscompiles concatenate along a sharded
    dim (observed on jax 0.4.37; tests/test_distributed.py pins parity).
    """
    if x.ndim == 4:
        x = hint(x, "batch", None, "heads", None)
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., s, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --- attention ----------------------------------------------------------------

def _gqa_shape(q: jax.Array, kv_heads: int):
    b, s, h, d = q.shape
    g = h // kv_heads
    return q.reshape(b, s, kv_heads, g, d), g


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """q (b,s,h,d), k/v (b,s,kv,d).  Materializes (s, s) scores."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qg, g = _gqa_shape(q, kvh)
    scale = d ** -0.5
    s_ = jnp.einsum("bqkgd,bmkd->bkgqm", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        s_ = jnp.where(mask, s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bkgqm,bmkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, h, d).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      chunk: int = 512, causal: bool = True) -> jax.Array:
    """Online-softmax attention, O(chunk*s) live memory (XLA analogue of the
    flash kernel; used where Pallas cannot lower, e.g. the CPU dry-run)."""
    b, s_orig, h, d = q.shape
    kvh = k.shape[2]
    c = min(chunk, s_orig)
    pad = (-s_orig) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    qg, g = _gqa_shape(q, kvh)
    scale = d ** -0.5
    nq = s // c
    nk = s // c
    kc = k.reshape(b, nk, c, kvh, d)
    vc = v.reshape(b, nk, c, kvh, d)

    def q_block(iq):
        qs = jax.lax.dynamic_slice_in_dim(qg, iq * c, c, axis=1)  # b,c,kv,g,d
        qs = qs.astype(jnp.float32) * scale

        def kv_step(carry, ik):
            m_p, l_p, acc = carry
            ks = kc[:, ik].astype(jnp.float32)            # (b,c,kv,d)
            vs = vc[:, ik].astype(jnp.float32)
            sc = jnp.einsum("bqkgd,bmkd->bkgqm", qs, ks)  # (b,kv,g,c,c)
            qi = iq * c + jnp.arange(c)
            ki = ik * c + jnp.arange(c)
            if causal:
                mask = qi[:, None] >= ki[None, :]
            else:
                mask = jnp.broadcast_to(ki[None, :] < s_orig, (c, c))
            sc = jnp.where(mask[None, None, None], sc, -1e30)
            m_c = jnp.max(sc, axis=-1)
            m_n = jnp.maximum(m_p, m_c)
            p = jnp.exp(sc - m_n[..., None])
            alpha = jnp.exp(m_p - m_n)
            l_n = alpha * l_p + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqm,bmkd->bkgqd", p, vs)
            return (m_n, l_n, acc), None

        init = (jnp.full((b, kvh, g, c), -1e30, jnp.float32),
                jnp.zeros((b, kvh, g, c), jnp.float32),
                jnp.zeros((b, kvh, g, c, d), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]    # (b,kv,g,c,d)
        return out.transpose(0, 3, 1, 2, 4)               # (b,c,kv,g,d)

    blocks = jax.lax.map(q_block, jnp.arange(nq))         # (nq,b,c,kv,g,d)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, d)
    return out[:, :s_orig].astype(q.dtype)


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array, window: int,
                    chunk: int = 512) -> jax.Array:
    """Causal sliding-window attention with true O(s * window) flops: each
    q chunk attends to a static-length [window + chunk] kv slice."""
    b, s_orig, h, d = q.shape
    kvh = k.shape[2]
    c = min(chunk, s_orig)
    pad = (-s_orig) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    qg, g = _gqa_shape(q, kvh)
    scale = d ** -0.5
    nq = s // c
    w = min(window, s)
    span = w + c  # static kv extent per q chunk

    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))

    def q_block(iq):
        qs = jax.lax.dynamic_slice_in_dim(qg, iq * c, c, axis=1)
        qs = qs.astype(jnp.float32) * scale
        start = iq * c  # in padded coords the window starts here
        ks = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        sc = jnp.einsum("bqkgd,bmkd->bkgqm", qs, ks.astype(jnp.float32))
        qi = iq * c + jnp.arange(c)                       # global q pos
        ki = iq * c - w + jnp.arange(span)                # global kv pos
        mask = (qi[:, None] >= ki[None, :]) & \
               (qi[:, None] - ki[None, :] <= w) & (ki[None, :] >= 0)
        sc = jnp.where(mask[None, None, None], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bkgqm,bmkd->bkgqd", p, vs.astype(jnp.float32))
        return o.transpose(0, 3, 1, 2, 4)

    blocks = jax.lax.map(q_block, jnp.arange(nq))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, d)
    return out[:, :s_orig].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, window: int = 0) -> jax.Array:
    """Single-token attention against a cache.

    q (b,1,h,d); k/v_cache (b,smax,kv,d); length (b,) current cache fill.
    On TPU this is a single fused kernel whose score rows never leave VMEM
    (tagged below for the kernel-adjusted roofline; the XLA lowering
    materializes (b,h,smax) score/probability buffers — measured to be the
    dominant decode-cell HBM term, 20x the cache reads at batch 128).
    """
    with jax.named_scope("vmem_kernel_decode_attention"):
        return _decode_attention(q, k_cache, v_cache, length, window)


def _decode_attention(q, k_cache, v_cache, length, window=0) -> jax.Array:
    b, _, h, d = q.shape
    smax = k_cache.shape[1]
    kvh = k_cache.shape[2]
    qg, g = _gqa_shape(q, kvh)                            # (b,1,kv,g,d)
    scale = d ** -0.5
    # keep the cache operands in their storage dtype and accumulate in f32
    # via preferred_element_type: an explicit astype would materialize an
    # f32 copy of the whole KV cache per layer (native mixed-dtype dots on
    # TPU; also what keeps the CPU dry-run's decode traffic honest)
    sc = jnp.einsum("bqkgd,bmkd->bkgqm", qg * scale, k_cache,
                    preferred_element_type=jnp.float32)   # (b,kv,g,1,smax)
    pos = jnp.arange(smax)
    valid = pos[None, :] < length[:, None]                # (b, smax)
    if window:
        valid &= pos[None, :] >= (length[:, None] - window)
    sc = jnp.where(valid[:, None, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqm,bmkd->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, d).astype(q.dtype)


def rowwise_cache_update(cache: jax.Array, new: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """Write `new` (b, 1, ...) into `cache` (b, smax, ...) at per-row
    positions `lengths` (b,) — each row of a decode batch may sit at a
    different sequence offset (continuous batching)."""
    if new.ndim == 4:
        # (b, 1, kv, hd) fresh KV arrives reshaped off a column-parallel
        # projection; pin the sharding to the kv-heads dim (or replicated
        # when it doesn't divide) BEFORE the scatter — same CPU-SPMD
        # miscompile class as the rotate-half in apply_rope.
        new = hint(new, "batch", None, "kv_heads", None)
    def upd(c, x, l):
        return jax.lax.dynamic_update_slice_in_dim(c, x, l, axis=0)
    return jax.vmap(upd)(cache, new.astype(cache.dtype), lengths)


def cache_lengths(cache: dict, batch: int) -> jax.Array:
    """Normalize cache["length"] — scalar (lock-step) or (b,) (per-slot) —
    to a per-row (b,) int32 vector."""
    return jnp.broadcast_to(cache["length"], (batch,)).astype(jnp.int32)


def last_valid_slice(h: jax.Array, true_len: jax.Array | None) -> jax.Array:
    """h (b, s, d) -> (b, 1, d) hidden state of the last *valid* position.

    With right-padded prompts (serving buckets) the last real token of row
    i is at true_len[i] - 1, not at s - 1."""
    if true_len is None:
        return h[:, -1:]
    idx = jnp.clip(true_len - 1, 0, h.shape[1] - 1)
    return jnp.take_along_axis(h, idx[:, None, None], axis=1)


def tail_window(x: jax.Array, true_len: jax.Array | None, width: int
                ) -> jax.Array:
    """Last `width` valid steps of x (b, s, ch) -> (b, width, ch).

    Rows shorter than `width` are zero-filled on the left, matching what a
    causal conv state would have seen."""
    if true_len is None:
        return x[:, -width:]
    xp = jnp.pad(x, ((0, 0), (width, 0), (0, 0)))

    def row(xr, t):
        return jax.lax.dynamic_slice_in_dim(xr, t, width, axis=0)

    return jax.vmap(row)(xp, jnp.clip(true_len, 0, x.shape[1]))


def prefill_length(true_len: jax.Array | None, s: int) -> jax.Array:
    """Cache "length" entry after prefilling s tokens: per-row (b,) when a
    true_len vector is given (mixed-length serving), scalar otherwise."""
    if true_len is None:
        return jnp.asarray(s, jnp.int32)
    return true_len.astype(jnp.int32)


def valid_mask(true_len: jax.Array | None, b: int, s: int
               ) -> jax.Array | None:
    """(b, s) float mask of valid (non-pad) positions, or None."""
    if true_len is None:
        return None
    return (jnp.arange(s)[None, :] < true_len[:, None]).astype(jnp.float32)


def attention(q, k, v, impl: str = "chunked", chunk: int = 512,
              causal: bool = True, window: int = 0,
              policy: str | None = None) -> jax.Array:
    """Dispatch.  "chunked" = blockwise flash-style custom-VJP attention
    (models/attention.py): O(chunk*s) fwd AND bwd memory — the lax.scan
    variants in this file are kept as test oracles only.

    For impl="flash", the kernel-dispatch `policy` (kernels/dispatch.py)
    decides Pallas kernel vs the XLA blockwise twin: "xla" (and "auto"
    off-TPU) falls back to blockwise_attention, "pallas" forces the kernel
    (interpret mode off-TPU).
    """
    from repro.models.attention import blockwise_attention
    if window:
        return blockwise_attention(q, k, v, chunk, True, window)
    if impl == "naive":
        return naive_attention(q, k, v, causal)
    if impl == "chunked":
        return blockwise_attention(q, k, v, chunk, causal, 0)
    if impl == "flash":
        from repro.kernels import dispatch
        b, s, h, d = q.shape
        if not dispatch.use_pallas_attention(policy, seq=s, head_dim=d):
            return blockwise_attention(q, k, v, chunk, causal, 0)
        from repro.kernels import ops as kops
        kvh = k.shape[2]
        g = h // kvh
        ke = jnp.repeat(k, g, axis=2) if g > 1 else k
        ve = jnp.repeat(v, g, axis=2) if g > 1 else v
        qs = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        ks = ke.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        vs = ve.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        o = kops.flash_attention(qs, ks, vs, causal=causal)
        return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    return chunked_attention(q, k, v, chunk, causal)


# --- MLP ----------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, spec: MultSpec | None) -> jax.Array:
    gate = AL.gemm(x, w_gate, spec)
    up = AL.gemm(x, w_up, spec)
    return AL.gemm(jax.nn.silu(gate) * up, w_down, spec)


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up, w_down: jax.Array, b_down,
             spec: MultSpec | None) -> jax.Array:
    h = AL.dense(x, w_up, b_up, spec)
    return AL.dense(jax.nn.gelu(h), w_down, b_down, spec)


# --- losses -------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy, f32.  logits (..., v), labels (...)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def maybe_remat(fn, enable: bool):
    if not enable:
        return fn
    return jax.checkpoint(fn,
                          policy=jax.checkpoint_policies.nothing_saveable)
