"""CNNs for the paper's own evaluation (VGG16/19, ResNet50/152) plus small
trainable variants for the accuracy-drop calibration experiments.

NHWC, conv via repro.approx.layers.conv2d (im2col + approximate GEMM when a
multiplier spec is active — exactly how the NVDLA-style accelerator maps
conv onto its MAC array).  BN is folded (inference-style affine), matching
post-training int8 deployment.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.approx import layers as AL
from repro.models import common as C

Params = dict[str, Any]

VGG_CFG = {
    "vgg16": [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"],
    "vgg19": [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
              512, 512, 512, 512, "M", 512, 512, 512, 512, "M"],
    "vgg_mini": [16, "M", 32, "M", 64, "M"],   # for 32x32 calibration runs
}


def init_vgg(arch: str, key: jax.Array, n_classes: int = 1000,
             in_ch: int = 3, image: int = 224, dtype=jnp.float32) -> Params:
    cfg = VGG_CFG[arch]
    params: Params = {"convs": [], "fcs": []}
    c_in, hw = in_ch, image
    keys = C.split_keys(key, len(cfg) + 3)
    ki = 0
    for v in cfg:
        if v == "M":
            hw //= 2
            continue
        w = (jax.random.normal(keys[ki], (3, 3, c_in, v), jnp.float32)
             * (9 * c_in) ** -0.5).astype(dtype)
        params["convs"].append({"w": w, "b": jnp.zeros((v,), dtype)})
        c_in = v
        ki += 1
    flat = c_in * hw * hw
    dims = ([4096, 4096, n_classes] if arch != "vgg_mini"
            else [128, n_classes])
    for dout in dims:
        params["fcs"].append({
            "w": C.dense_init(keys[ki], flat, dout, dtype),
            "b": jnp.zeros((dout,), dtype)})
        flat = dout
        ki += 1
    return params


def vgg_forward(params: Params, x: jax.Array, arch: str, spec=None
                ) -> jax.Array:
    cfg = VGG_CFG[arch]
    ci = 0
    for v in cfg:
        if v == "M":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
            continue
        p = params["convs"][ci]
        x = jax.nn.relu(AL.conv2d(x, p["w"], 1, 1, spec) + p["b"])
        ci += 1
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["fcs"]):
        x = AL.dense(x, p["w"], p["b"], spec)
        if i < len(params["fcs"]) - 1:
            x = jax.nn.relu(x)
    return x


RESNET_BLOCKS = {"resnet50": [3, 4, 6, 3], "resnet152": [3, 8, 36, 3],
                 "resnet_mini": [1, 1]}


def init_resnet(arch: str, key: jax.Array, n_classes: int = 1000,
                dtype=jnp.float32) -> Params:
    blocks = RESNET_BLOCKS[arch]
    mini = arch == "resnet_mini"
    widths = [16, 32] if mini else [64, 128, 256, 512]
    expansion = 2 if mini else 4
    keys = iter(C.split_keys(key, 4 + sum(blocks) * 4 + len(blocks)))

    def conv(cin, cout, k):
        return {"w": (jax.random.normal(next(keys), (k, k, cin, cout),
                                        jnp.float32)
                      * (k * k * cin) ** -0.5).astype(dtype),
                "s": jnp.ones((cout,), dtype), "b": jnp.zeros((cout,), dtype)}

    params: Params = {"stem": conv(3, widths[0], 3 if mini else 7),
                      "stages": []}
    c_in = widths[0]
    for stage, (nblk, w) in enumerate(zip(blocks, widths)):
        stage_p = []
        for b in range(nblk):
            blk = {"c1": conv(c_in, w, 1), "c2": conv(w, w, 3),
                   "c3": conv(w, w * expansion, 1)}
            if b == 0:
                blk["proj"] = conv(c_in, w * expansion, 1)
            stage_p.append(blk)
            c_in = w * expansion
        params["stages"].append(stage_p)
    params["fc"] = {"w": C.dense_init(next(keys), c_in, n_classes, dtype),
                    "b": jnp.zeros((n_classes,), dtype)}
    return params


def _affine(x, p):
    return x * p["s"] + p["b"]


def resnet_forward(params: Params, x: jax.Array, arch: str, spec=None
                   ) -> jax.Array:
    mini = arch == "resnet_mini"
    stem = params["stem"]
    x = AL.conv2d(x, stem["w"], 1 if mini else 2, 1 if mini else 3, spec)
    x = jax.nn.relu(_affine(x, stem))
    if not mini:
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                  (1, 2, 2, 1), "SAME")
    for stage_i, stage in enumerate(params["stages"]):
        for b_i, blk in enumerate(stage):
            stride = 2 if (stage_i > 0 and b_i == 0) else 1
            y = jax.nn.relu(_affine(
                AL.conv2d(x, blk["c1"]["w"], stride, 0, spec), blk["c1"]))
            y = jax.nn.relu(_affine(
                AL.conv2d(y, blk["c2"]["w"], 1, 1, spec), blk["c2"]))
            y = _affine(AL.conv2d(y, blk["c3"]["w"], 1, 0, spec), blk["c3"])
            if "proj" in blk:
                x = _affine(AL.conv2d(x, blk["proj"]["w"], stride, 0, spec),
                            blk["proj"])
            x = jax.nn.relu(x + y)
    x = x.mean(axis=(1, 2))
    return AL.dense(x, params["fc"]["w"], params["fc"]["b"], spec)
