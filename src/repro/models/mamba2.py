"""Mamba-2 (SSD — state-space duality) blocks, chunked-parallel training form
and O(1)-state decode (arXiv:2405.21060).

Input/output projections route through the approximate GEMM (they map to the
accelerator's MAC array); the SSD recurrence itself is f32 elementwise/state
math (vector unit — exact, see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.approx import layers as AL
from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.sharding.ctx import hint

Params = dict[str, Any]
NGROUPS = 1

#: Serving weight-plane cache eligibility (api.prepare_params): only the
#: in/out projections and the head run through the approximate GEMM; conv
#: taps, SSD parameters (A_log/D/dt_bias), and norms are consumed directly
#: by vector-unit math and must stay raw arrays.
PREPARED_GEMM_WEIGHTS = frozenset({"in_proj", "out_proj", "lm_head"})


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = cfg.ssm_heads or (d_in // cfg.ssm_head_dim)
    p = d_in // nheads
    n = cfg.ssm_state
    conv_ch = d_in + 2 * NGROUPS * n
    return d_in, nheads, p, n, conv_ch


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_in, h, p, n, conv_ch = _dims(cfg)
    L = cfg.n_layers
    ks = C.split_keys(key, 6)
    proj_out = 2 * d_in + 2 * NGROUPS * n + h
    layers = {
        "ln": jnp.zeros((L, d), dtype),
        "in_proj": C.stacked_dense_init(ks[0], L, d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (L, cfg.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((L, conv_ch), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.linspace(1.0, 16.0, h), (L, h)).astype(jnp.float32)),
        "D": jnp.ones((L, h), jnp.float32),
        "dt_bias": jnp.zeros((L, h), jnp.float32),
        "norm_gate": jnp.zeros((L, d_in), dtype),
        "out_proj": C.stacked_dense_init(ks[2], L, d_in, d, dtype),
    }
    return {
        "embed": (jax.random.normal(ks[3], (cfg.vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "layers": layers,
        "final_norm": jnp.zeros((d,), dtype),
        "lm_head": C.dense_init(ks[4], d, cfg.vocab, dtype, scale=0.02),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., l) -> (..., l, l) with out[i, j] = sum x[j+1..i], -inf above
    the diagonal."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(x: jax.Array, dtA: jax.Array, B: jax.Array, Cm: jax.Array,
             chunk: int, init_state: jax.Array | None = None):
    """Chunked SSD.

    x (b, s, h, p); dtA (b, s, h) [= dt * A, negative]; B, Cm (b, s, g, n).
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g = B.shape[2]
    n = B.shape[3]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    c = s // q
    hg = h // g

    xc = x.reshape(b, c, q, h, p)
    Ac = dtA.reshape(b, c, q, h).transpose(0, 3, 1, 2)       # (b,h,c,q)
    Bc = B.reshape(b, c, q, g, n)
    Cc = Cm.reshape(b, c, q, g, n)
    A_cum = jnp.cumsum(Ac, axis=-1)                          # (b,h,c,q)

    # --- intra-chunk (diagonal) term
    Lmat = jnp.exp(_segsum(Ac))                              # (b,h,c,q,q)
    Lg = Lmat.reshape(b, g, hg, c, q, q)
    xg = xc.reshape(b, c, q, g, hg, p)
    y_diag = jnp.einsum("bclgn,bcsgn,bghcls,bcsghp->bclghp",
                        Cc, Bc, Lg, xg)

    # --- chunk states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)          # (b,h,c,q)
    dsg = decay_states.reshape(b, g, hg, c, q)
    states = jnp.einsum("bclgn,bghcl,bclghp->bcghpn", Bc, dsg, xg)

    # --- inter-chunk recurrence
    chunk_decay = jnp.exp(A_cum[..., -1])                    # (b,h,c)
    cdg = chunk_decay.reshape(b, g, hg, c).transpose(3, 0, 1, 2)
    states_t = states.transpose(1, 0, 2, 3, 4, 5)            # (c,b,g,hg,p,n)
    s0 = (init_state.reshape(b, g, hg, p, n) if init_state is not None
          else jnp.zeros((b, g, hg, p, n), jnp.float32))

    def step(prev, inp):
        dec, st = inp
        new = prev * dec[..., None, None] + st
        return new, prev

    final, prev_states = jax.lax.scan(step, s0, (cdg, states_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)    # (b,c,g,hg,p,n)

    # --- inter-chunk (off-diagonal) output
    out_decay = jnp.exp(A_cum).reshape(b, g, hg, c, q)
    y_off = jnp.einsum("bclgn,bcghpn,bghcl->bclghp", Cc, prev_states,
                       out_decay)

    y = (y_diag + y_off).reshape(b, c, q, h, p).reshape(b, s, h, p)
    return y, final.reshape(b, h, p, n)


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x (b, s, ch), w (width, ch)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):  # width is 4: unrolled taps
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return (out + bias.astype(jnp.float32)).astype(x.dtype)


def _split_proj(z: jax.Array, cfg: ModelConfig):
    d_in, h, p, n, _ = _dims(cfg)
    gn = NGROUPS * n
    zg, xin, Bm, Cm, dt = jnp.split(
        z, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return zg, xin, Bm, Cm, dt


def block(hstate, lp, cfg: ModelConfig, spec, init_state=None,
          true_len=None):
    """One mamba2 block over a full sequence.  Returns (h, final_ssm_state,
    conv_tail).

    `true_len` (b,) marks right-padded rows: pad positions get dt = 0,
    which makes their state update the identity (decay exp(0) = 1, input
    contribution 0), so the final state is exactly the state after the
    last valid token; the conv tail is sliced at the valid boundary."""
    b, s, d = hstate.shape
    d_in, h, p, n, conv_ch = _dims(cfg)
    x = C.rmsnorm(hstate, lp["ln"])
    # gather the column-parallel projection before slicing it up: the
    # five sub-projections and the conv concat below cut across shard
    # boundaries, which XLA's CPU SPMD partitioner miscompiles (same
    # class as the rotate-half fix in common.apply_rope)
    z = hint(AL.gemm(x, lp["in_proj"], spec), "batch", None, None)
    zg, xin, Bm, Cm, dt = _split_proj(z, cfg)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, lp["conv_w"], lp["conv_b"]))
    xin, Bm, Cm = jnp.split(conv_out, [d_in, d_in + NGROUPS * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])   # (b,s,h)
    mask = C.valid_mask(true_len, b, s)
    if mask is not None:
        dt = dt * mask[:, :, None]
    A = -jnp.exp(lp["A_log"])                                      # (h,)
    dtA = dt * A
    xh = xin.reshape(b, s, h, p).astype(jnp.float32)
    Bh = Bm.reshape(b, s, NGROUPS, n).astype(jnp.float32)
    Ch = Cm.reshape(b, s, NGROUPS, n).astype(jnp.float32)

    y, final_state = ssd_scan(xh * dt[..., None], dtA, Bh, Ch,
                              cfg.ssd_chunk, init_state)
    y = y + lp["D"][None, None, :, None] * xh
    y = y.reshape(b, s, d_in).astype(hstate.dtype)
    y = C.rmsnorm(y * jax.nn.silu(zg), lp["norm_gate"])
    out = AL.gemm(y, lp["out_proj"], spec)
    conv_tail = C.tail_window(conv_in, true_len, cfg.conv_width - 1)
    return hstate + out, final_state, conv_tail


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            **_) -> tuple:
    hcur = AL.embed(tokens, params["embed"])
    hcur = hint(hcur, "batch", None, None)

    def scan_block(hh, lp):
        out, _, _ = C.maybe_remat(
            lambda a, b_: block(a, b_, cfg, spec), cfg.remat)(hh, lp)
        return out, None

    hcur, _ = jax.lax.scan(scan_block, hcur, params["layers"])
    hcur = C.rmsnorm(hcur, params["final_norm"])
    logits = AL.gemm(hcur, params["lm_head"], spec)
    return hint(logits, "batch", None, "vocab"), 0.0


# --- serving ----------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None
               ) -> dict:
    """SSM decode cache: per-layer conv tail + SSD state (O(1) in seq)."""
    d_in, h, p, n, conv_ch = _dims(cfg)
    L = cfg.n_layers
    return {
        "conv": jnp.zeros((L, batch, cfg.conv_width - 1, conv_ch),
                          jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((L, batch, h, p, n), jnp.float32),
        "length": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                cfg: ModelConfig, spec=None, **_) -> tuple:
    b = tokens.shape[0]
    d_in, h, p, n, conv_ch = _dims(cfg)
    hcur = AL.embed(tokens, params["embed"])          # (b, 1, d)

    def scan_block(hh, sp):
        lp, conv_st, ssm_st = sp
        x = C.rmsnorm(hh, lp["ln"])
        # gathered before the sub-projection splits; see block()
        z = hint(AL.gemm(x, lp["in_proj"], spec), "batch", None, None)
        zg, xin, Bm, Cm, dt = _split_proj(z, cfg)
        conv_in = jnp.concatenate([xin, Bm, Cm], -1)  # (b, 1, ch)
        window = jnp.concatenate([conv_st, conv_in], axis=1)  # (b, w, ch)
        conv_out = jax.nn.silu(
            (window.astype(jnp.float32) *
             lp["conv_w"].astype(jnp.float32)[None]).sum(1)
            + lp["conv_b"].astype(jnp.float32))[:, None, :].astype(hh.dtype)
        xin, Bm, Cm = jnp.split(conv_out, [d_in, d_in + NGROUPS * n], -1)
        dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["A_log"])
        da = jnp.exp(dt * A)                           # (b, h)
        xh = xin.reshape(b, h, p).astype(jnp.float32)
        Bh = jnp.repeat(Bm.reshape(b, NGROUPS, n), h // NGROUPS, axis=1)
        Ch = jnp.repeat(Cm.reshape(b, NGROUPS, n), h // NGROUPS, axis=1)
        new_state = ssm_st * da[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt, xh, Bh)
        y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch) + lp["D"][:, None] * xh
        y = y.reshape(b, 1, d_in).astype(hh.dtype)
        y = C.rmsnorm(y * jax.nn.silu(zg), lp["norm_gate"])
        out = AL.gemm(y, lp["out_proj"], spec)
        return hh + out, (window[:, 1:], new_state)

    hcur, (conv_new, ssm_new) = jax.lax.scan(
        scan_block, hcur,
        (params["layers"], cache["conv"], cache["ssm"]))
    hcur = C.rmsnorm(hcur, params["final_norm"])
    logits = AL.gemm(hcur, params["lm_head"], spec)
    return logits, {"conv": conv_new, "ssm": ssm_new,
                    "length": cache["length"] + 1}


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            max_len: int | None = None, true_len=None, **_) -> tuple:
    """Run the chunked form over the prompt, carrying states into a cache.

    With `true_len` (b,), right-padded rows carry exact per-row states
    (pads are identity updates in the SSD recurrence, see `block`)."""
    b, s = tokens.shape
    hcur = AL.embed(tokens, params["embed"])

    def scan_block(hh, lp):
        out, final_state, conv_tail = block(hh, lp, cfg, spec,
                                            true_len=true_len)
        return out, (final_state, conv_tail)

    hcur, (ssm_states, conv_tails) = jax.lax.scan(scan_block, hcur,
                                                  params["layers"])
    hcur = C.rmsnorm(C.last_valid_slice(hcur, true_len),
                     params["final_norm"])
    logits = AL.gemm(hcur, params["lm_head"], spec)[:, 0]
    cache = {"conv": conv_tails.astype(jnp.dtype(cfg.dtype)),
             "ssm": ssm_states,
             "length": C.prefill_length(true_len, s)}
    return logits, cache
