"""Unified model API: family dispatch for init/forward/loss/serve.

Every family module exposes init_params / forward / (prefill) / decode_step /
init_cache with the same signatures; training and serving steps (and the
dry-run) go through this façade only.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.approx import gemm as gemm_mod
from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models import encdec, mamba2, rglru, transformer

Params = dict[str, Any]

_FAMILIES = {
    "lm": transformer,
    "ssm": mamba2,
    "hybrid": rglru,
    "encdec": encdec,
}


def family_module(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def make_spec(cfg: ModelConfig,
              mult: str | None = None) -> gemm_mod.MultSpec | None:
    """Resolve the config's multiplier AND its kernel-dispatch policy.

    The policy rides on the spec (static pytree field), so every model /
    train / serve path that threads a spec automatically dispatches GEMMs
    per `cfg.kernel_policy` — no separate plumbing.

    `mult` overrides `cfg.mult` (same names, same policy resolution) —
    this is how the serving engine materializes its degradation-tier
    ladder from one config without forging config copies.
    """
    name = cfg.mult if mult is None else mult
    if name in ("exact", "", None):
        return None
    spec = gemm_mod.spec_from_name(name)
    return spec.with_policy(cfg.kernel_policy)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    return family_module(cfg).init_params(cfg, key)


def prepare_params(params: Params, cfg: ModelConfig,
                   spec: gemm_mod.MultSpec | None = None,
                   mesh=None) -> Params:
    """Build the serving-time weight-plane cache over a param tree.

    Every leaf named in the family's PREPARED_GEMM_WEIGHTS allowlist (the
    weights consumed exclusively through the approximate GEMM layer) is
    replaced by a `PreparedWeight`: per-output-channel int8 quantization
    plus — for the XLA fallback — the per-rank table-mapped weight planes,
    computed ONCE per (weight, spec) instead of on every decode step.
    Forward/decode/prefill through the prepared tree are bit-identical to
    the raw tree.

    `spec=None` resolves via `make_spec(cfg)`.  Identity for exact specs.
    Serving only — training re-quantizes live (weights change each step)
    and differentiation through prepared leaves raises.

    `mesh` commits the result onto the device mesh under the tensor-
    parallel rules of sharding/rules.py: each PreparedWeight's quantized
    plane(s) land PER SHARD (a column-parallel weight's wq/sw/planes live
    only where its output slice lives) instead of replicated on device 0
    — the serving engine passes its mesh here.
    """
    if spec is None:
        spec = make_spec(cfg)
    prepared = params
    if spec is not None and not spec.is_exact:
        from repro.approx import quant
        names = getattr(family_module(cfg), "PREPARED_GEMM_WEIGHTS",
                        frozenset())

        def prep(path, leaf):
            if gemm_mod.is_prepared(leaf):
                return leaf  # idempotent: re-preparing is a no-op
            if quant.leaf_name(path) not in names:
                return leaf
            if not hasattr(leaf, "ndim") or leaf.ndim < 2 or \
                    not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            return gemm_mod.prepare_weight(leaf, spec)

        # is_leaf keeps tree_map from descending INTO PreparedWeight
        # pytree nodes (whose w/sw fields would otherwise be re-wrapped
        # under the enclosing leaf name)
        prepared = jax.tree_util.tree_map_with_path(
            prep, params, is_leaf=gemm_mod.is_prepared)
    if mesh is not None:
        from repro.sharding import rules
        shardings = rules.param_shardings(prepared, mesh,
                                          fsdp=rules.should_fsdp(cfg))
        prepared = jax.device_put(prepared, shardings)
    return prepared


def forward(params: Params, batch: dict, cfg: ModelConfig, spec=None
            ) -> tuple[jax.Array, jax.Array]:
    """batch: {"tokens": (b, s)} (+ "frames" for encdec, "img" for vlm).
    Returns (logits (b, s, v), aux_loss)."""
    mod = family_module(cfg)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = batch.get("frames")
    if cfg.cross_every:
        kwargs["img_embeds"] = batch.get("img")
    return mod.forward(params, batch["tokens"], cfg, spec, **kwargs)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig, spec=None
            ) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy (teacher-forced for encdec)."""
    logits, aux = forward(params, batch, cfg, spec)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
        mask = mask.at[:, -1].set(0.0)
    ce = C.softmax_xent(logits, labels, mask)
    total = ce + 0.01 * aux
    return total, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return family_module(cfg).init_cache(cfg, batch, max_len)


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                cfg: ModelConfig, spec=None, extras: dict | None = None
                ) -> tuple[jax.Array, dict]:
    mod = family_module(cfg)
    kwargs = dict(extras or {})
    return mod.decode_step(params, cache, tokens, cfg, spec, **kwargs)


def chunk_step(params: Params, cache: dict, tokens: jax.Array,
               cfg: ModelConfig, spec=None, extras: dict | None = None,
               n_valid: jax.Array | None = None
               ) -> tuple[jax.Array, dict]:
    """Advance a single-request decode cache by up to `tokens.shape[1]`
    tokens — the chunked-prefill primitive.

    Scans `decode_step` over the chunk so every family works unchanged
    (ring buffers, SSM states, cross-attention all see exactly the ops a
    token-by-token decode would run).  `n_valid` (b,) masks the tail of a
    right-padded final chunk: steps at index >= n_valid leave the cache
    untouched, so per-row lengths stay exact.  Returns
    (logits (b, c, vocab) — position i holds the logits AFTER consuming
    tokens[:, i] — and the advanced cache).

    Restricted to b == 1: the partial-prefill workspace is per-request
    (batched chunking would need per-leaf batch-axis masking; the engine
    interleaves requests across ticks instead).
    """
    b, c = tokens.shape
    if b != 1:
        raise ValueError(f"chunk_step is single-request (got batch {b})")
    if n_valid is None:
        n_valid = jnp.full((b,), c, jnp.int32)

    def step(carry, i):
        logits, new = decode_step(
            params, carry, jax.lax.dynamic_slice_in_dim(tokens, i, 1, 1),
            cfg, spec=spec, extras=extras)
        valid = i < n_valid[0]
        out = jax.tree_util.tree_map(
            lambda n, o: jnp.where(valid, n, o), new, carry)
        return out, logits[:, -1]

    cache, logits = jax.lax.scan(step, cache, jnp.arange(c))
    return jnp.moveaxis(logits, 0, 1), cache


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, spec=None,
            max_len: int | None = None, extras: dict | None = None,
            true_len: jax.Array | None = None) -> tuple:
    """Uniform jit-compatible prefill for every family.

    `true_len` (b,) supports right-padded prompts (serving buckets): the
    returned logits are those of each row's last valid token and the cache
    carries per-row lengths, so mixed-length requests can share one decode
    batch."""
    mod = family_module(cfg)
    kwargs = dict(extras or {})
    return mod.prefill(params, tokens, cfg, spec, max_len=max_len,
                       true_len=true_len, **kwargs)


def param_count(params: Params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree_util.tree_leaves(params))
