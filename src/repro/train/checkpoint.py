"""Sharded, async, atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/proc_<k>.msgpack.zst  +  <dir>/step_<N>/manifest.json
(the .zst suffix is historical; the actual codec — zstd when the optional
`zstandard` package is installed, stdlib zlib otherwise — is recorded in the
blob header and manifest, and restore follows the header)

* atomic: written to `step_<N>.tmp/`, fsync'd, renamed — a crash never
  leaves a half-checkpoint that restore would pick up;
* sharded: each process saves only its addressable shards (single-process
  containers write one file; the format is multihost from day one);
* verified: per-leaf CRC32 checked on restore; corrupt checkpoints are
  skipped and the previous one restores instead;
* elastic: leaves are stored as full logical arrays + the manifest records
  logical shapes only — restore re-shards onto *any* mesh via device_put
  with the target NamedShardings (scale up/down across restarts);
* async: serialization runs on a background thread off the critical path
  (the step loop only pays for the device->host copy).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
import zlib
from typing import Any

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: stdlib zlib is the fallback codec
    zstandard = None

# v3 adds the top-level "codec" header field ("zstd" | "zlib"); v2 blobs
# (no field) are implicitly zstd and still restore.
_FORMAT_VERSION = 3


def default_codec() -> str:
    return "zstd" if zstandard is not None else "zlib"


def _compressor(codec: str):
    """One compression callable per _pack() call, reused across leaves."""
    if codec == "zstd":
        return zstandard.ZstdCompressor(level=3).compress
    if codec == "zlib":
        return lambda raw: zlib.compress(raw, 6)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _decompressor(codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise IOError("checkpoint was written with zstd but the "
                          "'zstandard' package is not installed")
        return zstandard.ZstdDecompressor().decompress
    if codec == "zlib":
        return zlib.decompress
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten(state: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def _pack(flat: dict[str, np.ndarray], codec: str | None = None) -> bytes:
    codec = codec or default_codec()
    compress = _compressor(codec)
    entries = {}
    for key, arr in flat.items():
        raw = arr.tobytes()
        entries[key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "crc": zlib.crc32(raw), "data": compress(raw),
        }
    return msgpack.packb({"version": _FORMAT_VERSION, "codec": codec,
                          "entries": entries}, use_bin_type=True)


def _unpack(blob: bytes) -> dict[str, np.ndarray]:
    doc = msgpack.unpackb(blob, raw=False)
    codec = doc.get("codec", "zstd")   # pre-v3 blobs are always zstd
    decompress = _decompressor(codec)
    out = {}
    for key, e in doc["entries"].items():
        raw = decompress(e["data"])
        if zlib.crc32(raw) != e["crc"]:
            raise IOError(f"checksum mismatch for {key}")
        out[key] = np.frombuffer(raw, dtype=np.dtype(e["dtype"])).reshape(
            e["shape"])
    return out


@dataclasses.dataclass
class CheckpointManager:
    directory: str | pathlib.Path
    keep_last: int = 3
    process_index: int = 0
    process_count: int = 1

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # --- save -----------------------------------------------------------
    def save(self, state: Any, step: int, blocking: bool = True,
             extra_manifest: dict | None = None) -> None:
        self.wait()
        flat = _flatten(jax.device_get(state))

        def work():
            tmp = self.directory / f"step_{step:08d}.tmp"
            final = self.directory / f"step_{step:08d}"
            tmp.mkdir(parents=True, exist_ok=True)
            (tmp / f"proc_{self.process_index}.msgpack.zst").write_bytes(
                _pack(flat))
            manifest = {
                "step": step, "version": _FORMAT_VERSION,
                "codec": default_codec(),
                "process_count": self.process_count,
                "leaves": {k: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for k, v in flat.items()},
            }
            manifest.update(extra_manifest or {})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._prune()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)

    # --- restore ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.directory.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of `target` (a pytree or eval_shape
        tree).  With `shardings`, leaves are placed as sharded global arrays
        on the *current* mesh (elastic restore)."""
        candidates = self.all_steps() if step is None else [step]
        for s in reversed(candidates):
            try:
                blob = (self.directory / f"step_{s:08d}" /
                        f"proc_{self.process_index}.msgpack.zst").read_bytes()
                flat = _unpack(blob)
            except Exception as e:  # corrupt/truncated payloads of any kind
                print(f"[checkpoint] step {s} unusable "
                      f"({type(e).__name__}: {e}); trying older")
                continue
            paths = jax.tree_util.tree_flatten_with_path(target)[0]
            treedef = jax.tree_util.tree_structure(target)
            sh_leaves = (jax.tree_util.tree_leaves(shardings)
                         if shardings is not None else None)
            leaves = []
            for i, (path, leaf) in enumerate(paths):
                key = jax.tree_util.keystr(path)
                if key not in flat:
                    raise KeyError(f"checkpoint missing leaf {key}")
                arr = flat[key]
                want_dtype = np.dtype(leaf.dtype)
                if arr.dtype != want_dtype:
                    arr = arr.astype(want_dtype)
                if tuple(arr.shape) != tuple(leaf.shape):
                    raise ValueError(
                        f"shape mismatch for {key}: {arr.shape} vs "
                        f"{leaf.shape}")
                if sh_leaves is not None:
                    leaves.append(jax.device_put(arr, sh_leaves[i]))
                else:
                    leaves.append(jax.numpy.asarray(arr))
            return jax.tree_util.tree_unflatten(treedef, leaves), s
        raise FileNotFoundError(f"no restorable checkpoint in "
                                f"{self.directory}")
