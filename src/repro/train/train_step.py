"""Distributed train/serve step builders (pjit).

`make_train_step` produces a jit-compiled function whose in/out shardings
come from sharding/rules.py; inside, model code runs under the logical-rule
context so activation hints become GSPMD constraints.  Gradient accumulation
is a lax.scan over microbatches (the standard compute/communication-overlap
lever: per-microbatch backward matmuls overlap the previous microbatch's
gradient reduce-scatter under XLA's latency-hiding scheduler).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import api
from repro.sharding import ctx, rules
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class StepOptions:
    accum_steps: int = 1
    optimizer: str = "adamw"
    moment_dtype: str = "f32"
    lr: float = 3e-4
    total_steps: int = 10000
    warmup_steps: int = 100
    fsdp: bool | None = None      # None -> auto by model size
    param_dtype: str | None = None
    # "grad_of_scan": differentiate through the microbatch scan, so DP
    # gradient all-reduces fire ONCE per step instead of once per
    # microbatch ("no_sync" semantics).  Measured on grok-1 train_4k
    # (accum=8): collective bytes 1.9e15 -> see EXPERIMENTS.md §Perf.
    # "scan_of_grad" is the naive per-microbatch value_and_grad.
    accum_mode: str = "scan_of_grad"


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return {k: split(v) for k, v in batch.items()}


def make_train_fns(cfg: ModelConfig, options: StepOptions):
    """Returns (init_fn(rng)->state, step_fn(state, batch)->(state, metrics)).
    Both are plain functions; wrap with jit/shardings via make_train_step."""
    spec = api.make_spec(cfg)
    init_opt, update_opt = opt.make_optimizer(
        options.optimizer, lr=options.lr, total_steps=options.total_steps,
        warmup_steps=options.warmup_steps,
        **({"moment_dtype": options.moment_dtype}
           if options.optimizer == "adamw" else {}))

    def init_fn(rng):
        params = api.init_params(cfg, rng)
        return {"params": params, "opt": init_opt(params),
                "step": jnp.zeros((), jnp.int32)}

    def loss(params, mb):
        return api.loss_fn(params, mb, cfg, spec)

    def step_fn(state, batch):
        params = state["params"]
        if options.accum_steps > 1 and options.accum_mode == "grad_of_scan":
            mbs = _split_microbatches(batch, options.accum_steps)

            def total_loss(p):
                def micro(l_acc, mb):
                    l, _extras = loss(p, mb)
                    return l_acc + l, None
                lsum, _ = jax.lax.scan(micro, 0.0, mbs)
                return lsum / options.accum_steps

            lval, grads = jax.value_and_grad(total_loss)(params)
        elif options.accum_steps > 1:
            mbs = _split_microbatches(batch, options.accum_steps)

            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _extras), g = jax.value_and_grad(loss, has_aux=True)(
                    params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / options.accum_steps, grads)
            lval = lsum / options.accum_steps
        else:
            (lval, _extras), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
        new_params, new_opt = update_opt(params, grads, state["opt"])
        metrics = {"loss": lval, "gnorm": opt.global_norm(grads),
                   "step": state["step"] + 1}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return init_fn, step_fn


def state_shardings(cfg: ModelConfig, options: StepOptions, mesh: Mesh,
                    init_fn) -> Any:
    """NamedShardings for the full train state (params + optimizer)."""
    fsdp = options.fsdp if options.fsdp is not None else rules.should_fsdp(cfg)
    shapes = jax.eval_shape(init_fn, jax.random.key(0))

    def mk(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        if keys and keys[0] == "params":
            return NamedSharding(mesh, rules.param_pspec(
                path[1:], leaf.shape, mesh, fsdp))
        if keys and keys[0] == "opt":
            # moments mirror their parameter's sharding; strip the opt
            # wrapper levels ("m"/"v"/"f" + quantization internals)
            core = [p for p in path[1:]
                    if str(getattr(p, "key", "")) not in
                    ("m", "v", "f", "q", "scale", "row", "col", "full")]
            if keys[-1] in ("step",) or leaf.ndim == 0:
                return NamedSharding(mesh, P())
            pspec = rules.param_pspec(core, leaf.shape, mesh, fsdp)
            if len(pspec) > leaf.ndim or any(
                    ax is not None and leaf.shape[i] %
                    _axsize(mesh, ax) != 0
                    for i, ax in enumerate(list(pspec) + [None] *
                                           (leaf.ndim - len(pspec)))
                    if i < leaf.ndim):
                return NamedSharding(mesh, P())
            return NamedSharding(mesh, pspec)
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(mk, shapes)


def _axsize(mesh: Mesh, ax) -> int:
    import math
    axes = ax if isinstance(ax, tuple) else (ax,)
    return math.prod(mesh.shape[a] for a in axes)


def make_train_step(cfg: ModelConfig, options: StepOptions, mesh: Mesh,
                    donate: bool = True):
    """jit-compiled distributed train step + its state shardings."""
    init_fn, step_fn = make_train_fns(cfg, options)
    st_sh = state_shardings(cfg, options, mesh, init_fn)

    def wrapped(state, batch):
        with ctx.use_rules(mesh, rules.logical_rules(mesh)):
            return step_fn(state, batch)

    jit_kwargs: dict = dict(
        in_shardings=(st_sh, None), out_shardings=(st_sh, None))
    if donate:
        jit_kwargs["donate_argnums"] = (0,)
    step = jax.jit(wrapped, **jit_kwargs)
    return init_fn, step, st_sh


# --- serving steps -----------------------------------------------------------

_RESOLVE_SPEC = object()   # sentinel: None is a meaningful spec (exact)


def make_prefill_step(cfg: ModelConfig, mesh: Mesh,
                      max_len: int | None = None, spec=_RESOLVE_SPEC):
    """Jitted prefill, uniform for all four families (the serving engine's
    prefill phase).  `max_len` pads position-indexed caches (KV) up to the
    decode arena size; `true_len` supports right-padded prompt buckets.
    `spec` overrides the config-resolved multiplier spec (explicit None =
    exact) — the engine passes one per degradation tier."""
    if spec is _RESOLVE_SPEC:
        spec = api.make_spec(cfg)

    def wrapped(params, tokens, extras, true_len=None):
        with ctx.use_rules(mesh, rules.logical_rules(mesh)):
            return api.prefill(params, tokens, cfg, spec=spec,
                               max_len=max_len, extras=extras,
                               true_len=true_len)

    return jax.jit(wrapped)


def make_decode_step(cfg: ModelConfig, mesh: Mesh, donate: bool = True):
    spec = api.make_spec(cfg)

    def wrapped(params, cache, tokens, extras):
        with ctx.use_rules(mesh, rules.logical_rules(mesh)):
            return api.decode_step(params, cache, tokens, cfg, spec=spec,
                                   extras=extras)

    kwargs = {"donate_argnums": (1,)} if donate else {}
    return jax.jit(wrapped, **kwargs)
