"""Fault-tolerance utilities: preemption handling, straggler detection,
crash-restart supervision.

On a real multi-pod deployment the same hooks attach to the cluster
scheduler's SIGTERM and to cross-host heartbeats; everything here is
process-local and unit-testable, with the coordination points marked.
Both the watchdog and the restart supervisor are clock-injectable —
deterministic tests (and the fleet's virtual-tick clock) supply their
own `clock` / `sleep` instead of touching the wall clock.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag; the step loop checkpoints and exits
    cleanly at the next step boundary (standard TPU-preemption protocol)."""

    def __init__(self) -> None:
        self._requested = False
        self._installed = False

    def install(self) -> None:
        if self._installed:
            return

        def handler(signum, frame):
            self._requested = True

        signal.signal(signal.SIGTERM, handler)
        self._installed = True

    def request(self) -> None:  # for tests / manual triggering
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps (hosts, in multihost) whose duration exceeds
    `factor` x running median.  At fleet scale the mitigation is: log,
    alert, and — when a host trips repeatedly — trigger an elastic restart
    without it (restart path exercised in tests via CheckpointManager).

    `clock` is the timebase for step_start/step_end (default: the wall
    clock).  `fleet.Replica` injects its deterministic virtual-tick
    clock so straggler detection replays bit-identically from a chaos
    seed; tests inject counters."""
    factor: float = 3.0
    window: int = 50
    min_samples: int = 5
    on_straggler: Callable[[int, float, float], None] | None = None
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self._durations: list[float] = []
        self.flagged: list[int] = []
        self._t0: float | None = None

    def step_start(self) -> None:
        self._t0 = self.clock()

    def step_end(self, step: int) -> bool:
        assert self._t0 is not None, "step_start not called"
        dur = self.clock() - self._t0
        self._t0 = None
        return self.observe(step, dur)

    def observe(self, step: int, duration: float) -> bool:
        """Duration-injection variant (external timers, the fleet's
        virtual clock, chaos straggler schedules) — no clock reads."""
        is_straggler = False
        if len(self._durations) >= self.min_samples:
            med = statistics.median(self._durations[-self.window:])
            if duration > self.factor * med:
                is_straggler = True
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, duration, med)
        self._durations.append(duration)
        return is_straggler


def run_with_restarts(main: Callable[[int], int], max_restarts: int = 3,
                      sleep: Callable[[float], None] = time.sleep) -> int:
    """Supervisor: re-invoke `main(attempt)` after crashes.  `main` must be
    resumable (checkpoint-based).  Returns its final value.  Backoff is
    linear in the attempt number; `sleep` is injectable so deterministic
    tests (and simulated clocks) observe the backoff without waiting."""
    attempt = 0
    while True:
        try:
            return main(attempt)
        except Exception as e:  # noqa: BLE001 — supervisor boundary
            attempt += 1
            if attempt > max_restarts:
                raise
            print(f"[fault] attempt {attempt}/{max_restarts} restarting "
                  f"after: {type(e).__name__}: {e}")
            sleep(0.1 * attempt)
