"""Optimizers: AdamW (f32 / bf16 / block-int8 moments) and Adafactor.

Quantized optimizer states are a first-class memory lever at scale: grok-1's
314B params with f32 Adam moments cost 2.5 TB; int8 moments with block-128
scales cut that 4x (see EXPERIMENTS.md §Dry-run memory table).  All
quantize/dequantize math is per-block symmetric, error is bounded by the
block absmax, and the update path dequantizes -> updates in f32 ->
requantizes (no error feedback needed at beta1/beta2's smoothing levels).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 128


# --- block-quantized tensor state --------------------------------------------

@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("q", "scale"), meta_fields=("shape", "pad"))
@dataclasses.dataclass(frozen=True)
class QMoment:
    """int8 moment tensor with per-(last-dim-block) f32 scales.  shape/pad
    are static metadata so the state pytree stays jit-friendly."""
    q: jax.Array
    scale: jax.Array
    shape: tuple
    pad: int


def _quantize_block(x: jax.Array) -> QMoment:
    """q keeps the parameter's dimensionality (padded last dim) so the
    parameter sharding rules apply to the quantized moments unchanged."""
    shape = tuple(x.shape)
    if not shape:
        shape = (1,)
        x = x.reshape(1)
    last = shape[-1]
    pad = (-last) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    blocks = xp.reshape(*shape[:-1], -1, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QMoment(q.reshape(*shape[:-1], last + pad),
                   scale[..., 0].astype(jnp.float32), shape, pad)


def _dequantize_block(st: QMoment) -> jax.Array:
    blocks = st.q.reshape(*st.q.shape[:-1], -1, BLOCK).astype(jnp.float32)
    x = blocks * st.scale[..., None]
    x = x.reshape(*st.q.shape[:-1], -1)
    if st.pad:
        x = x[..., :-st.pad]
    return x.reshape(st.shape)


class _QTensor:
    """Marker-free storage helpers for moment tensors."""

    @staticmethod
    def store(x: jax.Array, mode: str):
        if mode == "f32":
            return x.astype(jnp.float32)
        if mode == "bf16":
            return x.astype(jnp.bfloat16)
        if mode == "int8":
            return _quantize_block(x)
        raise ValueError(mode)

    @staticmethod
    def load(st) -> jax.Array:
        if isinstance(st, QMoment):
            return _dequantize_block(st)
        return jnp.asarray(st, jnp.float32)


# --- schedules -----------------------------------------------------------------

def warmup_cosine(step: jax.Array, base_lr: float, warmup: int,
                  total: int, min_frac: float = 0.1) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


# --- AdamW ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    moment_dtype: str = "f32"        # "f32" | "bf16" | "int8"


def _store_v(v: jax.Array, mode: str):
    """Second moments are nonnegative with huge dynamic range: store
    sqrt(v) under int8 (square on load) — measured to recover f32-Adam
    trajectories to ~1e-5 where plain int8 v diverges."""
    if mode == "int8":
        return _QTensor.store(jnp.sqrt(jnp.maximum(v, 0.0)), mode)
    return _QTensor.store(v, mode)


def _load_v(st, mode: str) -> jax.Array:
    x = _QTensor.load(st)
    if mode == "int8":
        return x * x
    return x


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    zeros = jax.tree_util.tree_map(
        lambda p: _QTensor.store(jnp.zeros_like(p, jnp.float32),
                                 cfg.moment_dtype), params)
    zeros2 = jax.tree_util.tree_map(
        lambda p: _store_v(jnp.zeros_like(p, jnp.float32),
                           cfg.moment_dtype), params)
    return {"m": zeros, "v": zeros2,
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(tree)))


def _is_moment(x) -> bool:
    return isinstance(x, dict) and "q" in x


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig
                 ) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = warmup_cosine(step, cfg.lr, cfg.warmup_steps, cfg.total_steps)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, m_st, v_st):
        g = g.astype(jnp.float32) * scale
        m = _QTensor.load(m_st)
        v = _load_v(v_st, cfg.moment_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _QTensor.store(m, cfg.moment_dtype), \
            _store_v(v, cfg.moment_dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "step": step}


# --- Adafactor (factored second moments for >=2-D params) -----------------------

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.999
    eps: float = 1e-30
    clip_rms: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.0


def adafactor_init(params: Any, cfg: AdafactorConfig) -> dict:
    def mk(p):
        if p.ndim >= 2:
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                     jnp.float32)}
        return {"full": jnp.zeros_like(p, jnp.float32)}
    return {"f": jax.tree_util.tree_map(
        mk, params, is_leaf=lambda x: isinstance(x, jax.Array)),
        "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params: Any, grads: Any, state: dict,
                     cfg: AdafactorConfig) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = warmup_cosine(step, cfg.lr, cfg.warmup_steps, cfg.total_steps)

    def upd(p, g, f):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if p.ndim >= 2:
            row = cfg.decay * f["row"] + (1 - cfg.decay) * g2.mean(-1)
            col = cfg.decay * f["col"] + (1 - cfg.decay) * g2.mean(-2)
            rmean = row.mean(-1, keepdims=True)
            vhat = (row / jnp.maximum(rmean, cfg.eps))[..., None] * \
                col[..., None, :]
            newf = {"row": row, "col": col}
        else:
            full = cfg.decay * f["full"] + (1 - cfg.decay) * g2
            vhat = full
            newf = {"full": full}
        update = g / jnp.sqrt(vhat + cfg.eps)
        rms = jnp.sqrt(jnp.mean(update ** 2))
        update = update / jnp.maximum(1.0, rms / cfg.clip_rms)
        new_p = (p.astype(jnp.float32) - lr *
                 (update + cfg.weight_decay * p.astype(jnp.float32))
                 ).astype(p.dtype)
        return new_p, newf

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_f = tdef.flatten_up_to(state["f"])
    outs = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    return (tdef.unflatten([o[0] for o in outs]),
            {"f": tdef.unflatten([o[1] for o in outs]), "step": step})


# --- façade -----------------------------------------------------------------------

def make_optimizer(kind: str = "adamw", **kw):
    if kind == "adamw":
        cfg = AdamWConfig(**kw)
        return (functools.partial(adamw_init, cfg=cfg),
                functools.partial(adamw_update, cfg=cfg))
    if kind == "adafactor":
        cfg = AdafactorConfig(**kw)
        return (functools.partial(adafactor_init, cfg=cfg),
                functools.partial(adafactor_update, cfg=cfg))
    raise ValueError(kind)
