"""Operational energy / CO2eq metering for the serving engine.

codecarbon-style accounting adapted to the engine's step structure: the
engine already times every jitted phase (prefill per admission, one
decode step per tick), so the meter converts those **measured step
seconds** into Joules through a pluggable device power model, and Joules
into grams CO2eq through a `grid.GridProvider` queried on the meter's
own step clock (cumulative measured seconds — timezone-free, replayable).

Attribution is exact by construction:

  * a prefill's energy goes wholly to the admitted request;
  * a decode step's energy splits equally across the slots it advanced
    (every occupied slot emits exactly one token per step);

so the sum of per-request Joules equals the engine's cumulative total up
to float rounding — the conservation property `tests/test_fleet.py`
asserts.  Metering is opt-in (`Engine(..., meter=...)`); when absent the
engine pays a single `is None` check per phase.

The default power model is TDP-based with per-phase utilization weights:
prefill is compute-bound (high utilization of the MAC array), decode is
memory-bandwidth-bound (low utilization, scaling with arena occupancy).
See EXPERIMENTS.md "Device power model" for the assumptions and
constants.
"""

from __future__ import annotations

import dataclasses

from repro.fleet.grid import GridProvider

J_PER_KWH = 3.6e6

#: Active power per PE [W] by technology node: ballpark from ~0.5-1
#: pJ/MAC logic energy at 7 nm (Horowitz, ISSCC'14 scaling surveys,
#: int8 MAC + local SRAM access) times the node clock in
#: `core.carbon.NODE_PARAMS`, with a ~2x margin for register-file and
#: NoC share.  Older nodes pay more energy per op at a lower clock.
PE_ACTIVE_W_BY_NODE: dict[int, float] = {7: 2.0e-3, 14: 3.5e-3, 28: 6.0e-3}

#: Package power floor [W] independent of the PE array (DRAM PHY, SoC
#: fabric, always-on control) — the term that makes tiny arrays not
#: free.
BASE_POWER_W = 2.0


@dataclasses.dataclass(frozen=True)
class DevicePowerModel:
    """TDP-based device power with per-phase utilization weighting.

    `power_w` interpolates between the idle floor and TDP:

        P(phase) = P_idle + (TDP - P_idle) * util(phase)

    with `util(prefill) = prefill_util` (compute-bound, whole array
    busy) and `util(decode) = decode_util * occupancy` (bandwidth-bound
    GEMV work that scales with how many arena slots the step advanced).
    """

    tdp_w: float = 15.0
    idle_frac: float = 0.15        # idle power as a fraction of TDP
    prefill_util: float = 0.85
    decode_util: float = 0.45

    def __post_init__(self):
        if self.tdp_w <= 0:
            raise ValueError("tdp_w must be > 0")
        if not 0.0 <= self.idle_frac <= 1.0:
            raise ValueError("idle_frac must be in [0, 1]")

    @property
    def idle_w(self) -> float:
        return self.idle_frac * self.tdp_w

    def power_w(self, phase: str, n_active: int = 1,
                capacity: int = 1) -> float:
        if phase == "prefill":
            util = self.prefill_util
        elif phase == "decode":
            util = self.decode_util * (n_active / max(capacity, 1))
        else:
            raise ValueError(f"unknown phase {phase!r}")
        return self.idle_w + (self.tdp_w - self.idle_w) * util

    @classmethod
    def for_target(cls, target, **kwargs) -> "DevicePowerModel":
        """TDP from a `core.target.HardwareTarget`: the package floor
        plus per-PE active power at the die's node, summed over dies."""
        pe_w = PE_ACTIVE_W_BY_NODE[target.die.node_nm]
        return cls(tdp_w=BASE_POWER_W + target.total_pes * pe_w, **kwargs)


@dataclasses.dataclass(frozen=True)
class RequestCarbon:
    """Per-request operational footprint, attached to `Completion.carbon`."""

    energy_j: float
    co2e_g: float
    tokens: int
    region: str
    grid_g_per_kwh_mean: float     # energy-weighted mean intensity

    @property
    def energy_j_per_token(self) -> float:
        return self.energy_j / max(self.tokens, 1)

    @property
    def co2e_g_per_token(self) -> float:
        return self.co2e_g / max(self.tokens, 1)

    def to_dict(self) -> dict:
        return {"energy_j": self.energy_j, "co2e_g": self.co2e_g,
                "tokens": self.tokens, "region": self.region,
                "energy_j_per_token": self.energy_j_per_token,
                "co2e_g_per_token": self.co2e_g_per_token,
                "grid_g_per_kwh_mean": self.grid_g_per_kwh_mean}


class _Account:
    __slots__ = ("energy_j", "co2e_g")

    def __init__(self):
        self.energy_j = 0.0
        self.co2e_g = 0.0


class EnergyMeter:
    """Accumulates per-phase energy and per-request attributions.

    The meter's clock is the sum of measured step seconds it has
    observed; the grid provider is queried at the clock value *before*
    each step (start-of-step intensity), so identical step-time
    sequences give identical CO2eq regardless of when the run happens.
    `clock0_s` offsets the clock — e.g. to start a replica mid-trace.
    """

    def __init__(self, power: DevicePowerModel | None = None,
                 grid: GridProvider | None = None, *,
                 clock0_s: float = 0.0):
        from repro.fleet.grid import StaticGrid
        self.power = power or DevicePowerModel()
        self.grid = grid or StaticGrid("us-east")
        self._clock_s = float(clock0_s)
        self._accounts: dict[str, _Account] = {}
        self.energy_j = 0.0
        self.co2e_g = 0.0
        self.prefill_j = 0.0
        self.decode_j = 0.0
        self.prefill_calls = 0
        self.decode_steps = 0
        self.finalized_tokens = 0
        self.finalized_co2e_g = 0.0
        self.finalized_energy_j = 0.0
        self.abandoned_requests = 0
        self.abandoned_co2e_g = 0.0
        self.abandoned_energy_j = 0.0

    @property
    def clock_s(self) -> float:
        return self._clock_s

    @property
    def region(self) -> str:
        return self.grid.region

    def g_per_kwh_now(self) -> float:
        return self.grid.g_per_kwh(self._clock_s)

    def _charge(self, request_id: str, energy_j: float, ci: float) -> None:
        acct = self._accounts.get(request_id)
        if acct is None:
            acct = self._accounts[request_id] = _Account()
        co2 = energy_j / J_PER_KWH * ci
        acct.energy_j += energy_j
        acct.co2e_g += co2
        self.energy_j += energy_j
        self.co2e_g += co2

    def on_prefill(self, request_id: str, dt_s: float) -> None:
        ci = self.g_per_kwh_now()
        e = self.power.power_w("prefill") * dt_s
        self._charge(request_id, e, ci)
        self.prefill_j += e
        self.prefill_calls += 1
        self._clock_s += dt_s

    def on_decode(self, dt_s: float, request_ids: list[str],
                  capacity: int) -> None:
        if not request_ids:
            self._clock_s += dt_s
            return
        ci = self.g_per_kwh_now()
        e = self.power.power_w("decode", len(request_ids), capacity) * dt_s
        share = e / len(request_ids)
        for rid in request_ids:
            self._charge(rid, share, ci)
        self.decode_j += e
        self.decode_steps += 1
        self._clock_s += dt_s

    def finalize(self, request_id: str, tokens: int) -> RequestCarbon:
        """Close a request's account (at eviction) and return its
        attribution; the account is dropped so re-used ids start clean."""
        acct = self._accounts.pop(request_id, None) or _Account()
        mean_ci = (acct.co2e_g / acct.energy_j * J_PER_KWH
                   if acct.energy_j > 0 else self.g_per_kwh_now())
        self.finalized_tokens += tokens
        self.finalized_co2e_g += acct.co2e_g
        self.finalized_energy_j += acct.energy_j
        return RequestCarbon(energy_j=acct.energy_j, co2e_g=acct.co2e_g,
                             tokens=tokens, region=self.region,
                             grid_g_per_kwh_mean=mean_ci)

    def abandon(self, request_id: str) -> None:
        """Close a request's account WITHOUT a completion — the failover
        path for work drained off a dead replica (the energy was really
        spent; it moves to the abandoned counters so conservation still
        holds: finalized + abandoned + open == total).  No-op for ids
        with no open account (queued-but-never-admitted requests)."""
        acct = self._accounts.pop(request_id, None)
        if acct is None:
            return
        self.abandoned_requests += 1
        self.abandoned_co2e_g += acct.co2e_g
        self.abandoned_energy_j += acct.energy_j

    def open_energy_j(self) -> float:
        """Energy charged to still-open accounts (in-flight requests)."""
        return sum(a.energy_j for a in self._accounts.values())

    def summary(self) -> dict:
        toks = max(self.finalized_tokens, 1)
        return {
            "region": self.region,
            "clock_s": self._clock_s,
            "g_per_kwh_now": self.g_per_kwh_now(),
            "energy_j": self.energy_j,
            "co2e_g": self.co2e_g,
            "prefill_j": self.prefill_j,
            "decode_j": self.decode_j,
            "prefill_calls": self.prefill_calls,
            "decode_steps": self.decode_steps,
            "finalized_tokens": self.finalized_tokens,
            "energy_j_per_token": self.finalized_energy_j / toks,
            "co2e_g_per_token": self.finalized_co2e_g / toks,
            "abandoned_requests": self.abandoned_requests,
            "abandoned_energy_j": self.abandoned_energy_j,
            "abandoned_co2e_g": self.abandoned_co2e_g,
            "finalized_energy_j": self.finalized_energy_j,
            "finalized_co2e_g": self.finalized_co2e_g,
            "open_energy_j": self.open_energy_j(),
            "power": {"tdp_w": self.power.tdp_w,
                      "idle_frac": self.power.idle_frac,
                      "prefill_util": self.power.prefill_util,
                      "decode_util": self.power.decode_util},
        }
