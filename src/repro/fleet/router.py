"""Carbon-aware multi-replica router.

`Fleet` fronts N `Replica`s (each an Engine in its own region, possibly
on its own `HardwareTarget`/mesh) behind one submit/step surface, and
admission-routes every request by **live grid intensity x SLO
headroom**:

  * a replica's *predicted TTFT* is estimated from its queue state
    (backlog beyond free slots x its running-mean service length /
    capacity) — pure tick arithmetic, so routing is deterministic and
    replayable;
  * among replicas whose prediction fits the TTFT budget, the request
    goes to the **lowest-intensity** region (ties break on predicted
    wait, then name);
  * if no replica fits the budget, latency wins: the request goes to
    the fastest-draining replica regardless of carbon.

So traffic follows the cleanest grid until the SLO pushes back — the
follow-the-sun behavior `launch/fleet.py` demos under a time-varying
`TraceGrid`.

Failover: a replica that dies mid-step (`ReplicaDead` — real crash or
injected fault) is dropped from the live set, its unfinished requests
are drained (`Replica.drain()`) and re-queued through normal routing on
the surviving replicas, and the router re-weights automatically because
the dead replica simply stops being a candidate.  Completed work on the
dead replica is kept; re-queued requests regenerate from scratch.  Net:
zero lost requests as long as one replica survives.
"""

from __future__ import annotations

import dataclasses
import heapq
import math

from repro.fleet.replica import Replica, ReplicaDead
from repro.serving import Completion, Request


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Router knobs.

    ttft_slo_ticks: admission-to-first-token budget in fleet ticks; the
      router only considers a replica "eligible" for carbon-first
      placement while its predicted TTFT fits this budget.
    default_service_ticks: prior for a replica's mean request service
      length (ticks) before it has observed any traffic.
    """
    ttft_slo_ticks: float = 32.0
    default_service_ticks: float = 12.0


@dataclasses.dataclass
class _RouteRecord:
    tick: int
    request_id: str
    replica: str
    g_per_kwh: float
    predicted_ttft: float
    was_lowest_carbon: bool
    requeue: bool


class Fleet:
    def __init__(self, replicas: list[Replica],
                 cfg: FleetConfig | None = None):
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names in {names}")
        self.replicas = list(replicas)
        self.cfg = cfg or FleetConfig()
        self._pending: list[tuple[float, int, Request]] = []
        self._order = 0
        self._tick = 0
        self._submitted: set[str] = set()
        self._service_mean: dict[str, tuple[int, float]] = {
            r.name: (0, self.cfg.default_service_ticks) for r in replicas}
        self.routes: list[_RouteRecord] = []
        self.requeued = 0
        self.requeue_events: list[dict] = []

    # --- submission -------------------------------------------------------

    @property
    def tick(self) -> int:
        return self._tick

    def live(self) -> list[Replica]:
        return [r for r in self.replicas if r.alive]

    def submit(self, request: Request) -> None:
        """Queue a request for routing at its arrival tick (fleet
        virtual clock, mirroring the engine-trace convention)."""
        if request.request_id in self._submitted:
            raise ValueError(
                f"duplicate request_id {request.request_id!r}")
        self._submitted.add(request.request_id)
        heapq.heappush(self._pending,
                       (float(request.arrival), self._order, request))
        self._order += 1

    # --- placement policy -------------------------------------------------

    def mean_service_ticks(self, name: str) -> float:
        return self._service_mean[name][1]

    def _note_service(self, name: str, ticks: float) -> None:
        n, mean = self._service_mean[name]
        self._service_mean[name] = (n + 1, mean + (ticks - mean) / (n + 1))

    def predicted_ttft_ticks(self, r: Replica) -> float:
        """Queue-theory-lite TTFT estimate: a free slot admits next
        step (1 tick to first token); a backlogged request waits for
        `backlog` evictions, which arrive at ~capacity per mean service
        length."""
        backlog = r.n_active + r.n_queued + 1 - r.capacity
        if backlog <= 0:
            return 1.0
        return 1.0 + backlog * self.mean_service_ticks(r.name) \
            / max(r.capacity, 1)

    def route(self, request: Request, *, requeue: bool = False) -> Replica:
        """Pick a replica for `request` and submit it there."""
        live = self.live()
        if not live:
            raise RuntimeError(
                f"no live replicas to serve {request.request_id!r}")
        scored = [(r, self.predicted_ttft_ticks(r), r.g_per_kwh_now())
                  for r in live]
        lowest_ci = min(ci for _, _, ci in scored)
        eligible = [(r, p, ci) for r, p, ci in scored
                    if p <= self.cfg.ttft_slo_ticks]
        if eligible:
            r, pred, ci = min(eligible,
                              key=lambda t: (t[2], t[1], t[0].name))
        else:  # SLO unsatisfiable everywhere: minimize the damage
            r, pred, ci = min(scored,
                              key=lambda t: (t[1], t[2], t[0].name))
        # the engine runs its own virtual clock; arrival "now" admits at
        # the replica's next step
        r.submit(dataclasses.replace(request, arrival=float(r.engine.tick)))
        self._note_service(r.name, float(request.sampling.max_new_tokens))
        self.routes.append(_RouteRecord(
            tick=self._tick, request_id=request.request_id, replica=r.name,
            g_per_kwh=ci, predicted_ttft=pred,
            was_lowest_carbon=math.isclose(ci, lowest_ci), requeue=requeue))
        return r

    # --- failover ---------------------------------------------------------

    def _failover(self, dead: Replica) -> None:
        lost = dead.drain()
        self.requeue_events.append({
            "tick": self._tick, "replica": dead.name,
            "requeued": [req.request_id for req in lost]})
        self.requeued += len(lost)
        for req in lost:
            # strip the engine-local arrival; route() restamps it
            self.route(dataclasses.replace(req, arrival=float(self._tick)),
                       requeue=True)

    # --- the fleet loop ---------------------------------------------------

    def step(self) -> None:
        """One fleet tick: route due arrivals, then advance every busy
        live replica one engine step, failing over any that die."""
        now = self._tick
        while self._pending and self._pending[0][0] <= now:
            _, _, req = heapq.heappop(self._pending)
            self.route(req)
        for r in self.replicas:
            if r.alive and r.busy:
                try:
                    r.step()
                except ReplicaDead:
                    self._failover(r)
        self._tick += 1

    def busy(self) -> bool:
        return bool(self._pending) or any(r.busy for r in self.live())

    def run_until_complete(self) -> list[Completion]:
        """Drive the fleet until every submitted request completed
        somewhere; idle ticks fast-forward to the next arrival."""
        while self.busy():
            if not any(r.busy for r in self.live()) and self._pending:
                nxt = self._pending[0][0]
                if nxt > self._tick:
                    self._tick = int(math.ceil(nxt))
            self.step()
        return self.completions()

    def completions(self) -> list[Completion]:
        out: list[Completion] = []
        for r in self.replicas:          # dead replicas keep finished work
            out.extend(r.completions())
        return out

    # --- accounting -------------------------------------------------------

    def lost_requests(self) -> set[str]:
        """Submitted ids with no completion anywhere (must be empty
        after `run_until_complete`)."""
        done = {c.request_id for c in self.completions()}
        return self._submitted - done

    def stats(self) -> dict:
        routes = self.routes
        n_routes = max(len(routes), 1)
        totals = {"energy_j": 0.0, "co2e_g": 0.0, "tokens": 0}
        for r in self.replicas:
            s = r.meter.summary()
            totals["energy_j"] += s["energy_j"]
            totals["co2e_g"] += s["co2e_g"]
            totals["tokens"] += s["finalized_tokens"]
        totals["co2e_g_per_token"] = (
            totals["co2e_g"] / max(totals["tokens"], 1))
        totals["energy_j_per_token"] = (
            totals["energy_j"] / max(totals["tokens"], 1))
        return {
            "ticks": self._tick,
            "submitted": len(self._submitted),
            "completed": len(self.completions()),
            "lost": sorted(self.lost_requests()),
            "requeued": self.requeued,
            "requeue_events": list(self.requeue_events),
            "routed": {r.name: r.routed for r in self.replicas},
            "low_carbon_share": sum(
                1 for rec in routes if rec.was_lowest_carbon) / n_routes,
            "slo": {
                "ttft_slo_ticks": self.cfg.ttft_slo_ticks,
                "predicted_ttft_max": max(
                    (rec.predicted_ttft for rec in routes), default=0.0),
            },
            "totals": totals,
            "replicas": [r.stats() for r in self.replicas],
        }
