"""Carbon-aware multi-replica router with graceful degradation.

`Fleet` fronts N `Replica`s (each an Engine in its own region, possibly
on its own `HardwareTarget`/mesh) behind one submit/step surface, and
admission-routes every request by **live grid intensity x SLO
headroom**:

  * a replica's *predicted TTFT* is estimated from its queue state
    (backlog beyond free slots x its running-mean service length /
    capacity, discounted by the serving tier's throughput speedup) —
    pure tick arithmetic, so routing is deterministic and replayable;
  * among replicas whose prediction fits the TTFT budget, the request
    goes to the **lowest-intensity** region (ties break on predicted
    wait, then name);
  * if no replica fits the budget, latency wins: the request goes to
    the fastest-draining replica regardless of carbon.

So traffic follows the cleanest grid until the SLO pushes back — the
follow-the-sun behavior `launch/fleet.py` demos under a time-varying
`TraceGrid`.

Failover & retry discipline: a replica that dies (mid-step, or at the
submission boundary after the router's last health view — both raise
`ReplicaDead`) is dropped from the live set and its unfinished requests
are drained and **re-queued with a retry budget**: attempt k re-arrives
after `retry_backoff_ticks * 2^(k-1)` fleet ticks (deterministic
tick-based exponential backoff, the request-level extension of
`fault.run_with_restarts`' attempt discipline), and a request that
exhausts `retry_budget` attempts completes as `finish_reason="shed"`
rather than vanishing — zero lost requests, exactly-once completions.
Transient deaths (`Replica.recovery_ticks`) are restarted on schedule
and re-admitted through **probation**: `probation_steps` healthy
health-check steps before the router sends them fresh traffic.

Graceful degradation (`DegradationController`): under SLO pressure
(predicted TTFT eating the budget, deep queues, straggler flags) a
replica steps DOWN its engine's multiplier-tier ladder — exact ->
approx -> aggressive-approx, each tier's weight planes prepared once at
engine build — trading bounded multiplier accuracy for decode
throughput instead of shedding load; when headroom returns it steps
back UP to exact.  Every completion records the tiers that served it,
so accuracy exposure under brownout is auditable (EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import heapq
import math

from repro.fleet.replica import Replica, ReplicaDead
from repro.serving import Completion, Request


@dataclasses.dataclass(frozen=True)
class DegradationConfig:
    """Brownout controller knobs (all in fleet ticks / SLO fractions).

    degrade_above: step a replica DOWN one tier after `patience`
      consecutive ticks with predicted TTFT above this fraction of the
      SLO (or a fresh straggler flag).
    restore_below: step back UP one tier after `patience` consecutive
      calm ticks below this fraction (hysteresis: restore_below <
      degrade_above so the controller cannot flap on the boundary).
    patience: consecutive-signal ticks required before any step.
    min_dwell_ticks: minimum ticks between two tier changes on the same
      replica (protects the jit caches from thrashing; each tier is
      compiled once regardless).
    """
    degrade_above: float = 0.75
    restore_below: float = 0.40
    patience: int = 2
    min_dwell_ticks: int = 4

    def __post_init__(self):
        if not self.restore_below < self.degrade_above:
            raise ValueError("hysteresis requires restore_below < "
                             "degrade_above")


class DegradationController:
    """Steps each replica along its engine's multiplier-tier ladder on
    SLO-headroom / queue-depth / straggler signals.  Pure tick
    arithmetic over router-visible state — deterministic, replayable,
    and engine-agnostic (replicas without a ladder are left alone)."""

    def __init__(self, cfg: DegradationConfig | None = None):
        self.cfg = cfg or DegradationConfig()
        self._pressure: dict[str, int] = {}
        self._calm: dict[str, int] = {}
        self._last_change: dict[str, int] = {}
        self.events: list[dict] = []

    def _change(self, fleet: "Fleet", r: Replica, direction: int,
                reason: str) -> None:
        ladder = r.engine.tiers
        idx = r.engine.tier_index + direction
        target = ladder[idx]
        self.events.append({
            "tick": fleet.tick, "replica": r.name,
            "from": r.engine.tier, "to": target, "reason": reason})
        r.engine.set_tier(target)
        self._last_change[r.name] = fleet.tick
        self._pressure[r.name] = 0
        self._calm[r.name] = 0

    def step(self, fleet: "Fleet") -> None:
        cfg = self.cfg
        slo = fleet.cfg.ttft_slo_ticks
        for r in fleet.routable():
            if len(r.engine.tiers) < 2:
                continue
            pred = fleet.predicted_ttft_ticks(r)
            straggling = r.straggling()
            pressured = pred > cfg.degrade_above * slo or straggling
            calm = pred < cfg.restore_below * slo and not straggling
            self._pressure[r.name] = \
                self._pressure.get(r.name, 0) + 1 if pressured else 0
            self._calm[r.name] = \
                self._calm.get(r.name, 0) + 1 if calm else 0
            dwell_ok = fleet.tick - self._last_change.get(
                r.name, -cfg.min_dwell_ticks) >= cfg.min_dwell_ticks
            if not dwell_ok:
                continue
            if self._pressure[r.name] >= cfg.patience and \
                    r.engine.tier_index < len(r.engine.tiers) - 1:
                self._change(fleet, r, +1,
                             "straggler" if straggling else "slo_headroom")
            elif self._calm[r.name] >= cfg.patience and \
                    r.engine.tier_index > 0:
                self._change(fleet, r, -1, "headroom_restored")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Router knobs.

    ttft_slo_ticks: admission-to-first-token budget in fleet ticks; the
      router only considers a replica "eligible" for carbon-first
      placement while its predicted TTFT fits this budget.
    default_service_ticks: prior for a replica's mean request service
      length (ticks) before it has observed any traffic.
    retry_budget: max re-queue attempts per request after failovers;
      exhausting it completes the request as "shed" (never silent loss).
    retry_backoff_ticks: base of the deterministic exponential backoff —
      attempt k re-arrives after retry_backoff_ticks * 2^(k-1) ticks.
    probation_steps: healthy health-check steps a restarted replica must
      complete before the router routes it fresh traffic.
    degradation: brownout controller knobs; None disables tier stepping
      (replicas serve their default tier forever).
    """
    ttft_slo_ticks: float = 32.0
    default_service_ticks: float = 12.0
    retry_budget: int = 3
    retry_backoff_ticks: float = 1.0
    probation_steps: int = 3
    degradation: DegradationConfig | None = None


@dataclasses.dataclass
class _RouteRecord:
    tick: int
    request_id: str
    replica: str
    g_per_kwh: float
    predicted_ttft: float
    was_lowest_carbon: bool
    requeue: bool


class Fleet:
    def __init__(self, replicas: list[Replica],
                 cfg: FleetConfig | None = None):
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names in {names}")
        self.replicas = list(replicas)
        self.cfg = cfg or FleetConfig()
        self.controller = (DegradationController(self.cfg.degradation)
                           if self.cfg.degradation is not None else None)
        self._pending: list[tuple[float, int, Request]] = []
        self._order = 0
        self._tick = 0
        self._submitted: set[str] = set()
        self._service_mean: dict[str, tuple[int, float]] = {
            r.name: (0, self.cfg.default_service_ticks) for r in replicas}
        self.routes: list[_RouteRecord] = []
        self.requeued = 0
        self.requeue_events: list[dict] = []
        self.retry_exhausted: list[Completion] = []
        self._recover_at: dict[str, int] = {}    # name -> due fleet tick
        self._probation: dict[str, int] = {}     # name -> healthy steps left
        self.recoveries: list[dict] = []

    # --- submission -------------------------------------------------------

    @property
    def tick(self) -> int:
        return self._tick

    def live(self) -> list[Replica]:
        return [r for r in self.replicas if r.alive]

    def routable(self) -> list[Replica]:
        """Live replicas the router may hand fresh traffic: excludes
        restarts still in probation (they step, but take no requests)."""
        return [r for r in self.replicas
                if r.alive and r.name not in self._probation]

    def submit(self, request: Request) -> None:
        """Queue a request for routing at its arrival tick (fleet
        virtual clock, mirroring the engine-trace convention)."""
        if request.request_id in self._submitted:
            raise ValueError(
                f"duplicate request_id {request.request_id!r}")
        self._submitted.add(request.request_id)
        heapq.heappush(self._pending,
                       (float(request.arrival), self._order, request))
        self._order += 1

    # --- placement policy -------------------------------------------------

    def mean_service_ticks(self, name: str) -> float:
        return self._service_mean[name][1]

    def _note_service(self, name: str, ticks: float) -> None:
        n, mean = self._service_mean[name]
        self._service_mean[name] = (n + 1, mean + (ticks - mean) / (n + 1))

    def predicted_ttft_ticks(self, r: Replica) -> float:
        """Queue-theory-lite TTFT estimate: a free slot admits next
        step (1 tick to first token); a backlogged request waits for
        `backlog` evictions, which arrive at ~capacity per mean service
        length — sped up by the serving tier's throughput multiple."""
        backlog = r.n_active + r.n_queued + 1 - r.capacity
        if backlog <= 0:
            return 1.0
        return 1.0 + backlog * self.mean_service_ticks(r.name) \
            / max(r.capacity, 1) / r.speedup_now()

    def route(self, request: Request, *,
              requeue: bool = False) -> Replica | None:
        """Pick a replica for `request` and submit it there; returns the
        chosen replica.  A replica that turns out dead at the submission
        boundary (died since the router's last health view) is failed
        over and the request is transparently retried on the remaining
        candidates — it is never lost to the race.  With every replica
        dead but recoveries scheduled, the request is deferred to the
        earliest recovery tick and None is returned."""
        while True:
            live = self.routable() or self.live()
            if not live:
                if self._recover_at:
                    due = max(min(self._recover_at.values()),
                              self._tick + 1)
                    req = dataclasses.replace(request,
                                              arrival=float(due))
                    heapq.heappush(self._pending,
                                   (req.arrival, self._order, req))
                    self._order += 1
                    return None
                raise RuntimeError(
                    f"no live replicas to serve {request.request_id!r}")
            scored = [(r, self.predicted_ttft_ticks(r), r.g_per_kwh_now())
                      for r in live]
            lowest_ci = min(ci for _, _, ci in scored)
            eligible = [(r, p, ci) for r, p, ci in scored
                        if p <= self.cfg.ttft_slo_ticks]
            if eligible:
                r, pred, ci = min(eligible,
                                  key=lambda t: (t[2], t[1], t[0].name))
            else:  # SLO unsatisfiable everywhere: minimize the damage
                r, pred, ci = min(scored,
                                  key=lambda t: (t[1], t[2], t[0].name))
            # the engine runs its own virtual clock; arrival "now"
            # admits at the replica's next step
            try:
                r.submit(dataclasses.replace(
                    request, arrival=float(r.engine.tick)))
            except ReplicaDead:
                self._failover(r)   # drains + re-queues ITS work too
                continue
            self._note_service(r.name,
                               float(request.sampling.max_new_tokens))
            self.routes.append(_RouteRecord(
                tick=self._tick, request_id=request.request_id,
                replica=r.name, g_per_kwh=ci, predicted_ttft=pred,
                was_lowest_carbon=math.isclose(ci, lowest_ci),
                requeue=requeue or request.attempt > 0))
            return r

    # --- failover / retry -------------------------------------------------

    def _requeue(self, request: Request) -> None:
        """Re-queue a drained request under the retry budget with
        deterministic tick-based exponential backoff; budget exhaustion
        completes it as "shed" (counted, never lost)."""
        attempt = request.attempt + 1
        if attempt > self.cfg.retry_budget:
            self.retry_exhausted.append(Completion(
                request_id=request.request_id,
                prompt_len=len(request.tokens), tokens=[],
                finish_reason="shed", arrival=request.arrival,
                admitted_tick=-1, finished_tick=self._tick,
                ttft_s=0.0, latency_s=0.0, carbon=None,
                attempt=request.attempt, tier_tokens={}))
            return
        delay = self.cfg.retry_backoff_ticks * (2.0 ** (attempt - 1))
        req = dataclasses.replace(request, attempt=attempt,
                                  arrival=float(self._tick) + delay)
        heapq.heappush(self._pending,
                       (req.arrival, self._order, req))
        self._order += 1

    def _failover(self, dead: Replica) -> None:
        drained = dead.drain()
        self.requeue_events.append({
            "tick": self._tick, "replica": dead.name,
            "requeued": [req.request_id for req in drained]})
        self.requeued += len(drained)
        for req in drained:
            self._requeue(req)
        if dead.recovery_ticks is not None:
            self._recover_at[dead.name] = \
                self._tick + max(int(dead.recovery_ticks), 1)

    def kill_replica(self, name: str,
                     recovery_ticks: int | None = None) -> None:
        """Out-of-band death at the current fleet tick (chaos drills /
        operator action): mark dead, fail over its work immediately,
        and schedule recovery when the death is transient.  Unlike
        `Replica.inject_fault` this fires even on an idle replica."""
        r = next(x for x in self.replicas if x.name == name)
        if not r.alive:
            return
        r.recovery_ticks = recovery_ticks
        r.kill()
        self._probation.pop(name, None)
        self._failover(r)

    def _process_recoveries(self) -> None:
        for name, due in sorted(self._recover_at.items()):
            if self._tick < due:
                continue
            del self._recover_at[name]
            r = next(x for x in self.replicas if x.name == name)
            r.restart()
            self._probation[name] = max(int(self.cfg.probation_steps), 0)
            self.recoveries.append(
                {"tick": self._tick, "replica": name,
                 "probation_steps": self._probation[name]})
            if self._probation[name] == 0:
                del self._probation[name]

    # --- the fleet loop ---------------------------------------------------

    def step(self) -> None:
        """One fleet tick: restart due recoveries, route due arrivals,
        run the degradation controller, then advance every busy live
        replica (plus probation health checks), failing over any that
        die."""
        now = self._tick
        self._process_recoveries()
        if self.live():
            while self._pending and self._pending[0][0] <= now:
                _, _, req = heapq.heappop(self._pending)
                self.route(req)
        elif self._pending and not self._recover_at:
            raise RuntimeError(
                "no live replicas and no scheduled recoveries; "
                f"{len(self._pending)} requests cannot be served")
        if self.controller is not None:
            self.controller.step(self)
        for r in self.replicas:
            probation = r.name in self._probation
            if r.alive and (r.busy or probation):
                try:
                    r.step(now=now)
                except ReplicaDead:
                    self._probation.pop(r.name, None)
                    self._failover(r)
                    continue
                if probation:
                    self._probation[r.name] -= 1
                    if self._probation[r.name] <= 0:
                        del self._probation[r.name]
        self._tick += 1

    def busy(self) -> bool:
        return bool(self._pending) or any(r.busy for r in self.live())

    def _next_wake(self) -> float | None:
        """Earliest future fleet tick with scheduled work: an arrival
        (incl. backoff re-queues) or a due recovery."""
        cands = []
        if self._pending:
            cands.append(self._pending[0][0])
        cands.extend(self._recover_at.values())
        return min(cands) if cands else None

    def run_until_complete(self) -> list[Completion]:
        """Drive the fleet until every submitted request completed
        somewhere; idle ticks fast-forward to the next scheduled work
        (arrival, backoff re-queue, or recovery)."""
        while self.busy():
            if not any(r.busy for r in self.live()):
                nxt = self._next_wake()
                if nxt is not None and nxt > self._tick:
                    self._tick = int(math.ceil(nxt))
            self.step()
        return self.completions()

    def completions(self) -> list[Completion]:
        out: list[Completion] = []
        for r in self.replicas:          # dead replicas keep finished work
            out.extend(r.completions())
        out.extend(self.retry_exhausted)
        return out

    # --- accounting -------------------------------------------------------

    def lost_requests(self) -> set[str]:
        """Submitted ids with no completion anywhere (must be empty
        after `run_until_complete`)."""
        done = {c.request_id for c in self.completions()}
        return self._submitted - done

    def wall_ttft_ticks(self) -> dict[str, float]:
        """Per-request TTFT on the *fleet* (wall) clock: replica wall
        admission stamp minus the routing tick, inclusive.  This is the
        SLO-facing metric — on a degraded tier the engine clock runs
        several ticks per fleet tick (step credit), so engine-tick TTFT
        cannot show the brownout win; wall TTFT does.  Requests that
        never reached a slot (shed / retry-exhausted) are omitted."""
        routed_at: dict[str, int] = {}
        for rec in self.routes:          # latest route = serving attempt
            routed_at[rec.request_id] = rec.tick
        out: dict[str, float] = {}
        for r in self.replicas:
            for c in r.completions():
                if c.admitted_tick < 0:
                    continue
                adm = r.wall_admitted.get(c.request_id)
                sub = routed_at.get(c.request_id)
                if adm is not None and sub is not None:
                    out[c.request_id] = float(adm - sub + 1)
        return out

    def tier_occupancy(self) -> dict[str, int]:
        """Fleet-wide tokens served per multiplier tier — the accuracy-
        exposure audit (EXPERIMENTS.md)."""
        occ: dict[str, int] = {}
        for c in self.completions():
            for tier, n in (c.tier_tokens or {}).items():
                occ[tier] = occ.get(tier, 0) + n
        return occ

    def stats(self) -> dict:
        routes = self.routes
        n_routes = max(len(routes), 1)
        totals = {"energy_j": 0.0, "co2e_g": 0.0, "tokens": 0,
                  "abandoned_energy_j": 0.0, "abandoned_co2e_g": 0.0}
        for r in self.replicas:
            s = r.carbon_summary()
            totals["energy_j"] += s["energy_j"]
            totals["co2e_g"] += s["co2e_g"]
            totals["tokens"] += s["finalized_tokens"]
            totals["abandoned_energy_j"] += s["abandoned_energy_j"]
            totals["abandoned_co2e_g"] += s["abandoned_co2e_g"]
        totals["co2e_g_per_token"] = (
            totals["co2e_g"] / max(totals["tokens"], 1))
        totals["energy_j_per_token"] = (
            totals["energy_j"] / max(totals["tokens"], 1))
        return {
            "ticks": self._tick,
            "submitted": len(self._submitted),
            "completed": len(self.completions()),
            "lost": sorted(self.lost_requests()),
            "requeued": self.requeued,
            "requeue_events": list(self.requeue_events),
            "routed": {r.name: r.routed for r in self.replicas},
            "low_carbon_share": sum(
                1 for rec in routes if rec.was_lowest_carbon) / n_routes,
            "slo": {
                "ttft_slo_ticks": self.cfg.ttft_slo_ticks,
                "predicted_ttft_max": max(
                    (rec.predicted_ttft for rec in routes), default=0.0),
            },
            "robustness": {
                "retry_budget": self.cfg.retry_budget,
                "retry_exhausted": len(self.retry_exhausted),
                "max_attempt": max(
                    (c.attempt for c in self.completions()), default=0),
                "recoveries": list(self.recoveries),
                "in_probation": sorted(self._probation),
                "restarts": {r.name: r.restarts for r in self.replicas
                             if r.restarts},
                "degradation_events": (list(self.controller.events)
                                       if self.controller else []),
                "tier_occupancy": self.tier_occupancy(),
            },
            "totals": totals,
            "replicas": [r.stats() for r in self.replicas],
        }
