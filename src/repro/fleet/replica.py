"""One fleet replica: a serving Engine pinned to a region.

A `Replica` owns an `Engine` (with its own `HardwareTarget` / mesh, so a
fleet can mix accelerator designs), a grid-intensity provider for its
region, an `EnergyMeter`, and the fault hooks from `train/fault.py`:

  * a `StragglerWatchdog` times every engine step and flags steps that
    blow past the running median — the degradation signal the router
    folds into its health view;
  * death is an *exception out of `step()`*: anything the engine raises
    (a real crash) or an injected `ReplicaDead` (tests / chaos drills)
    marks the replica dead, exactly like the crash boundary
    `fault.run_with_restarts` supervises for training.  The router then
    drains `pending_requests()` and re-queues them elsewhere — the
    fleet-level analogue of checkpoint-restart.

The replica's grid clock is its engine's virtual tick scaled by
`seconds_per_tick` (router-visible, deterministic); the meter runs on
measured seconds (see `fleet/meter.py`).
"""

from __future__ import annotations

from typing import Callable

from repro.fleet.grid import GridProvider, StaticGrid
from repro.fleet.meter import DevicePowerModel, EnergyMeter
from repro.serving import Completion, Request
from repro.serving.engine import Engine
from repro.train import fault


class ReplicaDead(RuntimeError):
    """Raised by a replica step after `inject_fault()` (and wrapped
    around real engine crashes) — the router's failover trigger."""


class Replica:
    """Engine + region + meter + fault hooks, with a submit/step surface
    the router drives.

    Args:
      name: fleet-unique replica name.
      cfg: model config for the engine.
      grid: region grid-intensity provider (default: static us-east).
      power: device power model (default: derived from `target` when one
        is given, else the generic edge-TDP default).
      target: optional `HardwareTarget`; forwarded to the Engine (mesh
        construction) and to `DevicePowerModel.for_target`.
      seconds_per_tick: virtual-clock scale for *router-side* grid
        lookups (the meter uses measured seconds independently).
      engine_kwargs: forwarded to `Engine(...)` (capacity, max_len,
        seed, prefill_buckets, mesh, ...).
    """

    def __init__(self, name: str, cfg, *, grid: GridProvider | None = None,
                 power: DevicePowerModel | None = None, target=None,
                 seconds_per_tick: float = 1.0,
                 straggler_factor: float = 3.0,
                 on_straggler: Callable[[int, float, float], None] | None
                 = None,
                 **engine_kwargs):
        self.name = name
        self.grid = grid or StaticGrid("us-east")
        if power is None:
            power = (DevicePowerModel.for_target(target)
                     if target is not None else DevicePowerModel())
        self.meter = EnergyMeter(power=power, grid=self.grid)
        self.engine = Engine(cfg, target=target, meter=self.meter,
                             **engine_kwargs)
        self.seconds_per_tick = seconds_per_tick
        self.watchdog = fault.StragglerWatchdog(
            factor=straggler_factor, on_straggler=on_straggler)
        self.alive = True
        self.routed = 0
        self._fault_at_step: int | None = None
        self._steps = 0

    # --- health / telemetry ----------------------------------------------

    @property
    def region(self) -> str:
        return self.grid.region

    @property
    def capacity(self) -> int:
        return self.engine.capacity

    @property
    def n_active(self) -> int:
        return self.engine.n_active

    @property
    def n_queued(self) -> int:
        return self.engine.n_queued

    @property
    def busy(self) -> bool:
        return bool(self.engine.n_active or self.engine.n_queued)

    def g_per_kwh_now(self) -> float:
        """Live intensity at the replica's virtual-tick clock."""
        return self.grid.g_per_kwh(self.engine.tick * self.seconds_per_tick)

    # --- traffic ----------------------------------------------------------

    def submit(self, request: Request) -> None:
        if not self.alive:
            raise ReplicaDead(f"replica {self.name} is dead")
        self.routed += 1
        self.engine.submit(request)

    def step(self) -> None:
        """One engine tick under the straggler watchdog.  Any exception
        marks the replica dead before propagating as `ReplicaDead` — the
        router catches it and re-queues `pending_requests()`."""
        if not self.alive:
            raise ReplicaDead(f"replica {self.name} is dead")
        if self._fault_at_step is not None and \
                self._steps >= self._fault_at_step:
            self.alive = False
            raise ReplicaDead(
                f"replica {self.name}: injected fault at step "
                f"{self._steps}")
        self.watchdog.step_start()
        try:
            self.engine.step()
        except Exception as e:
            self.alive = False
            raise ReplicaDead(
                f"replica {self.name} died mid-step: "
                f"{type(e).__name__}: {e}") from e
        self._steps += 1
        self.watchdog.step_end(self._steps)

    # --- failure ----------------------------------------------------------

    def inject_fault(self, at_step: int = 0) -> None:
        """Arrange for the replica to die at its `at_step`-th future
        step (0 = the very next one) — the chaos hook the failover
        tests and the `launch/fleet.py` --kill demo use."""
        self._fault_at_step = self._steps + max(at_step, 0)

    def drain(self) -> list[Request]:
        """All unfinished requests (in-flight + queued) for re-queueing
        elsewhere.  Valid on a dead replica — device state may be gone
        but the host-side request records survive."""
        return self.engine.pending_requests()

    def completions(self) -> list[Completion]:
        return self.engine.completions

    def stats(self) -> dict:
        return {
            "name": self.name,
            "region": self.region,
            "alive": self.alive,
            "routed": self.routed,
            "completed": len(self.engine.completions),
            "active": self.engine.n_active,
            "queued": self.engine.n_queued,
            "steps": self._steps,
            "straggler_steps": list(self.watchdog.flagged),
            "g_per_kwh_now": self.g_per_kwh_now(),
            "carbon": self.meter.summary(),
        }
