"""One fleet replica: a serving Engine pinned to a region.

A `Replica` owns an `Engine` (with its own `HardwareTarget` / mesh, so a
fleet can mix accelerator designs), a grid-intensity provider for its
region, an `EnergyMeter`, and the fault hooks from `train/fault.py`:

  * a `StragglerWatchdog` times every replica step **on the replica's
    virtual clock** (`seconds_per_tick`, stretched by injected
    slowdowns) and flags steps that blow past the running median — the
    degradation signal the router folds into its health view.  Virtual
    timing makes straggler detection deterministic and replayable from
    a chaos seed; the wall-clock mode of the watchdog stays available
    for training via `fault.StragglerWatchdog(clock=...)`.
  * death is an *exception out of `step()`*: anything the engine raises
    (a real crash) or an injected `ReplicaDead` (tests / chaos drills)
    marks the replica dead, exactly like the crash boundary
    `fault.run_with_restarts` supervises for training.  The router then
    drains `pending_requests()` and re-queues them elsewhere — the
    fleet-level analogue of checkpoint-restart.
  * a dead replica can *recover*: `restart()` builds a fresh engine
    (weight planes re-prepared per tier via `api.prepare_params`) and a
    fresh meter that resumes the old one's grid clock; prior
    completions and meter totals are retained.  The router re-admits a
    restarted replica through probation (healthy health-check steps)
    before routing it fresh traffic.

Graceful degradation: when the engine carries a multiplier-tier ladder
(`tiers=`), a degraded replica earns *step credit* — one fleet tick
buys `area(exact) / area(tier)` engine steps (the paper's area-delay
dual read at serve time: smaller approximate multipliers mean more of
them per die, i.e. proportionally more decode throughput).  That is
what lets the `DegradationController` trade multiplier accuracy for
queue drain rate under overload instead of shedding requests.
"""

from __future__ import annotations

from typing import Callable

from repro.core import multipliers as mm
from repro.fleet.grid import GridProvider, StaticGrid
from repro.fleet.meter import DevicePowerModel, EnergyMeter
from repro.serving import Completion, Request
from repro.serving.engine import Engine
from repro.train import fault


class ReplicaDead(RuntimeError):
    """Raised by a replica step after `inject_fault()` (and wrapped
    around real engine crashes) — the router's failover trigger."""


def tier_speedup(name: str) -> float:
    """Decode-throughput multiple of serving on multiplier tier `name`
    relative to exact, from the multiplier library's synthesized areas:
    a tier at area ratio a fits 1/a as many multipliers in the same
    silicon, so the same die drains its decode queue 1/a x faster."""
    lib = mm.static_library()
    if name not in lib:
        return 1.0
    exact_area = lib["exact"].area_nand2eq
    return max(1.0, exact_area / max(lib[name].area_nand2eq, 1e-9))


class Replica:
    """Engine + region + meter + fault hooks, with a submit/step surface
    the router drives.

    Args:
      name: fleet-unique replica name.
      cfg: model config for the engine.
      grid: region grid-intensity provider (default: static us-east).
      power: device power model (default: derived from `target` when one
        is given, else the generic edge-TDP default).
      target: optional `HardwareTarget`; forwarded to the Engine (mesh
        construction) and to `DevicePowerModel.for_target`.
      seconds_per_tick: virtual-clock scale — grid lookups AND the
        straggler watchdog run on this clock (the meter uses measured
        seconds independently).
      engine_cls: engine class to build (default `Engine`; pass
        `serving.PagedEngine` for paged-KV / chunked-prefill /
        speculative replicas — `restart()` rebuilds the same class, so
        failover keeps the replica's serving mode).
      engine_kwargs: forwarded to `engine_cls(...)` (capacity, max_len,
        seed, prefill_buckets, mesh, tiers, and for the paged engine
        page_size, prefill_chunk, draft_tier, ...).
    """

    def __init__(self, name: str, cfg, *, grid: GridProvider | None = None,
                 power: DevicePowerModel | None = None, target=None,
                 seconds_per_tick: float = 1.0,
                 straggler_factor: float = 3.0,
                 on_straggler: Callable[[int, float, float], None] | None
                 = None,
                 engine_cls: type[Engine] = Engine,
                 **engine_kwargs):
        self.name = name
        self._engine_cls = engine_cls
        self.grid = grid or StaticGrid("us-east")
        if power is None:
            power = (DevicePowerModel.for_target(target)
                     if target is not None else DevicePowerModel())
        self._power = power
        self._cfg = cfg
        self._target = target
        self._engine_kwargs = dict(engine_kwargs)
        self.seconds_per_tick = seconds_per_tick
        self._straggler_factor = straggler_factor
        self._on_straggler = on_straggler
        self._retired_meters: list[EnergyMeter] = []
        self._retired_completions: list[Completion] = []
        self._tick_base = 0            # virtual ticks served by dead engines
        self.restarts = 0
        self._boot(clock0_s=0.0)
        self.alive = True
        self.routed = 0
        #: None = permanent death; K = transient (restartable K fleet
        #: ticks after the fault) — the router's recovery schedule reads
        #: this at failover time.
        self.recovery_ticks: int | None = None
        self._fault_at_step: int | None = None
        self._submit_fault = False
        self._submit_recovery: int | None = None
        self._steps = 0
        self._vtime = 0.0              # virtual seconds, watchdog timebase
        self._slow_factor = 1.0
        self._slow_steps_left = 0
        self._credit = 0.0             # fractional engine steps banked
        #: request_id -> wall (fleet) tick the replica admitted it.  The
        #: engine clock runs FASTER than the fleet clock on a degraded
        #: tier (step credit), so engine-tick TTFT understates nothing
        #: but also shows no brownout win; wall stamps are what the
        #: fleet's SLO maths must use.  Survives restarts.
        self.wall_admitted: dict[str, int] = {}

    def _boot(self, clock0_s: float) -> None:
        """(Re)build the engine + meter + watchdog — the construction
        path `restart()` re-runs, including per-tier weight-plane
        re-preparation inside the Engine."""
        self.meter = EnergyMeter(power=self._power, grid=self.grid,
                                 clock0_s=clock0_s)
        self.engine = self._engine_cls(self._cfg, target=self._target,
                                       meter=self.meter,
                                       **self._engine_kwargs)
        self.watchdog = fault.StragglerWatchdog(
            factor=self._straggler_factor, on_straggler=self._on_straggler,
            clock=lambda: self._vtime)

    # --- health / telemetry ----------------------------------------------

    @property
    def region(self) -> str:
        return self.grid.region

    @property
    def capacity(self) -> int:
        return self.engine.capacity

    @property
    def n_active(self) -> int:
        return self.engine.n_active

    @property
    def n_queued(self) -> int:
        return self.engine.n_queued

    @property
    def busy(self) -> bool:
        return bool(self.engine.n_active or self.engine.n_queued)

    @property
    def tier(self) -> str:
        return self.engine.tier

    @property
    def virtual_ticks(self) -> float:
        """Replica lifetime in virtual ticks (survives restarts)."""
        return self._tick_base + self.engine.tick

    def g_per_kwh_now(self) -> float:
        """Live intensity at the replica's virtual-tick clock."""
        return self.grid.g_per_kwh(self.virtual_ticks * self.seconds_per_tick)

    def speedup_now(self) -> float:
        """Current decode-throughput multiple from the serving tier."""
        return tier_speedup(self.engine.tier)

    def straggling(self, within_steps: int = 3) -> bool:
        """True when the watchdog flagged a straggler step recently."""
        return bool(self.watchdog.flagged) and \
            self._steps - self.watchdog.flagged[-1] <= within_steps

    # --- traffic ----------------------------------------------------------

    def submit(self, request: Request) -> None:
        if not self.alive:
            raise ReplicaDead(f"replica {self.name} is dead")
        if self._submit_fault:
            # death discovered at the submission boundary (the replica
            # died after the router's last health view): mark dead
            # FIRST so drain() works, then refuse the request — the
            # router transparently re-routes it
            self._submit_fault = False
            self.alive = False
            self.recovery_ticks = self._submit_recovery
            raise ReplicaDead(
                f"replica {self.name} died before accepting "
                f"{request.request_id!r}")
        self.routed += 1
        self.engine.submit(request)

    def step(self, now: int | None = None) -> None:
        """One *fleet* tick under the straggler watchdog.  A degraded
        tier's step credit can run several engine steps inside it; an
        injected slowdown stretches its virtual duration.  Any exception
        marks the replica dead before propagating as `ReplicaDead` — the
        router catches it and re-queues `pending_requests()`.  `now` is
        the caller's wall (fleet) tick for admission stamping; defaults
        to the replica's own step count."""
        if not self.alive:
            raise ReplicaDead(f"replica {self.name} is dead")
        wall = self._steps if now is None else now
        if self._fault_at_step is not None and \
                self._steps >= self._fault_at_step:
            self.alive = False
            self._fault_at_step = None
            raise ReplicaDead(
                f"replica {self.name}: injected fault at step "
                f"{self._steps}")
        slow = self._slow_factor if self._slow_steps_left > 0 else 1.0
        self._credit += self.speedup_now() / slow
        n_engine_steps = int(self._credit)
        self._credit -= n_engine_steps
        if not self.busy:
            # idle health-check tick: advance the engine clock once,
            # bank no credit (a burst must not get free instant steps)
            n_engine_steps = max(n_engine_steps, 1)
            self._credit = 0.0
        active_before = self.engine.active_request_ids()
        done_before = len(self.engine.completions)
        self.watchdog.step_start()
        try:
            for _ in range(n_engine_steps):
                self.engine.step()
        except Exception as e:
            self.alive = False
            raise ReplicaDead(
                f"replica {self.name} died mid-step: "
                f"{type(e).__name__}: {e}") from e
        for rid in self.engine.active_request_ids() - active_before:
            self.wall_admitted.setdefault(rid, wall)
        for c in self.engine.completions[done_before:]:
            # admitted AND finished within this wall tick (step credit)
            if c.admitted_tick >= 0:
                self.wall_admitted.setdefault(c.request_id, wall)
        self._steps += 1
        if self._slow_steps_left > 0:
            self._slow_steps_left -= 1
        self._vtime += self.seconds_per_tick * slow
        self.watchdog.step_end(self._steps)

    # --- failure / recovery ----------------------------------------------

    def inject_fault(self, at_step: int = 0,
                     recovery_ticks: int | None = None) -> None:
        """Arrange for the replica to die at its `at_step`-th future
        step (0 = the very next one) — the chaos hook the failover
        tests and the `launch/fleet.py` --kill demo use.
        `recovery_ticks=K` makes the fault *transient*: the router may
        `restart()` the replica K fleet ticks after the death (None =
        permanent)."""
        self._fault_at_step = self._steps + max(at_step, 0)
        self.recovery_ticks = recovery_ticks

    def inject_submit_fault(self, recovery_ticks: int | None = None) -> None:
        """Die at the NEXT submission instead of the next step — the
        died-since-last-health-view race the router must survive.
        `recovery_ticks` makes the death transient, as in
        `inject_fault`."""
        self._submit_fault = True
        self._submit_recovery = recovery_ticks

    def inject_slowdown(self, factor: float, steps: int = 1) -> None:
        """Stretch the next `steps` steps' virtual duration by `factor`
        (a straggling replica: thermal throttling, a noisy neighbor).
        The watchdog flags these once past `straggler_factor` x median."""
        self._slow_factor = float(factor)
        self._slow_steps_left = int(steps)

    def kill(self) -> None:
        """Mark dead immediately (out-of-band death, no step involved)."""
        self.alive = False

    def restart(self) -> None:
        """Recover from a transient death: fresh engine (weight planes
        re-prepared per tier), fresh meter resuming the retired one's
        grid clock; completions and meter totals carry over.  The
        caller (router) gates re-admission through probation."""
        if self.alive:
            raise RuntimeError(f"replica {self.name} is not dead")
        self._retired_completions.extend(self.engine.completions)
        self._retired_meters.append(self.meter)
        self._tick_base += self.engine.tick
        self._boot(clock0_s=self.meter.clock_s)
        self.alive = True
        self.restarts += 1
        self.recovery_ticks = None
        self._submit_fault = False
        self._slow_steps_left = 0
        self._credit = 0.0

    def drain(self) -> list[Request]:
        """All unfinished requests (in-flight + queued) for re-queueing
        elsewhere, FIFO by admission/arrival.  Valid on a dead replica —
        device state may be gone but the host-side request records
        survive.  Open meter accounts for the drained requests move to
        the abandoned counters (their energy was really spent here)."""
        pending = self.engine.pending_requests()
        for req in pending:
            self.meter.abandon(req.request_id)
        return pending

    def completions(self) -> list[Completion]:
        return self._retired_completions + self.engine.completions

    def carbon_summary(self) -> dict:
        """Meter summary aggregated across restarts (retired meters +
        the live one) — the fleet's conservation maths read this."""
        live = self.meter.summary()
        if not self._retired_meters:
            return live
        out = dict(live)
        for m in self._retired_meters:
            s = m.summary()
            for key in ("energy_j", "co2e_g", "prefill_j", "decode_j",
                        "prefill_calls", "decode_steps",
                        "finalized_tokens", "finalized_energy_j",
                        "finalized_co2e_g", "abandoned_requests",
                        "abandoned_energy_j", "abandoned_co2e_g",
                        "open_energy_j"):
                out[key] += s[key]
        toks = max(out["finalized_tokens"], 1)
        out["energy_j_per_token"] = out["finalized_energy_j"] / toks
        out["co2e_g_per_token"] = out["finalized_co2e_g"] / toks
        return out

    def stats(self) -> dict:
        eng = self.engine.stats()
        out = {
            "name": self.name,
            "region": self.region,
            "alive": self.alive,
            "routed": self.routed,
            "completed": len(self.completions()),
            "active": self.engine.n_active,
            "queued": self.engine.n_queued,
            "steps": self._steps,
            "restarts": self.restarts,
            "straggler_steps": list(self.watchdog.flagged),
            "g_per_kwh_now": self.g_per_kwh_now(),
            "tiers": eng["tiers"],
            "speedup_now": self.speedup_now(),
            "carbon": self.carbon_summary(),
        }
        # paged/speculative serving sections surface verbatim so the
        # router's fleet view can audit page pressure and acceptance
        for key in ("paged", "spec"):
            if key in eng:
                out[key] = eng[key]
        return out

