"""Operational-carbon fleet layer: metering, grid intensity, routing,
and the total-carbon objective.

The core package optimizes *embodied* carbon at design time (Eq. 1-2 +
the CDP GA); this package closes the serve-time half of the loop:

  * `grid.py`   — grid carbon-intensity providers (static region table,
                  replayable time-varying traces);
  * `meter.py`  — codecarbon-style energy/CO2eq metering around the
                  serving engine (per-step power model x measured step
                  time, attributed per request and per token);
  * `replica.py`/`router.py` — a multi-replica fleet driver that routes
                  by live grid intensity x SLO headroom and survives
                  replica death without losing requests: retry budgets
                  with tick-based exponential backoff, transient-crash
                  recovery with router probation, and a
                  `DegradationController` that brownouts replicas down
                  a prepared multiplier-tier ladder under SLO pressure;
  * `chaos.py`  — seeded step-clock fault schedules + invariant
                  checkers (zero lost, exactly-once, meter
                  conservation) for deterministic chaos campaigns;
  * `total.py`  — amortized-embodied + operational total-carbon
                  objective, consumed by `core/ga_batched.py` /
                  `core/codesign.py` as a scenario axis.

`grid`, `meter`, and `total` are dependency-light (numpy-free host
code); `replica`/`router` pull in the serving engine and are imported
lazily so `from repro.fleet import total` stays cheap.
"""

from repro.fleet import grid, meter, total
from repro.fleet.grid import (REGION_INTENSITY_G_PER_KWH, GridProvider,
                              StaticGrid, TraceGrid, diurnal_trace)
from repro.fleet.meter import DevicePowerModel, EnergyMeter, RequestCarbon
from repro.fleet.total import OperationalModel

__all__ = [
    "grid", "meter", "total",
    "REGION_INTENSITY_G_PER_KWH", "GridProvider", "StaticGrid",
    "TraceGrid", "diurnal_trace",
    "DevicePowerModel", "EnergyMeter", "RequestCarbon",
    "OperationalModel",
    "Fleet", "FleetConfig", "Replica", "ReplicaDead",
    "DegradationConfig", "DegradationController",
    "ChaosCampaign", "ChaosReport", "ChaosSchedule",
]

_LAZY = {"Fleet": "repro.fleet.router", "FleetConfig": "repro.fleet.router",
         "DegradationConfig": "repro.fleet.router",
         "DegradationController": "repro.fleet.router",
         "Replica": "repro.fleet.replica",
         "ReplicaDead": "repro.fleet.replica",
         "ChaosCampaign": "repro.fleet.chaos",
         "ChaosReport": "repro.fleet.chaos",
         "ChaosSchedule": "repro.fleet.chaos",
         "router": "repro.fleet.router", "replica": "repro.fleet.replica",
         "chaos": "repro.fleet.chaos"}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(_LAZY[name])
        return (mod if name in ("router", "replica", "chaos")
                else getattr(mod, name))
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
