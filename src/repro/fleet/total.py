"""Total-carbon objective: embodied + operational per inference.

The paper's CDP metric prices only *embodied* carbon (fab footprint x
delay).  This module closes the loop the fleet opens: once serving is
metered (`fleet/meter.py`), a design's **operational** carbon per
inference is just as real as its fab carbon, and the two pull the search
in opposite directions — small approximate dies are cheap to build but
may run longer per inference; big exact dies amortize fab carbon over
more lifetime throughput but burn more Joules per token.

Per-inference model (scalar twin of the batched math inside
`core.ga_batched._metrics`; a parity test pins them together):

  fps_eff   = min(fps, fps_min)          duty-cycled at the requirement —
                                         speed headroom idles, it does
                                         not amortize more
  P_active  = pe_w(node) x num_pes x (0.5 + 0.5 x mult_escale)
                                         half the PE power rides the
                                         multiplier array, scaled by the
                                         approx multiplier's area ratio
            + die_w x (n_dies - 1)       die-to-die link power: chiplets
                                         buy fab yield (embodied) at the
                                         price of SerDes Joules — the
                                         axis where the two carbon terms
                                         pull in opposite directions
  P_idle    = idle_frac x P_active
  E_inf     = P_active / fps             race-to-idle active energy
            + P_idle x max(0, 1/fps_eff - 1/fps)
                                         idle tail while duty-cycling

  total_g   = embodied_g / (lifetime_s x util x fps_eff)   amortized fab
            + E_inf / 3.6e6 x ci_use                       operational

`OperationalModel` carries the deployment constants; `energy_scale` is
the measured-vs-modeled anchor (`EnergyCalibration`, same idiom as
`core/calibrate.py`'s delay anchor) so fleet meter readings ground the
analytic power model.

This module deliberately imports nothing from `core` — `core.ga_batched`
takes the model duck-typed (`op.pe_active_w(node_nm)` + scalar fields),
so the dependency stays one-way: fleet -> serving, core -> nothing new.
"""

from __future__ import annotations

import dataclasses

from repro.fleet.meter import J_PER_KWH, PE_ACTIVE_W_BY_NODE

#: default device lifetime for embodied amortization (3 years, the
#: figure commonly used for accelerator LCA baselines).
LIFETIME_3Y_S = 3 * 365 * 24 * 3600.0


@dataclasses.dataclass(frozen=True)
class OperationalModel:
    """Deployment constants for the operational-carbon term.

    ci_use_g_per_kwh: grid intensity where the device runs (use-phase
      CI; contrast `carbon.CI_FAB_G_PER_KWH` for the fab).
    lifetime_s / util: amortization window — the device serves for
      `lifetime_s` at duty-cycle `util`.
    idle_frac: idle power as a fraction of active power.
    die_w: watts per *extra* die for die-to-die links (SerDes +
      PHY) — zero for monolithic designs.
    energy_scale: measured/modeled anchor (see `EnergyCalibration`);
      multiplies the per-PE power constants.
    """
    ci_use_g_per_kwh: float = 379.0          # us-east static default
    lifetime_s: float = LIFETIME_3Y_S
    util: float = 0.8
    idle_frac: float = 0.15
    die_w: float = 0.25
    energy_scale: float = 1.0

    def __post_init__(self):
        if self.ci_use_g_per_kwh < 0:
            raise ValueError("ci_use_g_per_kwh must be >= 0")
        if self.lifetime_s <= 0 or not 0 < self.util <= 1:
            raise ValueError("lifetime_s > 0 and 0 < util <= 1 required")
        if self.energy_scale <= 0:
            raise ValueError("energy_scale must be > 0")

    def pe_active_w(self, node_nm: int) -> float:
        """Active watts per PE at `node_nm` (duck-typed surface used by
        `core.ga_batched.DesignSpace.tables`)."""
        return PE_ACTIVE_W_BY_NODE[int(node_nm)] * self.energy_scale


def pe_power_w(num_pes: float, mult_escale: float, node_nm: int,
               op: OperationalModel, n_dies: float = 1.0) -> float:
    """Active power: half static/routing at full weight, half in the
    multiplier array scaled by its area ratio vs the exact design, plus
    die-to-die link power for chiplet designs."""
    return (op.pe_active_w(node_nm) * num_pes * (0.5 + 0.5 * mult_escale)
            + op.die_w * max(n_dies - 1.0, 0.0))


def energy_j_per_inf(fps: float, num_pes: float, mult_escale: float,
                     node_nm: int, op: OperationalModel,
                     fps_min: float = 0.0, n_dies: float = 1.0) -> float:
    """Race-to-idle energy per inference plus the duty-cycle idle tail."""
    if fps <= 0:
        raise ValueError("fps must be > 0")
    fps_eff = min(fps, fps_min) if fps_min > 0 else fps
    p_active = pe_power_w(num_pes, mult_escale, node_nm, op, n_dies)
    p_idle = op.idle_frac * p_active
    return p_active / fps + p_idle * max(0.0, 1.0 / fps_eff - 1.0 / fps)


def operational_g_per_inf(fps: float, num_pes: float, mult_escale: float,
                          node_nm: int, op: OperationalModel,
                          fps_min: float = 0.0,
                          n_dies: float = 1.0) -> float:
    return (energy_j_per_inf(fps, num_pes, mult_escale, node_nm, op,
                             fps_min, n_dies) / J_PER_KWH
            * op.ci_use_g_per_kwh)


def embodied_g_per_inf(embodied_g: float, fps: float,
                       op: OperationalModel,
                       fps_min: float = 0.0) -> float:
    """Fab carbon amortized over lifetime inferences at the duty-cycled
    rate: lifetime_s x util x min(fps, fps_min)."""
    fps_eff = min(fps, fps_min) if fps_min > 0 else fps
    return embodied_g / (op.lifetime_s * op.util * fps_eff)


def total_carbon_g_per_inf(embodied_g: float, fps: float, num_pes: float,
                           mult_escale: float, node_nm: int,
                           op: OperationalModel,
                           fps_min: float = 0.0,
                           n_dies: float = 1.0) -> float:
    """The full objective: amortized embodied + operational gCO2e per
    inference.  Scalar twin of the batched `total_g_per_inf` metric."""
    return (embodied_g_per_inf(embodied_g, fps, op, fps_min)
            + operational_g_per_inf(fps, num_pes, mult_escale, node_nm,
                                    op, fps_min, n_dies))


# ---------------------------------------------------------------------------
# Measured-energy anchoring (calibrate.py idiom)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EnergyCalibration:
    """Anchor the analytic power model to fleet meter readings.

    `scale` = measured / modeled Joules per token; `apply` folds it into
    an `OperationalModel`'s `energy_scale` so the GA's operational term
    is grounded in what the meter actually observed — the same
    measured-over-analytic pattern as `core.calibrate.DelayCalibration`.
    """
    measured_j_per_token: float
    modeled_j_per_token: float

    @property
    def scale(self) -> float:
        if self.modeled_j_per_token <= 0 or self.measured_j_per_token <= 0:
            return 1.0
        return self.measured_j_per_token / self.modeled_j_per_token

    def apply(self, op: OperationalModel) -> OperationalModel:
        return dataclasses.replace(
            op, energy_scale=op.energy_scale * self.scale)

    @classmethod
    def from_meter_summary(cls, summary: dict,
                           modeled_j_per_token: float
                           ) -> "EnergyCalibration":
        """Build from `EnergyMeter.summary()` (its per-token Joules are
        the measured side)."""
        return cls(measured_j_per_token=float(summary["energy_j_per_token"]),
                   modeled_j_per_token=float(modeled_j_per_token))


def modeled_j_per_token(num_pes: float, mult_escale: float, node_nm: int,
                        op: OperationalModel,
                        tokens_per_s: float) -> float:
    """Analytic J/token at a measured serving rate — the modeled side of
    `EnergyCalibration` when anchoring against a serving run."""
    if tokens_per_s <= 0:
        raise ValueError("tokens_per_s must be > 0")
    return pe_power_w(num_pes, mult_escale, node_nm, op) / tokens_per_s
