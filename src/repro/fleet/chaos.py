"""Deterministic chaos harness for the carbon-aware fleet.

Everything runs on the fleet's step clock: a `ChaosSchedule` is a list
of `(tick, fault)` events — replica death (permanent or transient with
recovery), death at the submission boundary, straggler slowdowns,
grid-intensity spikes, burst floods — either hand-written or drawn from
a seed (`ChaosSchedule.random`), so every campaign is replayable
bit-for-bit from `(trace, schedule seed)`.  `ChaosCampaign` drives a
`Fleet` through the schedule, lets the degradation controller cool down
after the traffic drains, and then runs the **invariant checkers**:

  * zero lost requests — every submitted id completes somewhere;
  * exactly-once — no id completes twice (failover re-queues + retry
    budget may move an attempt, never duplicate it);
  * meter conservation — per replica (across restarts), finalized +
    abandoned + open energy equals the metered total;
  * deadline accounting — shed completions carry no tokens and were
    never admitted; deadline evictions and in-budget completions
    respect their tick budgets;
  * monotone degrade/restore — tier changes move one rung at a time
    and every replica is back on its top (exact) tier after cooldown.

The same campaigns run in `tests/test_chaos.py` and in
`bench_fleet.py --chaos`, which records the resulting `chaos` section
(faults injected, retries, p95 TTFT under chaos, tier occupancy) in
`BENCH_fleet.json`.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, Sequence

from repro.fleet.grid import GridProvider
from repro.fleet.router import Fleet
from repro.serving import Completion, Request, SamplingParams

FAULT_KINDS = ("kill", "transient", "submit_fault", "straggler",
               "grid_spike", "burst")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault.  `kind` selects which knobs apply:

    kind          | knobs used
    --------------|------------------------------------------------
    kill          | replica (permanent death at `tick`)
    transient     | replica, recovery_ticks (death, then restart)
    submit_fault  | replica (dies at its next submission instead)
    straggler     | replica, factor, duration_ticks (slowdown)
    grid_spike    | replica, factor, duration_ticks (intensity x factor)
    burst         | n_requests (flood submitted at `tick`)
    """
    tick: int
    kind: str
    replica: str | None = None
    recovery_ticks: int | None = None
    factor: float = 4.0
    duration_ticks: int = 3
    n_requests: int = 8

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.kind != "burst" and self.replica is None:
            raise ValueError(f"{self.kind} needs a replica name")

    def to_dict(self) -> dict:
        d = {"tick": self.tick, "kind": self.kind}
        if self.replica is not None:
            d["replica"] = self.replica
        if self.kind in ("transient", "submit_fault"):
            d["recovery_ticks"] = self.recovery_ticks
        if self.kind in ("straggler", "grid_spike"):
            d["factor"] = self.factor
            d["duration_ticks"] = self.duration_ticks
        if self.kind == "burst":
            d["n_requests"] = self.n_requests
        return d


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A seeded, ordered fault schedule on the fleet step clock."""
    events: tuple[ChaosEvent, ...]
    seed: int | None = None

    @classmethod
    def random(cls, seed: int, replicas: Sequence[str], *,
               horizon_ticks: int = 24, n_events: int = 6,
               kinds: Sequence[str] = ("transient", "submit_fault",
                                       "straggler", "grid_spike", "burst"),
               ) -> "ChaosSchedule":
        """Draw `n_events` faults from `seed` (replayable: same seed,
        same schedule).  The default kind pool has no permanent "kill"
        so a random schedule can never strand work with every replica
        dead; add "kill" explicitly to the pool if the fleet keeps a
        never-killed survivor."""
        rng = random.Random(seed)
        events = []
        for _ in range(n_events):
            kind = rng.choice(list(kinds))
            tick = rng.randrange(1, max(horizon_ticks, 2))
            name = rng.choice(list(replicas))
            if kind in ("transient", "submit_fault"):
                ev = ChaosEvent(tick, kind, name,
                                recovery_ticks=rng.randrange(2, 6))
            elif kind in ("straggler", "grid_spike"):
                ev = ChaosEvent(tick, kind, name,
                                factor=float(rng.randrange(3, 8)),
                                duration_ticks=rng.randrange(2, 5))
            elif kind == "burst":
                ev = ChaosEvent(tick, kind,
                                n_requests=rng.randrange(4, 10))
            else:  # kill / submit_fault
                ev = ChaosEvent(tick, kind, name)
            events.append(ev)
        events.sort(key=lambda e: (e.tick, e.kind, e.replica or ""))
        return cls(events=tuple(events), seed=seed)


@dataclasses.dataclass(frozen=True)
class SpikedGrid:
    """A grid-intensity spike: `base` x `factor` inside [t0_s, t1_s).
    Wraps the replica's *routing* view (`Replica.grid`), so the router
    steers traffic away from the spiked region while the spike lasts;
    the meter keeps charging on its own measured-seconds clock."""
    base: GridProvider
    t0_s: float
    t1_s: float
    factor: float

    @property
    def region(self) -> str:
        return self.base.region

    def g_per_kwh(self, t_s: float) -> float:
        g = self.base.g_per_kwh(t_s)
        return g * self.factor if self.t0_s <= t_s < self.t1_s else g


def _ttft_ticks(c: Completion) -> int:
    return int(c.admitted_tick - c.arrival) + 1


def _p95(values: list) -> float:
    if not values:
        return 0.0
    vs = sorted(values)
    return float(vs[min(int(0.95 * len(vs)), len(vs) - 1)])


# --- invariant checkers ----------------------------------------------------


def check_zero_lost(fleet: Fleet, requests: dict) -> list[str]:
    lost = fleet.lost_requests()
    return [f"lost requests: {sorted(lost)}"] if lost else []


def check_exactly_once(fleet: Fleet, requests: dict) -> list[str]:
    out = []
    seen: dict[str, int] = {}
    for c in fleet.completions():
        seen[c.request_id] = seen.get(c.request_id, 0) + 1
    dupes = {rid: n for rid, n in seen.items() if n > 1}
    if dupes:
        out.append(f"duplicate completions: {dupes}")
    extra = set(seen) - set(requests)
    if extra:
        out.append(f"completions for never-submitted ids: {sorted(extra)}")
    return out


def check_meter_conservation(fleet: Fleet, requests: dict,
                             rtol: float = 1e-9) -> list[str]:
    out = []
    for r in fleet.replicas:
        s = r.carbon_summary()
        acc = (s["finalized_energy_j"] + s["abandoned_energy_j"]
               + s["open_energy_j"])
        if abs(acc - s["energy_j"]) > rtol * max(s["energy_j"], 1.0):
            out.append(
                f"{r.name}: finalized+abandoned+open {acc:.6g} J != "
                f"metered total {s['energy_j']:.6g} J")
    return out


def check_deadline_accounting(fleet: Fleet, requests: dict) -> list[str]:
    out = []
    for c in fleet.completions():
        req = requests.get(c.request_id)
        if c.finish_reason == "shed":
            if c.tokens or c.admitted_tick != -1:
                out.append(f"{c.request_id}: shed with tokens/admission")
            continue
        if c.admitted_tick < 0:
            out.append(f"{c.request_id}: {c.finish_reason} but never "
                       "admitted")
            continue
        if req is None:
            continue
        span = c.finished_tick - c.arrival + 1
        ttft = _ttft_ticks(c)
        if req.ttft_deadline_ticks is not None and \
                ttft > req.ttft_deadline_ticks:
            out.append(f"{c.request_id}: TTFT {ttft} ticks blew the "
                       f"{req.ttft_deadline_ticks}-tick budget without "
                       "being shed")
        if req.deadline_ticks is not None:
            # a degraded tier's step credit can run a few engine steps
            # per fleet tick, so the eviction lands at most one credit
            # batch past the budget
            slack = 4.0
            if span > req.deadline_ticks + slack:
                out.append(f"{c.request_id}: span {span} ticks exceeds "
                           f"deadline {req.deadline_ticks} (+{slack})")
            if c.finish_reason == "deadline" and \
                    len(c.tokens) >= req.sampling.max_new_tokens:
                out.append(f"{c.request_id}: full generation marked "
                           "'deadline'")
    return out


def check_monotone_tiers(fleet: Fleet, requests: dict) -> list[str]:
    out = []
    if fleet.controller is None:
        return out
    for ev in fleet.controller.events:
        r = next(x for x in fleet.replicas if x.name == ev["replica"])
        ladder = r.engine.tiers
        try:
            step = ladder.index(ev["to"]) - ladder.index(ev["from"])
        except ValueError:
            out.append(f"tier event off-ladder: {ev}")
            continue
        if abs(step) != 1:
            out.append(f"non-adjacent tier step: {ev}")
    for r in fleet.replicas:
        if r.alive and len(r.engine.tiers) > 1 and \
                r.engine.tier_index != 0:
            out.append(f"{r.name}: still degraded ({r.engine.tier}) "
                       "after cooldown")
    return out


CHECKERS: tuple[Callable[[Fleet, dict], list[str]], ...] = (
    check_zero_lost, check_exactly_once, check_meter_conservation,
    check_deadline_accounting, check_monotone_tiers)


# --- the campaign ----------------------------------------------------------


@dataclasses.dataclass
class ChaosReport:
    """Outcome of one campaign: what was injected, what the invariants
    said, and the headline serving metrics under chaos."""
    seed: int | None
    events_applied: list[dict]
    violations: list[str]
    faults_by_kind: dict[str, int]
    submitted: int
    completed: int
    lost: int
    requeued: int
    retry_exhausted: int
    max_attempt: int
    recoveries: int
    restarts: dict[str, int]
    shed: int
    deadline_evictions: int
    ttft_p95_ticks: float
    ttft_slo_ticks: float
    tier_occupancy: dict[str, int]
    degradation_events: int
    final_tiers: dict[str, str]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


class ChaosCampaign:
    """Drive a fleet through a request trace + fault schedule, then run
    the invariant checkers.

    Args:
      fleet: the fleet under test (fresh — the campaign owns its clock).
      trace: base request trace (submitted up front; arrivals replay on
        the fleet tick clock as usual).
      schedule: the faults to inject.
      cooldown_ticks: extra idle ticks after the traffic drains so the
        degradation controller can restore the exact tier (checked by
        the monotone-tiers invariant).
      burst_factory: builds the k-th flood request for "burst" events;
        default derives prompts/ids from the schedule seed.
    """

    def __init__(self, fleet: Fleet, trace: Sequence[Request],
                 schedule: ChaosSchedule, *, cooldown_ticks: int = 48,
                 burst_factory: Callable[[int, int], Request] | None = None):
        self.fleet = fleet
        self.trace = list(trace)
        self.schedule = schedule
        self.cooldown_ticks = cooldown_ticks
        self._burst_factory = burst_factory or self._default_burst
        self._burst_rng = random.Random(
            (schedule.seed or 0) ^ 0x5EED)
        self._burst_n = 0
        self.requests: dict[str, Request] = {}
        self.events_applied: list[dict] = []

    def _default_burst(self, tick: int, k: int) -> Request:
        prompt = [self._burst_rng.randrange(1, 256) for _ in range(8)]
        slo = self.fleet.cfg.ttft_slo_ticks
        return Request(
            request_id=f"chaos-burst-{tick}-{k}",
            tokens=prompt,
            sampling=SamplingParams(max_new_tokens=8),
            arrival=float(tick),
            ttft_deadline_ticks=4.0 * slo,
            deadline_ticks=8.0 * slo)

    def _submit(self, req: Request) -> None:
        self.requests[req.request_id] = req
        self.fleet.submit(req)

    def _apply(self, ev: ChaosEvent) -> None:
        fleet = self.fleet
        self.events_applied.append(ev.to_dict())
        if ev.kind in ("kill", "transient"):
            r = next(x for x in fleet.replicas if x.name == ev.replica)
            recovery = (ev.recovery_ticks if ev.kind == "transient"
                        else None)
            if r.alive and r.busy:
                # die INSIDE the next step — exercises the ReplicaDead-
                # out-of-step failover path, incl. mid-prefill state
                r.inject_fault(at_step=0, recovery_ticks=recovery)
            else:
                fleet.kill_replica(ev.replica, recovery_ticks=recovery)
        elif ev.kind == "submit_fault":
            r = next(x for x in fleet.replicas if x.name == ev.replica)
            if r.alive:
                r.inject_submit_fault(recovery_ticks=ev.recovery_ticks)
        elif ev.kind == "straggler":
            r = next(x for x in fleet.replicas if x.name == ev.replica)
            if r.alive:
                r.inject_slowdown(ev.factor, steps=ev.duration_ticks)
        elif ev.kind == "grid_spike":
            r = next(x for x in fleet.replicas if x.name == ev.replica)
            t0 = r.virtual_ticks * r.seconds_per_tick
            t1 = t0 + ev.duration_ticks * r.seconds_per_tick
            r.grid = SpikedGrid(base=r.grid, t0_s=t0, t1_s=t1,
                                factor=ev.factor)
        elif ev.kind == "burst":
            for _ in range(ev.n_requests):
                self._burst_n += 1
                self._submit(self._burst_factory(ev.tick, self._burst_n))

    def run(self) -> ChaosReport:
        fleet = self.fleet
        for req in self.trace:
            self._submit(req)
        events = sorted(self.schedule.events,
                        key=lambda e: (e.tick, e.kind, e.replica or ""))
        i = 0
        while fleet.busy() or i < len(events):
            while i < len(events) and events[i].tick <= fleet.tick:
                self._apply(events[i])
                i += 1
            fleet.step()
        for _ in range(self.cooldown_ticks):
            fleet.step()
        return self.report()

    def report(self) -> ChaosReport:
        fleet = self.fleet
        violations = [v for chk in CHECKERS
                      for v in chk(fleet, self.requests)]
        comps = fleet.completions()
        by_kind: dict[str, int] = {}
        for ev in self.events_applied:
            by_kind[ev["kind"]] = by_kind.get(ev["kind"], 0) + 1
        st = fleet.stats()
        rb = st["robustness"]
        return ChaosReport(
            seed=self.schedule.seed,
            events_applied=list(self.events_applied),
            violations=violations,
            faults_by_kind=by_kind,
            submitted=st["submitted"],
            completed=st["completed"],
            lost=len(st["lost"]),
            requeued=st["requeued"],
            retry_exhausted=rb["retry_exhausted"],
            max_attempt=rb["max_attempt"],
            recoveries=len(rb["recoveries"]),
            restarts=dict(rb["restarts"]),
            shed=sum(1 for c in comps if c.finish_reason == "shed"),
            deadline_evictions=sum(
                1 for c in comps if c.finish_reason == "deadline"),
            # wall-clock (fleet-tick) TTFT: the SLO-facing metric — the
            # engine clock outruns the fleet clock on degraded tiers
            ttft_p95_ticks=_p95(list(fleet.wall_ttft_ticks().values())),
            ttft_slo_ticks=fleet.cfg.ttft_slo_ticks,
            tier_occupancy=fleet.tier_occupancy(),
            degradation_events=len(rb["degradation_events"]),
            final_tiers={r.name: r.engine.tier for r in fleet.replicas},
        )
