"""Grid carbon-intensity providers.

A provider answers one question: *how many grams of CO2-equivalent does
one kWh drawn from this region's grid emit at time t?*  Time is a plain
float of seconds on a region-local **step clock** that starts at 0 —
never a wall-clock timestamp — so traces replay deterministically in
tests and benchmarks regardless of host timezone or run date.  Callers
pick the clock: the energy meter advances its clock by measured step
seconds; the fleet router queries at its virtual tick time.

Two implementations:

  * `StaticGrid` — a constant intensity from the sourced region table
    (annual averages; the right model for design-time scenario sweeps);
  * `TraceGrid` — a replayable piecewise-constant trace (the right model
    for testing carbon-aware routing, where the *ordering* of intensity
    crossings is what the router reacts to).  `diurnal_trace` builds the
    canonical day-curve shape.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Protocol, Sequence, runtime_checkable

#: Region -> grid carbon intensity [g CO2eq / kWh], 2023 annual averages
#: (generation-based) from Ember's Electricity Data Explorer country
#: figures (ember-climate.org, "Carbon intensity of electricity", 2023),
#: rounded.  Region keys follow cloud-region naming; the mapped country
#: is in the comment.  These are *scenario constants*, not live signals:
#: a deployment would substitute an API-backed provider with the same
#: `g_per_kwh(t_s)` surface.
REGION_INTENSITY_G_PER_KWH: dict[str, float] = {
    "eu-north":   41.0,    # Sweden (hydro + nuclear)
    "ca-east":   130.0,    # Canada (Quebec hydro-dominated national mix)
    "us-west":   263.0,    # California
    "eu-west":   346.0,    # Ireland
    "us-east":   379.0,    # United States (Virginia ~ national average)
    "eu-central": 381.0,   # Germany
    "ap-northeast": 485.0,  # Japan
    "ap-east":   561.0,    # Taiwan
    "ap-south":  713.0,    # India (coal-heavy)
}


@runtime_checkable
class GridProvider(Protocol):
    """Minimal provider surface: a region label and an intensity curve
    over a region-local step clock (seconds since clock start)."""

    region: str

    def g_per_kwh(self, t_s: float) -> float: ...


@dataclasses.dataclass(frozen=True)
class StaticGrid:
    """Constant intensity; built from the region table by default."""

    region: str
    intensity_g_per_kwh: float | None = None

    def __post_init__(self):
        if self.intensity_g_per_kwh is None:
            if self.region not in REGION_INTENSITY_G_PER_KWH:
                raise ValueError(
                    f"unknown region {self.region!r}; pass "
                    f"intensity_g_per_kwh= or use one of "
                    f"{sorted(REGION_INTENSITY_G_PER_KWH)}")
            object.__setattr__(self, "intensity_g_per_kwh",
                               REGION_INTENSITY_G_PER_KWH[self.region])
        if self.intensity_g_per_kwh <= 0:
            raise ValueError("grid intensity must be > 0 g/kWh")

    def g_per_kwh(self, t_s: float) -> float:
        return self.intensity_g_per_kwh


@dataclasses.dataclass(frozen=True)
class TraceGrid:
    """Replayable piecewise-constant intensity trace.

    `values[i]` holds on `[i * step_s, (i + 1) * step_s)`; with
    `wrap=True` (default) the trace repeats, otherwise the last value
    holds forever.  Negative times clamp to the first sample rather than
    raising — a replica's clock may lag the router's by a warmup step.
    """

    region: str
    step_s: float
    values: tuple[float, ...]

    wrap: bool = True

    def __post_init__(self):
        if self.step_s <= 0:
            raise ValueError("step_s must be > 0")
        vals = tuple(float(v) for v in self.values)
        if not vals:
            raise ValueError("TraceGrid needs at least one sample")
        if any(v <= 0 for v in vals):
            raise ValueError("grid intensities must be > 0 g/kWh")
        object.__setattr__(self, "values", vals)

    def g_per_kwh(self, t_s: float) -> float:
        i = int(max(t_s, 0.0) // self.step_s)
        if self.wrap:
            i %= len(self.values)
        else:
            i = min(i, len(self.values) - 1)
        return self.values[i]

    @property
    def period_s(self) -> float:
        return self.step_s * len(self.values)


def diurnal_trace(region: str, *, mean_g_per_kwh: float | None = None,
                  swing: float = 0.4, period_s: float = 86400.0,
                  samples: int = 24, phase: float = 0.0) -> TraceGrid:
    """Sinusoidal day curve sampled into a `TraceGrid`: intensity peaks
    mid-trace (evening fossil ramp) and bottoms out a half-period away
    (solar noon), `swing` being the peak deviation as a fraction of the
    mean.  `phase` (radians) shifts the curve — two regions with opposed
    phases model the time-zone offset that makes follow-the-sun routing
    worthwhile."""
    mean = (REGION_INTENSITY_G_PER_KWH[region]
            if mean_g_per_kwh is None else mean_g_per_kwh)
    if not 0.0 <= swing < 1.0:
        raise ValueError("swing must be in [0, 1)")
    vals = [mean * (1.0 - swing * math.cos(2.0 * math.pi * i / samples
                                           + phase))
            for i in range(samples)]
    return TraceGrid(region=region, step_s=period_s / samples,
                     values=tuple(vals))
