"""Fixed-capacity slot arena for decode state.

The arena is the model's decode cache instantiated once at `capacity`
slots with static shapes, so the jitted decode step compiles exactly once
per config.  Admitting a request copies its single-row prefill cache into
a free slot with `dynamic_update_slice`; the slot axis of every cache
leaf is discovered structurally (families put the batch dimension at
different depths — transformer KV at axis 1, vision superblocks at axis
2, rglru tails at axis 1 — so nothing here is family-specific).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api


def _slot_axis(req_shape: tuple, arena_shape: tuple) -> int:
    """Axis along which a 1-row request cache stacks into the arena."""
    if len(req_shape) != len(arena_shape):
        raise ValueError(f"cache rank mismatch: {req_shape} vs {arena_shape}")
    for i, (r, a) in enumerate(zip(req_shape, arena_shape)):
        if r != a:
            if r != 1:
                raise ValueError(
                    f"non-slot axis differs: {req_shape} vs {arena_shape}")
            return i
    return 0  # capacity == 1: a full overwrite along any axis is exact


class SlotArena:
    """Holds the batched decode cache + per-leaf slot axes and the jitted
    insert.  `cache["length"]` is per-slot (capacity,), which is what the
    refactored model decode paths consume."""

    def __init__(self, cfg: ModelConfig, capacity: int, max_len: int):
        self.cfg, self.capacity, self.max_len = cfg, capacity, max_len
        cache = api.init_cache(cfg, capacity, max_len)
        cache["length"] = jnp.zeros((capacity,), jnp.int32)
        self.cache = cache
        ref = jax.eval_shape(lambda: api.init_cache(cfg, 1, max_len))
        ref["length"] = jax.ShapeDtypeStruct((1,), jnp.int32)
        ref_flat, ref_def = jax.tree_util.tree_flatten(ref)
        arena_flat, arena_def = jax.tree_util.tree_flatten(cache)
        if ref_def != arena_def:
            raise ValueError("cache structure depends on batch size")
        self._axes = tuple(_slot_axis(r.shape, a.shape)
                           for r, a in zip(ref_flat, arena_flat))
        self._treedef = arena_def
        self._insert = jax.jit(self._insert_impl)

    def _insert_impl(self, cache: dict, req_cache: dict,
                     slot: jax.Array) -> dict:
        flat_c = jax.tree_util.tree_leaves(cache)
        flat_r = jax.tree_util.tree_leaves(req_cache)
        out = [jax.lax.dynamic_update_slice_in_dim(
                   c, r.astype(c.dtype), slot, axis=ax)
               for c, r, ax in zip(flat_c, flat_r, self._axes)]
        return jax.tree_util.tree_unflatten(self._treedef, out)

    def insert(self, req_cache: dict, slot: int) -> None:
        """Copy a 1-row prefill cache (built with max_len=self.max_len and
        a true_len vector) into `slot`."""
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(slot, jnp.int32))


class PagedArena:
    """Paged decode state: cache leaves that scale with `max_len` (the
    KV-style buffers) become page POOLS — one global rows axis of
    `n_pages * page_size` positions — addressed through per-request
    block tables; every other leaf (SSM states, conv tails, ring
    buffers, per-slot lengths) stays a dense per-slot arena leaf exactly
    like `SlotArena`.

    Which leaves page is discovered structurally, never by name: a leaf
    pages iff probing `api.init_cache` at `max_len` and `2 * max_len`
    moves exactly one axis from `max_len` to `2 * max_len` and that axis
    sits immediately after the slot axis (the layout every family's KV
    buffers use; anything else — rglru's window-clamped rings, encdec's
    fixed `enc_seq` cross buffers, mamba2's O(1) states — falls back to
    the always-correct dense path).

    The jitted hot paths consume pools through `view()` — a pure gather
    that reconstructs EXACTLY the dense `(capacity, max_len)` cache the
    baseline decode consumes, so paged serving runs the same model math
    on the same values.  Stale garbage past each row's length is masked
    to -1e30 inside attention (exp underflows to exact 0), so page reuse
    cannot perturb outputs.  `scatter_rows()` commits one written view
    row per slot back to the pools; anything that must be dropped
    (inactive lanes, rejected speculative positions) is redirected to
    the reserved trash page 0, keeping every scatter's shape static.
    """

    TRASH_FLAT = 0   # flat row 0 == page 0: the write sink

    def __init__(self, cfg: ModelConfig, capacity: int, max_len: int,
                 page_size: int, n_pages: int):
        self.cfg, self.capacity, self.max_len = cfg, capacity, max_len
        self.page_size, self.n_pages = page_size, n_pages
        self.max_pages = -(-max_len // page_size)  # table width
        dense = api.init_cache(cfg, capacity, max_len)
        dense["length"] = jnp.zeros((capacity,), jnp.int32)
        ref = jax.eval_shape(lambda: api.init_cache(cfg, 1, max_len))
        ref["length"] = jax.ShapeDtypeStruct((1,), jnp.int32)
        big = jax.eval_shape(
            lambda: api.init_cache(cfg, capacity, 2 * max_len))
        big["length"] = jax.ShapeDtypeStruct((capacity,), jnp.int32)
        if set(dense) != set(ref) or set(dense) != set(big):
            raise ValueError("cache keys depend on batch/max_len")
        self.slot_axes: dict[str, int] = {}
        self.paged: dict[str, int] = {}   # key -> pool rows axis
        cache = {}
        for key in sorted(dense):
            a, g = dense[key], big[key]
            sax = _slot_axis(ref[key].shape, a.shape)
            self.slot_axes[key] = sax
            grew = [i for i, (x, y) in enumerate(zip(a.shape, g.shape))
                    if x != y]
            if (key != "length" and len(grew) == 1
                    and a.shape[grew[0]] == max_len
                    and g.shape[grew[0]] == 2 * max_len
                    and grew[0] == sax + 1):
                pool_shape = (a.shape[:sax] + (n_pages * page_size,)
                              + a.shape[sax + 2:])
                cache[key] = jnp.zeros(pool_shape, a.dtype)
                self.paged[key] = sax   # batch axis removed: rows at sax
            else:
                cache[key] = a
        self.cache = cache
        self._insert = jax.jit(self._insert_impl)
        self._copy = jax.jit(self._copy_impl)

    # --- pure helpers (used INSIDE the engine's jitted steps) -------------

    def view(self, cache: dict, table: jax.Array) -> dict:
        """Gather the dense (capacity, max_len) per-slot cache the
        baseline decode consumes.  Rows of unreserved table entries
        alias the trash page — harmless, they sit past `length`."""
        ps = self.page_size
        j = jnp.arange(self.max_len)
        idx = jnp.take(table, j // ps, axis=1) * ps + (j % ps)[None, :]
        out = dict(cache)
        for key, axis in self.paged.items():
            out[key] = jnp.take(cache[key], idx, axis=axis)
        return out

    def scatter_rows(self, cache: dict, view: dict, table: jax.Array,
                     pos: jax.Array, valid: jax.Array) -> dict:
        """Commit, per slot, the single view row at `pos` (capacity,)
        back into the pools; slots with `valid` False write the trash
        page instead.  Only paged leaves change — the caller carries
        slot leaves and lengths forward itself."""
        ps = self.page_size
        cap = pos.shape[0]
        page = jnp.take_along_axis(table, (pos // ps)[:, None], axis=1)[:, 0]
        flat = jnp.where(valid, page * ps + pos % ps, self.TRASH_FLAT)
        out = dict(cache)
        for key, axis in self.paged.items():
            v = jnp.moveaxis(view[key], (axis, axis + 1), (0, 1))
            rows = v[jnp.arange(cap), pos]          # (capacity, rest...)
            pool = jnp.moveaxis(cache[key], axis, 0)
            pool = pool.at[flat].set(rows.astype(pool.dtype))
            out[key] = jnp.moveaxis(pool, 0, axis)
        return out

    # --- jitted state mutations ------------------------------------------

    def _insert_impl(self, cache: dict, req_cache: dict, slot: jax.Array,
                     flat_idx: jax.Array) -> dict:
        """Admit a 1-row prefill/workspace cache: paged leaves scatter
        their `max_len` rows to `flat_idx` (host-built: prefix-shared
        and unwritten positions point at the trash page, so read-only
        pages are never touched and fresh pages stay zero past the
        prompt); slot leaves copy into `slot` like `SlotArena`."""
        out = {}
        for key in sorted(cache):
            c, r = cache[key], req_cache[key]
            if key in self.paged:
                axis = self.paged[key]
                rows = jnp.moveaxis(jnp.squeeze(r, axis=axis), axis, 0)
                pool = jnp.moveaxis(c, axis, 0)
                pool = pool.at[flat_idx].set(rows.astype(c.dtype))
                out[key] = jnp.moveaxis(pool, 0, axis)
            else:
                out[key] = jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=self.slot_axes[key])
        return out

    def _copy_impl(self, cache: dict, src: jax.Array, dst: jax.Array
                   ) -> dict:
        """Page-granular pool copy (copy-on-write / fork divergence).
        `src`/`dst` are page-id vectors; pad unused lanes with the trash
        page (0 -> 0 is a no-op)."""
        out = dict(cache)
        for key, axis in self.paged.items():
            pool = jnp.moveaxis(cache[key], axis, 0)
            pages = pool.reshape(self.n_pages, self.page_size,
                                 *pool.shape[1:])
            pages = pages.at[dst].set(pages[src])
            out[key] = jnp.moveaxis(
                pages.reshape(pool.shape), 0, axis)
        return out

    def insert(self, req_cache: dict, slot: int,
               flat_idx) -> None:
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(slot, jnp.int32),
                                  jnp.asarray(flat_idx, jnp.int32))

    def copy_pages(self, src, dst) -> None:
        self.cache = self._copy(self.cache, jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32))
