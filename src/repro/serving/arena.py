"""Fixed-capacity slot arena for decode state.

The arena is the model's decode cache instantiated once at `capacity`
slots with static shapes, so the jitted decode step compiles exactly once
per config.  Admitting a request copies its single-row prefill cache into
a free slot with `dynamic_update_slice`; the slot axis of every cache
leaf is discovered structurally (families put the batch dimension at
different depths — transformer KV at axis 1, vision superblocks at axis
2, rglru tails at axis 1 — so nothing here is family-specific).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api


def _slot_axis(req_shape: tuple, arena_shape: tuple) -> int:
    """Axis along which a 1-row request cache stacks into the arena."""
    if len(req_shape) != len(arena_shape):
        raise ValueError(f"cache rank mismatch: {req_shape} vs {arena_shape}")
    for i, (r, a) in enumerate(zip(req_shape, arena_shape)):
        if r != a:
            if r != 1:
                raise ValueError(
                    f"non-slot axis differs: {req_shape} vs {arena_shape}")
            return i
    return 0  # capacity == 1: a full overwrite along any axis is exact


class SlotArena:
    """Holds the batched decode cache + per-leaf slot axes and the jitted
    insert.  `cache["length"]` is per-slot (capacity,), which is what the
    refactored model decode paths consume."""

    def __init__(self, cfg: ModelConfig, capacity: int, max_len: int):
        self.cfg, self.capacity, self.max_len = cfg, capacity, max_len
        cache = api.init_cache(cfg, capacity, max_len)
        cache["length"] = jnp.zeros((capacity,), jnp.int32)
        self.cache = cache
        ref = jax.eval_shape(lambda: api.init_cache(cfg, 1, max_len))
        ref["length"] = jax.ShapeDtypeStruct((1,), jnp.int32)
        ref_flat, ref_def = jax.tree_util.tree_flatten(ref)
        arena_flat, arena_def = jax.tree_util.tree_flatten(cache)
        if ref_def != arena_def:
            raise ValueError("cache structure depends on batch size")
        self._axes = tuple(_slot_axis(r.shape, a.shape)
                           for r, a in zip(ref_flat, arena_flat))
        self._treedef = arena_def
        self._insert = jax.jit(self._insert_impl)

    def _insert_impl(self, cache: dict, req_cache: dict,
                     slot: jax.Array) -> dict:
        flat_c = jax.tree_util.tree_leaves(cache)
        flat_r = jax.tree_util.tree_leaves(req_cache)
        out = [jax.lax.dynamic_update_slice_in_dim(
                   c, r.astype(c.dtype), slot, axis=ax)
               for c, r, ax in zip(flat_c, flat_r, self._axes)]
        return jax.tree_util.tree_unflatten(self._treedef, out)

    def insert(self, req_cache: dict, slot: int) -> None:
        """Copy a 1-row prefill cache (built with max_len=self.max_len and
        a true_len vector) into `slot`."""
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(slot, jnp.int32))
