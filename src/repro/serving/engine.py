"""Continuous-batching inference engine.

The `Engine` turns the model API's (prefill, decode_step) pair into a
request/response service: requests are admitted from a queue into free
slots of a fixed-capacity decode arena (prefill-then-join), every decode
step advances all occupied slots at their own per-slot lengths, and
finished requests (max tokens / EOS) are evicted so their slots can be
reused mid-flight.  All device work happens in three jitted functions —
prefill (one compile per prompt bucket), slot insert, and
decode+sample — whose shapes depend only on (config, capacity, max_len),
never on the traffic, so there are no per-step recompiles.

Approximate-multiplier serving composes transparently: the engine
resolves `cfg.mult` / `cfg.kernel_policy` through `api.make_spec` exactly
like training, so exact and approximate serving share this code path.
Under an approximate spec the engine serves from a persistent weight-plane
cache (`api.prepare_params`): each GEMM weight is quantized — and, for the
XLA path, table-mapped — once at engine construction instead of on every
decode step.

Graceful degradation rides on the same machinery: `tiers=` names an
ordered ladder of multiplier tiers (index 0 = highest accuracy, the
default; later entries trade accuracy for energy/delay, the paper's
knob applied at serve time).  Each tier gets its own resolved spec,
prepared weight planes, and jitted prefill/decode pair at construction;
`set_tier` flips which one serves — an O(1) host-side pointer swap, no
re-quantization, no cache invalidation (the KV/state arena is
tier-independent).  Every emitted token is attributed to the tier that
produced it (`Completion.tier_tokens`), so accuracy exposure under
brownout is auditable.

Request-lifecycle robustness: per-request TTFT/total deadlines (in
ticks) with load-shedding (`finish_reason="shed"`) and mid-decode
deadline eviction (`"deadline"`), and exception-safe admission — a crash
inside prefill re-queues the victim request before propagating, so a
fleet supervisor draining `pending_requests()` off the dead engine never
loses it.
"""

from __future__ import annotations

import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serving import sampling
from repro.serving.arena import SlotArena
from repro.serving.scheduler import Scheduler
from repro.serving.types import (Completion, Request, SamplingParams,
                                 SpecStats)
from repro.sharding import ctx, rules
from repro.train import train_step as ts


class _Slot:
    """Host-side record of one occupied arena slot."""

    def __init__(self, request: Request, prompt_len: int, admitted_tick: int,
                 ready_wall: float, admit_seq: int):
        self.request = request
        self.prompt_len = prompt_len
        self.tokens: list[int] = []
        self.admitted_tick = admitted_tick
        self.ready_wall = ready_wall
        self.first_wall = 0.0
        #: engine tick at which the first token was emitted (chunked
        #: prefill emits it later than admitted_tick)
        self.first_tick = admitted_tick
        self.admit_seq = admit_seq            # FIFO drain order
        self.tier_tokens: dict[str, int] = {}
        #: speculative-decode counters ({"proposed", "accepted",
        #: "corrections"}) filled in by the paged engine; None means the
        #: slot was served without speculation (Completion.spec = None)
        self.spec_counts: dict[str, int] | None = None
        #: True while a paged-engine slot is still prefilling in chunks
        #: (occupies a slot + pages, but does not decode or emit yet)
        self.prefilling = False


class Engine:
    """Slot-based continuous-batching engine over `models/api.py`.

    Args:
      cfg: model config (any family: lm / ssm / hybrid / encdec).
      params: model params; initialized from `seed` when None.
      capacity: decode-arena slots (max concurrent requests).
      max_len: arena sequence horizon; prompt_len + max_new_tokens - 1
        must fit.
      prefill_buckets: prompt pad lengths; each bucket compiles prefill
        once.  Default (max_len,) keeps the one-compile-per-phase
        guarantee; pass e.g. (32, 128, 512) to trade a few compiles for
        less padded prefill compute.
      mesh: device mesh (host mesh by default).  Weights, decode caches,
        and sampler state commit onto it under the tensor-parallel rules
        of sharding/rules.py, so a multi-device "model" axis serves
        genuinely tensor-parallel.
      target: `core.target.HardwareTarget` — builds the mesh from the
        target's axes when `mesh` is None (one die == one TP shard).
      seed: engine RNG seed (params init + per-request sampling streams).
      on_token: streaming callback `f(request_id, token_id)`.
      meter: optional `repro.fleet.meter.EnergyMeter` — converts the
        measured prefill/decode step seconds into per-request energy and
        CO2eq (`Completion.carbon`, cumulative counters in `stats()`).
        None (default) serves unmetered at zero added work beyond an
        `is None` check per phase.
      tiers: ordered multiplier-tier ladder for graceful degradation
        (names resolvable by `api.make_spec`, e.g. ("exact", "trunc2x2",
        "trunc4x4")); index 0 serves by default.  None (default) keeps
        the single-tier behavior: one tier named by `cfg.mult`.
    """

    def __init__(self, cfg: ModelConfig, params: Any | None = None, *,
                 capacity: int = 4, max_len: int = 256,
                 prefill_buckets: tuple[int, ...] | None = None,
                 mesh=None, target=None, seed: int = 0,
                 on_token: Callable[[str, int], None] | None = None,
                 meter=None, tiers: tuple[str, ...] | None = None):
        if mesh is None:
            if target is not None:
                mesh = target.make_mesh()
            else:
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh()
        self.cfg, self.mesh, self.seed = cfg, mesh, seed
        self.target = target
        self.capacity, self.max_len = capacity, max_len
        self.buckets = tuple(sorted(prefill_buckets or (max_len,)))
        self.on_token = on_token
        self.meter = meter
        self.tiers = tuple(tiers) if tiers else (cfg.mult or "exact",)
        if len(set(self.tiers)) != len(self.tiers):
            raise ValueError(f"duplicate tier names in {self.tiers}")
        self.params = params if params is not None else api.init_params(
            cfg, jax.random.key(seed))

        self._build_state()

        # Per-tier serving artifacts.  The weight-plane cache is built
        # once per (weight, multiplier) — switching tiers later is a
        # pointer swap, exactly the reuse `api.prepare_params` promises.
        # `self.params` stays raw (bit-identical outputs either way —
        # the cache is a recomputation saving, not an approximation).
        self._tier_specs: dict[str, Any] = {}
        self._tier_exec: dict[str, Any] = {}
        self._tier_prefill_fns: dict[str, Any] = {}
        self._tier_decode_fns: dict[str, Any] = {}
        for name in self.tiers:
            spec = api.make_spec(cfg, mult=name)
            self._tier_specs[name] = spec
            self._tier_exec[name] = api.prepare_params(
                self.params, cfg, spec, mesh=self.mesh)
            self._tier_prefill_fns[name] = ts.make_prefill_step(
                cfg, mesh, max_len=max_len, spec=spec)
            self._tier_decode_fns[name] = self._make_decode(spec)
            self._extra_tier_fns(name, spec)
        self._first = jax.jit(sampling.sample_tokens)

        self._tier = self.tiers[0]
        self._tier_tokens: dict[str, int] = {t: 0 for t in self.tiers}
        self._tier_switches: list[dict] = []
        self._activate(self._tier)

        self._sched = Scheduler()
        self._ids: set[str] = set()
        self._slots: list[_Slot | None] = [None] * capacity
        self._free = list(range(capacity - 1, -1, -1))
        self._tick = 0
        self._decode_steps = 0
        self._admitted = 0
        self._prefill_s = 0.0
        self._decode_s = 0.0
        self._queue_wait_ticks = 0.0
        self._evictions = {"eos": 0, "length": 0}
        self.completions: list[Completion] = []

    def _build_state(self) -> None:
        """Construct the device arena + sampler state and commit it onto
        the mesh.  Overridable: the paged engine replaces the whole-slot
        arena with page pools + block tables while reusing everything
        else (tier artifacts, admission, accounting)."""
        cfg, capacity = self.cfg, self.capacity
        self._arena = SlotArena(cfg, capacity, self.max_len)
        self._state = {
            "cache": self._arena.cache,
            "tok": jnp.zeros((capacity, 1), jnp.int32),
            "temp": jnp.zeros((capacity,), jnp.float32),
            "topk": jnp.zeros((capacity,), jnp.int32),
            "rng": jax.random.split(jax.random.key(self.seed), capacity),
        }
        if cfg.cross_every:
            self._state["img"] = jnp.zeros(
                (capacity, cfg.n_img_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        # commit the state once under the SAME rules the decode step's
        # sharding hints request — caches shard their batch dim on "data"
        # and their kv-head dim on "model" (rules.cache_shardings), the
        # per-slot sampler state shards on "data" where it divides — and
        # pin the decode step's output to that commitment, so every step
        # sees identical shardings (a single compilation, and no
        # replicated-KV fallback on a multi-device mesh).
        self._state_sh = self._state_shardings()
        self._state = jax.device_put(self._state, self._state_sh)

    def _extra_tier_fns(self, name: str, spec) -> None:
        """Hook: build additional per-tier jitted functions (the paged
        engine adds chunked-prefill and speculative-verify steps)."""

    def _replicated(self):
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.mesh, PartitionSpec())

    def _state_shardings(self) -> dict:
        """Rules-driven NamedShardings for the decode-arena state."""
        from jax.sharding import NamedSharding
        mesh = self.mesh
        sh = {"cache": rules.cache_shardings(self._state["cache"], mesh)}
        for key in ("tok", "temp", "topk"):
            sh[key] = NamedSharding(mesh, rules.batch_pspec(
                key, self._state[key].shape, mesh))
        sh["rng"] = self._replicated()   # per-slot PRNG keys: tiny
        if "img" in self._state:
            sh["img"] = NamedSharding(mesh, rules.batch_pspec(
                "img", self._state["img"].shape, mesh))
        return sh

    # --- jitted decode + sample ------------------------------------------

    def _make_decode(self, spec):
        """One jitted decode+sample per tier: the spec is baked into the
        trace (it is a jit-cache-keying pytree), so each tier compiles
        exactly once and tier switches never retrace another tier."""

        def decode_impl(params, state):
            extras = {"img_embeds": state["img"]} if "img" in state else {}
            with ctx.use_rules(self.mesh, rules.logical_rules(self.mesh)):
                logits, cache = api.decode_step(params, state["cache"],
                                                state["tok"], self.cfg,
                                                spec=spec, extras=extras)
            keys = jax.vmap(lambda k: jax.random.split(k))(state["rng"])
            tok = sampling.sample_tokens(logits[:, -1], state["temp"],
                                         state["topk"], keys[:, 0])
            new = dict(state, cache=cache, tok=tok[:, None], rng=keys[:, 1])
            return new, tok

        return jax.jit(decode_impl, donate_argnums=(1,),
                       out_shardings=(self._state_sh, self._replicated()))

    # --- degradation tiers ------------------------------------------------

    @property
    def tier(self) -> str:
        """Name of the multiplier tier currently serving."""
        return self._tier

    @property
    def tier_index(self) -> int:
        return self.tiers.index(self._tier)

    def _activate(self, name: str) -> None:
        """Point the serving hot path at `name`'s artifacts (also used
        by the retrace sanitizer to re-point after wrapping)."""
        self._spec = self._tier_specs[name]
        self.exec_params = self._tier_exec[name]
        self._prefill = self._tier_prefill_fns[name]
        self._decode = self._tier_decode_fns[name]

    def set_tier(self, name: str) -> None:
        """Switch the serving tier (prefill AND decode).  In-flight
        requests keep their KV/state — tokens emitted after the switch
        come from the new tier's multiplier and are attributed to it."""
        if name not in self._tier_specs:
            raise ValueError(
                f"unknown tier {name!r}; engine tiers: {self.tiers}")
        if name == self._tier:
            return
        self._tier_switches.append(
            {"tick": self._tick, "from": self._tier, "to": name})
        self._tier = name
        self._activate(name)

    # --- submission -------------------------------------------------------

    def submit(self, request: Request) -> None:
        """Queue a request for admission at its arrival tick."""
        n = len(request.tokens)
        sp = request.sampling
        if request.request_id in self._ids:
            raise ValueError(
                f"duplicate request_id {request.request_id!r}")
        if n < 1:
            raise ValueError(f"{request.request_id}: empty prompt")
        if n > self.buckets[-1]:
            raise ValueError(
                f"{request.request_id}: prompt len {n} exceeds largest "
                f"prefill bucket {self.buckets[-1]}")
        if sp.max_new_tokens < 1:
            raise ValueError(f"{request.request_id}: max_new_tokens < 1")
        if n + sp.max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"{request.request_id}: prompt {n} + {sp.max_new_tokens} "
                f"new tokens exceeds arena max_len {self.max_len}")
        for field in ("ttft_deadline_ticks", "deadline_ticks"):
            v = getattr(request, field)
            if v is not None and v < 1:
                raise ValueError(f"{request.request_id}: {field} must be "
                                 f">= 1 tick (got {v})")
        self._ids.add(request.request_id)
        self._sched.submit(request)

    # --- admission (prefill-then-join) -----------------------------------

    def _request_key(self, sp: SamplingParams) -> jax.Array:
        if sp.seed is not None:
            return jax.random.key(sp.seed)
        return jax.random.fold_in(jax.random.key(self.seed),
                                  1 + self._admitted)

    def _prefill_extras(self, request: Request) -> dict:
        cfg = self.cfg
        ex = dict(request.extras or {})
        out = {}
        if cfg.family == "encdec":
            frames = ex.get("frames")
            if frames is None:
                frames = jnp.zeros((1, cfg.enc_seq, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
            out["frames"] = jnp.asarray(frames).reshape(
                1, cfg.enc_seq, cfg.d_model)
        if cfg.cross_every:
            img = ex.get("img_embeds")
            if img is None:
                img = jnp.zeros((1, cfg.n_img_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
            out["img_embeds"] = jnp.asarray(img).reshape(
                1, cfg.n_img_tokens, cfg.d_model)
        return out

    def _admit(self, request: Request, ready_wall: float,
               slot_id: int) -> None:
        sp = request.sampling
        prompt = np.asarray(request.tokens, np.int32)
        n = prompt.shape[0]
        bucket = next(b for b in self.buckets if b >= n)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :n] = prompt
        extras = self._prefill_extras(request)
        t0 = time.perf_counter()
        logits, req_cache = self._prefill(
            self.exec_params, jnp.asarray(padded), extras,
            true_len=jnp.asarray([n], jnp.int32))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self._prefill_s += dt
        if self.meter is not None:
            self.meter.on_prefill(request.request_id, dt)
        key = self._request_key(sp)
        first = self._first(logits.astype(jnp.float32),
                            jnp.asarray([sp.temperature], jnp.float32),
                            jnp.asarray([sp.top_k], jnp.int32),
                            key[None])
        self._admitted += 1

        self._arena.cache = self._state["cache"]
        self._arena.insert(req_cache, slot_id)
        self._state["cache"] = self._arena.cache
        at = jnp.asarray(slot_id)
        self._state = dict(
            self._state,
            tok=self._state["tok"].at[at].set(first[:, None][0]),
            temp=self._state["temp"].at[at].set(sp.temperature),
            topk=self._state["topk"].at[at].set(sp.top_k),
            rng=self._state["rng"].at[at].set(key))
        if "img" in self._state:
            self._state["img"] = jax.lax.dynamic_update_slice_in_dim(
                self._state["img"], extras["img_embeds"].astype(
                    self._state["img"].dtype), slot_id, axis=0)
        # re-commit the canonical shardings after the out-of-jit updates
        # (slot insert / .at scatters), so the decode step's jit cache
        # always keys on one sharding layout
        self._state = jax.device_put(self._state, self._state_sh)

        slot = _Slot(request, n, self._tick, ready_wall, self._admitted)
        slot.first_wall = time.perf_counter()
        self._slots[slot_id] = slot
        self._emit(slot_id, int(first[0]))

    # --- token accounting / eviction -------------------------------------

    def _emit(self, slot_id: int, token: int) -> None:
        slot = self._slots[slot_id]
        slot.tokens.append(token)
        slot.tier_tokens[self._tier] = \
            slot.tier_tokens.get(self._tier, 0) + 1
        self._tier_tokens[self._tier] += 1
        if self.on_token is not None:
            self.on_token(slot.request.request_id, token)
        sp = slot.request.sampling
        req = slot.request
        if sp.eos_id >= 0 and token == sp.eos_id:
            self._evict(slot_id, "eos")
        elif len(slot.tokens) >= sp.max_new_tokens:
            self._evict(slot_id, "length")
        elif req.deadline_ticks is not None and \
                self._tick - req.arrival + 1 >= req.deadline_ticks:
            # total budget exhausted: keep the partial generation, free
            # the slot for work that can still finish in time
            self._evict(slot_id, "deadline")

    def _evict(self, slot_id: int, reason: str) -> None:
        slot = self._slots[slot_id]
        now = time.perf_counter()
        self._evictions[reason] = self._evictions.get(reason, 0) + 1
        self._queue_wait_ticks += max(
            0.0, slot.admitted_tick - slot.request.arrival)
        self.completions.append(Completion(
            request_id=slot.request.request_id,
            prompt_len=slot.prompt_len,
            tokens=slot.tokens,
            finish_reason=reason,
            arrival=slot.request.arrival,
            admitted_tick=slot.admitted_tick,
            finished_tick=self._tick,
            ttft_s=slot.first_wall - slot.ready_wall,
            ttft_ticks=slot.first_tick - slot.request.arrival + 1.0,
            latency_s=now - slot.ready_wall,
            carbon=(self.meter.finalize(slot.request.request_id,
                                        len(slot.tokens))
                    if self.meter is not None else None),
            attempt=slot.request.attempt,
            tier_tokens=dict(slot.tier_tokens),
            spec=(SpecStats(**slot.spec_counts)
                  if slot.spec_counts is not None else None)))
        self._slots[slot_id] = None
        self._free.append(slot_id)

    def _shed(self, request: Request) -> None:
        """Complete a never-admitted request whose deadline is already
        unmeetable (load shedding at admission)."""
        self._evictions["shed"] = self._evictions.get("shed", 0) + 1
        self._sched._ready_wall.pop(request.request_id, None)
        self.completions.append(Completion(
            request_id=request.request_id,
            prompt_len=len(request.tokens),
            tokens=[],
            finish_reason="shed",
            arrival=request.arrival,
            admitted_tick=-1,
            finished_tick=self._tick,
            ttft_s=0.0,
            latency_s=0.0,
            carbon=(self.meter.finalize(request.request_id, 0)
                    if self.meter is not None else None),
            attempt=request.attempt,
            tier_tokens={}))

    # --- the serving loop -------------------------------------------------

    @property
    def tick(self) -> int:
        """Current virtual-clock tick (one decode step per tick)."""
        return self._tick

    @property
    def n_active(self) -> int:
        return self.capacity - len(self._free)

    @property
    def n_queued(self) -> int:
        return len(self._sched)

    def pending_requests(self) -> list[Request]:
        """Every submitted-but-unfinished request in FIFO order:
        in-flight slot occupants by admission order first, then the
        waiting queue by (arrival, submission) order.  This is the drain
        surface a fleet supervisor uses to re-queue work off a dead
        replica — requests, not partial generations, so a re-served
        request regenerates from scratch; the ordering guarantees a
        failover preserves arrival FIFO on the surviving replicas."""
        active = sorted((s for s in self._slots if s is not None),
                        key=lambda s: s.admit_seq)
        out = [s.request for s in active]
        out.extend(self._sched.pending())
        return out

    def active_request_ids(self) -> set[str]:
        """Ids currently holding arena slots (admitted, unfinished) —
        the diff surface a supervisor uses to wall-clock-stamp
        admissions without reaching into slot internals."""
        return {s.request.request_id for s in self._slots if s is not None}

    def step(self) -> None:
        """One engine tick: shed dead-on-arrival requests, admit due
        requests into free slots, then run one decode step across the
        whole arena."""
        now = self._tick
        self._sched.note_ready(now, time.perf_counter())
        for request in self._sched.pop_expired(now):
            self._shed(request)
        while self._free:
            request = self._sched.pop_ready(now)
            if request is None:
                break
            ready_wall = self._sched.ready_wall(request.request_id)
            slot_id = self._free.pop()
            try:
                self._admit(request, ready_wall, slot_id)
            except Exception:
                # crash mid-prefill/insert: restore the host-side queue
                # state so pending_requests() still drains the victim —
                # the supervisor re-queues it elsewhere (device state
                # dies with the engine)
                if self._slots[slot_id] is None:
                    self._free.append(slot_id)
                    self._sched.restore(request, ready_wall)
                raise
        if self.n_active:
            t0 = time.perf_counter()
            self._state, tok = self._decode(self.exec_params, self._state)
            self._decode_steps += 1
            tok_host = np.asarray(tok)          # syncs the step
            dt = time.perf_counter() - t0
            self._decode_s += dt
            if self.meter is not None:
                # charge BEFORE emitting: a request evicted this step
                # must carry this step's share of the energy
                self.meter.on_decode(
                    dt, [s.request.request_id for s in self._slots
                         if s is not None], self.capacity)
            for slot_id in range(self.capacity):
                if self._slots[slot_id] is not None:
                    self._emit(slot_id, int(tok_host[slot_id]))
        self._tick += 1

    def run_until_complete(self) -> list[Completion]:
        """Drive step() until the queue and the arena are both empty;
        idle ticks fast-forward to the next arrival."""
        while self.n_queued or self.n_active:
            if not self.n_active:
                nxt = self._sched.next_arrival()
                if nxt is not None and nxt > self._tick:
                    self._tick = int(math.ceil(nxt))
            self.step()
        return self.completions

    def stats(self) -> dict:
        done = len(self.completions)
        out = {"ticks": self._tick, "decode_steps": self._decode_steps,
               "admitted": self._admitted,
               "completed": done,
               "prefill_s": self._prefill_s, "decode_s": self._decode_s,
               # admission-queue wait (arrival -> admitted, in ticks) and
               # why slots were reclaimed — the signals a capacity planner
               # needs (a rising queue wait means the arena is the
               # bottleneck, not the model)
               "queue_wait_ticks_total": self._queue_wait_ticks,
               "queue_wait_ticks_mean":
                   self._queue_wait_ticks / done if done else 0.0,
               "evictions": dict(self._evictions),
               "mesh": {ax: int(sz) for ax, sz in self.mesh.shape.items()},
               # accuracy-exposure audit: tokens served per multiplier
               # tier plus the switch log (empty while single-tier)
               "tiers": {"active": self._tier,
                         "ladder": list(self.tiers),
                         "tokens": dict(self._tier_tokens),
                         "switches": list(self._tier_switches)}}
        if self.meter is not None:
            out["carbon"] = self.meter.summary()
        for name, fn in (("prefill", self._prefill),
                         ("decode", self._decode)):
            if hasattr(fn, "_cache_size"):
                out[f"{name}_compiles"] = fn._cache_size()
        return out
