"""Vectorized per-slot token sampling.

One fused computation over the whole decode batch: every slot carries its
own (temperature, top_k, PRNG key), so heterogeneous sampling never
fragments the jitted decode step.  temperature <= 0 selects greedy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits: jax.Array, temps: jax.Array, top_ks: jax.Array,
                  keys: jax.Array) -> jax.Array:
    """logits (n, v) -> sampled ids (n,) int32.

    temps (n,) float: <= 0 means greedy for that row.  top_ks (n,) int:
    0 disables the filter.  keys (n,) typed PRNG keys (unused by greedy
    rows).  Rows are fully independent — this is the vectorized-params
    alternative to one jit specialization per sampling config.
    """
    v = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    # per-row k-th largest value as the top-k admission threshold
    srt = jnp.sort(lg, axis=-1)                              # ascending
    k = jnp.clip(top_ks, 0, v)
    thr = jnp.take_along_axis(srt, jnp.clip(v - k, 0, v - 1)[:, None],
                              axis=-1)                        # (n, 1)
    keep = (k <= 0)[:, None] | (lg >= thr)
    masked = jnp.where(keep, lg, -jnp.inf)
    scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temps <= 0.0, greedy, sampled)
