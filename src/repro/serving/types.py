"""Request/response dataclasses for the serving engine.

A `Request` is a prompt plus `SamplingParams` and a (virtual-clock)
arrival time; the engine answers with a `Completion`.  These are plain
host-side objects — device state lives in the engine's slot arena.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

GREEDY = 0.0


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    temperature <= 0 is greedy (argmax); top_k = 0 disables top-k
    filtering; eos_id < 0 disables EOS stopping.  `seed` pins the
    request's sampling stream (None derives one from the engine seed and
    the submission index, so runs stay reproducible by default).
    """
    temperature: float = GREEDY
    top_k: int = 0
    max_new_tokens: int = 16
    eos_id: int = -1
    seed: int | None = None


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request.

    `arrival` is in engine ticks (one `Engine.step()` = one tick); the
    scheduler will not admit the request before that tick, which is how
    benchmarks replay arrival traces deterministically.  `extras` carries
    family-specific conditioning: "frames" (enc_seq, d_model) for encdec,
    "img_embeds" (n_img_tokens, d_model) for vision-cross models.
    """
    request_id: str
    tokens: Sequence[int]
    sampling: SamplingParams = SamplingParams()
    arrival: float = 0.0
    extras: dict[str, Any] | None = None


@dataclasses.dataclass
class Completion:
    """The engine's answer: generated ids + scheduling/latency metadata."""
    request_id: str
    prompt_len: int
    tokens: list[int]
    finish_reason: str          # "length" | "eos"
    arrival: float
    admitted_tick: int
    finished_tick: int
    ttft_s: float               # ready -> first token (wall clock)
    latency_s: float            # ready -> eviction (wall clock)
    #: per-request operational footprint (`repro.fleet.meter.
    #: RequestCarbon`) when the engine serves with an `EnergyMeter`
    #: attached; None when metering is off.  Typed loosely so the
    #: serving layer never imports the fleet package.
    carbon: Any | None = None
