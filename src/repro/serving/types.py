"""Request/response dataclasses for the serving engine.

A `Request` is a prompt plus `SamplingParams`, a (virtual-clock) arrival
time, and optional deadlines; the engine answers with a `Completion`.
These are plain host-side objects — device state lives in the engine's
slot arena.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

GREEDY = 0.0


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    temperature <= 0 is greedy (argmax); top_k = 0 disables top-k
    filtering; eos_id < 0 disables EOS stopping.  `seed` pins the
    request's sampling stream (None derives one from the engine seed and
    the submission index, so runs stay reproducible by default).
    """
    temperature: float = GREEDY
    top_k: int = 0
    max_new_tokens: int = 16
    eos_id: int = -1
    seed: int | None = None


@dataclasses.dataclass(frozen=True)
class SpecStats:
    """Per-request speculative-decoding audit trail.

    `proposed` counts draft-tier proposals the verifier examined;
    `accepted` counts proposals emitted verbatim; `corrections` counts
    tokens the verify tier emitted itself (every non-speculative token —
    the prefill first token included — is a correction, so
    `accepted + corrections == len(Completion.tokens)` always holds).
    """
    proposed: int = 0
    accepted: int = 0
    corrections: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request.

    `arrival` is in engine ticks (one `Engine.step()` = one tick); the
    scheduler will not admit the request before that tick, which is how
    benchmarks replay arrival traces deterministically.  `extras` carries
    family-specific conditioning: "frames" (enc_seq, d_model) for encdec,
    "img_embeds" (n_img_tokens, d_model) for vision-cross models.

    Deadlines are *relative* tick budgets measured from `arrival` (so a
    re-queued attempt, whose arrival is restamped, gets a fresh budget):

      * `ttft_deadline_ticks` — admission-to-first-token budget.  A
        request that cannot emit its first token inside the budget is
        never admitted: the engine sheds it (`finish_reason="shed"`)
        instead of spending prefill on a reply that is already late.
      * `deadline_ticks` — total budget (arrival -> last token).  A
        running request that exhausts it is evicted with its partial
        generation (`finish_reason="deadline"`).

    None (default) disables the respective deadline.  `attempt` is the
    retry ordinal stamped by the fleet router on failover re-queues
    (0 = first attempt); the engine copies it onto the `Completion` so
    exactly-once accounting is auditable end to end.
    """
    request_id: str
    tokens: Sequence[int]
    sampling: SamplingParams = SamplingParams()
    arrival: float = 0.0
    extras: dict[str, Any] | None = None
    ttft_deadline_ticks: float | None = None
    deadline_ticks: float | None = None
    attempt: int = 0


@dataclasses.dataclass
class Completion:
    """The engine's answer: generated ids + scheduling/latency metadata.

    finish_reason: "length" | "eos"      — natural completion;
                   "deadline"            — total deadline hit mid-decode
                                           (partial tokens kept);
                   "shed"                — never admitted: the TTFT
                                           deadline was already blown in
                                           the queue, or the fleet
                                           router exhausted the retry
                                           budget (tokens == []).
    """
    request_id: str
    prompt_len: int
    tokens: list[int]
    finish_reason: str          # "length" | "eos" | "deadline" | "shed"
    arrival: float
    admitted_tick: int          # -1 for shed requests (never admitted)
    finished_tick: int
    ttft_s: float               # ready -> first token (wall clock)
    latency_s: float            # ready -> eviction (wall clock)
    #: inclusive serving iterations from arrival to first token
    #: (first-token tick - arrival + 1): the wall-noise-free TTFT used
    #: by the slot-vs-paged bench gates.  0.0 for shed requests.
    ttft_ticks: float = 0.0
    #: per-request operational footprint (`repro.fleet.meter.
    #: RequestCarbon`) when the engine serves with an `EnergyMeter`
    #: attached; None when metering is off.  Typed loosely so the
    #: serving layer never imports the fleet package.
    carbon: Any | None = None
    #: retry ordinal of the attempt that produced this completion
    #: (copied from `Request.attempt`; 0 = first attempt).
    attempt: int = 0
    #: tokens served per multiplier tier, e.g. {"exact": 3,
    #: "trunc2x2": 5} — the accuracy-exposure audit trail when the
    #: engine serves with degradation tiers.  Empty for shed requests;
    #: None only for completions minted before tier accounting existed.
    tier_tokens: dict[str, int] | None = None
    #: speculative-decoding acceptance accounting (`SpecStats`) when the
    #: request was served by a paged engine with a draft tier; None when
    #: speculation was off (slot engine, or no draft configured).
    spec: SpecStats | None = None
