"""Paged-KV serving engine: block tables + chunked prefill + approx-draft
speculative decoding.

`PagedEngine` subclasses the whole-slot `Engine` and replaces only the
device-state layout and the step loop; admission validation, tier
ladders, deadlines, metering, and eviction accounting are inherited.
Three capabilities stack, each individually optional:

  1. **Paged KV** (always on): `max_len`-scaling cache leaves live in
     global page pools (`PagedArena`); a host-side `PageAllocator` hands
     out block tables with reserve-ahead allocation (every page a
     request can ever touch is reserved at admission, so decode can
     never deadlock mid-request), prefix sharing, and COW bookkeeping.
     The jitted steps gather a dense per-slot view that is bit-identical
     to the baseline arena at every valid position, so paged serving
     emits exactly the tokens the slot engine emits.
  2. **Chunked prefill** (`prefill_chunk=c`): prompts longer than `c`
     prefill in `c`-token chunks, at most `chunk_budget` chunks per
     tick, *interleaved* with decode — short requests no longer wait
     behind a long prompt's monolithic prefill (the TTFT win the
     benchmarks gate on).  The chunk step is `api.chunk_step`, a scan of
     the family's own `decode_step`, so partial-prefill state is exact
     for every family.
  3. **Speculative decoding** (`draft_tier=name`): an approximate
     multiplier tier (PR 8's ladder planes) drafts `spec_k` greedy
     tokens on a throwaway gathered view; the serving tier re-runs them
     in one verify scan and emits the longest agreeing prefix plus one
     correction.  Rejected positions are scattered to the trash page —
     they never enter the KV pools — and `Completion.spec` carries the
     proposed/accepted/corrections audit (`accepted + corrections ==
     len(tokens)` by construction).  Sampled (temperature > 0) rows
     bypass speculation — they emit one token per step from the same
     per-row RNG stream the baseline uses, so seeded sampling stays
     token-identical too.

Token-identity invariants the differential suite pins
(`tests/test_serving_paged.py`): masked attention lanes contribute
exactly 0 (−1e30 → exp underflow), so stale page garbage is invisible;
draft/verify/chunk are scans of the SAME `decode_step` the baseline
runs; greedy rows never consume RNG and sampled rows split once per
emitted token in both engines.
"""

from __future__ import annotations

import hashlib
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serving import sampling
from repro.serving.arena import PagedArena
from repro.serving.engine import Engine, _Slot
from repro.serving.paging import (
    PageAllocator, PageLease, PagingError, TRASH_PAGE)
from repro.serving.types import Request
from repro.sharding import ctx, rules


class _ChunkJob:
    """A request mid-chunked-prefill: holds the single-row workspace
    cache between ticks (its slot + pages are already reserved)."""

    def __init__(self, request: Request, slot_id: int, lease: PageLease,
                 digest: str, key: jax.Array, extras: dict,
                 workspace: dict, pos: int):
        self.request = request
        self.slot_id = slot_id
        self.lease = lease
        self.digest = digest
        self.key = key
        self.extras = extras
        self.workspace = workspace
        self.pos = pos


class PagedEngine(Engine):
    """Paged + chunked + speculative continuous-batching engine.

    Extra args on top of `Engine`:
      page_size: KV positions per page.
      n_pages: pool pages incl. the trash page; default sizes the pool
        so full occupancy at max_len always fits
        (capacity * ceil(max_len / page_size) + 1).
      prefill_chunk: chunk length for interleaved prefill; None/0 keeps
        the baseline's atomic prefill-then-join admission.
      chunk_budget: prefill chunks advanced per tick (oldest job first).
      draft_tier: multiplier-tier name drafting speculative tokens
        (e.g. "trunc4x4"; "exact" gives the 100%-acceptance identity
        draft).  None disables speculation.
      spec_k: draft tokens proposed per speculative step.
      prefix_cache: hash-matched prompt-prefix page sharing on/off.
    """

    def __init__(self, cfg: ModelConfig, params: Any | None = None, *,
                 page_size: int = 16, n_pages: int | None = None,
                 prefill_chunk: int | None = None, chunk_budget: int = 1,
                 draft_tier: str | None = None, spec_k: int = 4,
                 prefix_cache: bool = True, **kw):
        capacity = kw.get("capacity", 4)
        max_len = kw.get("max_len", 256)
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1 (got {page_size})")
        if prefill_chunk is not None and prefill_chunk < 1:
            prefill_chunk = None
        self.page_size = page_size
        self.n_pages = (n_pages if n_pages is not None
                        else capacity * (-(-max_len // page_size)) + 1)
        self.prefill_chunk = prefill_chunk
        self.chunk_budget = max(1, chunk_budget)
        self.draft_tier = draft_tier
        self.spec_k = spec_k
        self.prefix_cache = prefix_cache
        if draft_tier is not None and spec_k < 1:
            raise ValueError(f"spec_k must be >= 1 (got {spec_k})")
        self._tier_chunk_fns: dict[str, Any] = {}
        self._tier_verify_fns: dict[str, Any] = {}
        super().__init__(cfg, params, **kw)
        self._alloc = PageAllocator(self.n_pages, page_size)
        self._jobs: list[_ChunkJob] = []
        self._leases: dict[str, PageLease] = {}
        self._paged_stalls = 0
        self._chunks = 0
        self._spec_steps = 0
        self._spec_totals = {"proposed": 0, "accepted": 0, "corrections": 0}
        if draft_tier is not None:
            draft_spec = api.make_spec(cfg, mult=draft_tier)
            self._draft_exec = (
                self._tier_exec[draft_tier]
                if draft_tier in self._tier_exec else api.prepare_params(
                    self.params, cfg, draft_spec, mesh=self.mesh))
            self._draft = self._make_draft(draft_spec)
        else:
            self._draft = None

    # --- device state -----------------------------------------------------

    def _build_state(self) -> None:
        cfg, capacity = self.cfg, self.capacity
        self._arena = PagedArena(cfg, capacity, self.max_len,
                                 self.page_size, self.n_pages)
        self._state = {
            "cache": self._arena.cache,
            "table": jnp.zeros((capacity, self._arena.max_pages),
                               jnp.int32),
            "tok": jnp.zeros((capacity, 1), jnp.int32),
            "temp": jnp.zeros((capacity,), jnp.float32),
            "topk": jnp.zeros((capacity,), jnp.int32),
            "rng": jax.random.split(jax.random.key(self.seed), capacity),
        }
        if cfg.cross_every:
            self._state["img"] = jnp.zeros(
                (capacity, cfg.n_img_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        self._state_sh = self._state_shardings()
        self._state = jax.device_put(self._state, self._state_sh)

    def _state_shardings(self) -> dict:
        from jax.sharding import NamedSharding
        mesh = self.mesh
        sh = {"cache": rules.paged_cache_shardings(
            self._state["cache"], mesh, frozenset(self._arena.paged))}
        sh["table"] = self._replicated()
        for key in ("tok", "temp", "topk"):
            sh[key] = NamedSharding(mesh, rules.batch_pspec(
                key, self._state[key].shape, mesh))
        sh["rng"] = self._replicated()
        if "img" in self._state:
            sh["img"] = NamedSharding(mesh, rules.batch_pspec(
                "img", self._state["img"].shape, mesh))
        return sh

    @property
    def _prefill_shapes(self) -> int:
        """Distinct prefill compile shapes (retrace sanitizer budget):
        one per bucket plus the (1, chunk) first-chunk shape when
        chunking uses a non-bucket length."""
        extra = int(self.prefill_chunk is not None
                    and self.prefill_chunk not in self.buckets)
        return len(self.buckets) + extra

    # --- jitted steps -----------------------------------------------------

    def _slot_leaf_keys(self) -> list[str]:
        return [k for k in sorted(self._arena.cache)
                if k not in self._arena.paged and k != "length"]

    def _make_decode(self, spec):
        """Non-speculative paged decode: gather the dense view, run the
        baseline's exact decode+sample ops, commit each row's one new KV
        row back to its page (inactive lanes write the trash page)."""
        arena = self._arena

        def decode_impl(params, state):
            extras = {"img_embeds": state["img"]} if "img" in state else {}
            cache, table = state["cache"], state["table"]
            old_len = cache["length"]
            view = arena.view(cache, table)
            with ctx.use_rules(self.mesh, rules.logical_rules(self.mesh)):
                logits, new_view = api.decode_step(
                    params, view, state["tok"], self.cfg, spec=spec,
                    extras=extras)
            keys = jax.vmap(lambda k: jax.random.split(k))(state["rng"])
            tok = sampling.sample_tokens(logits[:, -1], state["temp"],
                                         state["topk"], keys[:, 0])
            new_cache = arena.scatter_rows(
                cache, new_view, table, old_len,
                jnp.ones(old_len.shape, bool))
            for key in self._slot_leaf_keys():
                new_cache[key] = new_view[key]
            new_cache["length"] = new_view["length"]
            new = dict(state, cache=new_cache, tok=tok[:, None],
                       rng=keys[:, 1])
            return new, tok

        return jax.jit(decode_impl, donate_argnums=(1,),
                       out_shardings=(self._state_sh, self._replicated()))

    def _extra_tier_fns(self, name: str, spec) -> None:
        self._tier_chunk_fns[name] = self._make_chunk(spec)
        if self.draft_tier is not None:
            self._tier_verify_fns[name] = self._make_verify(spec)

    def _activate(self, name: str) -> None:
        super()._activate(name)
        self._chunk = self._tier_chunk_fns[name]
        self._verify = self._tier_verify_fns.get(name)

    def _make_chunk(self, spec):
        def chunk_impl(params, workspace, tokens, extras, n_valid):
            with ctx.use_rules(self.mesh, rules.logical_rules(self.mesh)):
                return api.chunk_step(params, workspace, tokens, self.cfg,
                                      spec=spec, extras=extras,
                                      n_valid=n_valid)
        return jax.jit(chunk_impl, donate_argnums=(1,))

    def _make_draft(self, spec):
        """Draft `spec_k` greedy tokens per lane on a throwaway gathered
        view — nothing escapes but the proposals, so the draft tier can
        never pollute KV pages."""
        arena, k = self._arena, self.spec_k

        def draft_impl(params, state):
            extras = {"img_embeds": state["img"]} if "img" in state else {}
            view = arena.view(state["cache"], state["table"])

            def draft_body(carry, _):
                v, tok = carry
                logits, v = api.decode_step(params, v, tok, self.cfg,
                                            spec=spec, extras=extras)
                nxt = jnp.argmax(logits[:, -1].astype(jnp.float32),
                                 axis=-1).astype(jnp.int32)
                return (v, nxt[:, None]), nxt

            with ctx.use_rules(self.mesh, rules.logical_rules(self.mesh)):
                _, toks = jax.lax.scan(draft_body, (view, state["tok"]),
                                       None, length=k)
            return jnp.moveaxis(toks, 0, 1)   # (capacity, k)

        return jax.jit(draft_impl, out_shardings=self._replicated())

    def _make_verify(self, spec):
        """Verify `spec_k` drafted tokens in one scan of the serving
        tier's own decode_step.  Per lane: emit the longest agreeing
        prefix + one correction (greedy), or one sampled token (the
        baseline RNG stream); commit only accepted KV rows (rejected
        positions scatter to the trash page); roll non-paged state back
        to the snapshot of the last emitted position."""
        arena, cfg, k = self._arena, self.cfg, self.spec_k
        slot_keys = self._slot_leaf_keys()

        def verify_impl(params, state, draft, k_row):
            extras = {"img_embeds": state["img"]} if "img" in state else {}
            cache, table = state["cache"], state["table"]
            old_len = cache["length"]

            def verify_body(carry, i):
                v, tok = carry
                logits, nv = api.decode_step(params, v, tok, cfg,
                                             spec=spec, extras=extras)
                live = i < k_row                       # (capacity,)
                nv = {key: _sel(live, nv[key], v[key],
                                arena.slot_axes[key])
                      for key in nv}
                nxt = jax.lax.dynamic_index_in_dim(
                    draft, i, axis=1, keepdims=True)   # (capacity, 1)
                snap = {key: nv[key] for key in slot_keys}
                return (nv, jnp.where(live[:, None], nxt, tok)), \
                    (logits[:, -1], snap)

            with ctx.use_rules(self.mesh, rules.logical_rules(self.mesh)):
                (view_k, _), (lgs, snaps) = jax.lax.scan(
                    verify_body, (arena.view(cache, table), state["tok"]),
                    jnp.arange(k))
                # lgs: (k, capacity, vocab) — step i's next-token logits
                e = jnp.argmax(lgs.astype(jnp.float32), axis=-1) \
                    .astype(jnp.int32).T                    # (capacity, k)
                agree = jnp.cumprod((e == draft).astype(jnp.int32), axis=1)
                greedy = state["temp"] <= 0.0
                keys = jax.vmap(lambda r: jax.random.split(r))(state["rng"])
                corr0 = sampling.sample_tokens(
                    lgs[0], state["temp"], state["topk"], keys[:, 0])
                a = jnp.where(greedy, agree.sum(axis=1), 0)
                a = jnp.minimum(a, k_row)
                m = jnp.where(a >= k_row, k_row, a + 1)     # 0 when k_row=0
                e_at_a = jnp.take_along_axis(
                    e, jnp.minimum(a, k - 1)[:, None], axis=1)[:, 0]
                corr = jnp.where(greedy, e_at_a, corr0)
                cols = jnp.arange(k)[None, :]
                emitted = jnp.where(cols < a[:, None], draft, corr[:, None])
                tok_new = jnp.take_along_axis(
                    emitted, jnp.maximum(m - 1, 0)[:, None], axis=1)
                new_cache = dict(cache)
                for i in range(k):
                    new_cache = arena.scatter_rows(
                        new_cache, view_k, table, old_len + i, i < m)
                idx = jnp.maximum(m - 1, 0)
                for key in slot_keys:
                    new_cache[key] = _pick_snap(
                        snaps[key], idx, arena.slot_axes[key])
                new_cache["length"] = old_len + m
                # tok/rng advance unconditionally, exactly like the
                # baseline decode: idle lanes are re-seeded at install,
                # and a sampled lane consumes one split per emitted
                # token in both engines (stream parity)
                new = dict(state, cache=new_cache, tok=tok_new,
                           rng=keys[:, 1])
            return new, (emitted, m, a)

        repl = self._replicated()
        return jax.jit(verify_impl, donate_argnums=(1,),
                       out_shardings=(self._state_sh, (repl, repl, repl)))

    # --- submission / admission -------------------------------------------

    def submit(self, request: Request) -> None:
        sp = request.sampling
        n = len(request.tokens)
        if n >= 1 and sp.max_new_tokens >= 1:
            need = -(-(n + sp.max_new_tokens - 1) // self.page_size)
            if need > self.n_pages - 1:
                raise ValueError(
                    f"{request.request_id}: needs {need} pages, pool has "
                    f"{self.n_pages - 1} usable")
        super().submit(request)

    def _conditioning_digest(self, request: Request) -> str:
        """Prefix-cache key component: extras content (frames / image
        embeddings change KV for identical tokens) + the compute path
        (bucket vs chunk schedule), so only bit-identically produced
        prefixes ever share pages."""
        parts = []
        for key in sorted(request.extras or {}):
            v = np.asarray(request.extras[key])
            parts.append(f"{key}:{v.shape}:"
                         f"{hashlib.sha1(v.tobytes()).hexdigest()[:16]}")
        return "|".join(parts)

    def _flat_idx(self, lease: PageLease, shared_tokens: int, n: int
                  ) -> np.ndarray:
        """Host-built scatter map for the admission insert: position j
        -> pool row.  Prefix-shared positions and everything past the
        prompt go to the trash page (shared pages stay read-only, fresh
        pages stay zero past the prompt — the spec-leak invariant)."""
        ps = self.page_size
        idx = np.full((self.max_len,), TRASH_PAGE, np.int32)
        for j in range(shared_tokens, n):
            idx[j] = lease.pages[j // ps] * ps + j % ps
        return idx

    def _admit_ready(self, now: float) -> None:
        while self._free:
            request = self._sched.peek_ready(now)
            if request is None:
                break
            sp = request.sampling
            n = len(request.tokens)
            rid = request.request_id
            digest = self._conditioning_digest(request)
            chunked = (self.prefill_chunk is not None
                       and n > self.prefill_chunk)
            path = (f"chunk:{self.prefill_chunk}" if chunked
                    else f"bucket:{next(b for b in self.buckets if b >= n)}")
            digest = f"{digest}|{path}"
            lease = self._alloc.alloc(
                rid, n + sp.max_new_tokens - 1,
                prompt=tuple(request.tokens) if self.prefix_cache else None,
                digest=digest)
            if lease is None:
                # FIFO head waits for pages — no overtaking, so arrival
                # order is preserved exactly like the slot engine
                self._paged_stalls += 1
                break
            self._sched.pop_ready(now)
            ready_wall = self._sched.ready_wall(rid)
            slot_id = self._free.pop()
            self._leases[rid] = lease
            try:
                if chunked:
                    self._start_chunked(request, ready_wall, slot_id,
                                        lease, digest)
                else:
                    self._admit(request, ready_wall, slot_id,
                                lease=lease, digest=digest)
            except Exception:
                if self._slots[slot_id] is None:
                    self._free.append(slot_id)
                    self._sched.restore(request, ready_wall)
                    self._alloc.free(rid)
                    self._leases.pop(rid, None)
                raise

    def _admit(self, request: Request, ready_wall: float, slot_id: int,
               lease: PageLease | None = None, digest: str = "") -> None:
        """Whole-prompt admission: the baseline's exact prefill + first-
        token sampling (same bucket, same ops, same RNG), then a paged
        insert instead of a slot insert."""
        sp = request.sampling
        prompt = np.asarray(request.tokens, np.int32)
        n = prompt.shape[0]
        bucket = next(b for b in self.buckets if b >= n)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :n] = prompt
        extras = self._prefill_extras(request)
        t0 = time.perf_counter()
        logits, req_cache = self._prefill(
            self.exec_params, jnp.asarray(padded), extras,
            true_len=jnp.asarray([n], jnp.int32))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self._prefill_s += dt
        if self.meter is not None:
            self.meter.on_prefill(request.request_id, dt)
        key = self._request_key(sp)
        first = self._first(logits.astype(jnp.float32),
                            jnp.asarray([sp.temperature], jnp.float32),
                            jnp.asarray([sp.top_k], jnp.int32),
                            key[None])
        self._admitted += 1
        self._install(request, req_cache, slot_id, lease, n, extras, sp,
                      key, first, ready_wall, digest)

    def _start_chunked(self, request: Request, ready_wall: float,
                       slot_id: int, lease: PageLease, digest: str) -> None:
        """First chunk of an interleaved prefill: the request takes its
        slot + pages now but joins decode only when the last chunk
        lands; meanwhile every tick decodes the active lanes."""
        sp = request.sampling
        prompt = np.asarray(request.tokens, np.int32)
        c = self.prefill_chunk
        extras = self._prefill_extras(request)
        key = self._request_key(sp)
        self._admitted += 1
        t0 = time.perf_counter()
        logits, workspace = self._prefill(
            self.exec_params, jnp.asarray(prompt[None, :c]), extras,
            true_len=jnp.asarray([c], jnp.int32))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self._prefill_s += dt
        self._chunks += 1
        if self.meter is not None:
            self.meter.on_prefill(request.request_id, dt)
        slot = _Slot(request, len(prompt), self._tick, ready_wall,
                     self._admitted)
        slot.prefilling = True
        if self.draft_tier is not None:
            slot.spec_counts = {"proposed": 0, "accepted": 0,
                                "corrections": 0}
        self._slots[slot_id] = slot
        self._jobs.append(_ChunkJob(request, slot_id, lease, digest, key,
                                    extras, workspace, c))

    def _install(self, request: Request, req_cache: dict, slot_id: int,
                 lease: PageLease, n: int, extras: dict, sp, key,
                 first, ready_wall: float, digest: str,
                 slot: _Slot | None = None) -> None:
        """Common tail of both admission paths: paged insert, device
        row updates, prefix registration, slot record, first emit."""
        rid = request.request_id
        flat_idx = self._flat_idx(lease, lease.hit_tokens, n)
        self._arena.cache = self._state["cache"]
        self._arena.insert(req_cache, slot_id, flat_idx)
        self._state["cache"] = self._arena.cache
        row = np.zeros((self._arena.max_pages,), np.int32)
        row[:len(lease.pages)] = lease.pages
        at = jnp.asarray(slot_id)
        self._state = dict(
            self._state,
            table=self._state["table"].at[at].set(jnp.asarray(row)),
            tok=self._state["tok"].at[at].set(first[:, None][0]),
            temp=self._state["temp"].at[at].set(sp.temperature),
            topk=self._state["topk"].at[at].set(sp.top_k),
            rng=self._state["rng"].at[at].set(key))
        if "img" in self._state:
            self._state["img"] = jax.lax.dynamic_update_slice_in_dim(
                self._state["img"], extras["img_embeds"].astype(
                    self._state["img"].dtype), slot_id, axis=0)
        self._state = jax.device_put(self._state, self._state_sh)
        if self.prefix_cache:
            self._alloc.register_prefix(rid, tuple(request.tokens), digest)
        if slot is None:
            slot = _Slot(request, n, self._tick, ready_wall,
                         self._admitted)
            if self.draft_tier is not None:
                slot.spec_counts = {"proposed": 0, "accepted": 0,
                                    "corrections": 0}
            self._slots[slot_id] = slot
        slot.prefilling = False
        slot.first_wall = time.perf_counter()
        slot.first_tick = self._tick
        if slot.spec_counts is not None:
            slot.spec_counts["corrections"] += 1
            self._spec_totals["corrections"] += 1
        self._emit(slot_id, int(first[0]))

    # --- chunked-prefill advance ------------------------------------------

    def _advance_prefill(self) -> None:
        for _ in range(self.chunk_budget):
            if not self._jobs:
                return
            job = self._jobs[0]
            req = job.request
            over_budget = any(
                b is not None and self._tick - req.arrival + 1 >= b
                for b in (req.deadline_ticks, req.ttft_deadline_ticks))
            if over_budget:
                self._jobs.pop(0)
                self._evict(job.slot_id, "deadline")
                continue
            if self._advance_one(job):
                self._jobs.pop(0)

    def _advance_one(self, job: _ChunkJob) -> bool:
        """Run one chunk; returns True when the prefill finished (first
        token emitted, request joins decode this tick)."""
        prompt = np.asarray(job.request.tokens, np.int32)
        n = prompt.shape[0]
        c = self.prefill_chunk
        take = min(c, n - job.pos)
        padded = np.zeros((1, c), np.int32)
        padded[0, :take] = prompt[job.pos:job.pos + take]
        t0 = time.perf_counter()
        logits, job.workspace = self._chunk(
            self.exec_params, job.workspace, jnp.asarray(padded),
            job.extras, jnp.asarray([take], jnp.int32))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self._prefill_s += dt
        self._chunks += 1
        if self.meter is not None:
            self.meter.on_prefill(job.request.request_id, dt)
        job.pos += take
        if job.pos < n:
            return False
        sp = job.request.sampling
        first = self._first(logits[:, take - 1].astype(jnp.float32),
                            jnp.asarray([sp.temperature], jnp.float32),
                            jnp.asarray([sp.top_k], jnp.int32),
                            job.key[None])
        slot = self._slots[job.slot_id]
        self._install(job.request, job.workspace, job.slot_id, job.lease,
                      n, job.extras, sp, job.key, first, slot.ready_wall,
                      job.digest, slot=slot)
        return True

    # --- eviction ---------------------------------------------------------

    def _evict(self, slot_id: int, reason: str) -> None:
        slot = self._slots[slot_id]
        rid = slot.request.request_id
        if slot.prefilling:
            # never emitted: TTFT = time waited (the budget it blew)
            slot.first_wall = time.perf_counter()
        super()._evict(slot_id, reason)
        self._alloc.free(rid)
        self._leases.pop(rid, None)
        # neutralize the freed lane on device: with a zero table row and
        # zero length every future write it makes lands in the trash
        # page, so reused pages can never be corrupted by a stale lane
        at = jnp.asarray(slot_id)
        cache = self._state["cache"]
        self._state = dict(
            self._state,
            cache=dict(cache, length=cache["length"].at[at].set(0)),
            table=self._state["table"].at[at].set(
                jnp.zeros((self._arena.max_pages,), jnp.int32)))
        self._state = jax.device_put(self._state, self._state_sh)

    def _slot_of(self, request_id: str) -> int:
        slot_id = next((i for i, s in enumerate(self._slots)
                        if s is not None
                        and s.request.request_id == request_id), None)
        if slot_id is None:
            raise PagingError(
                f"request {request_id!r} is not resident "
                f"(never admitted, finished, or evicted)")
        return slot_id

    # --- copy-on-write ----------------------------------------------------

    def resolve_cow(self, request_id: str, index: int
                    ) -> tuple[int, int] | None:
        """Make block-table entry `index` of `request_id` writable:
        allocator bookkeeping + device page copy + table row update.
        The serving path itself never needs this (decode always writes
        strictly past the last shareable page — see ARCHITECTURE.md);
        it serves fork()-style consumers and the COW tests."""
        op = self._alloc.cow(request_id, index)
        if op is None:
            return None
        src, dst = op
        self._arena.cache = self._state["cache"]
        self._arena.copy_pages([src], [dst])
        self._state["cache"] = self._arena.cache
        slot_id = self._slot_of(request_id)
        self._state = dict(
            self._state,
            table=self._state["table"].at[slot_id, index].set(dst))
        self._state = jax.device_put(self._state, self._state_sh)
        return op

    # --- the serving loop -------------------------------------------------

    def step(self) -> None:
        """One tick: shed expired, advance at most `chunk_budget`
        prefill chunks, admit while slots AND pages allow, then one
        decode (or draft+verify) step over the active lanes."""
        now = self._tick
        self._sched.note_ready(now, time.perf_counter())
        for request in self._sched.pop_expired(now):
            self._shed(request)
        self._advance_prefill()
        self._admit_ready(now)
        decoding = [i for i, s in enumerate(self._slots)
                    if s is not None and not s.prefilling]
        if decoding:
            if self._draft is not None:
                self._spec_step(decoding)
            else:
                self._decode_step_paged(decoding)
        self._tick += 1

    def _decode_step_paged(self, decoding: list[int]) -> None:
        t0 = time.perf_counter()
        self._state, tok = self._decode(self.exec_params, self._state)
        self._decode_steps += 1
        tok_host = np.asarray(tok)
        dt = time.perf_counter() - t0
        self._decode_s += dt
        if self.meter is not None:
            self.meter.on_decode(
                dt, [self._slots[i].request.request_id for i in decoding],
                self.capacity)
        for slot_id in decoding:
            if self._slots[slot_id] is not None:
                self._emit(slot_id, int(tok_host[slot_id]))

    def _spec_step(self, decoding: list[int]) -> None:
        """Draft + verify one speculative step: greedy lanes emit up to
        `spec_k` accepted drafts + 1 correction, sampled lanes emit one
        baseline-stream token, idle/prefilling lanes are frozen
        (k_row = 0)."""
        kr = np.zeros((self.capacity,), np.int32)
        for i in decoding:
            slot = self._slots[i]
            sp = slot.request.sampling
            if sp.temperature <= 0.0:
                kr[i] = min(self.spec_k,
                            sp.max_new_tokens - len(slot.tokens))
            else:
                kr[i] = 1
        t0 = time.perf_counter()
        draft = self._draft(self._draft_exec, self._state)
        self._state, (emitted, m, a) = self._verify(
            self.exec_params, self._state, draft, jnp.asarray(kr))
        em = np.asarray(emitted)
        mh = np.asarray(m)
        ah = np.asarray(a)
        self._decode_steps += 1
        self._spec_steps += 1
        dt = time.perf_counter() - t0
        self._decode_s += dt
        if self.meter is not None:
            self.meter.on_decode(
                dt, [self._slots[i].request.request_id for i in decoding],
                self.capacity)
        for i in decoding:
            slot = self._slots[i]
            if slot is None:
                continue
            if slot.request.sampling.temperature <= 0.0:
                slot.spec_counts["proposed"] += int(kr[i])
                self._spec_totals["proposed"] += int(kr[i])
            for j in range(int(mh[i])):
                field = "accepted" if j < int(ah[i]) else "corrections"
                # count BEFORE emitting: _emit may evict and freeze the
                # Completion's SpecStats this very token
                slot.spec_counts[field] += 1
                self._spec_totals[field] += 1
                self._emit(i, int(em[i, j]))
                if self._slots[i] is None:
                    break

    # --- introspection ----------------------------------------------------

    def debug_kv_rows(self, request_id: str) -> dict:
        """Test/debug surface: the request's dense gathered KV rows per
        paged leaf ((max_len, ...) each), its device length, and how
        many positions its lease actually reserves — everything the
        no-leak invariant check needs."""
        slot_id = self._slot_of(request_id)
        view = self._arena.view(self._state["cache"],
                                self._state["table"])
        out = {}
        for key, axis in self._arena.paged.items():
            rows = jnp.moveaxis(view[key], (axis, axis + 1), (0, 1))
            out[key] = np.asarray(rows[slot_id])
        lease = self._leases[request_id]
        return {"rows": out,
                "length": int(np.asarray(
                    self._state["cache"]["length"])[slot_id]),
                "reserved": len(lease.pages) * self.page_size,
                "shared_tokens": lease.hit_tokens}

    def stats(self) -> dict:
        out = super().stats()
        out["paged"] = {
            **self._alloc.stats(),
            "admission_stalls": self._paged_stalls,
            "max_pages_per_request": self._arena.max_pages,
            "paged_leaves": sorted(self._arena.paged),
            "chunked": {"enabled": self.prefill_chunk is not None,
                        "chunk": self.prefill_chunk,
                        "budget": self.chunk_budget,
                        "chunks": self._chunks,
                        "inflight": len(self._jobs)},
        }
        if self.draft_tier is not None:
            tot = self._spec_totals
            out["spec"] = {
                "draft_tier": self.draft_tier, "k": self.spec_k,
                "steps": self._spec_steps, **tot,
                "acceptance_rate": (tot["accepted"] / tot["proposed"]
                                    if tot["proposed"] else 0.0)}
        extra = [("chunk", self._chunk)]
        if self._draft is not None:
            extra += [("draft", self._draft), ("verify", self._verify)]
        for name, fn in extra:
            if hasattr(fn, "_cache_size"):
                out[f"{name}_compiles"] = fn._cache_size()
        return out


def _sel(live: jax.Array, new, old, batch_axis: int):
    """Per-lane select along `batch_axis` (freeze lanes past k_row)."""
    shape = [1] * new.ndim
    shape[batch_axis] = live.shape[0]
    return jnp.where(live.reshape(shape), new, old)


def _pick_snap(stacked, idx: jax.Array, batch_axis: int):
    """Per-lane snapshot select: `stacked` is (k, *leaf) scan output,
    `idx` (capacity,) picks each lane's last-emitted step."""
    moved = jnp.moveaxis(stacked, batch_axis + 1, 1)   # (k, cap, rest...)
    ix = idx.reshape((1, idx.shape[0]) + (1,) * (moved.ndim - 2))
    picked = jnp.take_along_axis(moved, ix, axis=0)[0]  # (cap, rest...)
    return jnp.moveaxis(picked, 0, batch_axis)
