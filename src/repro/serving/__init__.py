"""Continuous-batching serving engine over the unified model API.

Quickstart::

    from repro import configs
    from repro.serving import Engine, Request, SamplingParams

    cfg = configs.reduced(configs.get_config("tinyllama-1.1b"))
    eng = Engine(cfg, capacity=4, max_len=128)
    eng.submit(Request("a", [1, 2, 3],
                       SamplingParams(max_new_tokens=8)))          # greedy
    eng.submit(Request("b", list(range(30)),
                       SamplingParams(temperature=0.8, top_k=16,
                                      max_new_tokens=4),
                       arrival=2.0))                   # joins mid-decode
    for done in eng.run_until_complete():
        print(done.request_id, done.tokens, done.finish_reason)

Requests of heterogeneous prompt lengths, arrival times, and sampling
params share one fixed-shape decode batch; free slots admit queued work
mid-decode (prefill-then-join) and finished requests are evicted so
their slots recycle.  See `engine.Engine` for the capacity / max_len /
prefill_buckets knobs, and README "Serving engine" for how `--mult`
approximate serving composes with it.
"""

from repro.serving.engine import Engine  # noqa: F401
from repro.serving.paged import PagedEngine  # noqa: F401
from repro.serving.paging import (  # noqa: F401
    PageAllocator, PageLease, PagingError,
)
from repro.serving.types import (  # noqa: F401
    Completion, Request, SamplingParams, SpecStats,
)
