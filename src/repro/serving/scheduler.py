"""Continuous-batching admission scheduler.

FIFO by (arrival tick, submission order).  The scheduler owns only the
waiting queue — slot occupancy lives in the engine.  Arrival times are in
engine ticks (one decode step = one tick), which keeps traces
deterministic and replayable; wall-clock readiness is stamped the first
time the engine observes a request as eligible, so latency metrics
include queueing-for-capacity but not simulated future arrivals.

Deadline-aware admission: a queued request whose TTFT budget is already
blown (it could not emit a first token in time even if admitted *right
now*) is surfaced through `pop_expired` so the engine can shed it
instead of wasting prefill compute on a reply that is late by
construction.
"""

from __future__ import annotations

import heapq

from repro.serving.types import Request


class Scheduler:
    def __init__(self):
        self._heap: list[tuple[float, int, Request]] = []
        self._order = 0
        self._ready_wall: dict[str, float] = {}

    def submit(self, request: Request) -> int:
        """Queue a request; returns its submission index."""
        idx = self._order
        heapq.heappush(self._heap, (float(request.arrival), idx, request))
        self._order += 1
        return idx

    def restore(self, request: Request, ready_wall: float | None = None
                ) -> None:
        """Put a popped request back at its original queue position —
        the exception-safety path for a crash mid-admission (the request
        must stay drainable, never lost with the dying engine)."""
        self.submit(request)
        if ready_wall is not None:
            self._ready_wall.setdefault(request.request_id, ready_wall)

    def note_ready(self, now: float, wall: float) -> None:
        """Stamp wall-clock readiness for requests whose arrival has
        passed (first observation wins)."""
        for arrival, _, req in self._heap:
            if arrival <= now and req.request_id not in self._ready_wall:
                self._ready_wall[req.request_id] = wall

    def ready_wall(self, request_id: str) -> float:
        return self._ready_wall.pop(request_id)

    @staticmethod
    def _admit_deadline(req: Request) -> float | None:
        """Latest tick at which admitting `req` can still meet its
        budgets: first token at tick t means TTFT = t - arrival + 1."""
        budgets = [b for b in (req.ttft_deadline_ticks, req.deadline_ticks)
                   if b is not None]
        if not budgets:
            return None
        return req.arrival + min(budgets) - 1.0

    def pop_expired(self, now: float) -> list[Request]:
        """Remove and return due requests whose deadline can no longer
        be met even if admitted this tick (FIFO order) — the engine
        sheds these."""
        expired, keep = [], []
        for item in self._heap:
            arrival, _, req = item
            latest = self._admit_deadline(req)
            if arrival <= now and latest is not None and now > latest:
                expired.append(item)
            else:
                keep.append(item)
        if expired:
            self._heap = keep
            heapq.heapify(self._heap)
        return [req for _, _, req in sorted(expired)]

    def pop_ready(self, now: float) -> Request | None:
        """Next request with arrival <= now, FIFO; None if none is due."""
        if self._heap and self._heap[0][0] <= now:
            return heapq.heappop(self._heap)[2]
        return None

    def peek_ready(self, now: float) -> Request | None:
        """Like `pop_ready` but non-destructive — the paged engine uses
        it to gate admission on page availability without reordering the
        FIFO (head-of-queue blocks until its pages fit)."""
        if self._heap and self._heap[0][0] <= now:
            return self._heap[0][2]
        return None

    def next_arrival(self) -> float | None:
        return self._heap[0][0] if self._heap else None

    def pending(self) -> list[Request]:
        """Waiting requests in admission (arrival, submission) order —
        read-only drain surface for fleet failover."""
        return [req for _, _, req in sorted(self._heap)]

    def __len__(self) -> int:
        return len(self._heap)
