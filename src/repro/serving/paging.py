"""Host-side paged-KV bookkeeping: fixed-size pages, free-list
allocation, refcounts, hash-matched prefix sharing, copy-on-write.

The allocator owns NO device memory — it hands out integer page ids
into the `PagedArena` pools and keeps the invariants the device side
relies on:

  * page 0 is the trash page: writes that must be dropped (inactive
    decode lanes, rejected speculative positions, pad lanes) are
    directed there, so every device scatter keeps a static shape;
  * a page a request may WRITE has exactly one referencing table and is
    not in the prefix cache — writable pages are never aliased;
  * prefix-shared and forked pages are read-only while referenced more
    than once; `cow()` resolves a write intent into a fresh page plus a
    (src, dst) device copy;
  * freed pages whose content is still prefix-cached stay reclaimable
    (LRU) instead of free, so a later request with the same prompt
    prefix shares them; allocation pressure reclaims them oldest-first
    (`reclaimed_pages` is the eviction accounting the engine surfaces).

Sharing is *memory* dedup only: a prefix-hit request still computes its
own prefill (token streams must stay independent of cache luck), it
just does not spend pages on positions another request already stores.
Prefix keys include the exact token prefix AND a conditioning digest
(encdec frames / VLM image embeddings change the KV content for the
same tokens), so a hit can never alias semantically different caches.

Pure Python, deliberately jax-free: `tests/test_property.py` drives it
with a hypothesis state machine, and `audit()` re-derives every
refcount from scratch so an invariant violation fails loudly.
"""

from __future__ import annotations

import dataclasses

TRASH_PAGE = 0


class PagingError(RuntimeError):
    """Misuse of the allocator (double free, unknown request, ...)."""


@dataclasses.dataclass(frozen=True)
class PageLease:
    """Result of `alloc`: the request's block table (page ids in
    position order) and how much of it was prefix-shared."""
    pages: tuple[int, ...]
    shared_pages: int
    hit_tokens: int


class PageAllocator:
    """Fixed-pool page allocator with refcounts and prefix sharing.

    Args:
      n_pages: total pool pages INCLUDING the reserved trash page 0.
      page_size: KV positions per page.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1 (got {page_size})")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free = list(range(n_pages - 1, 0, -1))  # stack, low ids first
        self._table_refs = [0] * n_pages
        self._tables: dict[str, list[int]] = {}
        self._cache: dict[tuple, int] = {}       # prefix chain key -> page
        self._cache_key_of: dict[int, tuple] = {}
        self._lru: dict[int, None] = {}          # cached, zero table refs
        self.prefix_hits = 0
        self.hit_tokens = 0
        self.cow_copies = 0
        self.reclaimed_pages = 0
        self.alloc_failures = 0

    # --- capacity ---------------------------------------------------------

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1

    @property
    def pages_free(self) -> int:
        """Immediately allocatable pages (free + reclaimable cache)."""
        return len(self._free) + len(self._lru)

    @property
    def pages_live(self) -> int:
        return self.usable_pages - len(self._free) - len(self._lru)

    def pages_needed(self, n_positions: int) -> int:
        return -(-max(n_positions, 1) // self.page_size)

    # --- prefix keys ------------------------------------------------------

    def _chain_key(self, digest: str, prompt, i: int) -> tuple:
        end = (i + 1) * self.page_size
        return (digest, i, tuple(prompt[:end]))

    # --- allocation -------------------------------------------------------

    def _reclaim_one(self) -> bool:
        """Evict the oldest reclaimable prefix-cached page to the free
        list.  Returns False when nothing is reclaimable."""
        if not self._lru:
            return False
        page = next(iter(self._lru))
        del self._lru[page]
        key = self._cache_key_of.pop(page)
        del self._cache[key]
        self._free.append(page)
        self.reclaimed_pages += 1
        return True

    def alloc(self, request_id: str, n_positions: int,
              prompt=None, digest: str = "") -> PageLease | None:
        """Reserve the block table for a request needing `n_positions`
        KV slots.  `prompt` (+ `digest`) enables prefix sharing: leading
        FULL pages whose chain key is cached are referenced instead of
        allocated.  Returns None (and counts a failure) when the pool
        cannot cover the non-shared remainder even after reclaiming."""
        if request_id in self._tables:
            raise PagingError(f"request {request_id!r} already holds pages")
        needed = self.pages_needed(n_positions)
        shared: list[int] = []
        if prompt is not None:
            n_full = min(len(prompt) // self.page_size, needed)
            for i in range(n_full):
                page = self._cache.get(self._chain_key(digest, prompt, i))
                if page is None:
                    break
                shared.append(page)
        n_fresh = needed - len(shared)
        # Pin the shared pages BEFORE reclaiming: a shared page with no
        # table refs yet lives on the LRU, exactly where _reclaim_one
        # evicts from — reclaiming first could free-list (and re-pop as
        # "fresh") a page this very request is about to reference.
        for page in shared:
            self._table_refs[page] += 1
            self._lru.pop(page, None)
        while len(self._free) < n_fresh:
            if not self._reclaim_one():
                for page in shared:  # roll back the pins
                    self._drop_ref(page)
                self.alloc_failures += 1
                return None
        fresh = [self._free.pop() for _ in range(n_fresh)]
        for page in fresh:
            self._table_refs[page] = 1
        self._tables[request_id] = shared + fresh
        if shared:
            self.prefix_hits += 1
            self.hit_tokens += len(shared) * self.page_size
        return PageLease(tuple(shared + fresh), len(shared),
                         len(shared) * self.page_size)

    def register_prefix(self, request_id: str, prompt, digest: str = ""
                        ) -> int:
        """Publish the request's fully-written prompt pages into the
        prefix cache (call AFTER the device insert).  Only pages wholly
        covered by the prompt are registered; already-cached chain keys
        are skipped.  Returns the number of newly registered pages."""
        table = self._table(request_id)
        n_full = min(len(prompt) // self.page_size, len(table))
        added = 0
        for i in range(n_full):
            key = self._chain_key(digest, prompt, i)
            if key in self._cache:
                continue
            page = table[i]
            if page in self._cache_key_of:
                continue  # page already published under another key
            self._cache[key] = page
            self._cache_key_of[page] = key
            added += 1
        return added

    # --- release ----------------------------------------------------------

    def _table(self, request_id: str) -> list[int]:
        try:
            return self._tables[request_id]
        except KeyError:
            raise PagingError(
                f"request {request_id!r} holds no pages "
                f"(double free or never allocated)") from None

    def _drop_ref(self, page: int) -> None:
        self._table_refs[page] -= 1
        if self._table_refs[page] < 0:
            raise PagingError(f"page {page} refcount underflow")
        if self._table_refs[page] == 0:
            if page in self._cache_key_of:
                self._lru[page] = None     # reclaimable, keep content
            else:
                self._free.append(page)

    def free(self, request_id: str) -> None:
        """Release every page reference a request holds.  Pages still
        referenced elsewhere (prefix sharing / forks) survive; cached
        pages become reclaimable rather than free."""
        for page in self._table(request_id):
            self._drop_ref(page)
        del self._tables[request_id]

    # --- fork / copy-on-write ---------------------------------------------

    def fork(self, src_id: str, dst_id: str) -> tuple[int, ...]:
        """Share `src_id`'s whole table with a new request (beam /
        parallel-sampling style).  Every page becomes read-only until a
        writer resolves it through `cow`."""
        if dst_id in self._tables:
            raise PagingError(f"request {dst_id!r} already holds pages")
        table = list(self._table(src_id))
        for page in table:
            self._table_refs[page] += 1
            self._lru.pop(page, None)
        self._tables[dst_id] = table
        return tuple(table)

    def writable(self, request_id: str, index: int) -> bool:
        page = self._table(request_id)[index]
        return self._table_refs[page] == 1 and \
            page not in self._cache_key_of

    def cow(self, request_id: str, index: int) -> tuple[int, int] | None:
        """Make table entry `index` writable.  Returns a (src, dst)
        device-copy instruction when the page was shared (the caller
        must copy the content), None when it was already exclusively
        owned.  Raises PagingError when the pool is exhausted."""
        table = self._table(request_id)
        page = table[index]
        if self._table_refs[page] == 1 and page not in self._cache_key_of:
            return None
        while not self._free:
            if not self._reclaim_one():
                self.alloc_failures += 1
                raise PagingError("copy-on-write: pool exhausted")
        fresh = self._free.pop()
        self._table_refs[fresh] = 1
        table[index] = fresh
        self._drop_ref(page)
        self.cow_copies += 1
        return (page, fresh)

    # --- introspection ----------------------------------------------------

    def table(self, request_id: str) -> tuple[int, ...]:
        return tuple(self._table(request_id))

    def holders(self) -> frozenset[str]:
        return frozenset(self._tables)

    def stats(self) -> dict:
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_live": self.pages_live,
            "pages_free": len(self._free),
            "pages_cached": len(self._cache),
            "pages_reclaimable": len(self._lru),
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.hit_tokens,
            "cow_copies": self.cow_copies,
            "reclaimed_pages": self.reclaimed_pages,
            "alloc_failures": self.alloc_failures,
        }

    def audit(self) -> None:
        """Re-derive every refcount from scratch and assert the full
        invariant set (the hypothesis state machine calls this after
        every step)."""
        counts = [0] * self.n_pages
        for rid, table in self._tables.items():
            assert len(set(table)) == len(table), \
                f"{rid}: duplicate page in table {table}"
            assert TRASH_PAGE not in table, f"{rid}: trash page in table"
            for page in table:
                counts[page] += 1
        assert counts == self._table_refs, \
            f"refcount drift: derived {counts} != {self._table_refs}"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate free pages"
        assert TRASH_PAGE not in free_set, "trash page on the free list"
        cached = set(self._cache_key_of)
        assert cached == set(self._cache.values()), "cache maps diverged"
        assert {self._cache_key_of[p]: p for p in cached} == {
            k: p for k, p in self._cache.items()}, "cache key mismatch"
        for page in range(1, self.n_pages):
            is_free = page in free_set
            live = counts[page] > 0 or page in cached
            assert is_free != live, \
                f"page {page}: free={is_free} live={live}"
        assert set(self._lru) == {p for p in cached if counts[p] == 0}, \
            "reclaimable set drift"
        # writable pages are never aliased: one table, not cached
        for rid, table in self._tables.items():
            for i, page in enumerate(table):
                if self.writable(rid, i):
                    others = [r for r, t in self._tables.items()
                              if page in t]
                    assert others == [rid], \
                        f"writable page {page} aliased by {others}"
        # conservation: every table/cache reference is counted exactly
        total_refs = sum(len(t) for t in self._tables.values()) + len(cached)
        assert sum(counts) + len(cached) == total_refs
