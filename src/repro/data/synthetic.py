"""Deterministic synthetic data pipelines.

No network access in this container, so every experiment runs on procedural
data: a Zipf-ish Markov token stream for LM training (compressible -> loss
actually decreases), frame/patch embeddings for the stub frontends, and a
separable shapes-classification task for the CNN accuracy-drop calibration.

Multihost-shaped API: `lm_batch(..., process_index, process_count)` yields
this host's shard of the global batch; per-step seeding keeps every host
deterministic and disjoint without coordination (restart-safe: data is a
pure function of (seed, step)).
"""

from __future__ import annotations

import numpy as np


def _rng(seed: int, step: int, stream: int = 0) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, stream]))


def lm_batch(vocab: int, batch: int, seq: int, step: int, seed: int = 0,
             process_index: int = 0, process_count: int = 1) -> dict:
    """Markov-chain token stream: P(next | cur) concentrated on a few
    successors, so cross-entropy has real structure to learn."""
    assert batch % process_count == 0
    local = batch // process_count
    rng = _rng(seed, step, process_index)
    # deterministic per-vocab successor table (seed-level, step-free)
    table_rng = _rng(seed, 0, 10_000)
    successors = table_rng.integers(0, vocab, size=(vocab, 4))
    toks = np.empty((local, seq), np.int32)
    cur = rng.integers(0, vocab, size=local)
    for t in range(seq):
        toks[:, t] = cur
        branch = rng.random(local)
        nxt = successors[cur, rng.integers(0, 4, size=local)]
        rand = rng.integers(0, vocab, size=local)
        cur = np.where(branch < 0.85, nxt, rand)
    labels = np.concatenate([toks[:, 1:], np.zeros((local, 1), np.int32)], 1)
    mask = np.ones((local, seq), np.float32)
    mask[:, -1] = 0
    return {"tokens": toks, "labels": labels, "mask": mask}


def frames_batch(batch: int, enc_seq: int, d_model: int, step: int,
                 seed: int = 0) -> np.ndarray:
    rng = _rng(seed, step, 1)
    return rng.standard_normal((batch, enc_seq, d_model)).astype(np.float32)


def img_batch(batch: int, n_tokens: int, d_model: int, step: int,
              seed: int = 0) -> np.ndarray:
    rng = _rng(seed, step, 2)
    return (rng.standard_normal((batch, n_tokens, d_model)) * 0.1
            ).astype(np.float32)


# --- CNN calibration task -------------------------------------------------------

def shapes_classification(n: int, image: int = 32, n_classes: int = 4,
                          seed: int = 0, amplitude: float = 2.5,
                          noise: float = 0.3
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Procedural image classification: class = which quadrant holds a
    bright blob + global orientation of a gradient.  Linearly non-trivial,
    CNN-learnable in a few hundred steps on CPU.  Lower `amplitude` /
    higher `noise` makes the task margin-sensitive, so approximate-
    multiplier error produces measurable accuracy drops (the calibration
    benchmark uses that regime)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, image, image, 3)).astype(np.float32) * noise
    y = rng.integers(0, n_classes, size=n)
    yy, xx = np.mgrid[0:image, 0:image].astype(np.float32) / image
    grid = max(2, int(np.ceil(np.sqrt(max(n_classes // 2, 2)))))
    for i in range(n):
        c = y[i]
        pos = c % (grid * grid)
        cy = image * (2 * (pos % grid) + 1) // (2 * grid)
        cx = image * (2 * (pos // grid) + 1) // (2 * grid)
        blob = np.exp(-(((np.arange(image) - cy)[:, None] / 4.0) ** 2
                        + ((np.arange(image) - cx)[None, :] / 4.0) ** 2))
        x[i, :, :, 0] += amplitude * blob.astype(np.float32)
        x[i, :, :, 1] += (yy if c % 2 else xx) * 0.8
    return x, y.astype(np.int32)


def batch_for(cfg, shape_kind: str, batch: int, seq: int, step: int,
              seed: int = 0) -> dict:
    """Assemble the full input dict for a ModelConfig."""
    out = lm_batch(cfg.vocab, batch, seq, step, seed)
    if cfg.family == "encdec":
        out["frames"] = frames_batch(batch, cfg.enc_seq, cfg.d_model, step,
                                     seed)
    if cfg.cross_every:
        out["img"] = img_batch(batch, cfg.n_img_tokens, cfg.d_model, step,
                               seed)
    return out
