"""Logical-axis sharding hints.

Models are written against *logical* axis names ("batch", "heads", "ff",
"experts", ...).  A training/serving step activates a mesh + rule set; the
`hint` calls inside model code then become `with_sharding_constraint`s.
Outside any context (unit tests, single-device smoke runs) hints are no-ops,
so model code never depends on distribution state.

A rule maps logical axis -> mesh axis (or tuple of mesh axes, or None).
`hint` drops a mapping whenever the dimension is not divisible by the mesh
axes' total size (e.g. kv_heads=4 on a model=16 axis), which keeps every
constraint valid for every architecture without per-arch special cases.
"""

from __future__ import annotations

import contextlib
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: list[tuple[Mesh, dict[str, Any]]] = []


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict[str, Any]):
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def active() -> tuple[Mesh, dict[str, Any]] | None:
    return _ACTIVE[-1] if _ACTIVE else None


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def spec_for(shape: tuple[int, ...], logical: tuple[str | None, ...],
             mesh: Mesh, rules: dict[str, Any]) -> P:
    assert len(shape) == len(logical), (shape, logical)
    out = []
    used: set = set()
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name else None
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None  # not divisible -> replicate this dim
        if axis is not None:
            flat = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
            if any(a in used for a in flat):
                axis = None  # a mesh axis can appear at most once per spec
            else:
                used.update(flat)
        out.append(axis)
    return P(*out)


def hint(x: jax.Array, *logical: str | None) -> jax.Array:
    ctx = active()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
