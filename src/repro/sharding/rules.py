"""Parameter / batch / cache PartitionSpec rules for every architecture.

Megatron-style TP on the "model" axis, optional ZeRO-3/FSDP weight sharding
on the "data" axis, EP for MoE experts, and pod-composed data parallelism on
the multi-pod mesh.  Every rule passes through a divisibility check: an axis
that does not divide the dimension is dropped (replicated) — this is what
makes one rule set valid for all 10 architectures (e.g. kv_heads=4 on a
model=16 axis, or 8 experts on 16-way model parallelism).
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def logical_rules(mesh: Mesh, fsdp: bool = True) -> dict[str, Any]:
    """Rules for activation hints (sharding/ctx.py)."""
    return {
        "batch": dp_axes(mesh),
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "embed": None,
        "seq": None,
    }


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    axes = axis if isinstance(axis, tuple) else (axis,)
    return dim % math.prod(mesh.shape[a] for a in axes) == 0


def _clean(spec_axes: list, shape: tuple[int, ...], mesh: Mesh) -> P:
    out = []
    for dim, ax in zip(shape, spec_axes):
        out.append(ax if _fits(dim, mesh, ax) else None)
    return P(*out)


# core-dimension rules per parameter name: list of mesh-axis entries for the
# *trailing* dims; leading stack dims (layer / superblock) get None.
def _param_rules(fsdp_ax) -> dict[str, list]:
    col = [fsdp_ax, "model"]     # (in, out) column-parallel
    row = ["model", fsdp_ax]     # (in, out) row-parallel
    return {
        # embeddings / heads
        "embed": ["model", None],
        "lm_head": col,
        "dec_pos": [None, None],
        # attention (incl. whisper x-prefixed and vlm cross)
        "wq": col, "wk": col, "wv": col, "wo": row,
        "xwq": col, "xwk": col, "xwv": col, "xwo": row,
        # dense mlp
        "w_gate": col, "w_up": col, "w_down": row,
        "m_gate": col, "m_up": col, "m_down": row,
        # moe
        "router": [fsdp_ax, None],
        "we_gate": ["model", fsdp_ax, None],
        "we_up": ["model", fsdp_ax, None],
        "we_down": ["model", None, fsdp_ax],
        # mamba2.  Only the (bigger) input projection is TP-sharded:
        # the depthwise conv taps are tiny vector-unit arrays whose
        # channel-sharded output would be split/concatenated across shard
        # boundaries, and a row-parallel out_proj feeds off the replicated
        # SSD state math — both patterns XLA's CPU SPMD partitioner
        # miscompiles on supported JAX versions (tests/test_distributed.py
        # pins TP token parity for the ssm family).  out_proj keeps its
        # ZeRO-3 weight sharding on the data axis: only the model-axis
        # split is the hazard.
        "in_proj": col, "out_proj": [None, fsdp_ax],
        # rg-lru
        "w_x": col, "w_gate_br": col, "w_rg": col, "w_in": col,
        "w_out": row,
    }


def _moe_fallback(name: str, shape: tuple[int, ...], mesh: Mesh, fsdp_ax
                  ) -> P | None:
    """Experts not divisible by the model axis -> TP inside each expert."""
    if name in ("we_gate", "we_up") and not _fits(shape[-3], mesh, "model"):
        return _clean([None, fsdp_ax, "model"], shape[-3:], mesh)
    if name == "we_down" and not _fits(shape[-3], mesh, "model"):
        return _clean([None, "model", fsdp_ax], shape[-3:], mesh)
    return None


#: Serving-cache wrapper fields (approx/gemm.PreparedWeight dataclass
#: attrs).  These appear in key paths as attribute keys, NOT dict keys, so
#: skipping them never shadows a real param ("wq" is also an attention
#: projection name — as a dict key it still resolves normally).  The
#: wrapped leaves then inherit the underlying weight's partition rule:
#: wq/w/planes carry the (..., k, n) core dims, sw is (..., 1, n).
_PREPARED_ATTRS = frozenset({"w", "wq", "sw", "planes"})


def param_pspec(path: tuple, arr_shape: tuple[int, ...], mesh: Mesh,
                fsdp: bool = True) -> P:
    fsdp_ax = "data" if fsdp else None
    name = None
    for part in reversed(path):
        is_attr = not hasattr(part, "key") and hasattr(part, "name")
        key = getattr(part, "key", None) or getattr(part, "name", None) or \
            (part if isinstance(part, str) else None)
        if key is None or str(key) in ("q", "s"):
            continue  # int8-weight wrapper levels ({"q","s"} dict leaves)
        if is_attr and str(key) in _PREPARED_ATTRS:
            continue  # PreparedWeight fields: use the enclosing leaf name
        name = str(key)
        break
    rules = _param_rules(fsdp_ax)
    if name not in rules:
        return P()  # norms, scalars, biases, gates: replicated
    core = rules[name]
    ncore = len(core)
    if len(arr_shape) < ncore:
        return P()
    moe_alt = _moe_fallback(name, arr_shape, mesh, fsdp_ax)
    if moe_alt is not None:
        core_spec = list(moe_alt)
    else:
        core_spec = list(_clean(core, arr_shape[-ncore:], mesh))
    lead = [None] * (len(arr_shape) - ncore)
    return P(*lead, *core_spec)


def param_shardings(params_shape: Any, mesh: Mesh, fsdp: bool = True) -> Any:
    """Tree of NamedShardings matching a params (shape-)tree."""
    def mk(path, leaf):
        shape = leaf.shape
        return NamedSharding(mesh, param_pspec(path, shape, mesh, fsdp))
    return jax.tree_util.tree_map_with_path(mk, params_shape)


# --- batches ------------------------------------------------------------------

def batch_pspec(name: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    if not shape:
        return P()
    spec = [dp if _fits(shape[0], mesh, dp) else None]
    spec += [None] * (len(shape) - 1)
    return P(*spec)


def batch_shardings(specs: dict, mesh: Mesh) -> dict:
    return {k: NamedSharding(mesh, batch_pspec(k, v.shape, mesh))
            for k, v in specs.items()}


# --- decode caches --------------------------------------------------------------

# batch-dim position per cache key (negative = from the end)
_CACHE_BATCH_DIM = {
    "k": -4, "v": -4, "xk": -4, "xv": -4,
    "conv": 1, "ssm": 1,
    "rec_conv": 2, "rec_lru": 2, "att_k": 1, "att_v": 1,
    "tail_conv": 1, "tail_lru": 1,
}
# additionally shard kv-heads/head dims on "model" where they exist.
# (The mamba2 "ssm" state is deliberately absent: the SSD recurrence runs
# replicated — see the in_proj-only TP rule above — so sharding its state
# would only buy a reshard per decode step.)
_CACHE_MODEL_DIM = {"k": -2, "v": -2, "xk": -2, "xv": -2,
                    "att_k": -2, "att_v": -2}


def cache_pspec(key: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    if key == "length" or not shape:
        return P()
    dp = dp_axes(mesh)
    spec: list = [None] * len(shape)
    bpos = _CACHE_BATCH_DIM.get(key)
    if bpos is not None:
        bpos = bpos % len(shape)
        if _fits(shape[bpos], mesh, dp):
            spec[bpos] = dp
    mpos = _CACHE_MODEL_DIM.get(key)
    if mpos is not None:
        mpos = mpos % len(shape)
        if spec[mpos] is None and _fits(shape[mpos], mesh, "model"):
            spec[mpos] = "model"
    return P(*spec)


def paged_pool_pspec(key: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for a paged-KV POOL leaf (serving/arena.PagedArena).

    Pools drop the per-slot batch axis — requests address pages through
    block tables, so there is no batch dim to put on "data"; the global
    page-rows axis stays replicated (gathers/scatters index it with
    traffic-dependent tables).  The kv-head/model dim keeps the exact
    rule of the dense cache leaf it replaces, so a TP mesh shards paged
    KV identically to slot KV."""
    if key == "length" or not shape:
        return P()
    spec: list = [None] * len(shape)
    mpos = _CACHE_MODEL_DIM.get(key)
    if mpos is not None:
        mpos = mpos % len(shape)
        if _fits(shape[mpos], mesh, "model"):
            spec[mpos] = "model"
    return P(*spec)


def paged_cache_shardings(cache_tree: Any, mesh: Mesh,
                          paged_keys: frozenset[str] | set[str]) -> Any:
    """Like `cache_shardings` but routes pool leaves (keys in
    `paged_keys`) through the pool rule and everything else (slot-dense
    leaves, lengths) through the dense cache rule."""
    def mk(path, leaf):
        key = ""
        for part in reversed(path):
            k = getattr(part, "key", None)
            if k is not None:
                key = str(k)
                break
        fn = paged_pool_pspec if key in paged_keys else cache_pspec
        return NamedSharding(mesh, fn(key, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(mk, cache_tree)


def cache_shardings(cache_tree: Any, mesh: Mesh) -> Any:
    def mk(path, leaf):
        key = None
        for part in reversed(path):
            k = getattr(part, "key", None)
            if k is not None:
                key = str(k)
                break
        return NamedSharding(mesh, cache_pspec(key or "", leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(mk, cache_tree)


def should_fsdp(cfg: ModelConfig) -> bool:
    """ZeRO-3 weight sharding on the data axis for >=20B-param configs."""
    return cfg.param_count() >= 20e9


# --- rule introspection (repro.analysis coverage checker) ---------------------

def known_param_rule_names() -> frozenset[str]:
    """Param leaf names with an explicit partition rule."""
    return frozenset(_param_rules(None))


def known_cache_keys() -> frozenset[str]:
    """Decode-cache keys with a batch-dim rule ("length" is handled as an
    explicit replicated special case in cache_pspec)."""
    return frozenset(_CACHE_BATCH_DIM) | {"length"}
