"""Pipeline parallelism: GPipe-style microbatch streaming over a "stage"
mesh axis with lax.ppermute activation transfer (shard_map).

This is the optional third parallelism dimension (the production meshes in
launch/mesh.py use data x model; PP composes by adding a leading "stage"
axis).  The schedule below is the classic fill-drain pipeline: M microbatches
over S stages in M + S - 1 ticks, bubble fraction (S-1)/(M+S-1).  Tested on
forced multi-device CPU in tests/test_distributed.py.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn: Callable, stage_params, x_mb: jax.Array,
                   mesh: Mesh, axis: str = "stage") -> jax.Array:
    """Run `stage_fn(params_i, x)` as a pipeline over mesh axis `axis`.

    stage_params: leading dim S (sharded over `axis`), one slice per stage.
    x_mb: (M, mb, d) microbatched input (replicated).
    Returns (M, mb, d) outputs (replicated).
    """
    s = mesh.shape[axis]
    m = x_mb.shape[0]
    steps = m + s - 1

    pspec_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(pspec_params, P()), out_specs=P(),
        check_rep=False)
    def run(params, xs):
        idx = jax.lax.axis_index(axis)
        local_params = jax.tree_util.tree_map(lambda p: p[0], params)
        perm = [(i, i + 1) for i in range(s - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; downstream stages consume buf
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(idx == 0, xs[mb_idx], buf)
            y = stage_fn(local_params, x_in)
            # the last stage's y for tick t is microbatch t-(s-1)
            out_idx = t - (s - 1)
            valid = (idx == s - 1) & (out_idx >= 0) & (out_idx < m)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, m - 1), 0),
                lambda o: o, outs)
            buf_next = jax.lax.ppermute(y, axis, perm)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(steps))
        # only the last stage holds real outputs; broadcast to all stages
        outs = jax.lax.psum(
            jnp.where(idx == s - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run(stage_params, x_mb)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
