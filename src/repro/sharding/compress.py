"""Gradient compression: int8 ring reduce-scatter / all-gather with error
feedback (shard_map + lax.ppermute).

Wire cost per device for an N-way all-reduce of a tensor with B bytes
(bf16): ring psum moves 2*(N-1)/N * B bytes; this path moves
(N-1)/N * B/2 * 2 = (N-1)/N * B bytes int8 total for RS+AG — a 4x wire-byte
reduction at int8 precision, with cross-step error feedback absorbing the
local quantization error (1-bit-Adam-style; per-hop requantization noise is
additional and documented).  Used as an opt-in (`compress_grads=True`) path
for DP gradient reduction; the default path is GSPMD's native psum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

INT8_MAX = 127.0


def _q(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX - 1, INT8_MAX)
    return q.astype(jnp.int8), scale


def _dq(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def ring_reduce_scatter_q(x: jax.Array, axis_name: str) -> jax.Array:
    """x (n*chunk,) f32 per device -> this device's summed chunk, int8 wire.

    Device i ends with sum_j x_j[(i+1) % n] (chunk indexed (i+1) mod n —
    callers pair this with the matching all-gather below).
    """
    n = jax.lax.psum(1, axis_name)
    i = jax.lax.axis_index(axis_name)
    parts = x.reshape(n, -1)
    perm = [(j, (j + 1) % n) for j in range(n)]
    cur = jnp.take(parts, i, axis=0)  # partial for chunk i (local only)
    for t in range(n - 1):
        q, s = _q(cur)
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        recv = _dq(q, s)  # partial for chunk (i - t - 1) mod n
        cur = recv + jnp.take(parts, (i - t - 1) % n, axis=0)
    return cur  # chunk (i + 1) % n fully reduced


def ring_all_gather_q(chunk: jax.Array, axis_name: str) -> jax.Array:
    """Inverse layout of ring_reduce_scatter_q: device i contributes chunk
    (i+1) % n; returns the full concatenated (n*chunk,) tensor, int8 wire."""
    n = jax.lax.psum(1, axis_name)
    i = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % n) for j in range(n)]
    q0, s0 = _q(chunk)
    out = jnp.zeros((n,) + chunk.shape, jnp.float32)
    out = out.at[(i + 1) % n].set(_dq(q0, s0))
    q, s = q0, s0
    for t in range(n - 1):
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        # received chunk belongs to device (i - t - 1): chunk idx (i - t)
        out = out.at[(i - t) % n].set(_dq(q, s))
    return out.reshape(-1)


def compressed_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Sum-all-reduce with int8 wire traffic (ring RS + ring AG)."""
    n = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunk = ring_reduce_scatter_q(flat, axis_name)
    full = ring_all_gather_q(chunk, axis_name)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


def ef_compressed_allreduce(g: jax.Array, e: jax.Array, axis_name: str
                            ) -> tuple[jax.Array, jax.Array]:
    """Error-feedback compressed all-reduce.

    c = Q(g + e);  e' = (g + e) - deQ(c);  return (allreduce(deQ(c)), e').
    The compounding quantization error stays local and is re-injected next
    step, keeping SGD convergence (Karimireddy et al., 2019).
    """
    x = g.astype(jnp.float32) + e
    q, s = _q(x)
    local = _dq(q, s)
    e_new = x - local
    return compressed_allreduce(local, axis_name), e_new


def make_compressed_allreduce_fn(mesh: Mesh, axis: str = "data"):
    """shard_map-wrapped compressed all-reduce over one mesh axis, for
    replicated-along-`axis` tensors."""
    @functools.partial(
        shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
        check_rep=False)
    def fn(x):
        return compressed_allreduce(x, axis) / jax.lax.psum(1, axis)

    return fn
