"""Roofline HLO analyzer: while-trip scaling, collective parsing, terms."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis, hlo_parse


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_scale_by_trip_count():
    def body(c, _):
        return c @ c.T @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.ones((256, 128), jnp.bfloat16)
    st = hlo_parse.analyze_module(_compile_text(f, x))
    expect = (2 * 256 * 256 * 128 + 2 * 256 * 128 * 256) * 10
    assert st.flops == pytest.approx(expect, rel=1e-6)


def test_unrolled_matches_scan():
    def f_scan(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=4)
        return y

    def f_unroll(x):
        for _ in range(4):
            x = x @ x
        return x

    x = jnp.ones((128, 128), jnp.float32)
    s1 = hlo_parse.analyze_module(_compile_text(f_scan, x))
    s2 = hlo_parse.analyze_module(_compile_text(f_unroll, x))
    assert s1.flops == pytest.approx(s2.flops, rel=1e-6)


def test_nested_scan_multiplies():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jnp.ones((64, 64), jnp.float32)
    st = hlo_parse.analyze_module(_compile_text(f, x))
    assert st.flops == pytest.approx(2 * 64 ** 3 * 15, rel=1e-6)


def test_collective_parsing_synthetic_text():
    txt = """
HloModule m, entry_computation_layout={()->f32[]}

ENTRY %main.1 (p0.1: f32[16,128]) -> f32[16,128] {
  %p0.1 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0.1), replica_groups={}
  %ag = f32[32,128]{1,0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[16,128]{1,0} reduce-scatter(%ag), dimensions={0}
}
"""
    st = hlo_parse.analyze_module(txt, entry="main.1")
    assert st.collectives["all-reduce"] == 16 * 128 * 4
    assert st.collectives["all-gather"] == 16 * 128 * 4
    assert st.collectives["reduce-scatter"] == 32 * 128 * 4


def test_parse_collectives_sums_operand_bytes():
    """analysis.parse_collectives: the regex-only fallback parser (no
    module structure needed) sums operand bytes per collective kind,
    including -start async forms and multi-operand tuples."""
    txt = """
  %ar = f32[16,128]{1,0} all-reduce(%a), replica_groups={}
  %ag = (bf16[8,64]{1,0}, bf16[8,64]{1,0}) all-gather-start(%b, %c)
  %cp = s8[1024]{0} collective-permute(%d)
  %a2a = f32[4,4]{1,0} all-to-all(%e)
  %rs = f32[32,128]{1,0} reduce-scatter(%f)
"""
    # operand types come from the argument list, which in real HLO
    # carries the full typed operands; synthesize that here
    txt = txt.replace("(%a)", "(f32[16,128] %a)")
    txt = txt.replace("(%b, %c)", "(bf16[8,64] %b, bf16[8,64] %c)")
    txt = txt.replace("(%d)", "(s8[1024] %d)")
    txt = txt.replace("(%e)", "(f32[4,4] %e)")
    txt = txt.replace("(%f)", "(f32[32,128] %f)")
    got = analysis.parse_collectives(txt)
    assert got["all-reduce"] == 16 * 128 * 4
    assert got["all-gather"] == 2 * 8 * 64 * 2
    assert got["collective-permute"] == 1024
    assert got["all-to-all"] == 4 * 4 * 4
    assert got["reduce-scatter"] == 32 * 128 * 4


def test_parse_collectives_ignores_non_collectives():
    txt = """
  %dot = f32[128,128]{1,0} dot(f32[128,64] %x, f32[64,128] %y)
  %add = f32[128,128]{1,0} add(f32[128,128] %dot, f32[128,128] %dot)
"""
    got = analysis.parse_collectives(txt)
    assert set(got) == set(analysis.COLLECTIVE_KINDS)
    assert all(v == 0 for v in got.values())


def test_parse_collectives_scalar_and_unknown_dtype():
    txt = ("  %ar = bf16[] all-reduce(bf16[] %s)\n"
           "  %ar2 = f32[8]{0} all-reduce(mystery[8] %t)\n")
    got = analysis.parse_collectives(txt)
    # scalar: 1 element * 2 bytes; unknown dtype contributes 0
    assert got["all-reduce"] == 2


def test_terms_and_bottleneck():
    t = analysis.RooflineTerms(
        flops=1e18, hbm_bytes=1e15, collective_bytes=1e14,
        collectives={}, chips=256, model_flops=5e17)
    assert t.compute_s == pytest.approx(1e18 / (256 * 197e12))
    assert t.memory_s == pytest.approx(1e15 / (256 * 819e9))
    assert t.collective_s == pytest.approx(1e14 / (256 * 50e9))
    assert t.bottleneck == "compute"
    assert 0 < t.roofline_fraction <= 1


def test_terms_bottleneck_variants_and_ratios():
    mem = analysis.RooflineTerms(
        flops=1e12, hbm_bytes=1e15, collective_bytes=0.0, collectives={},
        chips=1, model_flops=1e12)
    assert mem.bottleneck == "memory"
    coll = analysis.RooflineTerms(
        flops=1e12, hbm_bytes=1e9, collective_bytes=1e15, collectives={},
        chips=1, model_flops=1e12)
    assert coll.bottleneck == "collective"
    # useful_flops_ratio is MODEL/HLO; remat (HLO > MODEL) gives < 1
    assert coll.useful_flops_ratio == pytest.approx(1.0)
    remat = analysis.RooflineTerms(
        flops=2e12, hbm_bytes=1e9, collective_bytes=0.0, collectives={},
        chips=1, model_flops=1e12)
    assert remat.useful_flops_ratio == pytest.approx(0.5)
    assert remat.roofline_fraction == pytest.approx(0.5)


def test_terms_zero_edges():
    z = analysis.RooflineTerms(
        flops=0.0, hbm_bytes=0.0, collective_bytes=0.0, collectives={},
        chips=4, model_flops=0.0)
    assert z.useful_flops_ratio == 0.0
    assert z.roofline_fraction == 0.0
    assert z.roofline_fraction_kernel_adj == 0.0


def test_terms_as_dict_round_trip():
    t = analysis.RooflineTerms(
        flops=1e18, hbm_bytes=1e15, collective_bytes=1e14,
        collectives={"all-reduce": 1e14}, chips=256, model_flops=5e17,
        tagged_bytes=2e14, kernel_io_bytes=1e13)
    d = t.as_dict()
    assert {"flops", "hbm_bytes", "collective_bytes", "collectives",
            "chips", "model_flops", "compute_s", "memory_s",
            "collective_s", "bottleneck", "useful_flops_ratio",
            "roofline_fraction", "tagged_bytes", "kernel_io_bytes",
            "memory_kernel_adj_s",
            "roofline_fraction_kernel_adj"} <= set(d)
    assert d["compute_s"] == pytest.approx(t.compute_s)
    assert d["bottleneck"] == t.bottleneck
    import json
    json.dumps(d)  # JSON-serializable for the dry-run artifact


def test_kernel_adjustment_reduces_memory_term():
    t = analysis.RooflineTerms(
        flops=1e18, hbm_bytes=1e16, collective_bytes=0.0, collectives={},
        chips=256, model_flops=5e17, tagged_bytes=8e15,
        kernel_io_bytes=1e14)
    assert t.hbm_bytes_kernel_adj == pytest.approx(2e15 + 1e14)
    assert t.memory_kernel_adj_s < t.memory_s
    assert t.roofline_fraction_kernel_adj >= t.roofline_fraction


def test_model_flops_shapes():
    from repro import configs
    cfg = configs.get_config("tinyllama-1.1b")
    tr = analysis.model_flops_for_cell(cfg, configs.SHAPES["train_4k"])
    pf = analysis.model_flops_for_cell(cfg, configs.SHAPES["prefill_32k"])
    dc = analysis.model_flops_for_cell(cfg, configs.SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert dc == pytest.approx(2 * n * 128)


def test_vmem_tag_detected():
    from repro.models import attention as A
    q = jnp.ones((1, 64, 4, 32), jnp.float32)

    def f(q):
        return A.blockwise_attention(q, q, q, 32, True, 0)

    st = hlo_parse.analyze_module(_compile_text(f, q))
    assert st.tagged_traffic_bytes > 0
    assert st.tagged_traffic_bytes <= st.traffic_bytes


def test_dryrun_results_json_schema():
    """The committed sweep artifacts stay consistent with the analyzer."""
    import json
    import os
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "dryrun_results_optimized.json")
    if not os.path.exists(path):
        pytest.skip("sweep artifact not present")
    results = json.load(open(path))
    assert len(results) == 80
    ok = [r for r in results if r["ok"]]
    assert len(ok) == 64
    for r in ok:
        rf = r["roofline"]
        assert rf["flops"] > 0
        assert rf["hbm_bytes"] > 0
        assert rf["bottleneck"] in ("compute", "memory", "collective")
    skips = [r for r in results if r.get("skip_reason")]
    assert len(skips) == 16
