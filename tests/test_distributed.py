"""Distributed runtime: sharding rules, compressed collectives, pipeline
parallelism, sharded train step.  Multi-device cases run in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process keeps its single real device (per the brief)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(code: str, n: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_param_pspec_rules():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding import rules
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class K:  # fake DictKey
        def __init__(self, k):
            self.key = k

    # column parallel
    assert rules.param_pspec((K("layers"), K("wq")), (22, 2048, 2048),
                             mesh, fsdp=True) == P(None, "data", "model")
    # row parallel
    assert rules.param_pspec((K("layers"), K("wo")), (22, 2048, 2048),
                             mesh, fsdp=False) == P(None, "model", None)
    # norms replicated
    assert rules.param_pspec((K("layers"), K("ln1")), (22, 2048),
                             mesh) == P()
    # embedding vocab-sharded
    assert rules.param_pspec((K("embed"),), (32000, 2048), mesh) == \
        P("model", None)


def test_param_pspec_divisibility_drop():
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_abstract_mesh
    from repro.sharding import rules

    class K:
        def __init__(self, k):
            self.key = k

    mesh16 = make_abstract_mesh((2, 16), ("data", "model"))
    # kv proj with kv*hd=60 not divisible by 16 -> model axis dropped
    assert rules.param_pspec((K("wk"),), (2048, 60), mesh16,
                             fsdp=False) == P(None, None)
    # same name, divisible dim -> sharded
    assert rules.param_pspec((K("wk"),), (2048, 64), mesh16,
                             fsdp=False) == P(None, "model")
    # row-parallel with contraction dim not divisible -> dropped; the
    # fsdp dim still applies when it divides
    assert rules.param_pspec((K("wo"),), (60, 2048), mesh16,
                             fsdp=True) == P(None, "data")
    # stacked leaf: leading layer dims stay None, core rule on the tail
    assert rules.param_pspec((K("layers"), K("wq")), (22, 2048, 2048),
                             mesh16, fsdp=False) == P(None, None, "model")


def test_prepared_weight_leaves_inherit_weight_rules():
    """PreparedWeight wrapper fields (attr keys) resolve to the enclosing
    weight's partition rule; a REAL param named like a wrapper field
    (dict key "wq") still resolves normally."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.approx import gemm as G
    from repro.compat import make_abstract_mesh
    from repro.sharding import rules
    import jax.numpy as jnp

    mesh = make_abstract_mesh((1, 4), ("data", "model"))
    pw = G.prepare_weight(jnp.ones((128, 64), jnp.float32),
                          G.spec_from_name("pareto:0.02:r2"))
    tree = {"layers": {"wq": pw}}
    shapes = jax.tree_util.tree_map_with_path(
        lambda p, l: rules.param_pspec(p, l.shape, mesh, fsdp=False), tree)
    got = shapes["layers"]["wq"]
    # w and wq carry the (k, n) col rule; sw (1, n) shards n; planes
    # (R, k, n) gets a leading None
    assert got.w == P(None, "model")
    assert got.wq == P(None, "model")
    assert got.sw == P(None, "model")
    assert got.planes == P(None, None, "model")


def test_tp_fused_qgemm_shard_map_parity():
    """Fused approx-QGEMM through shard_map on a 4-way model axis vs the
    single-device kernel: bit-identical for the pure-integer trunc mode;
    lowrank matches to the f32 flush's FMA-fusion jitter (the per-plane
    int32 accumulators are exact — only the final scale-and-sum is
    compiled per program context)."""
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.approx import gemm as G
        from repro.kernels import ops
        from repro.launch.mesh import make_mesh_from_spec

        mesh = make_mesh_from_spec("model=4,data=2")
        rng = np.random.default_rng(0)
        m, k, n = 96, 160, 256
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)

        spec = G.spec_from_name("trunc2x2")
        ref = np.asarray(ops.approx_qgemm(a, b, spec))
        tp = np.asarray(jax.jit(
            lambda a, b: ops.approx_qgemm_tp(a, b, spec, mesh))(a, b))
        assert np.array_equal(ref, tp), "trunc TP != single-device kernel"
        # the stacked reference twin stays bit-identical under TP too
        tps = np.asarray(jax.jit(lambda a, b: ops.approx_qgemm_tp(
            a, b, spec, mesh, fused=False))(a, b))
        assert np.array_equal(ref, tps)

        spec = G.spec_from_name("pareto:0.02:r2")
        ref = np.asarray(ops.approx_qgemm(a, b, spec))
        tp = np.asarray(jax.jit(
            lambda a, b: ops.approx_qgemm_tp(a, b, spec, mesh))(a, b))
        err = np.abs(tp - ref) / np.maximum(np.abs(ref), 1.0)
        assert err.max() < 1e-3, err.max()
        print("OK")
    """)


def test_serving_decode_token_parity_across_meshes():
    """Greedy decode through the Engine on a 1-die mesh must be
    token-identical to a 4-way model-parallel mesh, for an attention
    family and an SSM family (the tentpole acceptance criterion)."""
    run_devices("""
        import jax, numpy as np
        from repro import configs
        from repro.models import api
        from repro.serving import Engine, Request, SamplingParams
        from repro.launch.mesh import make_mesh_from_spec

        def serve(arch, mesh_spec):
            cfg = configs.reduced(configs.get_config(arch))
            params = api.init_params(cfg, jax.random.key(0))
            eng = Engine(cfg, params, capacity=3, max_len=64, seed=0,
                         mesh=make_mesh_from_spec(mesh_spec))
            rng = np.random.default_rng(5)
            for i, n in enumerate([5, 19, 33]):
                eng.submit(Request(f"r{i}",
                                   rng.integers(1, 256, (n,)).tolist(),
                                   SamplingParams(max_new_tokens=6)))
            done = {c.request_id: c.tokens
                    for c in eng.run_until_complete()}
            return done, eng.stats()

        for arch in ("tinyllama-1.1b", "mamba2-370m"):
            one, _ = serve(arch, "data=1,model=1")
            tp, stats = serve(arch, "model=4,data=2")
            assert one == tp, (arch, one, tp)
            assert stats["mesh"] == {"data": 2, "model": 4}, stats
            assert stats["evictions"]["length"] == 3, stats
        print("OK")
    """, timeout=1800)


def test_tp_serving_calibration_anchor():
    """The delay anchor can measure TENSOR-PARALLEL serving decode, with
    the analytical mirror running the same die partitioning."""
    run_devices("""
        from repro.core import calibrate as cal
        c = cal.calibrate_serving(requests=2, capacity=2, max_len=32,
                                  prompt=6, gen=3,
                                  mesh_spec="model=2,data=1")
        assert c.source == "serving"
        assert c.meta["n_dies"] == 2, c.meta
        assert c.measured > 0 and c.analytical > 0 and c.scale > 0
        assert "x 2 dies" in c.anchor, c.anchor
        print("OK")
    """, timeout=1200)


def test_engine_respects_repro_mesh_env(monkeypatch):
    """REPRO_MESH reaches the engine through make_mesh_from_spec."""
    import jax
    from repro.launch import mesh as meshmod
    monkeypatch.setenv("REPRO_MESH", "data=1,model=1")
    m = meshmod.make_mesh_from_spec()
    assert dict(m.shape) == {"data": 1, "model": 1}
    monkeypatch.setenv("REPRO_MESH", "model=999")
    import pytest
    with pytest.raises(ValueError, match="devices"):
        meshmod.make_mesh_from_spec()
    # explicit spec takes precedence over the env
    m2 = meshmod.make_mesh_from_spec("model=1,data=1")
    assert dict(m2.shape) == {"data": 1, "model": 1}


def test_moe_expert_sharding_fallback():
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_abstract_mesh
    from repro.sharding import rules

    class K:
        def __init__(self, k):
            self.key = k

    mesh = make_abstract_mesh((1, 2), ("data", "model"))
    # 128 experts % 2 == 0 -> EP on experts dim
    assert rules.param_pspec((K("we_gate"),), (128, 512, 256), mesh) == \
        P("model", "data", None)
    # 3 experts % 2 != 0 -> TP inside the expert instead
    assert rules.param_pspec((K("we_gate"),), (3, 512, 256), mesh) == \
        P(None, "data", "model")
    # production mesh: grok's 8 experts vs model=16 -> in-expert TP
    mesh16 = make_abstract_mesh((16, 16), ("data", "model"))
    assert rules.param_pspec((K("we_gate"),), (8, 6144, 32768), mesh16) == \
        P(None, "data", "model")
    # llama4's 128 experts vs model=16 -> EP
    assert rules.param_pspec((K("we_gate"),), (128, 5120, 8192),
                             mesh16) == P("model", "data", None)


def test_compressed_allreduce_matches_psum():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.sharding import compress

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        xs = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)

        @functools.partial(shard_map, mesh=mesh, in_specs=P("data"),
                           out_specs=P("data"), check_rep=False)
        def f(x):
            local = x[0]
            s = compress.compressed_allreduce(local, "data")
            return s[None]

        got = np.asarray(f(xs))
        want = np.asarray(xs.sum(0))
        # int8 wire: error bounded by ~n_hops quantization steps of the
        # tensor scale (NOT element-relative — near-zero sums would make
        # any quantized scheme look unbounded)
        tol = 0.05 * np.abs(want).max()
        for i in range(8):
            assert np.abs(got[i] - want).max() < tol
            np.testing.assert_allclose(got[i], got[0], rtol=0, atol=0)
        print("OK")
    """)


def test_error_feedback_reduces_bias():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.sharding import compress

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.standard_normal((8, 1024)), jnp.float32)

        @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")), check_rep=False)
        def step(gs, es):
            out, e2 = compress.ef_compressed_allreduce(gs[0], es[0], "data")
            return out[None], e2[None]

        # accumulate the same gradient over steps; with EF the running sum of
        # compressed reductions tracks the true sum closely
        e = jnp.zeros_like(g)
        acc = np.zeros(1024)
        for _ in range(8):
            out, e = step(g, e)
            acc += np.asarray(out[0])
        want = 8 * np.asarray(g.sum(0))
        rel = np.abs(acc - want).mean() / (np.abs(want).mean() + 1e-6)
        assert rel < 0.02, rel
        print("OK")
    """)


def test_pipeline_matches_sequential():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding import pipeline

        mesh = jax.make_mesh((4,), ("stage",))
        rng = np.random.default_rng(2)
        S, M, MB, D = 4, 6, 8, 32
        w = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)

        def stage_fn(wi, x):
            return jnp.tanh(x @ wi)

        x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)
        got = pipeline.pipeline_apply(stage_fn, w, x, mesh, "stage")
        want = x
        for i in range(S):
            want = jnp.tanh(want @ w[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("OK", pipeline.bubble_fraction(S, M))
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.configs.base import reduced
        from repro.models import api
        from repro.train import train_step as ts
        from repro.data import synthetic

        cfg = reduced(configs.get_config("tinyllama-1.1b"), remat=True)
        options = ts.StepOptions(accum_steps=2, lr=1e-3, total_steps=50)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        init_fn, step, st_sh = ts.make_train_step(cfg, options, mesh,
                                                  donate=False)
        state = jax.device_put(init_fn(jax.random.key(0)), st_sh)
        batch_np = synthetic.lm_batch(cfg.vocab, 8, 64, step=0)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        state2, m1 = step(state, batch)
        state3, m2 = step(state2, batch)
        assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
        assert float(m2["loss"]) < float(m1["loss"]) + 1.0

        # single-device reference: same init, same batch, same update
        mesh1 = jax.make_mesh((1, 1), ("data", "model"))
        init1, step1, sh1 = ts.make_train_step(cfg, options, mesh1,
                                               donate=False)
        s1 = jax.device_put(init1(jax.random.key(0)), sh1)
        s1b, r1 = step1(s1, batch)
        np.testing.assert_allclose(float(r1["loss"]), float(m1["loss"]),
                                   rtol=2e-4)
        print("OK", float(m1["loss"]))
    """)


def test_elastic_checkpoint_restore_across_meshes():
    run_devices("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.configs.base import reduced
        from repro.train import train_step as ts, checkpoint as ckpt
        from repro.data import synthetic

        cfg = reduced(configs.get_config("tinyllama-1.1b"))
        options = ts.StepOptions(lr=1e-3, total_steps=50)
        d = tempfile.mkdtemp()
        mgr = ckpt.CheckpointManager(d)

        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        init_fn, step_a, sh_a = ts.make_train_step(cfg, options, mesh_a,
                                                   donate=False)
        state = jax.device_put(init_fn(jax.random.key(0)), sh_a)
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic.lm_batch(cfg.vocab, 8, 64, step=0).items()}
        state, _ = step_a(state, batch)
        mgr.save(state, step=1)

        # restore onto a DIFFERENT mesh shape (elastic rescale)
        mesh_b = jax.make_mesh((8, 1), ("data", "model"))
        init_b, step_b, sh_b = ts.make_train_step(cfg, options, mesh_b,
                                                  donate=False)
        target = jax.eval_shape(init_b, jax.random.key(0))
        restored, at_step = mgr.restore(target, shardings=sh_b)
        assert at_step == 1
        # values identical regardless of mesh
        a = jax.device_get(state["params"]["embed"])
        b = jax.device_get(restored["params"]["embed"])
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and training continues
        restored2, m = step_b(restored, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK")
    """)


def test_hierarchical_batch_sharding_multipod():
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_abstract_mesh
    from repro.sharding import rules
    mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = rules.batch_pspec("tokens", (256, 4096), mesh)
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k) not divisible -> replicated
    spec1 = rules.batch_pspec("tokens", (1, 1), mesh)
    assert spec1 == P(None, None)
