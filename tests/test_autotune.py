"""kernels/autotune.py: tuning cache + roofline-pruned tile search, and
its integration with kernels/dispatch.choose_gemm_path.

Measurement is injected as a seeded deterministic stub everywhere — these
tests must never depend on wall-clock timer noise.
"""

import json
import os

import pytest

from repro.kernels import approx_qgemm as qk
from repro.kernels import autotune, dispatch


@pytest.fixture
def cache_path(tmp_path, monkeypatch):
    p = str(tmp_path / "TUNING_gemm.json")
    monkeypatch.setenv("REPRO_TUNING_CACHE", p)
    autotune._MEMO.clear()
    yield p
    autotune._MEMO.clear()


def _stub(winner="fused", best_bk=256):
    """measure(path, m, k, n, bm, bk, bn, unroll, skinny) -> seconds."""
    def measure(path, m, k, n, bm, bk, bn, unroll, skinny):
        base = {"fused": 10.0, "stacked": 20.0, "xla": 30.0}
        t = base[path]
        if path == winner:
            t = 1.0
        if path == "fused":
            t += 0.0 if bk == best_bk else 0.5
            t += 0.01 * unroll
        return t
    return measure


BUDGET = dispatch.VMEM_BUDGET_BYTES


def test_cache_round_trip(cache_path):
    plan = autotune.tune_gemm(256, 512, 256, mode="lowrank", rank=2,
                              measure=_stub("fused"), backend="cpu",
                              vmem_budget=BUDGET)
    assert plan.path == "fused"
    assert os.path.exists(cache_path)
    hit = autotune.lookup(256, 512, 256, "lowrank", 2, backend="cpu",
                          vmem_budget=BUDGET)
    assert hit is not None
    assert (hit.path, hit.bm, hit.bk, hit.bn, hit.unroll, hit.skinny) == \
        (plan.path, plan.bm, plan.bk, plan.bn, plan.unroll, plan.skinny)
    # same shape bucket: a nearby shape hits the same entry
    assert autotune.lookup(250, 500, 250, "lowrank", 2, backend="cpu",
                           vmem_budget=BUDGET) is not None
    # different mode/rank/backend/budget cells all miss
    assert autotune.lookup(256, 512, 256, "exact", 0, backend="cpu",
                           vmem_budget=BUDGET) is None
    assert autotune.lookup(256, 512, 256, "lowrank", 4, backend="cpu",
                           vmem_budget=BUDGET) is None
    assert autotune.lookup(256, 512, 256, "lowrank", 2, backend="tpu",
                           vmem_budget=BUDGET) is None
    assert autotune.lookup(256, 512, 256, "lowrank", 2, backend="cpu",
                           vmem_budget=BUDGET + 1) is None


def test_deterministic_winner(cache_path):
    plans = [autotune.tune_gemm(256, 512, 256, mode="lowrank", rank=2,
                                measure=_stub("fused", best_bk=256),
                                backend="cpu", vmem_budget=BUDGET)
             for _ in range(3)]
    assert len({(p.path, p.bm, p.bk, p.bn, p.unroll) for p in plans}) == 1
    assert plans[0].bk == 256
    assert plans[0].unroll == 1  # 0.01/plane penalty: unroll=1 wins the tie
    # a stub that makes xla the winner elects xla
    p2 = autotune.tune_gemm(256, 512, 256, mode="exact", rank=0,
                            measure=_stub("xla"), backend="cpu",
                            vmem_budget=BUDGET)
    assert p2.path == "xla"


def test_stale_entry_invalidation(cache_path):
    autotune.tune_gemm(256, 512, 256, mode="exact", rank=0,
                       measure=_stub("fused"), backend="cpu",
                       vmem_budget=BUDGET)

    def reload():
        autotune._MEMO.clear()
        return autotune.lookup(256, 512, 256, "exact", 0, backend="cpu",
                               vmem_budget=BUDGET)

    assert reload() is not None
    # kernel schedule changed -> every measured entry is stale
    with open(cache_path) as f:
        raw = json.load(f)
    raw["kernel_version"] = qk.KERNEL_VERSION - 1
    with open(cache_path, "w") as f:
        json.dump(raw, f)
    assert reload() is None
    # cache schema changed -> same
    raw["kernel_version"] = qk.KERNEL_VERSION
    raw["schema"] = autotune.CACHE_SCHEMA + 1
    with open(cache_path, "w") as f:
        json.dump(raw, f)
    assert reload() is None


def test_corrupt_cache_falls_back(cache_path):
    with open(cache_path, "w") as f:
        f.write("{ this is not json")
    assert autotune.load_cache(cache_path)["entries"] == {}
    assert autotune.lookup(256, 512, 256, "exact", 0, backend="cpu",
                           vmem_budget=BUDGET) is None
    # and a tuner run REPLACES the corrupt file with a valid one
    autotune.tune_gemm(256, 512, 256, mode="exact", rank=0,
                       measure=_stub("fused"), backend="cpu",
                       vmem_budget=BUDGET)
    cache = autotune.load_cache(cache_path)
    assert cache["schema"] == autotune.CACHE_SCHEMA
    assert len(cache["entries"]) == 1


def test_candidate_plans_admission_and_pruning():
    cands = autotune.candidate_plans(256, 512, 256, 3, vmem_budget=BUDGET)
    assert 0 < len(cands) <= autotune.MAX_MEASURED_CANDIDATES
    for c in cands:
        assert not c.skinny  # m=256 is not decode-shaped
        assert qk.fused_vmem_bytes(c.bm, c.bk, c.bn, 3) <= BUDGET
    # decode-shaped m: skinny candidates appear and respect their model
    dec = autotune.candidate_plans(4, 512, 256, 3, vmem_budget=BUDGET)
    assert any(c.skinny for c in dec)
    for c in dec:
        if c.skinny:
            assert c.bm == 4
            assert qk.skinny_vmem_bytes(4, c.bk, c.bn, 3) <= BUDGET
    # a tiny budget prunes everything except nothing at all
    assert autotune.candidate_plans(256, 512, 256, 3, vmem_budget=1) == []


def test_dispatch_consults_cache(cache_path):
    # no cache -> off-TPU auto pins xla
    plan = dispatch.choose_gemm_path("auto", m=256, k=512, n=256,
                                     mode="lowrank", rank=2, n_planes=3)
    assert plan.path == "xla" and plan.source == "default"
    # measured fused winner in the cache -> auto now returns it, tiles
    # included (backend must match the live jax backend for the hit)
    import jax
    autotune.tune_gemm(256, 512, 256, mode="lowrank", rank=2,
                       measure=_stub("fused", best_bk=256),
                       backend=jax.default_backend(),
                       vmem_budget=dispatch.vmem_budget_bytes())
    plan = dispatch.choose_gemm_path("auto", m=256, k=512, n=256,
                                     mode="lowrank", rank=2, n_planes=3)
    assert plan.path == "fused" and plan.source == "tuned"
    assert plan.bk == 256
    # a measured xla winner must veto fused even under policy "auto"
    autotune.record_winner(512, 512, 512, "exact", 0,
                           {"fused": 10.0, "stacked": 9.0, "xla": 1.0},
                           backend=jax.default_backend(),
                           vmem_budget=dispatch.vmem_budget_bytes(),
                           path=cache_path)
    plan = dispatch.choose_gemm_path("auto", m=512, k=512, n=512,
                                     mode="exact", rank=0, n_planes=1)
    assert plan.path == "xla" and plan.source == "tuned"


def test_tuned_entry_revalidated_against_admission(cache_path, monkeypatch):
    """A fused cache entry that no longer fits the CURRENT budget is
    ignored (PC405 flags the producer; dispatch just won't schedule it)."""
    import jax
    budget = dispatch.vmem_budget_bytes()
    autotune.put(autotune.TunedPlan("fused", 256, 512, 256), 256, 512, 256,
                 "exact", 0, backend=jax.default_backend(),
                 vmem_budget=budget, path=cache_path)
    assert dispatch.choose_gemm_path(
        "auto", m=256, k=512, n=256, mode="exact", rank=0,
        n_planes=1).source == "tuned"
    # shrink the live budget below the entry's working set: the entry's
    # KEY no longer matches either, and even a key-matching entry would
    # fail _fused_admissible — dispatch falls back
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    plan = dispatch.choose_gemm_path("auto", m=256, k=512, n=256,
                                     mode="exact", rank=0, n_planes=1)
    assert plan.source != "tuned"


def test_record_winner_prefers_measured_min(cache_path):
    us = {"fused": 5.0, "stacked": 4.0, "xla": 6.0}
    plan = autotune.record_winner(256, 512, 256, "exact", 0, us,
                                  backend="cpu", vmem_budget=BUDGET,
                                  path=cache_path)
    assert plan.path == "stacked"
    hit = autotune.lookup(256, 512, 256, "exact", 0, backend="cpu",
                          vmem_budget=BUDGET)
    assert hit.path == "stacked"
    assert hit.us == us


def test_shape_bucket_separates_decode_sizes():
    assert autotune.shape_bucket(1, 512, 256) != \
        autotune.shape_bucket(32, 512, 256)
    assert autotune.shape_bucket(250, 512, 256) == \
        autotune.shape_bucket(256, 512, 256)
