"""Continuous-batching engine tests: slot reuse, mixed prompt lengths,
late arrivals joining mid-decode, per-request sampling, greedy
determinism vs the pre-refactor lock-step driver, and a cross-family
smoke — all on reduced configs (CPU-scale)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serving import Engine, Request, SamplingParams
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import Scheduler

FAMILY_ARCHS = ["tinyllama-1.1b", "mamba2-370m", "recurrentgemma-9b",
                "whisper-medium"]


def _cfg(arch):
    return configs.reduced(configs.get_config(arch))


@functools.lru_cache(maxsize=None)
def _params(arch):
    return api.init_params(_cfg(arch), jax.random.key(0))


def _prompt(n, seed, vocab=512):
    return np.random.default_rng(seed).integers(1, vocab, (n,)).tolist()


def _solo_greedy(cfg, params, tokens, gen, max_len, extras=None):
    """Reference: one request alone, exact-length prefill + greedy loop."""
    t = jnp.asarray([tokens], jnp.int32)
    ex = {k: jnp.asarray(v)[None] for k, v in (extras or {}).items()}
    lg, cache = api.prefill(params, t, cfg, max_len=max_len, extras=ex)
    out = [int(jnp.argmax(lg, -1)[0])]
    tok = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]
    for _ in range(gen - 1):
        lg2, cache = api.decode_step(params, cache, tok, cfg, extras=ex)
        tok = jnp.argmax(lg2[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(int(tok[0, 0]))
    return out


# --- mixed lengths / arrivals / slot reuse ---------------------------------

def test_mixed_prompt_lengths_match_solo_runs():
    """Heterogeneous prompt lengths share one decode batch, each stream
    identical to running that request alone."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=3, max_len=64, seed=0)
    lens = [5, 19, 33]
    for i, n in enumerate(lens):
        eng.submit(Request(f"r{i}", _prompt(n, i),
                           SamplingParams(max_new_tokens=6)))
    done = {c.request_id: c for c in eng.run_until_complete()}
    assert len(done) == 3
    for i, n in enumerate(lens):
        ref = _solo_greedy(cfg, params, _prompt(n, i), 6, 64)
        assert done[f"r{i}"].tokens == ref, (i, done[f"r{i}"].tokens, ref)


def test_late_arrivals_join_mid_decode():
    """A request arriving mid-decode joins a half-busy arena and still
    reproduces its solo-run stream."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=2, max_len=64, seed=0)
    eng.submit(Request("early0", _prompt(12, 10),
                       SamplingParams(max_new_tokens=10)))
    eng.submit(Request("late", _prompt(20, 11),
                       SamplingParams(max_new_tokens=5), arrival=3.0))
    done = {c.request_id: c for c in eng.run_until_complete()}
    # the late request was admitted after its arrival tick but before the
    # early one finished -> it genuinely joined mid-decode
    assert done["late"].admitted_tick >= 3
    assert done["late"].admitted_tick < done["early0"].finished_tick
    for rid, n, seed, gen in [("early0", 12, 10, 10), ("late", 20, 11, 5)]:
        assert done[rid].tokens == _solo_greedy(cfg, params,
                                                _prompt(n, seed), gen, 64)


def test_slot_reuse_after_completion():
    """5 requests through 2 slots: later admissions must wait for (and
    then reuse) freed slots, with streams unchanged."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=2, max_len=64, seed=0)
    for i in range(5):
        eng.submit(Request(f"r{i}", _prompt(8 + 3 * i, 20 + i),
                           SamplingParams(max_new_tokens=4)))
    done = {c.request_id: c for c in eng.run_until_complete()}
    assert len(done) == 5
    first_free = min(c.finished_tick for c in done.values())
    assert done["r2"].admitted_tick > first_free  # waited for a freed slot
    for i in range(5):
        ref = _solo_greedy(cfg, params, _prompt(8 + 3 * i, 20 + i), 4, 64)
        assert done[f"r{i}"].tokens == ref
    # slots cycled: 5 admissions never exceeded 2 concurrent
    stats = eng.stats()
    assert stats["admitted"] == 5 and eng.capacity == 2
    # queue-wait accounting: requests 3+ waited for a freed slot, so the
    # total admission wait must be positive and the mean consistent
    assert stats["queue_wait_ticks_total"] > 0
    assert stats["queue_wait_ticks_mean"] == pytest.approx(
        stats["queue_wait_ticks_total"] / 5)
    # all five ran to their token budget
    assert stats["evictions"] == {"eos": 0, "length": 5}
    assert stats["mesh"]["model"] >= 1


# --- sampling ---------------------------------------------------------------

def test_per_request_sampling_params():
    """Greedy, top-k=1 (argmax regardless of temperature), and seeded
    temperature sampling coexist in one decode batch."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")

    def run():
        eng = Engine(cfg, params, capacity=3, max_len=48, seed=7)
        prompt = _prompt(10, 42)
        eng.submit(Request("greedy", prompt,
                           SamplingParams(max_new_tokens=8)))
        eng.submit(Request("topk1", prompt,
                           SamplingParams(temperature=1.7, top_k=1,
                                          max_new_tokens=8)))
        eng.submit(Request("hot", prompt,
                           SamplingParams(temperature=1.0, top_k=8,
                                          max_new_tokens=8, seed=123)))
        return {c.request_id: c.tokens for c in eng.run_until_complete()}

    a = run()
    # top_k=1 collapses sampling to argmax -> must equal greedy
    assert a["topk1"] == a["greedy"]
    assert all(0 <= t < cfg.vocab for t in a["hot"])
    # seeded sampling is reproducible run-to-run
    assert run()["hot"] == a["hot"]


def test_sample_tokens_vectorized():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)),
                         jnp.float32)
    temps = jnp.asarray([0.0, 0.0, 1.0, 1.0], jnp.float32)
    topks = jnp.asarray([0, 0, 1, 4], jnp.int32)
    keys = jax.random.split(jax.random.key(0), 4)
    toks = np.asarray(sample_tokens(logits, temps, topks, keys))
    argmax = np.argmax(np.asarray(logits), -1)
    assert toks[0] == argmax[0] and toks[1] == argmax[1]  # greedy rows
    assert toks[2] == argmax[2]                           # top-k = 1
    top4 = np.argsort(np.asarray(logits)[3])[-4:]         # top-k = 4
    assert toks[3] in top4


def test_eos_stops_early():
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    ref = _solo_greedy(cfg, params, _prompt(9, 5), 10, 64)
    eos = ref[3]
    stop = ref.index(eos)  # first emission of the eos token
    eng = Engine(cfg, params, capacity=1, max_len=64, seed=0)
    eng.submit(Request("e", _prompt(9, 5),
                       SamplingParams(max_new_tokens=10, eos_id=eos)))
    (done,) = eng.run_until_complete()
    assert done.finish_reason == "eos"
    assert done.tokens == ref[:stop + 1]
    assert eng.stats()["evictions"] == {"eos": 1, "length": 0}


# --- determinism vs the pre-refactor lock-step driver ----------------------

def test_greedy_matches_lockstep_driver():
    """The old serve driver ran one fixed-size batch of equal-length
    prompts in lock-step greedy decode.  The engine must reproduce it
    token-for-token on the lm family."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    b, s, gen, max_len = 4, 16, 8, 32
    prompts = np.random.default_rng(3).integers(1, cfg.vocab, (b, s))

    # pre-refactor driver semantics: batch prefill + lock-step argmax
    lg, cache = api.prefill(params, jnp.asarray(prompts, jnp.int32), cfg,
                            max_len=max_len)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]
    ref = [tok]
    for _ in range(gen - 1):
        lg2, cache = api.decode_step(params, cache, tok, cfg)
        tok = jnp.argmax(lg2[:, -1], -1).astype(jnp.int32)[:, None]
        ref.append(tok)
    ref = np.concatenate([np.asarray(t) for t in ref], axis=1)

    eng = Engine(cfg, params, capacity=b, max_len=max_len,
                 prefill_buckets=(s,), seed=0)
    for i in range(b):
        eng.submit(Request(f"r{i}", prompts[i].tolist(),
                           SamplingParams(max_new_tokens=gen)))
    done = {c.request_id: c for c in eng.run_until_complete()}
    for i in range(b):
        assert done[f"r{i}"].tokens == ref[i].tolist(), i


# --- cross-family smoke -----------------------------------------------------

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_families_serve_heterogeneous_trace(arch):
    """All four families serve a trace of 8 requests with heterogeneous
    prompt lengths, arrivals, and sampling params — with at most one jit
    compilation per (config, phase)."""
    cfg, params = _cfg(arch), _params(arch)
    rng = np.random.default_rng(1)
    eng = Engine(cfg, params, capacity=3, max_len=48, seed=0)
    gens = []
    for i in range(8):
        n = int(rng.integers(4, 20))
        gen = int(rng.integers(2, 5))
        gens.append(gen)
        sp = SamplingParams(max_new_tokens=gen) if i % 2 == 0 else \
            SamplingParams(temperature=0.9, top_k=8, max_new_tokens=gen,
                           seed=i)
        extras = None
        if cfg.family == "encdec":
            extras = {"frames": rng.standard_normal(
                (cfg.enc_seq, cfg.d_model)).astype(np.float32)}
        eng.submit(Request(f"t{i}", rng.integers(1, cfg.vocab, (n,)).tolist(),
                           sp, arrival=float(i) * 0.7, extras=extras))
    done = {c.request_id: c for c in eng.run_until_complete()}
    assert len(done) == 8
    for i, gen in enumerate(gens):
        c = done[f"t{i}"]
        assert len(c.tokens) == gen
        assert c.finish_reason == "length"
        assert all(0 <= t < cfg.vocab for t in c.tokens)
    stats = eng.stats()
    if "decode_compiles" in stats:     # pjit cache introspection available
        # single-device: exactly one decode compile.  Multi-device: the
        # first step's input comes from device_put and later steps from
        # the jitted output — identical shardings but possibly different
        # XLA layouts, which costs one extra (stable) executable.
        n_dev = 1
        for sz in stats["mesh"].values():
            n_dev *= sz
        assert stats["decode_compiles"] <= (1 if n_dev == 1 else 2), stats
        assert stats["prefill_compiles"] == 1, stats


# --- request lifecycle: deadlines, shedding, tiers --------------------------

def test_ttft_deadline_sheds_instead_of_admitting_late():
    """A queued request whose TTFT budget is already blown is shed —
    tokens empty, never admitted — while the running request and an
    in-budget waiter are unaffected."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=1, max_len=64, seed=0)
    eng.submit(Request("hog", _prompt(8, 0),
                       SamplingParams(max_new_tokens=10)))
    eng.submit(Request("tight", _prompt(8, 1),
                       SamplingParams(max_new_tokens=4),
                       ttft_deadline_ticks=2.0))
    eng.submit(Request("patient", _prompt(8, 2),
                       SamplingParams(max_new_tokens=4),
                       ttft_deadline_ticks=64.0))
    done = {c.request_id: c for c in eng.run_until_complete()}
    assert done["hog"].finish_reason == "length"
    shed = done["tight"]
    assert shed.finish_reason == "shed"
    assert shed.tokens == [] and shed.admitted_tick == -1
    ok = done["patient"]
    assert ok.finish_reason == "length" and len(ok.tokens) == 4
    assert ok.admitted_tick - ok.arrival + 1 <= 64
    assert eng.stats()["evictions"]["shed"] == 1


def test_total_deadline_evicts_partial_generation():
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=1, max_len=64, seed=0)
    eng.submit(Request("d", _prompt(8, 3),
                       SamplingParams(max_new_tokens=12),
                       deadline_ticks=5.0))
    (c,) = eng.run_until_complete()
    assert c.finish_reason == "deadline"
    assert 0 < len(c.tokens) < 12                 # partial kept
    assert c.finished_tick - c.arrival + 1 <= 5
    # the partial stream is a prefix of the undeadlined one
    ref = _solo_greedy(cfg, params, _prompt(8, 3), 12, 64)
    assert c.tokens == ref[:len(c.tokens)]


def test_deadline_validation():
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=1, max_len=32, seed=0)
    with pytest.raises(ValueError, match="ttft_deadline_ticks"):
        eng.submit(Request("a", [1, 2], ttft_deadline_ticks=0.0))
    with pytest.raises(ValueError, match="deadline_ticks"):
        eng.submit(Request("b", [1, 2], deadline_ticks=-3.0))


def test_tier_ladder_switch_attributes_tokens():
    """A mid-flight tier switch: generated tokens are attributed to the
    tier that served them, the switch is audited, and restoring the
    exact tier does not recompile (per-tier jits are built once)."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=1, max_len=48, seed=0,
                 tiers=("exact", "trunc4x4"))
    assert eng.tiers == ("exact", "trunc4x4") and eng.tier == "exact"
    eng.submit(Request("t", _prompt(8, 7), SamplingParams(max_new_tokens=8)))
    for _ in range(4):
        eng.step()
    eng.set_tier("trunc4x4")
    assert eng.tier_index == 1
    (c,) = eng.run_until_complete()
    assert c.finish_reason == "length"
    assert set(c.tier_tokens) == {"exact", "trunc4x4"}
    assert sum(c.tier_tokens.values()) == len(c.tokens) == 8
    assert c.tier_tokens["exact"] > 0 and c.tier_tokens["trunc4x4"] > 0
    st = eng.stats()["tiers"]
    assert st["ladder"] == ["exact", "trunc4x4"]
    assert len(st["switches"]) == 1
    assert st["tokens"] == c.tier_tokens
    with pytest.raises(ValueError, match="unknown tier"):
        eng.set_tier("trunc9x9")


def test_single_tier_engine_stats_unchanged():
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=1, max_len=32, seed=0)
    assert eng.tiers == ("exact",)
    eng.submit(Request("s", _prompt(6, 1), SamplingParams(max_new_tokens=3)))
    (c,) = eng.run_until_complete()
    assert c.tier_tokens == {"exact": 3}
    assert eng.stats()["tiers"]["switches"] == []


# --- scheduler unit ---------------------------------------------------------

def test_scheduler_fifo_and_arrival_gating():
    s = Scheduler()
    s.submit(Request("b", [1], arrival=2.0))
    s.submit(Request("a", [1], arrival=0.0))
    s.submit(Request("c", [1], arrival=2.0))
    assert s.pop_ready(0.0).request_id == "a"
    assert s.pop_ready(0.0) is None        # b, c not yet arrived
    assert s.next_arrival() == 2.0
    assert s.pop_ready(2.0).request_id == "b"   # FIFO among same arrival
    assert s.pop_ready(2.0).request_id == "c"
    assert len(s) == 0


def test_submit_validation():
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = Engine(cfg, params, capacity=1, max_len=32, seed=0)
    with pytest.raises(ValueError):
        eng.submit(Request("x", []))                      # empty prompt
    with pytest.raises(ValueError):
        eng.submit(Request("y", [1] * 40))                # > bucket
    with pytest.raises(ValueError):
        eng.submit(Request("z", [1] * 30,
                           SamplingParams(max_new_tokens=8)))  # > max_len
    eng.submit(Request("ok", [1, 2], SamplingParams(max_new_tokens=2)))
    with pytest.raises(ValueError):
        eng.submit(Request("ok", [3, 4]))                 # duplicate id
