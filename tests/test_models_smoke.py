"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-grad step on CPU, shape + finiteness assertions, and serving-path
consistency (decode == teacher-forced forward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import reduced
from repro.models import api, cnn

RNG = np.random.default_rng(0)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    extras = {}
    if cfg.family == "encdec":
        frames = jnp.asarray(RNG.standard_normal((b, cfg.enc_seq,
                                                   cfg.d_model)), jnp.float32)
        batch["frames"] = frames
        extras["frames"] = frames
    if cfg.cross_every:
        img = jnp.asarray(
            RNG.standard_normal((b, cfg.n_img_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
        batch["img"] = img
        extras["img_embeds"] = img
    return batch, extras


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduced(configs.get_config(arch))
    params = api.init_params(cfg, jax.random.key(0))
    batch, _ = _batch(cfg)
    logits, aux = api.forward(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    def loss(p):
        return api.loss_fn(p, batch, cfg)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_matches_forward(arch):
    over = {"capacity_factor": 16.0} if \
        configs.get_config(arch).n_experts else {}
    cfg = reduced(configs.get_config(arch), **over)
    params = api.init_params(cfg, jax.random.key(1))
    b, s = 2, 8
    batch, extras = _batch(cfg, b, s)
    toks = batch["tokens"]
    logits_fwd, _ = api.forward(params, batch, cfg)
    cache = api.init_cache(cfg, b, s)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc_out = encdec.encode(params, batch["frames"], cfg)
        xk, xv = encdec.precompute_cross(params, enc_out, cfg)
        cache["xk"] = xk.astype(cache["xk"].dtype)
        cache["xv"] = xv.astype(cache["xv"].dtype)
    outs = []
    for t in range(s):
        lg, cache = api.decode_step(params, cache, toks[:, t:t + 1], cfg,
                                    extras=extras)
        outs.append(lg[:, 0])
    dec = np.stack([np.asarray(o) for o in outs], 1)
    np.testing.assert_allclose(dec, np.asarray(logits_fwd), atol=2e-4,
                               rtol=2e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-370m",
                                  "recurrentgemma-9b", "whisper-medium",
                                  "grok-1-314b"])
def test_prefill_then_decode(arch):
    over = {"capacity_factor": 16.0} if \
        configs.get_config(arch).n_experts else {}
    cfg = reduced(configs.get_config(arch), **over)
    params = api.init_params(cfg, jax.random.key(2))
    b, s = 2, 40  # > reduced window (32) to exercise the rolling cache
    batch, extras = _batch(cfg, b, s + 1)
    toks = batch["tokens"]
    logits_fwd, _ = api.forward(params, batch, cfg)
    lg_pre, cache = api.prefill(params, toks[:, :s], cfg, max_len=s + 8,
                                extras=extras)
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits_fwd[:, s - 1]),
                               atol=2e-4, rtol=2e-3)
    lg_dec, _ = api.decode_step(params, cache, toks[:, s:s + 1], cfg,
                                extras=extras)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(logits_fwd[:, s]),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-370m"])
def test_forward_under_approximation(arch):
    """The paper's technique: same model, approximate multiplier swapped in.
    Output must stay finite and close-ish to exact (error grows with
    truncation depth)."""
    cfg = reduced(configs.get_config(arch))
    params = api.init_params(cfg, jax.random.key(3))
    batch, _ = _batch(cfg)
    exact, _ = api.forward(params, batch, cfg, spec=None)
    errs = []
    for mult in ("trunc1x1", "trunc3x3"):
        cfg2 = configs.reduced(configs.get_config(arch), mult=mult)
        spec = api.make_spec(cfg2)
        approx, _ = api.forward(params, batch, cfg2, spec=spec)
        assert np.isfinite(np.asarray(approx)).all()
        errs.append(float(jnp.mean(jnp.abs(approx - exact))))
    assert errs[0] < errs[1], errs  # deeper truncation -> larger drift


def test_param_counts_match_literature():
    """Full configs must land near their nameplate sizes."""
    expect = {
        "tinyllama-1.1b": 1.1e9,
        "qwen1.5-32b": 32.5e9,
        "starcoder2-7b": 7.2e9,
        "mistral-large-123b": 123e9,
        "mamba2-370m": 0.37e9,
        "grok-1-314b": 314e9,
        "llama4-maverick-400b-a17b": 400e9,
        "recurrentgemma-9b": 9e9,
        "whisper-medium": 0.76e9,
        "llama-3.2-vision-11b": 9.8e9,  # text backbone + cross (frontend is
                                        # a stub; full model is 10.6B)
    }
    for arch, want in expect.items():
        n = configs.get_config(arch).param_count()
        assert 0.6 * want < n < 1.45 * want, (arch, n, want)


def test_moe_active_params():
    cfg = configs.get_config("llama4-maverick-400b-a17b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
    assert 10e9 < cfg.active_param_count() < 30e9


# --- CNNs (the paper's own workloads) ---------------------------------------

def test_vgg_mini_forward_exact_and_approx():
    params = cnn.init_vgg("vgg_mini", jax.random.key(0), n_classes=10,
                          image=32)
    x = jnp.asarray(RNG.standard_normal((2, 32, 32, 3)), jnp.float32)
    y = cnn.vgg_forward(params, x, "vgg_mini")
    assert y.shape == (2, 10)
    from repro.approx import gemm as G
    y2 = cnn.vgg_forward(params, x, "vgg_mini",
                         spec=G.spec_from_name("trunc2x2"))
    assert np.isfinite(np.asarray(y2)).all()
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_resnet_mini_forward():
    params = cnn.init_resnet("resnet_mini", jax.random.key(0), n_classes=10)
    x = jnp.asarray(RNG.standard_normal((2, 32, 32, 3)), jnp.float32)
    y = cnn.resnet_forward(params, x, "resnet_mini")
    assert y.shape == (2, 10)
    assert np.isfinite(np.asarray(y)).all()


def test_cell_support_matrix():
    """40 cells: long_500k runs only for ssm/hybrid; everything else runs."""
    total, runnable, skipped = 0, 0, 0
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for shape in configs.SHAPES.values():
            total += 1
            ok, why = configs.cell_supported(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert shape.name == "long_500k"
                assert cfg.family not in ("ssm", "hybrid")
    assert total == 40
    assert skipped == 8
    assert runnable == 32
