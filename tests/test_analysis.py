"""repro.analysis: findings model, all four checkers (each proven live
by a seeded violation), suppressions, the CLI, the VMEM budget override,
and the shared bench-report schema checker."""

import importlib.util
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import findings as fmod
from repro.analysis.findings import Baseline, Finding, apply_suppressions

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# findings / suppression machinery
# --------------------------------------------------------------------------

def test_finding_checker_derived_from_code():
    assert Finding("JH101", "a.py", "m").checker == "jit"
    assert Finding("RT201", "x", "m").checker == "retrace"
    assert Finding("SC301", "x", "m").checker == "sharding"
    assert Finding("PC401", "x", "m").checker == "pallas"
    with pytest.raises(AssertionError):
        Finding("ZZ999", "x", "m")


def test_inline_allow_comment():
    assert fmod.inline_allowed("x = 1  # analysis: allow[JH102] why") \
        == "JH102"
    assert fmod.inline_allowed("x = 1  # plain comment") is None


def test_baseline_match_and_stale_tracking():
    b = Baseline([{"code": "SC301", "path": "sharding/rules:lm",
                   "reason": "known"},
                  {"code": "JH101", "path": "never/hit.py",
                   "reason": "stale"}])
    f = Finding("SC301", "sharding/rules:lm", "m")
    assert b.match(f) == "known"
    assert [e["path"] for e in b.unused()] == ["never/hit.py"]
    with pytest.raises(ValueError):
        Baseline([{"code": "XX000", "path": "p", "reason": "r"}])
    with pytest.raises(ValueError):
        Baseline([{"code": "JH101"}])


def test_apply_suppressions_inline(tmp_path):
    (tmp_path / "mod.py").write_text(
        "x = 1\ny = 2  # analysis: allow[JH103] vetted\n")
    fs = [Finding("JH103", "mod.py", "m", line=2),
          Finding("JH103", "mod.py", "m", line=1)]
    apply_suppressions(fs, Baseline([]), str(tmp_path))
    assert fs[0].suppressed and fs[0].suppress_reason == "inline allow"
    assert not fs[1].suppressed


# --------------------------------------------------------------------------
# jit-hazard lint (JH)
# --------------------------------------------------------------------------

HAZARD_SRC = textwrap.dedent("""\
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np


    @jax.jit
    def f(x):
        if jnp.any(x > 0):
            x = x + 1
        np.asarray(x)
        np.square(x)
        return helper(x)


    def helper(x):
        return float(x)


    @functools.partial(jax.jit, static_argnames=("opts",))
    def g(x, opts=[]):
        return x
""")


@pytest.fixture
def hazard_tree(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(HAZARD_SRC)
    return tmp_path


def test_lint_seeded_violations_fire_exact_codes(hazard_tree):
    from repro.analysis import lint
    fs = lint.check(str(hazard_tree))
    codes = sorted(f.code for f in fs)
    assert codes == ["JH101", "JH101", "JH102", "JH103", "JH104"], \
        [f.render() for f in fs]
    # reachability: helper() is flagged only because f() is jit-entry
    helper_f = [f for f in fs if "helper" in f.message]
    assert helper_f and helper_f[0].code == "JH101"
    # findings carry the repo-relative path + line for inline suppression
    assert all(f.path == os.path.join("src", "repro", "bad.py")
               for f in fs)
    assert all(f.line > 0 for f in fs)


def test_lint_unreachable_function_not_flagged(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "host.py").write_text(textwrap.dedent("""\
        import numpy as np

        def host_only(x):
            return float(x) + np.asarray(x).sum()
    """))
    from repro.analysis import lint
    assert lint.check(str(tmp_path)) == []


def test_lint_clean_on_this_repo():
    from repro.analysis import lint
    assert [f.render() for f in lint.check(REPO)] == []


# --------------------------------------------------------------------------
# retrace sanitizer (RT)
# --------------------------------------------------------------------------

def test_retrace_over_budget_rt201():
    from repro.analysis.retrace import RetraceSanitizer
    s = RetraceSanitizer()
    fn = jax.jit(lambda x: x * 2)
    w = s.watch("test:shape-storm", fn, budget=1, warmup=1)
    for n in (2, 3, 4):  # every call a new shape -> a new compile
        w(jnp.ones((n,)))
    fs = s.findings()
    assert [f.code for f in fs] == ["RT201"]
    assert "3 compiles (budget 1)" in fs[0].message
    with pytest.raises(AssertionError):
        s.assert_ok()


def test_retrace_late_retrace_rt202():
    from repro.analysis.retrace import RetraceSanitizer
    s = RetraceSanitizer()
    fn = jax.jit(lambda x: x + 1)
    w = s.watch("test:late", fn, budget=5, warmup=1)
    w(jnp.ones((2,)))
    w(jnp.ones((3,)))  # within budget but after warmup -> RT202
    assert [f.code for f in s.findings()] == ["RT202"]


def test_retrace_within_budget_clean(retrace_sanitizer):
    fn = jax.jit(lambda x: x - 1)
    w = retrace_sanitizer.watch("test:ok", fn, budget=1)
    w(jnp.ones((4,)))
    w(jnp.ones((4,)))  # cache hit
    assert retrace_sanitizer.findings() == []
    rep = retrace_sanitizer.report()["test:ok"]
    assert rep["calls"] == 2 and rep["compiles"] == 1


def test_engine_budget_table():
    from repro.analysis.retrace import engine_budgets

    class FakeEngine:
        buckets = (16, 32, 64)
    b = engine_budgets(FakeEngine())
    assert b["serving/engine:decode"] == 1
    assert b["serving/engine:prefill"] == 3


# --------------------------------------------------------------------------
# sharding coverage (SC)
# --------------------------------------------------------------------------

def test_coverage_unknown_param_leaf_sc301():
    from repro import configs
    from repro.analysis import coverage
    cfg = configs.apply_overrides(configs.get_config("tinyllama-1.1b"),
                                  reduced=True)
    shapes = {"mystery_w": jax.ShapeDtypeStruct((128, 128), jnp.float32),
              "ln1": jax.ShapeDtypeStruct((2, 64), jnp.float32)}
    fs = coverage._check_params(cfg, shapes)
    assert [f.code for f in fs] == ["SC301"]
    assert "mystery_w" in fs[0].message  # exempt ln1 not flagged


def test_coverage_unknown_cache_key_sc302():
    from repro import configs
    from repro.analysis import coverage
    cfg = configs.apply_overrides(configs.get_config("tinyllama-1.1b"),
                                  reduced=True)
    fake = {"weird_state": jax.ShapeDtypeStruct((2, 4, 8), jnp.float32)}
    fs = coverage._check_cache(cfg, fake)
    assert [f.code for f in fs] == ["SC302"]
    assert "weird_state" in fs[0].message


def test_coverage_clean_on_all_families():
    from repro.analysis import coverage
    assert [f.render() for f in coverage.check()] == []


# --------------------------------------------------------------------------
# Pallas contracts (PC)
# --------------------------------------------------------------------------

def test_contracts_vmem_drift_pc401(monkeypatch):
    from repro.analysis import contracts
    from repro.kernels import approx_qgemm as qk
    monkeypatch.setattr(qk, "fused_vmem_bytes", lambda *a: 0)
    monkeypatch.setattr(qk, "stacked_vmem_bytes", lambda *a: 0)
    fs = contracts._check_vmem_models()
    assert fs and all(f.code == "PC401" for f in fs)


def test_contracts_grid_divisibility_pc402():
    from repro.analysis.contracts import PallasCapture, _check_grid
    cap = PallasCapture(
        kernel_name="_fused_kernel", grid=(2, 2, 3),
        in_blocks=[((96, 100), 1)], out_blocks=[((96, 96), 4)],
        scratch_bytes=0, operand_shapes=[(192, 512)])
    fs = _check_grid(cap)
    assert [f.code for f in fs] == ["PC402"]


def test_contracts_dispatch_budget_pc403(monkeypatch):
    from repro.analysis import contracts
    from repro.kernels import approx_qgemm as qk
    # declared model says "free" while $REPRO_VMEM_BUDGET shrinks the
    # budget below any real working set -> dispatch would admit shapes
    # that bust VMEM
    monkeypatch.setattr(qk, "fused_vmem_bytes", lambda *a: 0)
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    fs = contracts._check_dispatch_consistency()
    assert fs and all(f.code == "PC403" for f in fs)


def test_contracts_ktail_mismatch_pc404(monkeypatch):
    from repro.analysis import contracts
    from repro.kernels import ops

    def fake_gemm(a, b, spec, fused=True, **kw):
        out = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
        return out if fused else out + 1  # fused != stacked
    monkeypatch.setattr(ops, "approx_qgemm", fake_gemm)
    fs = contracts._check_ktail()
    assert [f.code for f in fs] == ["PC404"]


def test_contracts_clean_on_kernels():
    from repro.analysis import contracts
    assert [f.render() for f in contracts.check()] == []


def test_vmem_budget_env_override(monkeypatch):
    from repro.kernels import dispatch
    assert dispatch.vmem_budget_bytes() == dispatch.VMEM_BUDGET_BYTES
    monkeypatch.setenv("REPRO_VMEM_BUDGET", str(1 << 20))
    assert dispatch.vmem_budget_bytes() == 1 << 20
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "0x100000")
    assert dispatch.vmem_budget_bytes() == 1 << 20
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "lots")
    with pytest.raises(ValueError):
        dispatch.vmem_budget_bytes()
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "-1")
    with pytest.raises(ValueError):
        dispatch.vmem_budget_bytes()


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_cli_json_report_clean_lint(tmp_path):
    from repro.analysis import cli
    out = tmp_path / "report.json"
    rc = cli.run(["--checks", "jit", "--format", "json",
                  "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["checks"] == ["jit"] and rep["open"] == 0
    assert rep["errors"] == []


def test_cli_exit_1_on_findings_and_baseline_suppression(hazard_tree,
                                                         tmp_path):
    from repro.analysis import cli
    assert cli.run(["--checks", "jit", "--root", str(hazard_tree)]) == 1
    # a full baseline turns the same run green (exit 0, all suppressed)
    bad = os.path.join("src", "repro", "bad.py")
    baseline = tmp_path / "b.json"
    baseline.write_text(json.dumps(
        [{"code": c, "path": bad, "reason": "seeded"}
         for c in ("JH101", "JH102", "JH103", "JH104")]))
    out = tmp_path / "rep.json"
    rc = cli.run(["--checks", "jit", "--root", str(hazard_tree),
                  "--baseline", str(baseline), "--format", "json",
                  "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["open"] == 0 and rep["suppressed"] == 5


def test_cli_rejects_unknown_checker():
    from repro.analysis import cli
    with pytest.raises(SystemExit):
        cli.run(["--checks", "nope"])


def test_checked_in_baseline_is_valid():
    b = Baseline.load(os.path.join(REPO, "analysis-baseline.json"))
    assert isinstance(b.entries, list)


# --------------------------------------------------------------------------
# docs stay in sync with the code registry
# --------------------------------------------------------------------------

def test_docs_list_every_finding_code():
    doc = open(os.path.join(REPO, "docs", "ANALYSIS.md")).read()
    for code, desc in fmod.CODES.items():
        assert code in doc, f"docs/ANALYSIS.md missing {code}: {desc}"


# --------------------------------------------------------------------------
# shared bench-report schema checks (benchmarks/check_schema.py)
# --------------------------------------------------------------------------

def _load_check_schema():
    spec = importlib.util.spec_from_file_location(
        "check_schema", os.path.join(REPO, "benchmarks",
                                     "check_schema.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_checked_in_bench_reports_pass_schema():
    cs = _load_check_schema()
    for name in ("BENCH_serving.json", "BENCH_gemm.json",
                 "BENCH_codesign.json", "BENCH_fleet.json"):
        path = os.path.join(REPO, name)
        if not os.path.exists(path):
            pytest.skip(f"{name} not committed")
        kind = cs.check_report(json.load(open(path)))
        assert kind == name[len("BENCH_"):-len(".json")]


def test_schema_checker_rejects_mutations():
    cs = _load_check_schema()
    path = os.path.join(REPO, "BENCH_serving.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_serving.json not committed")
    r = json.load(open(path))
    r["engine"]["completed"] += 1
    with pytest.raises(AssertionError):
        cs.check_report(r)
    with pytest.raises(AssertionError):
        cs.check_report({"bench": "mystery"})
    # serving mesh expectation is enforced when supplied
    r2 = json.load(open(path))
    with pytest.raises(AssertionError):
        cs.check_serving(r2, {"data": 512, "model": 2})
