"""Carbon model, accelerator area model, dataflow perf model, GA-CDP."""

import pytest

from repro.core import accelerator as acc
from repro.core import carbon as cb
from repro.core import codesign
from repro.core import dataflow as df
from repro.core import ga
from repro.core import multipliers as mm
from repro.core import workloads as wl


# --- carbon ------------------------------------------------------------------

def test_yield_decreases_with_area_and_node():
    assert cb.murphy_yield(10, 7) > cb.murphy_yield(100, 7)
    assert cb.murphy_yield(50, 28) > cb.murphy_yield(50, 7)
    assert 0 < cb.murphy_yield(500, 7) < 1
    assert cb.murphy_yield(1e-6, 7) == pytest.approx(1.0, abs=1e-4)


def test_carbon_monotone_in_area():
    prev = 0.0
    for a in (1, 5, 20, 100, 400):
        c = cb.embodied_carbon(a, 7).total_g
        assert c > prev
        prev = c


def test_carbon_superlinear_in_area():
    """Yield loss makes carbon grow faster than area (paper's 'exponential
    carbon increase' for compute-heavy designs)."""
    c1 = cb.embodied_carbon(50, 7).total_g
    c2 = cb.embodied_carbon(100, 7).total_g
    assert c2 > 2.0 * c1 * 0.999


def test_cfpa_eq2_structure():
    val, y = cb.cfpa(7, 50.0)
    p = cb.NODE_PARAMS[7]
    expect = (cb.CI_FAB_G_PER_KWH * p["EPA"] + p["C_gas"]
              + cb.C_MATERIAL_G_PER_CM2) / y
    assert val == pytest.approx(expect)


def test_dies_per_wafer_sane():
    assert cb.dies_per_wafer(100) > cb.dies_per_wafer(400)
    # a 300mm wafer is ~70,685 mm^2
    assert cb.dies_per_wafer(100) < 70686 / 100


def test_cdp():
    assert cb.cdp(100.0, 50.0) == pytest.approx(2.0)


# --- accelerator area ---------------------------------------------------------

def test_area_scales_with_pes_and_multiplier():
    a_exact = acc.area_model(acc.nvdla_default(1024, 7, "exact"))
    a_trunc = acc.area_model(acc.nvdla_default(1024, 7, "trunc3x3"))
    assert a_trunc.total_mm2 < a_exact.total_mm2
    assert a_trunc.mult_mm2 < a_exact.mult_mm2
    a_small = acc.area_model(acc.nvdla_default(64, 7, "exact"))
    assert a_small.total_mm2 < a_exact.total_mm2


def test_mult_fraction_plausible():
    """Multiplier share of die must sit in the band that reproduces the
    paper's 3-13% approx-only carbon savings."""
    for pes in (512, 1024, 2048):
        for node in (7, 14, 28):
            frac = acc.area_model(acc.nvdla_default(pes, node)).mult_fraction
            assert 0.05 < frac < 0.35, (pes, node, frac)


def test_invalid_config_rejected():
    with pytest.raises(ValueError):
        acc.AcceleratorConfig(10, 10, 32, 256, "exact", 7).validate()


# --- workloads ----------------------------------------------------------------

def test_workload_macs_match_literature():
    assert wl.total_macs(wl.vgg16()) == pytest.approx(15.5e9, rel=0.02)
    assert wl.total_macs(wl.vgg19()) == pytest.approx(19.6e9, rel=0.02)
    assert wl.total_macs(wl.resnet50()) == pytest.approx(4.1e9, rel=0.08)
    assert wl.total_macs(wl.resnet152()) == pytest.approx(11.5e9, rel=0.05)


# --- dataflow ------------------------------------------------------------------

def test_perf_model_invariants():
    for pes in (64, 512, 2048):
        cfg = acc.nvdla_default(pes, 7)
        p = df.workload_perf("vgg16", cfg)
        assert 0 < p.avg_utilization <= 1.0
        assert p.fps > 0
        for lp in p.layers:
            assert lp.utilization <= 1.0 + 1e-9
            # compute cycles lower-bounded by macs / peak
            assert lp.compute_cycles >= 0


def test_perf_monotone_in_pes():
    f = [df.fps("vgg16", acc.nvdla_default(p, 7)) for p in (64, 256, 1024)]
    assert f[0] < f[1] < f[2]


def test_perf_compute_bound_matches_roofline():
    """With huge DRAM bandwidth, cycles -> pure compute cycles >= macs/PEs."""
    cfg = acc.AcceleratorConfig(32, 32, 32, 512, "exact", 7, dram_gbps=1e6)
    p = df.workload_perf("vgg16", cfg)
    ideal = wl.total_macs(wl.vgg16()) / 1024
    assert p.total_cycles >= ideal
    assert p.total_cycles < 3.0 * ideal  # array is reasonably utilized


def test_memory_bound_when_bandwidth_tiny():
    fast = df.workload_perf(
        "vgg16", acc.AcceleratorConfig(32, 32, 32, 512, "exact", 7,
                                       dram_gbps=100.0))
    slow = df.workload_perf(
        "vgg16", acc.AcceleratorConfig(32, 32, 32, 512, "exact", 7,
                                       dram_gbps=0.5))
    assert slow.fps < fast.fps


# --- GA ------------------------------------------------------------------------

def _fast_mults():
    return [mm.exact_multiplier(), mm.truncated(1, 1), mm.truncated(2, 2),
            mm.truncated(3, 3)]


def test_ga_respects_accuracy_constraint():
    res = ga.run_ga("vgg16", 7, 30.0, max_accuracy_drop=0.5,
                    mults=_fast_mults(),
                    cfg=ga.GAConfig(pop_size=10, generations=4, seed=3))
    m = mm.get_multiplier(res.best.config.multiplier)
    assert ga.proxy_accuracy_drop(m) <= 0.5


def test_ga_meets_fps_or_penalized():
    res = ga.run_ga("vgg16", 7, 30.0, 2.0, mults=_fast_mults(),
                    cfg=ga.GAConfig(pop_size=12, generations=6, seed=0))
    assert res.best.fps >= 30.0 * 0.999


def test_ga_improves_over_generations():
    res = ga.run_ga("vgg16", 7, 30.0, 2.0, mults=_fast_mults(),
                    cfg=ga.GAConfig(pop_size=12, generations=6, seed=0))
    assert res.history[-1] <= res.history[0]


def test_ga_deterministic():
    kw = dict(mults=_fast_mults(),
              cfg=ga.GAConfig(pop_size=8, generations=3, seed=11))
    r1 = ga.run_ga("vgg16", 7, 30.0, 2.0, **kw)
    r2 = ga.run_ga("vgg16", 7, 30.0, 2.0, **kw)
    assert r1.best.cdp == r2.best.cdp
    assert r1.best.config == r2.best.config


def test_exact_baseline_meets_fps():
    e = ga.exact_baseline("vgg16", 7, 30.0)
    assert e.fps >= 30.0
    assert e.config.multiplier == "exact"


# --- codesign -------------------------------------------------------------------

def test_codesign_reductions_positive_and_ordered():
    rep = codesign.run_codesign(
        "vgg16", 7, 30.0, 2.0, mults=_fast_mults(),
        ga_cfg=ga.GAConfig(pop_size=12, generations=6, seed=0))
    # approx-only saves something; GA-CDP saves at least as much as approx-only
    assert rep.approx_only_reduction > 0.0
    assert rep.ga_reduction >= rep.approx_only_reduction - 1e-9
    assert rep.ga_cdp.fps >= 30.0 * 0.999


def test_approx_only_band_matches_paper():
    """Paper Fig.2 table: approx-only carbon reduction (same arch) is in the
    single-digit-to-low-teens percent band."""
    for node in (7, 14, 28):
        rep = codesign.run_codesign(
            "vgg16", node, 30.0, 2.0, mults=_fast_mults(),
            ga_cfg=ga.GAConfig(pop_size=8, generations=3, seed=0))
        assert 0.005 <= rep.approx_only_reduction <= 0.20, (
            node, rep.approx_only_reduction)
