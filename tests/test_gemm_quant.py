"""Approx-GEMM dispatch, quantization, layers, and gradients (STE)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.approx import gemm as G
from repro.approx import layers as L
from repro.approx import quant
from repro.core import multipliers as mm
from repro.kernels import ref


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    q, s = quant.quantize(x)
    err = np.abs(np.asarray(quant.dequantize(q, s)) - np.asarray(x))
    assert err.max() <= np.asarray(s).max() * 0.5 + 1e-7


def test_quantize_per_channel_axes():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 32)) * 100, jnp.float32)
    q, s = quant.quantize(x, axis=1)
    assert s.shape == (1, 32)
    # each channel must use its own scale
    x2 = np.asarray(quant.dequantize(q, s))
    np.testing.assert_allclose(x2, np.asarray(x), atol=np.asarray(s).max())


def test_spec_modes():
    assert G.from_multiplier(mm.exact_multiplier()).mode == "exact"
    assert G.from_multiplier(mm.truncated(2, 2)).mode == "trunc"
    m = mm.pruned(np.ones(10, bool).repeat(1)[:10] if False else
                  (np.random.default_rng(0).random(
                      len(__import__("repro.core.netlist",
                                     fromlist=["bw8"]).bw8()
                          .prunable_gates())) < 0.03))
    assert G.from_multiplier(m).mode == "lowrank"


def test_spec_is_pytree():
    spec = G.spec_from_name("trunc2x2")
    leaves = jax.tree_util.tree_leaves(spec)
    assert all(isinstance(l, jax.Array) for l in leaves)
    # must be usable as a jit static-free argument
    @jax.jit
    def f(s, a, b):
        return G.approx_qgemm(a, b, s)
    a = jnp.ones((8, 8), jnp.int8)
    b = jnp.ones((8, 8), jnp.int8)
    f(spec, a, b)


def test_approx_matmul_exact_spec_matches_float():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    out = G.approx_matmul(x, w, G.exact_spec())
    want = np.asarray(x) @ np.asarray(w)
    # int8 quantization error only
    np.testing.assert_allclose(np.asarray(out), want, rtol=0.1, atol=0.5)


def test_approx_matmul_lut_consistency():
    """Float wrapper == manual quantize -> LUT-matmul -> dequantize."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    mobj = mm.truncated(3, 3)
    spec = G.from_multiplier(mobj)
    out = np.asarray(G.approx_matmul(x, w, spec))
    xq, sx = quant.quantize(x.reshape(-1, 32), axis=0)
    wq, sw = quant.quantize(w, axis=1)
    lut_out = np.asarray(ref.lut_matmul(xq, wq, jnp.asarray(mobj.lut)))
    want = lut_out.astype(np.float32) * np.asarray(sx) * np.asarray(sw)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_ste_gradients_flow():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    spec = G.spec_from_name("trunc2x2")

    def loss(w_):
        return jnp.sum(G.approx_matmul(x, w_, spec) ** 2)

    g = jax.grad(loss)(w)
    assert g.shape == w.shape
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).max() > 0


def test_ste_gradient_equals_exact_backward():
    """Backward pass must be the float-exact gradient (ApproxTrain STE)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    spec = G.spec_from_name("trunc3x3")
    gout = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    _, vjp = jax.vjp(lambda xx, ww: G.approx_matmul(xx, ww, spec), x, w)
    dx, dw = vjp(gout)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(gout) @ np.asarray(w).T,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(x).T @ np.asarray(gout),
                               rtol=1e-5)


def test_conv2d_exact_vs_approx_small_error():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 4)) * 0.1, jnp.float32)
    y_exact = L.conv2d(x, w, spec=None)
    y_trunc1 = L.conv2d(x, w, spec=G.spec_from_name("trunc1x1"))
    rel = np.linalg.norm(np.asarray(y_trunc1) - np.asarray(y_exact)) / \
        np.linalg.norm(np.asarray(y_exact))
    assert rel < 0.1, rel
    # deeper truncation -> more error
    y_trunc4 = L.conv2d(x, w, spec=G.spec_from_name("trunc4x4"))
    rel4 = np.linalg.norm(np.asarray(y_trunc4) - np.asarray(y_exact)) / \
        np.linalg.norm(np.asarray(y_exact))
    assert rel4 > rel


def test_im2col_matches_lax_conv():
    rng = np.random.default_rng(7)
    for stride, padding, r in [(1, 1, 3), (2, 0, 1), (2, 3, 7)]:
        h = 16
        x = jnp.asarray(rng.standard_normal((2, h, h, 5)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((r, r, 5, 6)), jnp.float32)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(padding, padding), (padding, padding)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        patches, ho, wo = L._im2col(x, r, r, stride, padding)
        got = (patches.reshape(-1, r * r * 5) @ w.reshape(-1, 6)).reshape(
            2, ho, wo, 6)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_dense_bias_exact():
    x = jnp.ones((2, 4), jnp.float32)
    w = jnp.ones((4, 3), jnp.float32)
    b = jnp.arange(3, dtype=jnp.float32)
    y = L.dense(x, w, b, spec=G.spec_from_name("trunc2x2"))
    y0 = L.dense(x, w, None, spec=G.spec_from_name("trunc2x2"))
    np.testing.assert_allclose(np.asarray(y - y0), np.broadcast_to(
        np.arange(3, dtype=np.float32), (2, 3)), atol=1e-5)
