"""Fleet layer tests: grid providers, device power model, energy-meter
attribution (conservation), carbon-aware routing (determinism, SLO
spill), replica failover (zero lost), and the total-carbon objective
(scalar twin vs the batched GA metrics)."""

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import accelerator as acc
from repro.core import ga_batched as gb
from repro.core import multipliers as mm
from repro.core.target import HardwareTarget
from repro.fleet import (REGION_INTENSITY_G_PER_KWH, DevicePowerModel,
                         EnergyMeter, Fleet, FleetConfig, GridProvider,
                         Replica, StaticGrid, TraceGrid, diurnal_trace)
from repro.fleet import total as ftotal
from repro.fleet.meter import BASE_POWER_W, J_PER_KWH, PE_ACTIVE_W_BY_NODE
from repro.launch.fleet import build_fleet, poisson_requests, ttft_ticks
from repro.models import api
from repro.serving import Engine, Request, SamplingParams

ARCH = "tinyllama-1.1b"


def _cfg():
    return configs.reduced(configs.get_config(ARCH))


@functools.lru_cache(maxsize=1)
def _params():
    return api.init_params(_cfg(), jax.random.key(0))


def _prompt(n, seed, vocab=512):
    return np.random.default_rng(seed).integers(1, vocab, (n,)).tolist()


def _fast_mults():
    return [mm.exact_multiplier(), mm.truncated(1, 1), mm.truncated(2, 2),
            mm.truncated(3, 3)]


# --- grid providers ----------------------------------------------------------

def test_static_grid_from_region_table():
    g = StaticGrid("eu-north")
    assert isinstance(g, GridProvider)
    assert g.g_per_kwh(0.0) == REGION_INTENSITY_G_PER_KWH["eu-north"]
    assert g.g_per_kwh(1e9) == g.g_per_kwh(0.0)  # constant in time
    # explicit override wins over the table
    assert StaticGrid("anywhere", 123.0).g_per_kwh(0.0) == 123.0


def test_static_grid_validation():
    with pytest.raises(ValueError, match="unknown region"):
        StaticGrid("atlantis")
    with pytest.raises(ValueError, match="> 0"):
        StaticGrid("us-east", -1.0)


def test_trace_grid_lookup_wrap_and_clamp():
    t = TraceGrid("x", step_s=10.0, values=(1.0, 2.0, 3.0))
    assert [t.g_per_kwh(s) for s in (0.0, 9.99, 10.0, 25.0)] == \
        [1.0, 1.0, 2.0, 3.0]
    assert t.g_per_kwh(30.0) == 1.0        # wraps
    assert t.period_s == 30.0
    clamp = TraceGrid("x", step_s=10.0, values=(1.0, 2.0), wrap=False)
    assert clamp.g_per_kwh(1e6) == 2.0     # last value holds
    # negative times clamp to the first sample (warmup lag), not raise
    assert t.g_per_kwh(-5.0) == 1.0


def test_trace_grid_validation():
    with pytest.raises(ValueError, match="step_s"):
        TraceGrid("x", step_s=0.0, values=(1.0,))
    with pytest.raises(ValueError, match="at least one"):
        TraceGrid("x", step_s=1.0, values=())
    with pytest.raises(ValueError, match="> 0 g/kWh"):
        TraceGrid("x", step_s=1.0, values=(1.0, -2.0))


def test_diurnal_trace_shape_and_phase():
    d = diurnal_trace("us-west", swing=0.4, samples=24)
    vals = d.values
    assert len(vals) == 24 and d.period_s == 86400.0
    mean = REGION_INTENSITY_G_PER_KWH["us-west"]
    assert sum(vals) / len(vals) == pytest.approx(mean, rel=1e-6)
    # cos-shaped: trough at t=0 (solar noon), peak half a period later
    assert min(vals) == vals[0] and max(vals) == vals[12]
    assert vals[0] == pytest.approx(mean * 0.6, rel=1e-6)
    # opposed phases swap which region is cleanest across the day
    a = diurnal_trace("us-west", phase=0.0)
    b = diurnal_trace("us-west", phase=math.pi)
    assert a.g_per_kwh(0.0) < b.g_per_kwh(0.0)
    assert a.g_per_kwh(43200.0) > b.g_per_kwh(43200.0)
    with pytest.raises(ValueError, match="swing"):
        diurnal_trace("us-west", swing=1.0)


# --- device power model ------------------------------------------------------

def test_power_model_phase_weighting():
    pm = DevicePowerModel(tdp_w=10.0, idle_frac=0.1, prefill_util=1.0,
                          decode_util=0.5)
    assert pm.idle_w == pytest.approx(1.0)
    assert pm.power_w("prefill") == pytest.approx(10.0)
    # decode scales with arena occupancy: idle + span * 0.5 * (n/cap)
    assert pm.power_w("decode", 2, 4) == pytest.approx(1.0 + 9.0 * 0.25)
    assert pm.power_w("decode", 4, 4) == pytest.approx(1.0 + 9.0 * 0.5)
    assert pm.power_w("decode", 1, 4) < pm.power_w("prefill")
    with pytest.raises(ValueError, match="phase"):
        pm.power_w("train")
    with pytest.raises(ValueError):
        DevicePowerModel(tdp_w=0.0)
    with pytest.raises(ValueError):
        DevicePowerModel(idle_frac=1.5)


def test_power_model_for_target():
    die = acc.nvdla_default(256, 7)
    pm1 = DevicePowerModel.for_target(HardwareTarget.monolithic(die))
    assert pm1.tdp_w == pytest.approx(
        BASE_POWER_W + 256 * PE_ACTIVE_W_BY_NODE[7])
    # more dies -> more PEs -> higher TDP
    pm2 = DevicePowerModel.for_target(HardwareTarget(
        die, n_dies=2, mesh_axes=(("model", 2),)))
    assert pm2.tdp_w > pm1.tdp_w


# --- energy meter ------------------------------------------------------------

def test_meter_charging_clock_and_finalize():
    grid = TraceGrid("x", step_s=1.0, values=(100.0, 200.0), wrap=False)
    pm = DevicePowerModel(tdp_w=10.0, idle_frac=0.1, prefill_util=1.0,
                          decode_util=0.5)
    m = EnergyMeter(power=pm, grid=grid)
    m.on_prefill("a", 0.5)                     # 10 W x 0.5 s @ 100 g/kWh
    assert m.energy_j == pytest.approx(5.0)
    assert m.clock_s == pytest.approx(0.5)
    m.on_decode(1.0, ["a", "b"], capacity=2)   # 5.5 W @ 100, split 2 ways
    assert m.decode_j == pytest.approx(5.5)
    m.on_decode(1.0, ["b"], capacity=2)        # 3.25 W @ 200 (clock=1.5)
    # an empty decode step advances the clock but charges nothing
    before = m.energy_j
    m.on_decode(1.0, [], capacity=2)
    assert m.energy_j == before and m.clock_s == pytest.approx(3.5)

    ca = m.finalize("a", tokens=2)
    cb = m.finalize("b", tokens=3)
    assert ca.energy_j == pytest.approx(5.0 + 2.75)
    assert cb.energy_j == pytest.approx(2.75 + 3.25)
    assert ca.energy_j + cb.energy_j == pytest.approx(m.energy_j)
    assert ca.co2e_g + cb.co2e_g == pytest.approx(m.co2e_g)
    # all of a's energy was drawn at 100 g/kWh; b mixes 100 and 200
    assert ca.grid_g_per_kwh_mean == pytest.approx(100.0)
    assert 100.0 < cb.grid_g_per_kwh_mean < 200.0
    assert ca.co2e_g == pytest.approx(ca.energy_j / J_PER_KWH * 100.0)
    assert ca.energy_j_per_token == pytest.approx(ca.energy_j / 2)
    assert m.finalized_tokens == 5
    # unknown id closes an empty account rather than raising
    z = m.finalize("ghost", tokens=1)
    assert z.energy_j == 0.0 and z.grid_g_per_kwh_mean == 200.0
    s = m.summary()
    assert s["prefill_calls"] == 1 and s["decode_steps"] == 2
    assert s["energy_j"] == pytest.approx(s["prefill_j"] + s["decode_j"])


def test_engine_metering_conserves_energy():
    """Sum of per-request attributed Joules == the engine meter's
    cumulative total (the conservation property the attribution rules
    guarantee by construction), and Completion.carbon is populated."""
    cfg, params = _cfg(), _params()
    meter = EnergyMeter(power=DevicePowerModel(),
                        grid=StaticGrid("us-east"))
    eng = Engine(cfg, params, capacity=3, max_len=64, seed=0, meter=meter)
    for i, (n, gen, arr) in enumerate([(5, 6, 0.0), (12, 4, 0.0),
                                       (8, 5, 2.0), (6, 7, 5.0)]):
        eng.submit(Request(f"r{i}", _prompt(n, i, cfg.vocab),
                           SamplingParams(max_new_tokens=gen),
                           arrival=arr))
    done = eng.run_until_complete()
    assert len(done) == 4
    for c in done:
        assert c.carbon is not None
        assert c.carbon.energy_j > 0 and c.carbon.co2e_g > 0
        assert c.carbon.tokens == len(c.tokens)
        assert c.carbon.region == "us-east"
    total_j = sum(c.carbon.energy_j for c in done)
    total_g = sum(c.carbon.co2e_g for c in done)
    assert total_j == pytest.approx(meter.energy_j, rel=1e-9)
    assert total_g == pytest.approx(meter.co2e_g, rel=1e-9)
    assert meter.finalized_tokens == sum(len(c.tokens) for c in done)
    # static grid: per-request mean intensity is exactly the region's
    assert all(c.carbon.grid_g_per_kwh_mean
               == pytest.approx(379.0) for c in done)


def test_engine_without_meter_has_no_carbon():
    eng = Engine(_cfg(), _params(), capacity=2, max_len=64, seed=0)
    eng.submit(Request("r0", _prompt(5, 0),
                       SamplingParams(max_new_tokens=3)))
    (c,) = eng.run_until_complete()
    assert c.carbon is None


# --- router ------------------------------------------------------------------

def _two_replica_fleet(ttft_slo_ticks=32.0, capacity=2):
    cfg, params = _cfg(), _params()
    reps = [Replica(name, cfg, grid=StaticGrid(name), params=params,
                    capacity=capacity, max_len=48, seed=0)
            for name in ("us-west", "eu-west")]   # 263 vs 346 g/kWh
    return Fleet(reps, FleetConfig(ttft_slo_ticks=ttft_slo_ticks))


def test_fleet_validation():
    with pytest.raises(ValueError, match="at least one"):
        Fleet([])
    cfg, params = _cfg(), _params()
    reps = [Replica("a", cfg, params=params, capacity=1, max_len=32),
            Replica("a", cfg, params=params, capacity=1, max_len=32)]
    with pytest.raises(ValueError, match="duplicate replica names"):
        Fleet(reps)


def test_duplicate_request_id_rejected():
    fleet = _two_replica_fleet()
    fleet.submit(Request("x", _prompt(4, 0),
                         SamplingParams(max_new_tokens=2)))
    with pytest.raises(ValueError, match="duplicate request_id"):
        fleet.submit(Request("x", _prompt(4, 1),
                             SamplingParams(max_new_tokens=2)))


def test_router_prefers_cleanest_region_then_spills_on_slo():
    """Idle fleet: lowest-intensity region wins.  Once its predicted
    TTFT blows the budget, latency wins and the request spills to the
    dirtier region."""
    fleet = _two_replica_fleet(ttft_slo_ticks=1.5, capacity=1)
    r0 = fleet.route(Request("a", _prompt(4, 0),
                             SamplingParams(max_new_tokens=4), arrival=0.0))
    assert r0.name == "us-west"
    assert fleet.routes[0].was_lowest_carbon
    # running-mean service estimator updated from the routed request
    assert fleet.mean_service_ticks("us-west") == pytest.approx(4.0)
    # us-west now has a queued request: backlog pushes prediction past
    # the 1.5-tick budget, so the next request goes to eu-west
    assert fleet.predicted_ttft_ticks(r0) > 1.5
    r1 = fleet.route(Request("b", _prompt(4, 1),
                             SamplingParams(max_new_tokens=4), arrival=0.0))
    assert r1.name == "eu-west"
    assert not fleet.routes[1].was_lowest_carbon


def test_routing_is_deterministic():
    """Same seed, same trace -> identical placement and completions."""
    cfg, params = _cfg(), _params()

    def run():
        fleet = build_fleet(cfg, regions=("us-west", "eu-west"),
                            trace="diurnal", capacity=2, max_len=48,
                            params=params)
        for r in poisson_requests(8, 6, 4, cfg.vocab, seed=3):
            fleet.submit(r)
        comps = fleet.run_until_complete()
        placement = [(rec.tick, rec.request_id, rec.replica,
                      rec.g_per_kwh) for rec in fleet.routes]
        streams = {c.request_id: tuple(c.tokens) for c in comps}
        return placement, streams

    p1, s1 = run()
    p2, s2 = run()
    assert p1 == p2
    assert s1 == s2


def test_idle_fleet_fast_forwards_to_next_arrival():
    fleet = _two_replica_fleet()
    fleet.submit(Request("late", _prompt(4, 0),
                         SamplingParams(max_new_tokens=3), arrival=100.0))
    comps = fleet.run_until_complete()
    assert len(comps) == 1 and not fleet.lost_requests()
    s = fleet.stats()
    assert 100 <= s["ticks"] < 120   # jumped, not crawled, to t=100


def test_failover_requeues_with_zero_lost():
    """Kill the replica the router prefers mid-trace: its in-flight
    requests drain onto the survivor and every submitted id completes
    exactly once."""
    cfg, params = _cfg(), _params()
    fleet = build_fleet(cfg, regions=("us-west", "eu-west"),
                        trace="static", capacity=2, max_len=48,
                        params=params)
    for r in poisson_requests(10, 6, 6, cfg.vocab, seed=0):
        fleet.submit(r)
    fleet.replicas[0].inject_fault(at_step=3)  # us-west: the clean one
    comps = fleet.run_until_complete()
    s = fleet.stats()
    assert not fleet.replicas[0].alive and fleet.replicas[1].alive
    assert s["requeued"] >= 1
    assert s["requeue_events"] and \
        s["requeue_events"][0]["replica"] == "us-west"
    assert s["lost"] == [] and s["completed"] == s["submitted"] == 10
    ids = [c.request_id for c in comps]
    assert len(ids) == len(set(ids)) == 10   # nothing served twice
    # re-queued routes are tagged and land on the survivor
    requeues = [rec for rec in fleet.routes if rec.requeue]
    assert requeues and all(rec.replica == "eu-west" for rec in requeues)


def test_dead_replica_rejects_traffic():
    cfg, params = _cfg(), _params()
    rep = Replica("a", cfg, params=params, capacity=1, max_len=32)
    rep.submit(Request("r", _prompt(4, 0),
                       SamplingParams(max_new_tokens=2)))
    rep.inject_fault(at_step=0)
    from repro.fleet import ReplicaDead
    with pytest.raises(ReplicaDead):
        rep.step()
    assert not rep.alive
    with pytest.raises(ReplicaDead):
        rep.submit(Request("r2", _prompt(4, 1),
                           SamplingParams(max_new_tokens=2)))
    # the dead replica still drains its pending work for re-queueing
    assert [r.request_id for r in rep.drain()] == ["r"]


def test_fleet_stats_totals_aggregate_meters():
    fleet = _two_replica_fleet()
    for r in poisson_requests(6, 5, 4, _cfg().vocab, seed=1):
        fleet.submit(r)
    fleet.run_until_complete()
    s = fleet.stats()
    per_replica_j = sum(rs["carbon"]["energy_j"] for rs in s["replicas"])
    assert s["totals"]["energy_j"] == pytest.approx(per_replica_j)
    assert s["totals"]["co2e_g"] > 0
    assert s["totals"]["co2e_g_per_token"] == pytest.approx(
        s["totals"]["co2e_g"] / s["totals"]["tokens"])
    assert ttft_ticks(fleet.completions()[0]) >= 1


# --- robustness: submit races, retries, recovery, degradation ---------------

def test_submit_fault_rerouted_transparently():
    """A replica that died since the router's last health view raises
    ReplicaDead at the submission boundary; route() must fail it over
    and land the request on a survivor — never lose it (regression for
    the raise escaping the routing path)."""
    fleet = _two_replica_fleet()
    fleet.replicas[0].inject_submit_fault()   # us-west: the preferred one
    r = fleet.route(Request("x", _prompt(5, 0),
                            SamplingParams(max_new_tokens=3), arrival=0.0))
    assert r.name == "eu-west"
    assert not fleet.replicas[0].alive
    comps = fleet.run_until_complete()
    assert [c.request_id for c in comps] == ["x"]
    assert comps[0].finish_reason == "length"
    assert not fleet.lost_requests()
    assert len(fleet.routes) == 1 and fleet.routes[0].replica == "eu-west"


def test_failover_during_prefill_restores_request():
    """A crash inside the prefill step: the slot must not leak and the
    request must complete on the survivor exactly once."""
    fleet = _two_replica_fleet()
    victim = fleet.replicas[0]                # us-west is preferred
    real = victim.engine._prefill

    def boom(*a, **kw):
        raise RuntimeError("XlaRuntimeError: device lost")

    victim.engine._prefill = boom
    fleet.submit(Request("p", _prompt(8, 1),
                         SamplingParams(max_new_tokens=4), arrival=0.0))
    comps = fleet.run_until_complete()
    victim.engine._prefill = real
    assert not victim.alive                   # crash marked it dead
    assert [c.request_id for c in comps] == ["p"]
    assert comps[0].attempt == 1              # served by the retry
    assert not fleet.lost_requests()
    # the re-queued attempt backed off deterministically then landed on
    # the survivor
    assert fleet.requeued == 1
    assert fleet.routes[-1].replica == "eu-west"


def test_drain_fifo_ordering_preserved_across_failover():
    """drain() yields in-flight (by admission) then queued (by arrival)
    requests; the router re-queues them in that order, so the survivor
    serves the dead replica's work in the original FIFO order."""
    fleet = _two_replica_fleet(ttft_slo_ticks=1000.0, capacity=2)
    victim = fleet.replicas[0]
    # the generous SLO keeps every request carbon-routed to us-west
    for i in range(5):
        fleet.route(Request(f"r{i}", _prompt(5, i),
                            SamplingParams(max_new_tokens=6), arrival=0.0))
    assert victim.routed == 5
    fleet.step()                              # r0, r1 admitted; r2+ queued
    drained_preview = [r.request_id
                       for r in victim.engine.pending_requests()]
    assert drained_preview == [f"r{i}" for i in range(5)]
    fleet.kill_replica("us-west")
    assert fleet.requeue_events[-1]["requeued"] == drained_preview
    fleet.run_until_complete()
    assert not fleet.lost_requests()
    # FIFO preserved end to end: the survivor admitted r0..r4 in order
    requeues = [rec for rec in fleet.routes if rec.requeue]
    assert [rec.request_id for rec in requeues] == drained_preview
    done = {c.request_id: c for c in fleet.completions()}
    admits = [done[f"r{i}"].admitted_tick for i in range(5)]
    assert admits == sorted(admits)


def test_retry_budget_exhaustion_sheds_not_loses():
    fleet = _two_replica_fleet()
    fleet.cfg = dataclasses.replace(fleet.cfg, retry_budget=0)
    fleet.submit(Request("doomed", _prompt(5, 0),
                         SamplingParams(max_new_tokens=4), arrival=0.0))
    fleet.step()                              # routed + admitted
    victim = next(r for r in fleet.replicas if r.routed)
    fleet.kill_replica(victim.name)           # attempt 1 > budget 0
    comps = fleet.run_until_complete()
    assert not fleet.lost_requests()
    (c,) = [c for c in comps if c.request_id == "doomed"]
    assert c.finish_reason == "shed" and c.tokens == []
    s = fleet.stats()["robustness"]
    assert s["retry_exhausted"] == 1


def test_retry_backoff_is_exponential_in_ticks():
    fleet = _two_replica_fleet()
    fleet.cfg = dataclasses.replace(fleet.cfg, retry_budget=3,
                                    retry_backoff_ticks=2.0)
    base = Request("b", _prompt(4, 0), SamplingParams(max_new_tokens=2))
    for attempt, delay in [(0, 2.0), (1, 4.0), (2, 8.0)]:
        fleet._requeue(dataclasses.replace(base, attempt=attempt))
    arrivals = sorted(t for t, _, _ in fleet._pending)
    assert arrivals == [2.0, 4.0, 8.0]
    # attempts are restamped on the re-queued copies
    attempts = sorted(req.attempt for _, _, req in fleet._pending)
    assert attempts == [1, 2, 3]


def test_transient_death_restarts_through_probation():
    """kill_replica(recovery_ticks=K): the replica restarts K ticks
    later with a fresh engine + re-prepared planes, serves no fresh
    traffic during probation, and rejoins afterwards."""
    cfg, params = _cfg(), _params()
    fleet = _two_replica_fleet()
    fleet.cfg = dataclasses.replace(fleet.cfg, probation_steps=2)
    for r in poisson_requests(6, 5, 4, cfg.vocab, seed=2):
        fleet.submit(r)
    fleet.step()
    fleet.kill_replica("us-west", recovery_ticks=3)
    dead_tick = fleet.tick
    assert not fleet.replicas[0].alive
    fleet.run_until_complete()
    s = fleet.stats()
    assert s["lost"] == [] and s["completed"] == s["submitted"]
    rec, = s["robustness"]["recoveries"]
    assert rec["replica"] == "us-west" and rec["tick"] >= dead_tick + 3
    assert s["robustness"]["restarts"] == {"us-west": 1}
    rep = fleet.replicas[0]
    assert rep.alive and rep.restarts == 1
    # probation over (it was stepped while idle); fresh traffic OK again
    assert "us-west" not in fleet._probation
    fleet.submit(Request("after", _prompt(5, 8),
                         SamplingParams(max_new_tokens=3),
                         arrival=float(fleet.tick)))
    fleet.run_until_complete()
    assert not fleet.lost_requests()
    # meter conservation across the restart: finalized + abandoned +
    # open == metered total on every replica
    for r in fleet.replicas:
        cs = r.carbon_summary()
        acc = (cs["finalized_energy_j"] + cs["abandoned_energy_j"]
               + cs["open_energy_j"])
        assert acc == pytest.approx(cs["energy_j"], rel=1e-9)


def test_degradation_controller_brownout_and_restore():
    """Burst overload on a tier-laddered replica: the controller steps
    down the ladder under SLO pressure (tokens attributed to the approx
    tier), then restores exact once the queue drains; wall-clock TTFT
    stamps are recorded for every served request."""
    from repro.fleet import DegradationConfig
    cfg, params = _cfg(), _params()
    rep = Replica("us-west", cfg, grid=StaticGrid("us-west"),
                  params=params, capacity=1, max_len=48, seed=0,
                  tiers=("exact", "trunc4x4"))
    fleet = Fleet([rep], FleetConfig(
        ttft_slo_ticks=6.0,
        degradation=DegradationConfig(patience=1, min_dwell_ticks=2)))
    for i in range(6):
        fleet.submit(Request(f"b{i}", _prompt(5, i),
                             SamplingParams(max_new_tokens=5), arrival=0.0))
    fleet.run_until_complete()
    for _ in range(10):                      # idle ticks: headroom back
        fleet.step()
    ev = fleet.controller.events
    assert ev[0]["reason"] == "slo_headroom" and ev[0]["to"] == "trunc4x4"
    assert any(e["reason"] == "headroom_restored" for e in ev)
    assert rep.engine.tier == "exact"        # restored after the burst
    occ = fleet.tier_occupancy()
    assert occ.get("trunc4x4", 0) > 0        # brownout really served
    assert sum(occ.values()) == 30
    wall = fleet.wall_ttft_ticks()
    assert set(wall) == {f"b{i}" for i in range(6)}
    assert all(t >= 1 for t in wall.values())
    # the degraded tier banked step credit: the flood drained in fewer
    # fleet ticks than tokens served on a single exact slot would need
    assert fleet.stats()["ticks"] < 30 + 10


# --- total-carbon objective --------------------------------------------------

def test_operational_model_validation():
    with pytest.raises(ValueError):
        ftotal.OperationalModel(ci_use_g_per_kwh=-1.0)
    with pytest.raises(ValueError):
        ftotal.OperationalModel(util=0.0)
    with pytest.raises(ValueError):
        ftotal.OperationalModel(energy_scale=0.0)
    op = ftotal.OperationalModel()
    assert op.pe_active_w(7) == PE_ACTIVE_W_BY_NODE[7]
    assert dataclasses.replace(op, energy_scale=2.0).pe_active_w(7) \
        == pytest.approx(2 * PE_ACTIVE_W_BY_NODE[7])


def test_total_carbon_scalar_model_properties():
    op = ftotal.OperationalModel()
    with pytest.raises(ValueError):
        ftotal.energy_j_per_inf(0.0, 256, 1.0, 7, op)
    # race-to-idle: running faster than the duty-cycle floor cuts energy
    # per inference (active time shrinks, only idle power fills the gap)
    e_fast = ftotal.energy_j_per_inf(60.0, 256, 1.0, 7, op, fps_min=30.0)
    e_slow = ftotal.energy_j_per_inf(30.0, 256, 1.0, 7, op, fps_min=30.0)
    assert e_fast < e_slow
    # but embodied amortization is capped at the floor: speed headroom
    # does not buy more lifetime inferences
    assert ftotal.embodied_g_per_inf(1e4, 60.0, op, fps_min=30.0) == \
        ftotal.embodied_g_per_inf(1e4, 30.0, op, fps_min=30.0)
    # approximate multipliers draw less power than exact (escale < 1)
    assert ftotal.pe_power_w(256, 0.5, 7, op) < \
        ftotal.pe_power_w(256, 1.0, 7, op)
    # chiplets pay die-to-die link power
    assert ftotal.pe_power_w(256, 1.0, 7, op, n_dies=4.0) == \
        pytest.approx(ftotal.pe_power_w(256, 1.0, 7, op) + 3 * op.die_w)
    # total = embodied + operational, exactly
    tot = ftotal.total_carbon_g_per_inf(1e4, 40.0, 256, 1.0, 7, op,
                                        fps_min=30.0, n_dies=2.0)
    assert tot == pytest.approx(
        ftotal.embodied_g_per_inf(1e4, 40.0, op, fps_min=30.0)
        + ftotal.operational_g_per_inf(40.0, 256, 1.0, 7, op,
                                       fps_min=30.0, n_dies=2.0))


def test_energy_calibration_anchors_power_model():
    c = ftotal.EnergyCalibration(measured_j_per_token=2.0,
                                 modeled_j_per_token=1.0)
    assert c.scale == pytest.approx(2.0)
    op = c.apply(ftotal.OperationalModel())
    assert op.energy_scale == pytest.approx(2.0)
    assert op.pe_active_w(7) == pytest.approx(2 * PE_ACTIVE_W_BY_NODE[7])
    # degenerate inputs fall back to the identity scale
    assert ftotal.EnergyCalibration(0.0, 1.0).scale == 1.0
    assert ftotal.EnergyCalibration(1.0, 0.0).scale == 1.0
    got = ftotal.EnergyCalibration.from_meter_summary(
        {"energy_j_per_token": 3.0}, modeled_j_per_token=1.5)
    assert got.scale == pytest.approx(2.0)
    with pytest.raises(ValueError):
        ftotal.modeled_j_per_token(256, 1.0, 7,
                                   ftotal.OperationalModel(), 0.0)


def test_total_carbon_batched_matches_scalar_twin():
    """The GA's batched total-carbon metrics equal the scalar model in
    fleet/total.py genome-for-genome (the parity contract both
    docstrings promise)."""
    op = ftotal.OperationalModel()
    space = gb.build_space("vgg16", 7, 30.0, 2.0, mults=_fast_mults(),
                           op=op)
    rng = np.random.default_rng(0)
    pop = np.stack([rng.integers(0, n, 48) for n in space.gene_sizes],
                   axis=1).astype(np.int32)
    met = gb.evaluate_population(jnp.asarray(pop), space.tables(), 7)
    escale = space.mult_area / space.mult_area[space.exact_idx]
    for row, carbon, fps, e_inf, tot in zip(
            pop, np.asarray(met["carbon_g"]), np.asarray(met["fps"]),
            np.asarray(met["energy_j_per_inf"]),
            np.asarray(met["total_g_per_inf"])):
        pe, _aspect, _rf, _glb, mult, die = row
        kw = dict(fps_min=30.0, n_dies=float(space.dies[die]))
        assert e_inf == pytest.approx(ftotal.energy_j_per_inf(
            float(fps), float(space.num_pes[pe]), float(escale[mult]),
            7, op, **kw), rel=1e-4)
        assert tot == pytest.approx(ftotal.total_carbon_g_per_inf(
            float(carbon), float(fps), float(space.num_pes[pe]),
            float(escale[mult]), 7, op, **kw), rel=1e-4)


def test_total_carbon_objective_requires_op():
    with pytest.raises(ValueError, match="total_carbon"):
        gb.run_ga_batched(
            "vgg16", 7, 30.0, 2.0, mults=_fast_mults(),
            cfg=gb.BatchedGAConfig(pop_size=64, generations=1,
                                   objective="total_carbon"))
    space = gb.build_space("vgg16", 7, 30.0, 2.0, mults=_fast_mults())
    pop = jnp.zeros((4, gb.N_GENES), jnp.int32)
    with pytest.raises(ValueError, match="unknown objective"):
        gb.evaluate_population(pop, space.tables(), 7,
                               objective="banana")


def test_total_carbon_ga_matches_exhaustive_optimum():
    op = ftotal.OperationalModel()
    space = gb.build_space("vgg16", 7, 30.0, 2.0, mults=_fast_mults(),
                           op=op)
    res = gb.run_ga_batched(
        "vgg16", 7, 30.0, 2.0, space=space,
        cfg=gb.BatchedGAConfig(pop_size=1024, generations=8, seed=0,
                               objective="total_carbon"))
    g_tot, met_tot = gb.exhaustive_best(space, objective="total_carbon")
    assert float(np.min(res.metrics["fitness"])) <= \
        float(met_tot["fitness"]) * (1 + 1e-4)
    # the total-carbon optimum can't lose to the CDP optimum on total
    _g_cdp, met_cdp = gb.exhaustive_best(space, objective="cdp")
    assert float(met_tot["total_g_per_inf"]) <= \
        float(met_cdp["total_g_per_inf"]) * (1 + 1e-6)
    assert res.metrics["feasible"][
        int(np.argmin(res.metrics["fitness"]))]
