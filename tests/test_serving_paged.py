"""Differential harness for the paged serving stack.

The contract under test: `PagedEngine` (paged KV + chunked prefill +
approx-draft speculative decoding, in any combination) is
**token-identical** to the whole-slot `Engine` on the 4-family
mixed-arrival trace — greedy and seeded sampling, single die and TP
mesh.  Plus the speculative-decode invariants: an exact draft is
accepted 100%, rejected draft prefixes never leak into KV pages, and
`Completion.spec` conserves (`accepted + corrections == len(tokens)`),
including under a chaos-seeded burst schedule.
"""

import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serving import Engine, PagedEngine, Request, SamplingParams
from test_distributed import run_devices  # same-dir test module (pytest path)

FAMILY_ARCHS = ["tinyllama-1.1b", "mamba2-370m", "recurrentgemma-9b",
                "whisper-medium"]


def _cfg(arch):
    return configs.reduced(configs.get_config(arch))


@functools.lru_cache(maxsize=None)
def _params(arch):
    return api.init_params(_cfg(arch), jax.random.key(0))


def _prompt(n, seed, vocab=256):
    return np.random.default_rng(seed).integers(1, vocab, (n,)).tolist()


def _mixed_trace(cfg, n_requests=8, seed=1):
    """The 4-family mixed-arrival trace: heterogeneous prompt lengths,
    staggered arrivals, alternating greedy / seeded-sampling rows."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        n = int(rng.integers(4, 24))
        gen = int(rng.integers(2, 6))
        sp = SamplingParams(max_new_tokens=gen) if i % 2 == 0 else \
            SamplingParams(temperature=0.9, top_k=8, max_new_tokens=gen,
                           seed=100 + i)
        extras = None
        if cfg.family == "encdec":
            extras = {"frames": rng.standard_normal(
                (cfg.enc_seq, cfg.d_model)).astype(np.float32)}
        out.append(Request(
            f"t{i}", rng.integers(1, cfg.vocab, (n,)).tolist(), sp,
            arrival=float(i) * 0.7, extras=extras))
    return out


def _serve(engine, trace):
    for req in trace:
        engine.submit(req)
    return {c.request_id: (c.tokens, c.finish_reason)
            for c in engine.run_until_complete()}


def _differential(arch, **paged_kw):
    cfg, params = _cfg(arch), _params(arch)
    trace = _mixed_trace(cfg)
    base = _serve(Engine(cfg, params, capacity=3, max_len=64, seed=0),
                  trace)
    eng = PagedEngine(cfg, params, capacity=3, max_len=64, seed=0,
                      **paged_kw)
    paged = _serve(eng, trace)
    assert base == paged, (arch, paged_kw, base, paged)
    return eng


# --- token identity: paged / chunked / speculative vs the slot engine ------

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_differential_all_families(arch):
    """Paged KV alone: token-identical on the mixed-arrival trace."""
    eng = _differential(arch, page_size=8)
    st = eng.stats()["paged"]
    assert st["alloc_failures"] == 0
    # mamba2 / rglru reduced configs have no max_len-scaling leaves:
    # paging must degenerate gracefully, not misclassify a state buffer
    if _cfg(arch).family in ("ssm", "hybrid"):
        assert st["paged_leaves"] == []
    else:
        assert st["paged_leaves"], st


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_chunked_spec_stacked_all_families(arch):
    """All three features at once, still token-identical everywhere."""
    eng = _differential(arch, page_size=8, prefill_chunk=8,
                        draft_tier="exact", spec_k=3)
    st = eng.stats()
    assert st["paged"]["chunked"]["chunks"] > 0
    assert st["spec"]["steps"] > 0


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt prefills in chunks while a short request decodes:
    the short request's first token must land BEFORE the long prompt
    finishes prefilling (the TTFT win), with streams unchanged."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    trace = [
        Request("long", _prompt(40, 0), SamplingParams(max_new_tokens=4)),
        Request("short", _prompt(4, 1), SamplingParams(max_new_tokens=4),
                arrival=0.0),
    ]
    base = _serve(Engine(cfg, params, capacity=2, max_len=64, seed=0),
                  list(trace))
    eng = PagedEngine(cfg, params, capacity=2, max_len=64, seed=0,
                      page_size=8, prefill_chunk=8, chunk_budget=1)
    for req in trace:
        eng.submit(req)
    short_first_tick = None
    while eng.n_queued or eng.n_active:
        eng.step()
        done = {c.request_id for c in eng.completions}
        slot_tokens = {s.request.request_id: len(s.tokens)
                       for s in eng._slots if s is not None}
        if short_first_tick is None and (
                slot_tokens.get("short", 0) > 0 or "short" in done):
            short_first_tick = eng.tick
            # the long prompt is still mid-prefill at this point
            assert eng.stats()["paged"]["chunked"]["inflight"] == 1
    paged = {c.request_id: (c.tokens, c.finish_reason)
             for c in eng.completions}
    assert base == paged
    assert short_first_tick is not None
    assert eng.stats()["paged"]["chunked"]["chunks"] >= 5  # 40/8 chunks


def test_approx_draft_differential():
    """Speculation with a genuinely approximate draft tier: acceptance
    may be partial, but emitted tokens are STILL exactly the serving
    tier's own stream (verify re-runs everything)."""
    eng = _differential("tinyllama-1.1b", page_size=8,
                        draft_tier="trunc4x4", spec_k=4)
    spec = eng.stats()["spec"]
    assert spec["proposed"] > 0
    assert 0.0 <= spec["acceptance_rate"] <= 1.0


def test_prefix_sharing_differential_and_hits():
    """Requests with a common system prompt share read-only pages —
    and still emit exactly the baseline streams."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    system = _prompt(24, 9)
    trace = []
    for i in range(4):
        trace.append(Request(
            f"s{i}", system + _prompt(4, 50 + i),
            SamplingParams(max_new_tokens=4), arrival=float(i)))
    base = _serve(Engine(cfg, params, capacity=2, max_len=64, seed=0),
                  list(trace))
    eng = PagedEngine(cfg, params, capacity=2, max_len=64, seed=0,
                      page_size=8)
    paged = _serve(eng, list(trace))
    assert base == paged
    st = eng.stats()["paged"]
    assert st["prefix_hits"] >= 1
    assert st["prefix_hit_tokens"] >= 16  # >= 2 shared pages per hit


def test_page_pressure_stalls_preserve_fifo():
    """A pool too small for full concurrency stalls admission at the
    queue head (no overtaking) and every request still completes with
    baseline-identical tokens."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    trace = [Request(f"p{i}", _prompt(20, 60 + i),
                     SamplingParams(max_new_tokens=4))
             for i in range(4)]
    base = _serve(Engine(cfg, params, capacity=3, max_len=32, seed=0),
                  list(trace))
    # 5 usable pages of 8 = 40 positions: ~1.4 requests' worth at a time
    eng = PagedEngine(cfg, params, capacity=3, max_len=32, seed=0,
                      page_size=8, n_pages=6, prefix_cache=False)
    paged = _serve(eng, list(trace))
    assert base == paged
    st = eng.stats()["paged"]
    assert st["admission_stalls"] > 0
    done = {c.request_id: c for c in eng.completions}
    order = sorted(done, key=lambda r: done[r].admitted_tick)
    assert order == [f"p{i}" for i in range(4)]   # FIFO held under stalls
    eng._alloc.audit()                            # pool fully reconciled


def test_pool_fit_validation():
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = PagedEngine(cfg, params, capacity=1, max_len=32, seed=0,
                      page_size=8, n_pages=3)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request("big", _prompt(20, 0),
                           SamplingParams(max_new_tokens=8)))


def test_cow_resolves_shared_page():
    """resolve_cow on a prefix-shared page: the request gets a private
    copy with identical content, and the allocator invariants hold."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = PagedEngine(cfg, params, capacity=2, max_len=64, seed=0,
                      page_size=8)
    system = _prompt(16, 3)
    eng.submit(Request("a", system + [5], SamplingParams(max_new_tokens=12)))
    eng.submit(Request("b", system + [9], SamplingParams(max_new_tokens=12)))
    for _ in range(3):
        eng.step()
    assert eng._leases["b"].shared_pages == 2
    before = eng.debug_kv_rows("b")
    assert not eng._alloc.writable("b", 0)
    op = eng.resolve_cow("b", 0)
    assert op is not None and op[1] != op[0]
    assert eng._alloc.writable("b", 0)
    after = eng.debug_kv_rows("b")
    for key in before["rows"]:
        np.testing.assert_array_equal(before["rows"][key][:8],
                                      after["rows"][key][:8])
    eng._alloc.audit()
    # already-private page: no copy needed
    assert eng.resolve_cow("b", 0) is None


# --- speculative-decode invariants -----------------------------------------

def test_exact_draft_accepts_everything():
    """Drafting with the serving tier itself must accept every proposed
    token (the speculation machinery's identity check)."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = PagedEngine(cfg, params, capacity=2, max_len=64, seed=0,
                      page_size=8, draft_tier="exact", spec_k=4)
    for i in range(3):
        eng.submit(Request(f"g{i}", _prompt(6 + 4 * i, i),
                           SamplingParams(max_new_tokens=9)))
    done = eng.run_until_complete()
    spec = eng.stats()["spec"]
    assert spec["proposed"] > 0
    assert spec["accepted"] == spec["proposed"]
    assert spec["acceptance_rate"] == 1.0
    for c in done:
        # full acceptance: the only corrections are first tokens and
        # the k_row clamp at the max_new_tokens boundary
        assert c.spec.accepted + c.spec.corrections == len(c.tokens)
        assert c.spec.proposed == c.spec.accepted


def test_sampled_rows_bypass_speculation():
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = PagedEngine(cfg, params, capacity=1, max_len=48, seed=0,
                      page_size=8, draft_tier="exact", spec_k=4)
    eng.submit(Request("hot", _prompt(6, 2),
                       SamplingParams(temperature=0.9, top_k=8,
                                      max_new_tokens=6, seed=5)))
    (c,) = eng.run_until_complete()
    assert c.spec.proposed == 0 and c.spec.accepted == 0
    assert c.spec.corrections == len(c.tokens) == 6
    assert c.spec.acceptance_rate == 0.0


def test_rejected_drafts_never_leak_into_kv_pages():
    """Mid-flight, every reserved-but-unwritten KV position of every
    active request must still be zero: rejected speculative positions
    were scattered to the trash page, never into the request's pages."""
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = PagedEngine(cfg, params, capacity=2, max_len=64, seed=0,
                      page_size=8, draft_tier="trunc4x4", spec_k=4,
                      prefix_cache=False)
    for i in range(2):
        eng.submit(Request(f"r{i}", _prompt(10 + 5 * i, 30 + i),
                           SamplingParams(max_new_tokens=12)))
    rejections = 0
    while eng.n_queued or eng.n_active:
        eng.step()
        spec = eng.stats()["spec"]
        rejections = spec["proposed"] - spec["accepted"]
        for slot in eng._slots:
            if slot is None or slot.prefilling:
                continue
            d = eng.debug_kv_rows(slot.request.request_id)
            assert d["length"] <= d["reserved"]
            for key, rows in d["rows"].items():
                tail = rows[d["length"]:d["reserved"]]
                assert not np.any(tail), \
                    f"{key}: rejected draft leaked into KV pages"
    assert rejections > 0, "trace produced no rejections; weaken draft"


def test_spec_stats_conserve_under_chaos_burst_schedule():
    """`accepted + corrections == len(tokens)` for every completion of
    a chaos-seeded burst trace (fleet/chaos.py schedule), greedy and
    sampled rows mixed, with zero lost and zero duplicated requests."""
    from repro.fleet.chaos import ChaosSchedule
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    sched = ChaosSchedule.random(17, ["e0"], kinds=("burst",),
                                 n_events=3, horizon_ticks=10)
    eng = PagedEngine(cfg, params, capacity=3, max_len=48, seed=0,
                      page_size=8, prefill_chunk=8,
                      draft_tier="trunc4x4", spec_k=3)
    submitted = []
    rid = 0
    for ev in sched.events:
        assert ev.kind == "burst"
        for j in range(ev.n_requests):
            sp = SamplingParams(max_new_tokens=2 + rid % 4) \
                if rid % 3 else SamplingParams(
                    temperature=0.8, top_k=8, max_new_tokens=3,
                    seed=rid)
            eng.submit(Request(f"b{rid}", _prompt(4 + rid % 14, rid), sp,
                               arrival=float(ev.tick)))
            submitted.append(f"b{rid}")
            rid += 1
    done = eng.run_until_complete()
    ids = [c.request_id for c in done]
    assert sorted(ids) == sorted(submitted)       # zero lost
    assert len(set(ids)) == len(ids)              # exactly once
    for c in done:
        assert c.spec is not None
        assert c.spec.accepted + c.spec.corrections == len(c.tokens), c
    tot = eng.stats()["spec"]
    assert tot["accepted"] + tot["corrections"] == \
        sum(len(c.tokens) for c in done)
    eng._alloc.audit()


def test_allocator_random_walk_audit():
    """Seeded random alloc/free/fork/COW walk with `audit()` after every
    step — the hypothesis state machine's deterministic twin, so the
    allocator invariants run even where hypothesis is not installed
    (tests/test_property.py carries the full stateful version)."""
    import random
    from repro.serving import PageAllocator, PagingError
    rng = random.Random(23)
    alloc = PageAllocator(n_pages=9, page_size=4)
    live: list[str] = []
    for step in range(400):
        op = rng.randrange(5)
        if op in (0, 1):                                        # alloc
            rid = f"r{step}"
            n = rng.randrange(1, 30)
            prompt = tuple([rng.randrange(1, 3)] * n) \
                if rng.random() < 0.5 else None
            lease = alloc.alloc(rid, n, prompt=prompt, digest="d")
            if lease is not None:
                live.append(rid)
                if prompt is not None:
                    alloc.register_prefix(rid, prompt, "d")
        elif op == 2 and live:                                  # free
            alloc.free(live.pop(rng.randrange(len(live))))
        elif op == 3 and live:                                  # fork
            dst = f"f{step}"
            alloc.fork(rng.choice(live), dst)
            live.append(dst)
        elif op == 4 and live:                                  # cow
            rid = rng.choice(live)
            table = alloc.table(rid)
            i = rng.randrange(len(table))
            try:
                alloc.cow(rid, i)
            except PagingError:
                pass                    # pool exhausted: allowed
            else:
                assert alloc.writable(rid, i)
        alloc.audit()
    with pytest.raises(PagingError):
        alloc.free("never-allocated")
    for rid in live:
        alloc.free(rid)
    alloc.audit()
    assert alloc.pages_live == 0


def test_alloc_reclaim_never_evicts_pending_shared_pages():
    """Regression: under pool pressure, `alloc` must pin its prefix-hit
    pages BEFORE reclaiming.  Reclaiming first could evict a page from
    the request's own shared list onto the free list and re-pop it as
    "fresh" — a duplicate page in one block table (and a page both
    free-listed and refcounted, i.e. cross-request KV corruption)."""
    from repro.serving import PageAllocator
    alloc = PageAllocator(n_pages=7, page_size=2)
    pa, px = (1, 1, 1, 1), (9, 9)
    assert alloc.alloc("A", 4, prompt=pa, digest="d") is not None
    alloc.register_prefix("A", pa, "d")
    alloc.free("A")                      # A's 2 pages: oldest on the LRU
    assert alloc.alloc("X", 2, prompt=px, digest="d") is not None
    alloc.register_prefix("X", px, "d")
    alloc.free("X")                      # X's page: newest on the LRU
    assert alloc.alloc("B", 6) is not None   # drain the free list
    lease = alloc.alloc("C", 6, prompt=pa, digest="d")
    assert lease is not None
    assert lease.shared_pages == 2
    assert len(set(lease.pages)) == len(lease.pages) == 3
    # pressure evicted X's (unrelated) cache entry, not the shared pages
    assert alloc.reclaimed_pages == 1
    alloc.audit()


def test_alloc_failure_with_shared_pages_rolls_back_pins():
    """When reclaiming cannot cover the fresh remainder, a prefix-hit
    alloc must fail cleanly: the pinned shared pages return to the
    reclaimable cache, so a later same-prefix request still hits."""
    from repro.serving import PageAllocator
    alloc = PageAllocator(n_pages=5, page_size=2)
    pa = (1, 1, 1, 1)
    assert alloc.alloc("A", 4, prompt=pa, digest="d") is not None
    alloc.register_prefix("A", pa, "d")
    alloc.free("A")
    assert alloc.alloc("B", 4) is not None   # drain the free list
    # needs 2 shared + 2 fresh, but only the 2 shared pages are
    # reclaimable — with them pinned nothing can be reclaimed
    assert alloc.alloc("C", 8, prompt=pa, digest="d") is None
    assert alloc.alloc_failures == 1
    alloc.audit()
    alloc.free("B")
    lease = alloc.alloc("D", 4, prompt=pa, digest="d")
    assert lease is not None and lease.shared_pages == 2
    alloc.audit()


# --- compile budgets --------------------------------------------------------

def test_paged_engine_compile_budgets(retrace_sanitizer):
    """Paged + chunked + speculative serving keeps the one-compile-per-
    phase contract: chunk/draft/verify each compile exactly once and
    never retrace across a trace (fixture asserts at teardown)."""
    from repro.analysis.retrace import instrument_engine
    cfg, params = _cfg("tinyllama-1.1b"), _params("tinyllama-1.1b")
    eng = PagedEngine(cfg, params, capacity=2, max_len=48, seed=0,
                      page_size=8, prefill_chunk=8, draft_tier="exact",
                      spec_k=3)
    instrument_engine(eng, retrace_sanitizer)
    for i, (n, temp) in enumerate([(4, 0.0), (21, 0.0), (6, 0.8)]):
        eng.submit(Request(f"c{i}", _prompt(n, i),
                           SamplingParams(max_new_tokens=4,
                                          temperature=temp,
                                          top_k=8 if temp else 0, seed=i),
                           arrival=float(i)))
    eng.run_until_complete()
    rep = retrace_sanitizer.report()
    assert rep["serving/paged:chunk"]["compiles"] <= 1
    assert rep["serving/paged:draft"]["calls"] > 0


# --- TP mesh ---------------------------------------------------------------

def test_paged_tp_token_parity():
    """Differential under tensor parallelism: the paged + chunked +
    speculative engine must be token-identical to the whole-slot engine
    ON THE SAME MESH (same logit bits, so sampled lanes match too), and
    greedy rows must additionally match the 1-die paged run (PR 5's
    cross-mesh greedy identity; sampled draws may legitimately flip on
    ULP-level logit differences between meshes)."""
    run_devices("""
        import jax, numpy as np
        from repro import configs
        from repro.models import api
        from repro.serving import Engine, PagedEngine, Request, \\
            SamplingParams
        from repro.launch.mesh import make_mesh_from_spec

        def serve(arch, mesh_spec, paged):
            cfg = configs.reduced(configs.get_config(arch))
            params = api.init_params(cfg, jax.random.key(0))
            kw = dict(page_size=8, prefill_chunk=8,
                      draft_tier="exact", spec_k=3) if paged else {}
            cls = PagedEngine if paged else Engine
            eng = cls(cfg, params, capacity=3, max_len=64, seed=0,
                      mesh=make_mesh_from_spec(mesh_spec), **kw)
            rng = np.random.default_rng(5)
            for i, n in enumerate([5, 19, 33]):
                sp = SamplingParams(max_new_tokens=6) if i % 2 == 0 else \\
                    SamplingParams(temperature=0.9, top_k=8,
                                   max_new_tokens=6, seed=40 + i)
                eng.submit(Request(f"r{i}",
                                   rng.integers(1, 256, (n,)).tolist(),
                                   sp))
            done = {c.request_id: c.tokens
                    for c in eng.run_until_complete()}
            return done, eng.stats()

        TP = "model=4,data=2"
        for arch in ("tinyllama-1.1b", "mamba2-370m"):
            slot_tp, _ = serve(arch, TP, paged=False)
            paged_tp, stats = serve(arch, TP, paged=True)
            assert slot_tp == paged_tp, (arch, slot_tp, paged_tp)
            assert stats["mesh"] == {"data": 2, "model": 4}, stats
            assert stats["spec"]["acceptance_rate"] == 1.0, stats
            one, _ = serve(arch, "data=1,model=1", paged=True)
            for rid in ("r0", "r2"):   # the greedy rows
                assert one[rid] == paged_tp[rid], (arch, rid, one, paged_tp)
        print("OK")
    """, timeout=1800)
