"""Exhaustive correctness of the gate-level multiplier substrate."""

import numpy as np
import pytest

from repro.core import lut as lutmod
from repro.core import multipliers as mm
from repro.core import netlist as nlmod
from repro.core import pareto


def test_bw8_exact_exhaustive():
    nlmod.self_check()  # all 65,536 int8 pairs


def test_packed_matches_unpacked():
    nl = nlmod.bw8()
    pr = nlmod.truncation_pruning(nl, 2, 1)
    a_bits, b_bits, _, _ = nlmod.all_input_bits()
    slow = nlmod.bits_to_int16(nl.evaluate(a_bits, b_bits, pr))
    fast = nlmod.bits_to_int16(nlmod.evaluate_packed(nl, pr))
    np.testing.assert_array_equal(slow, fast)


@pytest.mark.parametrize("t", [1, 2, 3, 4])
def test_truncation_closed_form(t):
    """Precision scaling == zeroing t LSBs of each two's-complement operand."""
    m = mm.truncated(t, t)
    a = np.arange(-128, 128, dtype=np.int64)
    ta = a - np.mod(a, 2 ** t)  # positive remainder mod
    expect = ta[:, None] * ta[None, :]
    got = np.empty((256, 256), dtype=np.int64)
    ua = (a & 0xFF).astype(int)
    got = m.lut[np.ix_(ua, ua)].astype(np.int64)
    np.testing.assert_array_equal(got, expect)


def test_truncation_area_monotone():
    areas = [mm.truncated(t, t).area_nand2eq for t in range(5)]
    assert all(a1 > a2 for a1, a2 in zip(areas, areas[1:]))


def test_truncation_error_monotone():
    nmeds = [mm.truncated(t, t).stats.nmed for t in range(5)]
    assert nmeds[0] == 0.0
    assert all(e1 < e2 for e1, e2 in zip(nmeds, nmeds[1:]))


def test_pruning_reduces_area_and_reports_error():
    nl = nlmod.bw8()
    n = len(nl.prunable_gates())
    rng = np.random.default_rng(0)
    mask = rng.random(n) < 0.05
    m = mm.pruned(mask)
    ex = mm.exact_multiplier()
    assert m.area_nand2eq < ex.area_nand2eq
    assert m.stats.nmed >= 0
    assert m.stats.wce >= 0


def test_exact_multiplier_is_exact():
    ex = mm.exact_multiplier()
    assert ex.stats.wce == 0
    assert ex.stats.nmed == 0.0
    assert ex.lowrank.rank == 0


def test_dead_gate_elimination_credits_truncation():
    """Truncating operands must remove whole partial-product cones."""
    nl = nlmod.bw8()
    pr = nlmod.constant_propagate(nl, nlmod.truncation_pruning(nl, 4, 4))
    assert nl.area_nand2eq(pr) < 0.6 * nl.area_nand2eq()


def test_lowrank_reconstruction_bound():
    m = mm.truncated(2, 2)
    lr = lutmod.lowrank_error(m.lut, rank=4)
    e = lutmod.error_surface(m.lut).astype(np.float64)
    resid = np.abs(e - lr.reconstruct())
    assert resid.mean() / lutmod.MAX_ABS_PRODUCT <= lr.residual_nmed + 1e-12
    # truncation errors are (numerically) rank <= 3
    assert lr.residual_nmed < 1e-6


def test_lowrank_rank_zero_for_exact():
    ex = mm.exact_multiplier()
    lr = lutmod.choose_rank(ex.lut)
    assert lr.rank == 0 and lr.residual_nmed == 0.0


def test_choose_rank_meets_tolerance_or_maxrank():
    nl = nlmod.bw8()
    rng = np.random.default_rng(1)
    mask = rng.random(len(nl.prunable_gates())) < 0.04
    m = mm.pruned(mask)
    lr = lutmod.choose_rank(m.lut, tol_nmed=5e-4, max_rank=8)
    assert lr.rank <= 8
    if lr.rank < 8:
        assert lr.residual_nmed <= 5e-4


def test_nsga2_front_is_nondominated():
    front = pareto.nsga2(pareto.NSGAConfig(pop_size=10, generations=3, seed=1))
    objs = np.array([[p.area, p.nmed] for p in front])
    for i in range(len(objs)):
        for j in range(len(objs)):
            if i == j:
                continue
            dominates = (objs[j] <= objs[i]).all() and (objs[j] < objs[i]).any()
            assert not dominates, f"{j} dominates {i} in final front"


def test_nsga2_deterministic():
    cfg = pareto.NSGAConfig(pop_size=8, generations=2, seed=7)
    f1 = pareto.nsga2(cfg)
    f2 = pareto.nsga2(cfg)
    assert [(p.area, p.nmed) for p in f1] == [(p.area, p.nmed) for p in f2]


def test_pick_by_nmed():
    lib = list(mm.static_library().values())
    m = pareto.pick_by_nmed(lib, 0.01)
    assert m.stats.nmed <= 0.01
    # must pick something cheaper than exact when allowed error
    assert m.area_nand2eq < mm.exact_multiplier().area_nand2eq
    # zero budget -> exact
    m0 = pareto.pick_by_nmed(lib, 0.0)
    assert m0.stats.wce == 0


def test_static_library_names_unique_and_loadable():
    lib = mm.static_library()
    for name in lib:
        assert mm.get_multiplier(name).name == name
