"""The repro.compat shims must resolve on the *installed* JAX, and the
kernel-dispatch policy must behave: interpret=True off-TPU, policy knobs
honored, and the Pallas path reachable from the model layer (not just the
direct kernel tests)."""

import dataclasses
import os
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.kernels import dispatch

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"


# --- version probe -------------------------------------------------------------

def test_jax_version_parses():
    v = compat.jax_version()
    assert isinstance(v, tuple) and len(v) >= 2
    assert all(isinstance(x, int) for x in v)
    assert compat.at_least(0, 4)
    assert not compat.at_least(99, 0)


def test_backend_probe():
    assert compat.backend() in ("cpu", "gpu", "tpu")
    assert compat.is_tpu_backend() == (compat.backend() == "tpu")


# --- pallas compiler-params shim ----------------------------------------------

def test_tpu_compiler_params_resolves():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    cls = compat.compiler_params_cls()
    assert cls is not None, "installed JAX should expose a params class"
    assert isinstance(params, cls)
    assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")


def test_dimension_semantics_normalization():
    assert compat.normalize_dimension_semantics(
        ("parallel", "sequential")) == ("parallel", "arbitrary")
    with pytest.raises(ValueError):
        compat.normalize_dimension_semantics(("bogus",))


def test_compiler_params_accepted_by_pallas_call():
    """The shim's output must be accepted end-to-end by pl.pallas_call."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    x = jnp.arange(8 * 128, dtype=jnp.float32).reshape(8, 128)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=dispatch.interpret_mode(),
    )(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) * 2.0)


# --- mesh shims ----------------------------------------------------------------

def test_make_abstract_mesh_on_installed_jax():
    mesh = compat.make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert tuple(mesh.axis_names) == ("pod", "data", "model")
    assert dict(mesh.shape) == {"pod": 2, "data": 16, "model": 16}


def test_make_abstract_mesh_feeds_sharding_rules():
    from jax.sharding import PartitionSpec as P
    from repro.sharding import rules
    mesh = compat.make_abstract_mesh((16, 16), ("data", "model"))
    assert rules.batch_pspec("tokens", (256, 4096), mesh) == \
        P(("data",), None)


def test_make_mesh_builds_device_mesh():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert tuple(mesh.axis_names) == ("data", "model")
    assert mesh.devices.shape == (1, 1)
    # explicit-devices path (exercises the manual fallback construction)
    mesh2 = compat.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    assert mesh2.devices.shape == (1,)


def test_make_abstract_mesh_rejects_mismatched_axes():
    with pytest.raises(ValueError):
        compat.make_abstract_mesh((1, 2), ("only_one",))


# --- kernel dispatch -----------------------------------------------------------

def test_dispatch_interpret_mode_off_tpu():
    if compat.is_tpu_backend():
        pytest.skip("running on a real TPU")
    assert dispatch.interpret_mode() is True


def test_dispatch_policy_table(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_POLICY", raising=False)
    shapes = dict(m=512, k=512, n=512)
    assert dispatch.use_pallas_gemm("pallas", **shapes) is True
    assert dispatch.use_pallas_gemm("xla", **shapes) is False
    if not compat.is_tpu_backend():
        # auto never picks interpret-mode Pallas for the hot path
        assert dispatch.use_pallas_gemm("auto", **shapes) is False
    with pytest.raises(ValueError):
        dispatch.resolve("mosaic")


def test_dispatch_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_POLICY", "pallas")
    assert dispatch.default_policy() == "pallas"
    assert dispatch.use_pallas_gemm(None, m=8, k=8, n=8) is True
    monkeypatch.setenv("REPRO_KERNEL_POLICY", "nonsense")
    assert dispatch.default_policy() == "auto"


def test_spec_policy_is_static_pytree_meta(monkeypatch):
    """Policy changes must change the treedef (fresh jit cache key)."""
    monkeypatch.delenv("REPRO_KERNEL_POLICY", raising=False)
    from repro.approx import gemm as G
    spec = G.spec_from_name("trunc2x2")
    sp = spec.with_policy("pallas")
    assert sp.policy == "pallas" and spec.policy == "auto"
    assert sp.with_policy("pallas") is sp
    t1 = jax.tree_util.tree_structure(spec)
    t2 = jax.tree_util.tree_structure(sp)
    assert t1 != t2


def test_model_forward_exercises_pallas_path():
    """A reduced model forward under kernel_policy="pallas" runs every GEMM
    through the interpret-mode Pallas kernel and matches the XLA policy
    bit-for-bit on the integer (trunc) path."""
    from repro import configs
    from repro.configs.base import reduced
    from repro.models import api

    outs = {}
    for policy in ("xla", "pallas"):
        cfg = dataclasses.replace(
            reduced(configs.get_config("tinyllama-1.1b")),
            mult="trunc2x2", kernel_policy=policy)
        spec = api.make_spec(cfg)
        assert spec is not None and spec.policy == policy
        params = api.init_params(cfg, jax.random.key(0))
        tokens = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)),
            jnp.int32)
        logits, _ = api.forward(params, {"tokens": tokens}, cfg, spec)
        outs[policy] = np.asarray(logits, dtype=np.float32)
    np.testing.assert_allclose(outs["pallas"], outs["xla"],
                               rtol=1e-6, atol=1e-6)


def test_attention_policy_dispatch():
    """impl="flash" honors the kernel policy: "pallas" runs the Pallas
    kernel (interpret off-TPU), "xla" the blockwise twin; results agree."""
    from repro.models import common as C
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 64)), jnp.float32)
    o_pallas = C.attention(q, k, v, impl="flash", policy="pallas")
    o_xla = C.attention(q, k, v, impl="flash", policy="xla")
    np.testing.assert_allclose(np.asarray(o_pallas), np.asarray(o_xla),
                               rtol=2e-5, atol=2e-5)


# --- drift hygiene -------------------------------------------------------------

def test_no_direct_version_sensitive_api_use_outside_compat():
    """No module outside repro/compat may spell the version-sensitive APIs
    directly (the acceptance rule that keeps future JAX drift localized)."""
    banned = re.compile(r"CompilerParams|AbstractMesh\s*\(")
    offenders = []
    for path in SRC.rglob("*.py"):
        if "compat" in path.parts:
            continue
        if banned.search(path.read_text()):
            offenders.append(str(path.relative_to(SRC)))
    assert not offenders, f"direct version-sensitive JAX use in: {offenders}"
