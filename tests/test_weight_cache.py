"""Serving weight-plane cache (PreparedWeight / api.prepare_params):

* prepared forward is bit-identical to the fresh-quantize forward (per
  mode, Pallas and XLA dispatch);
* the cache plumbs through the model families and the engine (decode /
  prefill outputs unchanged bit-for-bit);
* the cache is serving-only: training-style differentiation raises, and
  mismatched (weight, spec) pairs are rejected.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.approx import gemm as G
from repro.approx import layers as L
from repro.core import multipliers as mm
from repro.core import netlist as nl
from repro.models import api

RNG = np.random.default_rng(7)


def _lowrank_spec(rank=4, seed=1):
    mask = np.random.default_rng(seed).random(
        len(nl.bw8().prunable_gates())) < 0.03
    return G.from_multiplier(mm.pruned(mask, name=f"wc_test_{seed}"),
                             rank=rank)


SPECS = [
    ("trunc", G.from_multiplier(mm.truncated(2, 2))),
    ("lowrank_r2", _lowrank_spec(rank=2)),
    ("lowrank_r4", _lowrank_spec(rank=4)),
]


@pytest.mark.parametrize("name,spec", SPECS, ids=[s[0] for s in SPECS])
@pytest.mark.parametrize("policy", ["xla", "pallas"])
def test_prepared_matches_fresh_bitexact(name, spec, policy):
    spec = spec.with_policy(policy)
    x = jnp.asarray(RNG.standard_normal((37, 64)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((64, 48)), jnp.float32)
    fresh = np.asarray(G.approx_matmul(x, w, spec))
    pw = G.prepare_weight(w, spec)
    prepared = np.asarray(G.approx_matmul_prepared(x, pw, spec))
    np.testing.assert_array_equal(fresh, prepared)


def test_prepared_stacked_leaf_slices_like_raw():
    """Layer-stacked (L, k, n) leaves prepare once; per-layer slices must
    equal per-layer fresh preparation (what lax.scan sees)."""
    spec = _lowrank_spec(rank=2)
    w = jnp.asarray(RNG.standard_normal((3, 32, 16)), jnp.float32)
    pw = G.prepare_weight(w, spec)
    for i in range(3):
        pw_i = G.prepare_weight(w[i], spec)
        np.testing.assert_array_equal(np.asarray(pw.wq[i]),
                                      np.asarray(pw_i.wq))
        np.testing.assert_array_equal(np.asarray(pw.sw[i]),
                                      np.asarray(pw_i.sw))
        np.testing.assert_array_equal(np.asarray(pw.planes[i]),
                                      np.asarray(pw_i.planes))


def test_layers_gemm_routes_prepared_and_exact_fallback():
    spec = G.from_multiplier(mm.truncated(2, 2))
    x = jnp.asarray(RNG.standard_normal((5, 32)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((32, 24)), jnp.float32)
    pw = G.prepare_weight(w, spec)
    np.testing.assert_array_equal(np.asarray(L.gemm(x, pw, spec)),
                                  np.asarray(L.gemm(x, w, spec)))
    # exact/spec-less consumers fall back to the original float weight
    np.testing.assert_array_equal(np.asarray(L.gemm(x, pw, None)),
                                  np.asarray(L.gemm(x, w, None)))


def test_prepared_rejects_mismatched_spec():
    spec_a = G.from_multiplier(mm.truncated(2, 2))
    spec_b = _lowrank_spec(rank=2)
    w = jnp.asarray(RNG.standard_normal((32, 16)), jnp.float32)
    pw = G.prepare_weight(w, spec_a)
    x = jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32)
    with pytest.raises(ValueError, match="PreparedWeight"):
        G.approx_matmul_prepared(x, pw, spec_b)


def test_prepared_bypassed_under_training():
    """The cache must not silently feed training: differentiating through
    the prepared path raises, while the live path keeps its STE vjp."""
    spec = G.from_multiplier(mm.truncated(2, 2))
    x = jnp.asarray(RNG.standard_normal((8, 32)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((32, 16)), jnp.float32)
    pw = G.prepare_weight(w, spec)
    with pytest.raises(NotImplementedError, match="serving-time"):
        jax.grad(lambda xx: G.approx_matmul_prepared(xx, pw, spec).sum())(x)
    # live path still differentiates (straight-through)
    g = jax.grad(lambda xx: G.approx_matmul(xx, w, spec).sum())(x)
    assert g.shape == x.shape


# --- model / engine plumbing -------------------------------------------------

def _cfg(arch, mult="trunc2x2"):
    cfg = configs.reduced(configs.get_config(arch))
    return configs.apply_overrides(cfg, mult=mult)


def _n_prepared(tree) -> int:
    return sum(1 for leaf in jax.tree_util.tree_leaves(
        tree, is_leaf=G.is_prepared) if G.is_prepared(leaf))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-370m",
                                  "recurrentgemma-9b", "whisper-medium"])
def test_decode_step_prepared_matches_fresh_all_families(arch):
    cfg = _cfg(arch)
    spec = api.make_spec(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    prepared = api.prepare_params(params, cfg, spec)
    assert _n_prepared(prepared) > 0
    assert _n_prepared(params) == 0  # source tree untouched
    cache = api.init_cache(cfg, 2, 16)
    tok = jnp.asarray([[3], [7]], jnp.int32)
    # Every GEMM in the prepared graph reproduces the fresh-quantize GEMM
    # bit-for-bit (asserted at approx_matmul level above); the two decode
    # graphs are nonetheless different XLA programs, so fusion may
    # reassociate the surrounding f32 vector math (rope / recurrence /
    # attention epilogues) at ULP scale.  Full-graph criterion: logits and
    # cache state within f32-ULP noise, greedy tokens identical — chained
    # over two steps so cached state is exercised, not just produced.
    c1, c2 = cache, cache
    for _ in range(2):
        l1, c1 = api.decode_step(params, c1, tok, cfg, spec=spec)
        l2, c2 = api.decode_step(prepared, c2, tok, cfg, spec=spec)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=0, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(jnp.argmax(l1, -1)),
                                      np.asarray(jnp.argmax(l2, -1)))
        for a, b in zip(jax.tree_util.tree_leaves(c1),
                        jax.tree_util.tree_leaves(c2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=0, atol=1e-5)


def test_prepare_params_lowrank_spec_object():
    """prepare_params accepts an explicit (non-config) lowrank spec."""
    cfg = _cfg("tinyllama-1.1b", mult="")
    spec = _lowrank_spec(rank=2)
    params = api.init_params(cfg, jax.random.key(0))
    prepared = api.prepare_params(params, cfg, spec)
    assert _n_prepared(prepared) > 0
    tokens = jnp.asarray(RNG.integers(1, cfg.vocab, (2, 8)), jnp.int32)
    l1, _ = api.prefill(params, tokens, cfg, spec=spec, max_len=16)
    l2, _ = api.prefill(prepared, tokens, cfg, spec=spec, max_len=16)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_prepare_params_idempotent():
    """Re-preparing a prepared tree is a no-op (tree_map must not descend
    into PreparedWeight nodes and re-wrap their fields)."""
    cfg = _cfg("tinyllama-1.1b")
    spec = api.make_spec(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    prepared = api.prepare_params(params, cfg, spec)
    again = api.prepare_params(prepared, cfg, spec)
    assert _n_prepared(again) == _n_prepared(prepared)
    for leaf in jax.tree_util.tree_leaves(again, is_leaf=G.is_prepared):
        if G.is_prepared(leaf):
            assert not G.is_prepared(leaf.w) and not G.is_prepared(leaf.sw)
    cache = api.init_cache(cfg, 1, 8)
    tok = jnp.asarray([[3]], jnp.int32)
    l1, _ = api.decode_step(prepared, cache, tok, cfg, spec=spec)
    l2, _ = api.decode_step(again, cache, tok, cfg, spec=spec)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_prepare_weight_pallas_policy_skips_planes():
    """Pallas-pinned specs skip the XLA planes (dead memory on that path);
    a later XLA re-dispatch live-maps from the cached wq, bit-identically."""
    spec_p = _lowrank_spec(rank=2).with_policy("pallas")
    w = jnp.asarray(RNG.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((8, 64)), jnp.float32)
    pw = G.prepare_weight(w, spec_p)
    assert pw.planes.shape[-3] == 0
    spec_x = spec_p.with_policy("xla")
    fresh = np.asarray(G.approx_matmul(x, w, spec_x))
    prepared = np.asarray(G.approx_matmul_prepared(x, pw, spec_x))
    np.testing.assert_array_equal(fresh, prepared)
    # non-pinned policies keep the planes cached
    pw_x = G.prepare_weight(w, spec_x)
    assert pw_x.planes.shape[-3] == spec_x.rank


def test_prepare_params_identity_for_exact():
    cfg = _cfg("tinyllama-1.1b", mult="")
    params = api.init_params(cfg, jax.random.key(0))
    assert api.prepare_params(params, cfg) is params


def test_engine_serves_from_cache_bitexact():
    """Engine with an approx multiplier prepares its exec_params and emits
    exactly the tokens of a raw-params solo greedy run."""
    from repro.serving import Engine, Request, SamplingParams
    cfg = _cfg("tinyllama-1.1b")
    params = api.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, capacity=2, max_len=32, seed=0)
    assert _n_prepared(eng.exec_params) > 0
    assert _n_prepared(eng.params) == 0

    prompt = RNG.integers(1, cfg.vocab, (9,)).tolist()
    gen = 5
    eng.submit(Request("r0", prompt, SamplingParams(max_new_tokens=gen)))
    (done,) = eng.run_until_complete()

    # raw-params reference: exact-length prefill + greedy decode loop
    spec = api.make_spec(cfg)
    t = jnp.asarray([prompt], jnp.int32)
    lg, cache = api.prefill(params, t, cfg, spec=spec, max_len=32)
    want = [int(jnp.argmax(lg, -1)[0])]
    tok = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]
    for _ in range(gen - 1):
        lg2, cache = api.decode_step(params, cache, tok, cfg, spec=spec)
        tok = jnp.argmax(lg2[:, -1], -1).astype(jnp.int32)[:, None]
        want.append(int(tok[0, 0]))
    assert done.tokens == want
