"""Population-parallel GA engine: parity with the numpy reference twin,
batched-model equivalence, constraint masking, calibration, scenarios."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accelerator as acc
from repro.core import calibrate as cal
from repro.core import carbon as cb
from repro.core import codesign
from repro.core import dataflow as df
from repro.core import ga
from repro.core import ga_batched as gb
from repro.core import multipliers as mm
from repro.core import workloads as wl


def _fast_mults():
    return [mm.exact_multiplier(), mm.truncated(1, 1), mm.truncated(2, 2),
            mm.truncated(3, 3)]


# --- batched model parity ----------------------------------------------------

@pytest.mark.parametrize("workload", ["vgg16", "resnet50", "lm_serving"])
def test_batched_fps_matches_reference(workload):
    rows, cols, glbs, ref = [], [], [], []
    for pes in (64, 512, 2048):
        for aspect in ga.ASPECTS:
            r, c = ga._pe_split(pes, aspect)
            for g in (64, 512):
                cfg = acc.AcceleratorConfig(r, c, 32, g, "exact", 7)
                rows.append(r), cols.append(c), glbs.append(g)
                ref.append(df.workload_perf(workload, cfg).fps)
    got = np.asarray(df.batched_fps(workload, np.array(rows),
                                    np.array(cols), np.array(glbs), 7))
    np.testing.assert_allclose(got, np.array(ref), rtol=1e-5)


def test_batched_carbon_matches_reference():
    areas = np.geomspace(0.05, 500, 25)
    for node in (7, 14, 28):
        ref = [cb.embodied_carbon(a, node).total_g for a in areas]
        got = np.asarray(cb.embodied_carbon_g_arr(
            jnp.asarray(areas, jnp.float32), node))
        np.testing.assert_allclose(got, ref, rtol=1e-5)
        # ci_fab override moves carbon the right way
        lo = np.asarray(cb.embodied_carbon_g_arr(
            jnp.asarray(areas, jnp.float32), node, ci_fab=50.0))
        assert (lo < got).all()


def test_batched_area_matches_reference():
    for pes in (64, 256, 2048):
        for mult in ("exact", "trunc2x2"):
            cfg = acc.nvdla_default(pes, 7, mult)
            ref = acc.area_model(cfg).total_mm2
            got = float(acc.area_total_mm2_arr(
                jnp.asarray([float(pes)]), jnp.asarray([32.0]),
                jnp.asarray([float(cfg.glb_kib)]),
                jnp.asarray([mm.get_multiplier(mult).area_nand2eq]), 7)[0])
            assert got == pytest.approx(ref, rel=1e-5)


def test_population_fitness_matches_sequential_evaluate():
    """Every genome of a random population — including multi-die splits
    and infeasible (uneven) ones — scores identically (to f32) under the
    batched evaluator and the sequential reference."""
    mults = _fast_mults()
    space = gb.build_space("vgg16", 7, 30.0, 2.0, mults=mults)
    rng = np.random.default_rng(0)
    pop = np.stack([rng.integers(0, n, 64) for n in space.gene_sizes],
                   axis=1).astype(np.int32)
    allowed = np.flatnonzero(space.mult_allowed)
    pop[:, gb.MULT_GENE] = allowed[pop[:, gb.MULT_GENE] % len(allowed)]
    met = gb.evaluate_population(jnp.asarray(pop), space.tables(), 7)
    gcfg = ga.GAConfig()
    n_multi = 0
    for row, fit, fps, carbon in zip(pop, np.asarray(met["fitness"]),
                                     np.asarray(met["fps"]),
                                     np.asarray(met["carbon_g"])):
        e = ga.evaluate(space.decode(row), "vgg16", 7, list(space.mults),
                        30.0, gcfg)
        n_multi += e.n_dies > 1
        assert fps == pytest.approx(e.fps, rel=1e-5)
        assert carbon == pytest.approx(e.carbon_g, rel=1e-5)
        if np.isinf(e.fitness):
            assert np.isinf(fit)
        else:
            assert fit == pytest.approx(e.fitness, rel=1e-5)
    assert n_multi > 0  # the random population exercised the die gene


# --- GA parity ---------------------------------------------------------------

@pytest.mark.parametrize("workload", ["vgg16", "resnet50"])
def test_ga_parity_with_numpy_reference(workload):
    """Fixed seed, two engines, one selected design (the acceptance
    criterion), and the exhaustive optimum confirms both found it."""
    mults = _fast_mults()
    rb = gb.run_ga_batched(
        workload, 7, 30.0, 2.0, mults=mults,
        cfg=gb.BatchedGAConfig(pop_size=2048, generations=8, seed=0))
    rn = ga.run_ga(workload, 7, 30.0, 2.0, mults=mults,
                   cfg=ga.GAConfig(pop_size=32, generations=16, seed=0))
    assert rb.best.config == rn.best.config
    assert rb.best.n_dies == rn.best.n_dies
    assert rb.best.cdp == pytest.approx(rn.best.cdp, rel=1e-6)
    # exhaustive ground truth: nothing in the space beats the GA designs
    g_ex, met_ex = gb.exhaustive_best(rb.space)
    assert rb.best.fitness <= float(met_ex["fitness"]) * (1 + 1e-4)


def test_ga_batched_improves_and_deterministic():
    kw = dict(mults=_fast_mults(),
              cfg=gb.BatchedGAConfig(pop_size=256, generations=5, seed=11))
    r1 = gb.run_ga_batched("vgg16", 7, 30.0, 2.0, **kw)
    r2 = gb.run_ga_batched("vgg16", 7, 30.0, 2.0, **kw)
    assert r1.best.config == r2.best.config
    assert r1.history == r2.history
    assert r1.history[-1] <= r1.history[0]


# --- constraint masking ------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_masking_never_admits_infeasible_genomes(seed):
    """Property: across generations, every surviving genome is in-range
    and its multiplier respects the accuracy-drop ceiling."""
    mults = _fast_mults()
    max_drop = 0.5  # excludes trunc2x2 / trunc3x3 under the proxy model
    res = gb.run_ga_batched(
        "vgg16", 7, 30.0, max_drop, mults=mults,
        cfg=gb.BatchedGAConfig(pop_size=128, generations=4, seed=seed))
    space = res.space
    pop = res.population
    for g, n in zip(pop.T, space.gene_sizes):
        assert (g >= 0).all() and (g < n).all()
    assert space.mult_allowed[pop[:, gb.MULT_GENE]].all()
    assert space.die_ok[pop[:, 0], pop[:, 1], pop[:, gb.DIE_GENE]].all()
    assert res.metrics["feasible"].all()
    drop = ga.proxy_accuracy_drop(space.mults[res.best_genome.mult_idx])
    assert drop <= max_drop


@pytest.mark.parametrize("seed", [7, 8])
def test_masking_repairs_seeded_infeasible_population(seed):
    """Even a population seeded ENTIRELY with infeasible multiplier genes
    is repaired by the step's constraint mask (and scores +inf fitness
    before repair, so selection can never prefer it)."""
    import jax
    mults = _fast_mults()
    space = gb.build_space("vgg16", 7, 30.0, 0.5, mults=mults)
    bad_idx = int(np.flatnonzero(~space.mult_allowed)[0])
    rng = np.random.default_rng(seed)
    pop = np.stack([rng.integers(0, n, 64) for n in space.gene_sizes],
                   axis=1).astype(np.int32)
    pop[:, gb.MULT_GENE] = bad_idx
    met = gb.evaluate_population(jnp.asarray(pop), space.tables(), 7)
    assert np.isinf(np.asarray(met["fitness"])).all()
    # elitism=2: even the verbatim-surviving elites must be repaired
    new_pop, _, _ = gb._ga_step(
        jax.random.PRNGKey(seed), jnp.asarray(pop), space.tables(), 7,
        space.gene_sizes, 3, 2, 0.7, 0.25, 50.0)
    new_pop = np.asarray(new_pop)
    assert space.mult_allowed[new_pop[:, gb.MULT_GENE]].all()
    # and no uneven die split survives the step either
    assert space.die_ok[new_pop[:, 0], new_pop[:, 1],
                        new_pop[:, gb.DIE_GENE]].all()


def test_prebuilt_space_must_match_problem():
    space = gb.build_space("vgg16", 7, 30.0, 2.0, mults=_fast_mults())
    with pytest.raises(ValueError, match="requested problem"):
        gb.run_ga_batched("resnet50", 7, 30.0, 2.0, space=space,
                          cfg=gb.BatchedGAConfig(pop_size=32, generations=1))


# --- workloads ---------------------------------------------------------------

def test_lm_serving_workloads_registered():
    for name in ("lm_decode", "lm_serving"):
        layers = wl.WORKLOADS[name]()
        assert wl.total_macs(layers) > 0
        p = df.workload_perf(name, acc.nvdla_default(256, 7))
        assert p.fps > 0
    # a serving trace costs more than a single decode step
    assert wl.total_macs(wl.lm_serving()) > wl.total_macs(wl.lm_decode())


# --- calibration -------------------------------------------------------------

def test_gemm_calibration_scales_cdp():
    c = cal.calibrate_gemm(m=32, k=48, n=32, reps=1)
    assert c.source == "gemm" and c.measured > 0 and c.analytical > 0
    assert c.scale > 0
    assert c.calibrated_cdp(100.0, 50.0) == pytest.approx(
        2.0 / c.scale, rel=1e-9)
    ident = cal.identity()
    assert ident.calibrated_cdp(100.0, 50.0) == pytest.approx(2.0)


def test_scenario_sweep_with_calibration():
    scen = [codesign.Scenario("vgg16", 7, ci_fab=50.0),
            codesign.Scenario("vgg16", 7)]
    c = cal.calibrate_gemm(m=32, k=48, n=32, reps=1)
    res = codesign.run_scenarios(
        scen, mults=_fast_mults(),
        cfg=gb.BatchedGAConfig(pop_size=256, generations=4, seed=0),
        calibration=c)
    assert len(res) == 2
    for r in res:
        assert r.ga_reduction > 0
        assert r.cdp_calibrated == pytest.approx(
            r.best.cdp / c.scale, rel=1e-6)
        d = r.to_dict()
        assert d["best"]["multiplier"] != ""
    # greener fab grid => less embodied carbon => smaller CDP
    assert res[0].best.carbon_g < res[1].best.carbon_g


def test_scenario_grid_shape():
    grid = codesign.scenario_grid(workloads=("vgg16",), nodes=(7, 14),
                                  ci_fabs=(620.0,))
    assert len(grid) == 2
    assert {s.node_nm for s in grid} == {7, 14}
