"""Direct unit tests of the NSGA-II internals in core/pareto.py:
nondominated sorting vs brute force (2 and 3 objectives), the public
`nondominated_front` surface, crowding distances, front dedup, and the
NMED-constrained picker."""

import numpy as np
import pytest

from repro.core import multipliers as mm
from repro.core import netlist as nlmod
from repro.core import pareto


def _brute_front(objs: np.ndarray) -> set[int]:
    def dom(a, b):
        return bool(np.all(a <= b) and np.any(a < b))
    return {i for i in range(len(objs))
            if not any(dom(objs[j], objs[i])
                       for j in range(len(objs)) if j != i)}


@pytest.mark.parametrize("n_obj", [2, 3])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_nondominated_sort_matches_brute_force(n_obj, seed):
    rng = np.random.default_rng(seed)
    objs = rng.random((40, n_obj))
    fronts = pareto._nondominated_sort(objs)
    # first front is exactly the brute-force nondominated set
    assert set(fronts[0].tolist()) == _brute_front(objs)
    # fronts partition the population
    all_idx = np.concatenate(fronts)
    assert sorted(all_idx.tolist()) == list(range(len(objs)))
    # peeling is consistent: each later front is the nondominated set of
    # what remains after removing the earlier ones
    remaining = np.arange(len(objs))
    for fr in fronts:
        sub = _brute_front(objs[remaining])
        assert set(fr.tolist()) == {int(remaining[i]) for i in sub}
        remaining = np.setdiff1d(remaining, fr)


def test_nondominated_sort_with_duplicates():
    objs = np.array([[1.0, 1.0], [1.0, 1.0], [2.0, 2.0]])
    fronts = pareto._nondominated_sort(objs)
    assert set(fronts[0].tolist()) == {0, 1}   # ties don't dominate
    assert fronts[1].tolist() == [2]


def test_nondominated_front_sorted_by_first_objective():
    pts = np.array([[3.0, 1.0],    # on the front
                    [1.0, 3.0],    # on the front
                    [2.0, 2.0],    # on the front
                    [3.0, 3.0],    # dominated by (2,2)
                    [1.0, 3.5]])   # dominated by (1,3)
    idx = pareto.nondominated_front(pts)
    assert idx.tolist() == [1, 2, 0]           # ascending first objective
    assert pareto.nondominated_front(np.empty((0, 2))).tolist() == []
    with pytest.raises(ValueError, match=r"\(n, m\)"):
        pareto.nondominated_front(np.array([1.0, 2.0]))


def test_crowding_boundaries_are_infinite():
    rng = np.random.default_rng(0)
    objs = rng.random((30, 2))
    front = pareto._nondominated_sort(objs)[0]
    d = pareto._crowding(objs, front)
    assert len(d) == len(front)
    for m in range(objs.shape[1]):
        assert np.isinf(d[np.argmin(objs[front, m])])
        assert np.isinf(d[np.argmax(objs[front, m])])
    if len(front) > 2:
        interior = d[np.isfinite(d)]
        assert (interior >= 0).all()


def test_front_to_multipliers_dedups_objective_points():
    n_genes = len(nlmod.bw8().prunable_gates())
    mask = np.zeros(n_genes, dtype=bool)
    a = pareto.Individual(mask, 0, 0, area=100.0, nmed=0.01)
    b = pareto.Individual(mask.copy(), 1, 0, area=100.0, nmed=0.01)
    c = pareto.Individual(mask.copy(), 0, 1, area=90.0, nmed=0.02)
    out = pareto.front_to_multipliers([a, b, c])
    assert len(out) == 2                       # a and b collapse
    assert all(hasattr(m, "area_nand2eq") for m in out)


def test_pick_by_nmed_constrained_and_fallback():
    mults = [mm.truncated(1, 1), mm.truncated(3, 3)]
    got = pareto.pick_by_nmed(mults, max_nmed=1.0)
    assert got is min(mults, key=lambda m: m.area_nand2eq)
    # nothing feasible -> exact fallback
    assert pareto.pick_by_nmed(mults, max_nmed=0.0).is_exact
